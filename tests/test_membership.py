"""Online cluster-identity serving tests (ISSUE 5).

The MembershipEngine's contract: a newcomer's cluster identity from its
(k x d) signature alone, identical across backends; lifecycle ops that
keep the directory consistent under admits/evictions; drift triggers
that are deterministic functions of the stream; and a directory that can
shard over devices without changing any verdict.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.engine import ProtocolEngine
from repro.core.membership_engine import (MembershipConfig,
                                          MembershipEngine,
                                          signature_relevance)
from repro.core.similarity import SimilarityConfig
from repro.data import synthetic as syn
from repro.fed import partition as fpart

SRC = str(Path(__file__).resolve().parents[1] / "src")
BACKENDS = ("numpy", "jnp", "pallas")
N_SEED, N_TASKS, D, TOP_K = 24, 3, 16, 6


@pytest.fixture(scope="module")
def seed_result():
    feats, task_ids = syn.make_task_feature_mixture(
        n_users=N_SEED, n_samples=48, d=D, n_tasks=N_TASKS, seed=7)
    res = oneshot.one_shot_clustering(jnp.asarray(feats), N_TASKS,
                                      cfg=SimilarityConfig(top_k=TOP_K))
    return res, task_ids


@pytest.fixture(scope="module")
def wave():
    feats, task_ids = syn.make_task_feature_mixture(
        n_users=N_SEED + 9, n_samples=48, d=D, n_tasks=N_TASKS, seed=7)
    lam, v, _ = ProtocolEngine(SimilarityConfig(top_k=TOP_K)).signatures(
        jnp.asarray(feats[N_SEED:]))
    return lam, v, task_ids[N_SEED:]


def make_engine(seed_result, backend, **cfg_kw):
    res, _ = seed_result
    return MembershipEngine.from_oneshot(
        res, MembershipConfig(backend=backend, **cfg_kw))


class TestSeedParity:
    """Every seed user re-assigns to its own cluster exactly, on every
    backend, and all backends agree to tie order."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_seed_reassigned_exact(self, seed_result, backend):
        res, _ = seed_result
        eng = make_engine(seed_result, backend)
        out = eng.assign(res.lam, res.v)
        assert (np.asarray(out.labels) == np.asarray(res.labels)).all()
        assert (np.asarray(out.margin) > 0).all()

    def test_backends_agree(self, seed_result, wave):
        lam_w, v_w, _ = wave
        labels = [np.asarray(make_engine(seed_result, b)
                             .assign(lam_w, v_w).labels)
                  for b in BACKENDS]
        for got in labels[1:]:
            assert (got == labels[0]).all()

    def test_wave_matches_oracle(self, seed_result, wave):
        res, seed_tasks = seed_result
        lam_w, v_w, wave_tasks = wave
        out = make_engine(seed_result, "jnp").assign(lam_w, v_w)
        # cluster ids -> task ids via the seed majority
        seed_labels = np.asarray(res.labels)
        task_of = np.array([np.bincount(
            np.asarray(seed_tasks)[seed_labels == t]).argmax()
            for t in range(N_TASKS)])
        assert (task_of[np.asarray(out.labels)] == wave_tasks).all()


class TestConstruction:
    def test_missing_signatures_raise(self, seed_result):
        res, _ = seed_result
        bare = dataclasses.replace(res, lam=None, v=None)
        with pytest.raises(ValueError, match="signatures"):
            MembershipEngine.from_oneshot(bare)

    def test_capacity_too_small_raises(self, seed_result):
        with pytest.raises(ValueError, match="capacity"):
            make_engine(seed_result, "jnp", capacity=N_SEED - 1)

    def test_unseeded_engine_raises(self):
        with pytest.raises(ValueError, match="directory is empty"):
            MembershipEngine().assign(np.zeros((1, TOP_K)),
                                      np.zeros((1, D, TOP_K)))

    @pytest.mark.parametrize("kw", [
        {"backend": "cuda"},
        {"capacity": -1},
        {"recluster_unassigned_frac": 0.0},
        {"recluster_unassigned_frac": 1.5},
        {"recluster_proto_shift": 0.0},
        {"eig_floor": 0.0},
        {"compute_dtype": "fp16"},
    ])
    def test_config_validation(self, kw):
        with pytest.raises(ValueError):
            MembershipConfig(**kw)


class TestUnassignedBucket:
    @pytest.mark.parametrize("backend", ("numpy", "jnp"))
    def test_margin_floor_unassigns(self, seed_result, wave, backend):
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, backend, margin_floor=10.0)
        out = eng.assign(lam_w, v_w)
        assert (np.asarray(out.labels) == -1).all()

    @pytest.mark.parametrize("backend", ("numpy", "jnp"))
    def test_affinity_floor_unassigns(self, seed_result, backend, rng):
        # an off-subspace outlier scores low affinity everywhere
        junk = np.linalg.qr(rng.standard_normal((D, TOP_K)))[0]
        eng = make_engine(seed_result, backend, affinity_floor=0.9)
        out = eng.assign(np.ones((1, TOP_K), np.float32),
                         junk[None].astype(np.float32))
        assert np.asarray(out.labels)[0] == -1

    def test_emptied_cluster_cannot_win(self, seed_result):
        res, _ = seed_result
        eng = make_engine(seed_result, "jnp")
        seed_labels = np.asarray(res.labels)
        t_gone = int(seed_labels[0])
        eng.evict(np.flatnonzero(seed_labels == t_gone))
        out = eng.assign(res.lam, res.v)
        assert not (np.asarray(out.labels) == t_gone).any()


class TestLifecycle:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_admit_then_evict_roundtrip(self, seed_result, wave, backend):
        """Admit a wave, evict the same slots: the directory state
        round-trips (table exactly, prototypes to fp tolerance)."""
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, backend)
        st0 = eng.state
        out = eng.assign(lam_w, v_w)
        slots = eng.admit(lam_w, v_w, out.labels)
        assert eng.state.n_members == N_SEED + len(np.asarray(lam_w))
        eng.evict(slots)
        assert (np.asarray(eng.state.valid) == np.asarray(st0.valid)).all()
        assert (np.asarray(eng.state.labels)
                == np.asarray(st0.labels)).all()
        np.testing.assert_allclose(np.asarray(eng.state.counts),
                                   np.asarray(st0.counts), atol=1e-5)
        np.testing.assert_allclose(np.asarray(eng.state.protos),
                                   np.asarray(st0.protos), atol=1e-5)

    def test_admit_updates_prototypes_streaming(self, seed_result, wave):
        """The streaming-mean admit equals a from-scratch prototype
        rebuild over the grown table."""
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, "jnp")
        out = eng.assign(lam_w, v_w)
        eng.admit(lam_w, v_w, out.labels)
        st = eng.state
        rebuilt, counts = eng._rebuild_protos(st.v, st.labels, st.valid,
                                              st.n_clusters)
        np.testing.assert_allclose(np.asarray(st.protos),
                                   np.asarray(rebuilt), atol=1e-5)
        np.testing.assert_allclose(np.asarray(st.counts),
                                   np.asarray(counts), atol=1e-5)

    def test_unassigned_admit_skips_prototypes(self, seed_result, wave):
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, "jnp")
        protos0 = np.asarray(eng.state.protos)
        eng.admit(lam_w, v_w, np.full(np.asarray(lam_w).shape[0], -1))
        np.testing.assert_allclose(np.asarray(eng.state.protos), protos0,
                                   atol=1e-6)
        assert eng.state.n_unassigned == np.asarray(lam_w).shape[0]

    def test_directory_full_raises(self, seed_result, wave):
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, "jnp", capacity=N_SEED + 2)
        with pytest.raises(ValueError, match="directory full"):
            eng.admit(lam_w, v_w, np.zeros(np.asarray(lam_w).shape[0]))

    def test_evicting_empty_slot_raises(self, seed_result):
        eng = make_engine(seed_result, "jnp")
        with pytest.raises(ValueError, match="empty slots"):
            eng.evict([eng.state.capacity - 1])

    def test_evicting_duplicate_slots_raises(self, seed_result):
        eng = make_engine(seed_result, "jnp")
        with pytest.raises(ValueError, match="duplicate"):
            eng.evict([0, 0])

    @pytest.mark.parametrize("backend", ("numpy", "jnp"))
    def test_assignment_permutation_invariant(self, seed_result, wave,
                                              backend, rng):
        """The verdict depends on the directory CONTENT, not slot order:
        seeding from a permuted table yields identical assignments."""
        res, _ = seed_result
        lam_w, v_w, _ = wave
        base = make_engine(seed_result, backend).assign(lam_w, v_w)
        perm = rng.permutation(N_SEED)
        eng = MembershipEngine(MembershipConfig(backend=backend))
        eng.seed(np.asarray(res.lam)[perm], np.asarray(res.v)[perm],
                 np.asarray(res.labels)[perm], n_clusters=N_TASKS)
        out = eng.assign(lam_w, v_w)
        assert (np.asarray(out.labels) == np.asarray(base.labels)).all()
        np.testing.assert_allclose(np.asarray(out.affinity),
                                   np.asarray(base.affinity), atol=1e-5)


class TestDrift:
    def test_fresh_directory_has_no_drift(self, seed_result):
        eng = make_engine(seed_result, "jnp")
        s = eng.drift_stats()
        assert s["unassigned_frac"] == 0.0
        assert s["proto_shift"] == 0.0
        assert not eng.should_recluster()

    def test_unassigned_fraction_trips_trigger(self, seed_result, wave):
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, "jnp",
                          recluster_unassigned_frac=0.1)
        eng.admit(lam_w, v_w, np.full(np.asarray(lam_w).shape[0], -1))
        assert eng.drift_stats()["unassigned_frac"] > 0.1
        assert eng.should_recluster()

    @pytest.mark.parametrize("backend", ("numpy", "jnp"))
    def test_recluster_preserves_clean_directory(self, seed_result, wave,
                                                 backend):
        """On drift-free data a forced re-cluster reproduces the current
        labels (greedy id matching keeps serving continuity)."""
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, backend)
        out = eng.assign(lam_w, v_w)
        eng.admit(lam_w, v_w, out.labels)
        before = np.asarray(eng.state.labels).copy()
        assert eng.recluster(force=True)
        assert eng.state.n_reclusters == 1
        assert (np.asarray(eng.state.labels) == before).all()

    def test_recluster_resets_drift_baseline(self, seed_result, wave):
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, "jnp")
        out = eng.assign(lam_w, v_w)
        eng.admit(lam_w, v_w, out.labels)
        assert eng.drift_stats()["proto_shift"] > 0.0
        eng.recluster(force=True)
        assert eng.drift_stats()["proto_shift"] == 0.0

    def test_too_few_members_raises(self, seed_result):
        res, _ = seed_result
        eng = MembershipEngine(MembershipConfig(backend="jnp"))
        eng.seed(np.asarray(res.lam)[:2], np.asarray(res.v)[:2],
                 np.asarray([0, 1]), n_clusters=3)
        with pytest.raises(ValueError, match="cannot cut"):
            eng.recluster(force=True)

    def test_trigger_determinism(self, seed_result, wave):
        """The same arrival/churn stream replayed twice produces the
        same re-cluster events and the same final directory."""
        lam_w, v_w, _ = wave

        def replay():
            eng = make_engine(seed_result, "jnp",
                              recluster_unassigned_frac=0.08)
            events = []
            for start in (0, 3, 6):
                lw = np.asarray(lam_w)[start:start + 3]
                vw = np.asarray(v_w)[start:start + 3]
                labels = (np.full(3, -1) if start == 3
                          else np.asarray(eng.assign(lw, vw).labels))
                eng.admit(lw, vw, labels)
                events.append(eng.maybe_recluster())
            return events, np.asarray(eng.state.labels)

        ev1, lab1 = replay()
        ev2, lab2 = replay()
        assert ev1 == ev2
        assert any(ev1)
        assert (lab1 == lab2).all()


class TestSignatureRelevance:
    def test_structure(self, seed_result):
        res, task_ids = seed_result
        r = np.asarray(signature_relevance(res.lam, res.v))
        np.testing.assert_allclose(r, r.T, atol=1e-6)
        np.testing.assert_allclose(np.diag(r), 1.0, atol=1e-4)
        assert (r >= -1e-6).all() and (r <= 1 + 1e-6).all()
        same = np.equal.outer(task_ids, task_ids)
        off = ~np.eye(len(task_ids), dtype=bool)
        assert r[same & off].min() > r[~same].max()

    def test_recovers_clusters(self, seed_result):
        res, task_ids = seed_result
        r = np.asarray(signature_relevance(res.lam, res.v))
        labels = clu.hac_clusters(r, N_TASKS)
        assert clu.clustering_accuracy(labels, task_ids) == 1.0


class TestStackWarmStart:
    def test_admit_layout_matches_full_relayout(self, rng):
        labels = jnp.asarray(rng.integers(0, 3, size=12))
        rows, slot, mask = fpart.stack_layout(labels, 3, c_max=10)
        new = jnp.asarray([0, 2, -1, 1])
        r2, s2, mask2 = fpart.admit_layout(mask, new)
        full = jnp.concatenate([labels, jnp.asarray([0, 2, 1])])
        rf, sf, mf = fpart.stack_layout(full, 3, c_max=10)
        assert (np.asarray(mf) == np.asarray(mask2)).all()
        keep = np.asarray([0, 1, 3])
        assert (np.asarray(rf)[12:] == np.asarray(r2)[keep]).all()
        assert (np.asarray(sf)[12:] == np.asarray(s2)[keep]).all()
        # the unassigned arrival got the out-of-range sentinel
        assert np.asarray(r2)[2] == 3 and np.asarray(s2)[2] == 10

    def test_refills_holes_left_by_departures(self):
        """Churn: freed columns are reused, not leaked — a new same-label
        user lands in the hole, not past the high-water mark."""
        mask = jnp.asarray([[1.0, 0.0, 1.0], [1.0, 1.0, 0.0]])
        rows, slot, mask2 = fpart.admit_layout(mask, jnp.asarray([0, 1]))
        assert np.asarray(rows).tolist() == [0, 1]
        assert np.asarray(slot).tolist() == [1, 2]   # the hole, then append
        assert np.asarray(mask2).tolist() == [[1, 1, 1], [1, 1, 1]]
        # two arrivals into the one-hole row genuinely overflow
        with pytest.raises(ValueError, match="C_max"):
            fpart.admit_layout(mask, jnp.asarray([0, 0]))

    def test_overflow_raises_instead_of_retracing(self):
        _, _, mask = fpart.stack_layout(jnp.asarray([0, 0]), 2, c_max=2)
        with pytest.raises(ValueError, match="C_max"):
            fpart.admit_layout(mask, jnp.asarray([0]))

    def test_shape_mismatch_raises(self):
        _, _, mask = fpart.stack_layout(jnp.asarray([0, 1]), 2)
        with pytest.raises(ValueError, match="mask rows"):
            fpart.admit_layout(mask, jnp.asarray([0]), n_clusters=3)


SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + os.environ.get("XLA_FLAGS", ""))
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import oneshot
    from repro.core.membership_engine import (MembershipConfig,
                                              MembershipEngine)
    from repro.core.similarity import SimilarityConfig
    from repro.data import synthetic as syn

    assert len(jax.devices()) == 4
    feats, _ = syn.make_task_feature_mixture(32, 48, 16, 4, seed=7)
    res = oneshot.one_shot_clustering(jnp.asarray(feats), 4,
                                      cfg=SimilarityConfig(top_k=6))
    eng = MembershipEngine.from_oneshot(res,
                                        MembershipConfig(backend="jnp"))
    single = eng.assign(res.lam, res.v)
    sharded = eng.assign_sharded(res.lam, res.v)
    assert (np.asarray(single.labels) == np.asarray(sharded.labels)).all()
    err = float(np.abs(np.asarray(single.affinity)
                       - np.asarray(sharded.affinity)).max())
    assert err < 1e-5, err
    err = float(np.abs(np.asarray(single.margin)
                       - np.asarray(sharded.margin)).max())
    assert err < 1e-5, err
    try:                       # 4 clusters over 3 devices cannot shard
        import jax.sharding as shd
        mesh = shd.Mesh(np.asarray(jax.devices()[:3]), ("data",))
        eng.assign_sharded(res.lam, res.v, mesh=mesh)
        raise SystemExit("expected divisibility error")
    except ValueError:
        pass
    print("MEMBERSHIP_SHARD_OK")
""")


def test_sharded_directory_4dev():
    """Directory sharded over 4 forced host devices: same labels,
    affinities and margins as the single-device path."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "MEMBERSHIP_SHARD_OK" in res.stdout


def test_sharded_single_device_matches(seed_result):
    """assign_sharded degenerates cleanly on the default 1-device mesh
    (T % 1 == 0): identical verdict to the in-process path."""
    res, _ = seed_result
    eng = make_engine(seed_result, "jnp")
    single = eng.assign(res.lam, res.v)
    sharded = eng.assign_sharded(res.lam, res.v)
    assert (np.asarray(single.labels) == np.asarray(sharded.labels)).all()
    np.testing.assert_allclose(np.asarray(single.affinity),
                               np.asarray(sharded.affinity), atol=1e-5)
    np.testing.assert_allclose(np.asarray(single.margin),
                               np.asarray(sharded.margin), atol=1e-5)


def test_sharded_requires_device_backend(seed_result):
    eng = make_engine(seed_result, "numpy")
    res, _ = seed_result
    with pytest.raises(ValueError, match="device backend"):
        eng.assign_sharded(res.lam, res.v)


class TestQuantizedDirectory:
    """``directory_dtype``: the serving directory stored bf16/int8 with
    dequant-in-kernel scoring — verdicts must survive the compression."""

    @pytest.mark.parametrize("dtype", ("f32", "bf16", "int8"))
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_backend_parity_per_dtype(self, seed_result, wave, backend,
                                      dtype):
        """All backends score the SAME dequantized table, so labels are
        exactly equal across backends at every directory dtype."""
        lam_w, v_w, _ = wave
        base = make_engine(seed_result, "numpy",
                           directory_dtype=dtype).assign(lam_w, v_w)
        out = make_engine(seed_result, backend,
                          directory_dtype=dtype).assign(lam_w, v_w)
        assert (np.asarray(out.labels) == np.asarray(base.labels)).all()

    @pytest.mark.parametrize("dtype", ("bf16", "int8"))
    def test_agreement_vs_f32(self, seed_result, wave, dtype):
        lam_w, v_w, _ = wave
        f32 = make_engine(seed_result, "jnp").assign(lam_w, v_w)
        q = make_engine(seed_result, "jnp",
                        directory_dtype=dtype).assign(lam_w, v_w)
        agree = (np.asarray(q.labels) == np.asarray(f32.labels)).mean()
        assert agree >= 0.99

    def test_directory_bytes_ratio(self, seed_result):
        f32 = make_engine(seed_result, "jnp").state.directory_bytes
        bf16 = make_engine(seed_result, "jnp",
                           directory_dtype="bf16").state.directory_bytes
        i8 = make_engine(seed_result, "jnp",
                         directory_dtype="int8").state.directory_bytes
        assert f32 / bf16 == 2.0
        assert 3.8 < f32 / i8 <= 4.0

    def test_state_holds_quantized_table_and_scales(self, seed_result):
        st = make_engine(seed_result, "jnp", directory_dtype="int8").state
        assert np.asarray(st.protos).dtype == np.int8
        assert st.proto_scales is not None
        assert np.asarray(st.proto_scales).shape == (st.n_clusters,)
        assert np.asarray(st.protos_f32).dtype == np.float32

    @pytest.mark.parametrize("backend", ("numpy", "jnp", "pallas"))
    def test_lifecycle_requantizes(self, seed_result, wave, backend):
        """Admit/evict on an int8 directory: the table stays int8 (the
        dequant -> update -> requant stream never leaves a resident f32
        copy) and the round-trip restores prototypes to quant tolerance."""
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, backend, directory_dtype="int8")
        p0 = np.asarray(eng.state.protos_f32)
        out = eng.assign(lam_w, v_w)
        slots = eng.admit(lam_w, v_w, out.labels)
        assert np.asarray(eng.state.protos).dtype == np.int8
        eng.evict(slots)
        assert np.asarray(eng.state.protos).dtype == np.int8
        step = np.abs(p0).max() / 127
        assert np.abs(np.asarray(eng.state.protos_f32) - p0).max() < 4 * step

    def test_drift_stats_work_quantized(self, seed_result, wave):
        lam_w, v_w, _ = wave
        eng = make_engine(seed_result, "jnp", directory_dtype="int8")
        out = eng.assign(lam_w, v_w)
        eng.admit(lam_w, v_w, out.labels)
        s = eng.drift_stats()
        assert np.isfinite(s["proto_shift"])

    def test_bad_dtype_rejected(self, seed_result):
        with pytest.raises(ValueError, match="directory_dtype"):
            make_engine(seed_result, "jnp", directory_dtype="fp8")
