"""Scale-path tests: hierarchical two-level clustering + Nystrom sketch.

Guards the ISSUE-6 scaling claim in three layers:

* **Sketched R properties** (jnp + pallas single-host backends):
  symmetry, permutation equivariance under landmark-set-preserving
  permutations (landmark selection is INDEX-based, so only permutations
  mapping the landmark set onto itself commute with the sketch),
  monotone error decay in the landmark count (nested landmark sets), and
  exactness as m -> N on the projector-affinity kernel.
* **Hierarchical vs exact**: label agreement on synthetic multi-task
  mixtures (after ``greedy_match_labels`` id alignment), result-contract
  duck-typing (``MembershipEngine.from_oneshot``, ``fed.partition``),
  and the stitched-index identity ``labels == entry_labels[group_ids *
  T_g + local_labels]``.
* **Config validation**: ``landmarks >= N`` raises at dispatch,
  ``landmarks`` + ``block_users`` are rejected as mutually exclusive at
  config construction, hierarchical routing rejects incompatible
  protocol/cluster backends, non-divisible group counts raise.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.cluster_engine import ClusterConfig, ClusterEngine
from repro.core.engine import ProtocolEngine, landmark_indices
from repro.core.hierarchy import (HierarchyConfig, HierarchicalResult,
                                  greedy_match_labels, group_permutation,
                                  hierarchical_one_shot)
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.core.similarity import SimilarityConfig
from repro.data import synthetic as syn
from repro.fed import partition as fpart

# The sketch is a single-host mode; shard_map is rejected by config.
SKETCH_BACKENDS = ("jnp", "pallas")
TASKS = 4
TOP_K = 6


def _mixture(n, seed=0, d=16, samples=16, tasks=TASKS):
    feats, tids = syn.make_task_feature_mixture(n, samples, d, tasks,
                                                seed=seed)
    return jnp.asarray(feats), tids


def _affinity(v):
    """Exact projector-affinity kernel the sketch approximates."""
    v = np.asarray(v)
    c = np.einsum("idk,jdl->ijkl", v, v)
    return (c ** 2).sum((2, 3)) / v.shape[-1]


# ---------------------------------------------------------------------------
# Landmark index schedule
# ---------------------------------------------------------------------------

class TestLandmarkIndices:
    def test_nested_and_unique(self):
        prev = set()
        for m in (1, 4, 16, 63, 64):
            idx = landmark_indices(64, m)
            assert len(idx) == m == len(set(idx.tolist()))
            assert prev <= set(idx.tolist())
            prev = set(idx.tolist())

    def test_bounds(self):
        with pytest.raises(ValueError, match="0 < m <= n"):
            landmark_indices(8, 0)
        with pytest.raises(ValueError, match="0 < m <= n"):
            landmark_indices(8, 9)

    def test_covers_round_robin_tasks(self):
        # Round-robin rosters (task = i % T) are the repo's synthetic
        # default; a stride-aligned schedule would collapse onto one task.
        idx = landmark_indices(128, 16)
        assert len(set((idx % TASKS).tolist())) == TASKS


# ---------------------------------------------------------------------------
# Sketched-R properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", SKETCH_BACKENDS)
class TestSketchedRelevance:
    def _engine(self, backend, m):
        return ProtocolEngine(SimilarityConfig(top_k=TOP_K, backend=backend,
                                               landmarks=m))

    def test_symmetric_unit_range(self, backend):
        feats, _ = _mixture(32)
        r = np.asarray(self._engine(backend, 8).similarity(feats))
        np.testing.assert_allclose(r, r.T, atol=1e-5)
        assert (r >= 0.0).all() and (r <= 1.0 + 1e-6).all()

    def test_permutation_equivariant(self, backend):
        # Landmark selection is index-based, so the sketch commutes only
        # with permutations that map the landmark set onto itself:
        # shuffle landmarks among themselves and the rest among the rest.
        n, m = 24, 6
        feats, _ = _mixture(n, seed=3)
        land = landmark_indices(n, m)
        rng = np.random.default_rng(0)
        perm = np.arange(n)
        perm[land] = land[rng.permutation(m)]
        rest = np.setdiff1d(np.arange(n), land)
        perm[rest] = rest[rng.permutation(rest.size)]
        eng = self._engine(backend, m)
        r = np.asarray(eng.similarity(feats))
        r_perm = np.asarray(eng.similarity(feats[perm]))
        np.testing.assert_allclose(r_perm, r[np.ix_(perm, perm)],
                                   atol=1e-4)

    def test_error_monotone_in_landmarks(self, backend):
        feats, _ = _mixture(48, seed=1)
        exact = ProtocolEngine(SimilarityConfig(top_k=TOP_K,
                                                backend=backend)).run(feats)
        target = _affinity(exact.v)
        errs = []
        for m in (4, 12, 24, 47):
            r = np.asarray(self._engine(backend, m).similarity(feats))
            errs.append(np.abs(r - target).mean())
        assert all(b <= a + 1e-6 for a, b in zip(errs, errs[1:])), errs
        # Nystrom completion of a PSD kernel is exact at m ~ N.
        assert errs[-1] < 1e-3

    def test_signatures_match_exact_path(self, backend):
        feats, _ = _mixture(16, seed=2)
        sk = self._engine(backend, 4).run(feats)
        ex = ProtocolEngine(SimilarityConfig(top_k=TOP_K,
                                             backend=backend)).run(feats)
        np.testing.assert_allclose(np.asarray(sk.lam), np.asarray(ex.lam),
                                   atol=1e-5)

    def test_recovers_tasks(self, backend):
        feats, tids = _mixture(64, seed=4)
        r = self._engine(backend, 16).similarity(feats)
        labels = ClusterEngine(ClusterConfig(backend="jnp")).labels(r, TASKS)
        assert clu.adjusted_rand_index(np.asarray(labels), tids) == 1.0


class TestSketchConfigValidation:
    def test_negative_rejected(self):
        with pytest.raises(ValueError, match="landmarks must be >= 0"):
            SimilarityConfig(landmarks=-1)

    def test_block_users_mutually_exclusive(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            SimilarityConfig(landmarks=8, block_users=16)

    def test_shard_map_rejected(self):
        with pytest.raises(ValueError, match="single-host"):
            ProtocolEngine(SimilarityConfig(backend="shard_map",
                                            landmarks=8))

    def test_landmarks_ge_n_raises_at_dispatch(self):
        feats, _ = _mixture(8)
        eng = ProtocolEngine(SimilarityConfig(top_k=TOP_K, landmarks=8))
        with pytest.raises(ValueError, match="must be < n_users"):
            eng.similarity(feats)

    def test_run_raw_rejected(self):
        from repro.data.features import FeatureConfig

        eng = ProtocolEngine(SimilarityConfig(landmarks=4))
        with pytest.raises(ValueError, match="landmark"):
            eng.run_raw(np.zeros((8, 4, 6), np.float32),
                        FeatureConfig(kind="identity"))


# ---------------------------------------------------------------------------
# Hierarchical two-level protocol
# ---------------------------------------------------------------------------

class TestHierarchical:
    def _run(self, feats, **hkw):
        return hierarchical_one_shot(
            feats, TASKS, cfg=SimilarityConfig(top_k=TOP_K),
            hierarchy_cfg=HierarchyConfig(**hkw),
            cluster_cfg=ClusterConfig(backend="jnp"))

    def test_agrees_with_exact(self):
        feats, tids = _mixture(128, seed=5)
        hres = self._run(feats, n_groups=8)
        eres = oneshot.one_shot_clustering(
            feats, TASKS, cfg=SimilarityConfig(top_k=TOP_K),
            cluster_cfg=ClusterConfig(backend="jnp"))
        hl, el = np.asarray(hres.labels), np.asarray(eres.labels)
        assert clu.adjusted_rand_index(hl, tids) == 1.0
        matched = greedy_match_labels(hl, el, TASKS)
        assert (matched == el).mean() >= 0.95

    @pytest.mark.parametrize("assignment", ["contiguous", "strided"])
    def test_assignment_modes_recover_tasks(self, assignment):
        feats, tids = _mixture(96, seed=6)
        res = self._run(feats, n_groups=6, assignment=assignment)
        assert clu.adjusted_rand_index(np.asarray(res.labels), tids) == 1.0

    def test_group_batching_invariant(self):
        feats, _ = _mixture(64, seed=7)
        full = self._run(feats, n_groups=8)
        batched = self._run(feats, n_groups=8, group_batch=3)
        np.testing.assert_array_equal(np.asarray(full.labels),
                                      np.asarray(batched.labels))

    def test_stitch_identity_and_directory_shapes(self):
        feats, _ = _mixture(64, seed=8)
        res = self._run(feats, n_groups=4, group_clusters=5)
        g, t_g = 4, 5
        entry_id = np.asarray(res.group_ids) * t_g \
            + np.asarray(res.local_labels)
        np.testing.assert_array_equal(
            np.asarray(res.labels),
            np.asarray(res.entry_labels)[entry_id])
        assert res.entry_lam.shape == (g * t_g, TOP_K)
        assert res.entry_protos.shape[0] == g * t_g
        assert int(np.asarray(res.entry_counts).sum()) == 64
        assert res.global_similarity.shape == (g * t_g, g * t_g)

    def test_oneshot_entry_point_routes(self):
        feats, tids = _mixture(64, seed=9)
        res = oneshot.one_shot_clustering(
            feats, TASKS, cfg=SimilarityConfig(top_k=TOP_K),
            hierarchy_cfg=HierarchyConfig(n_groups=4))
        assert isinstance(res, HierarchicalResult)
        assert clu.adjusted_rand_index(np.asarray(res.labels), tids) == 1.0
        # ledger reports the per-user view WITHIN the edge group
        assert res.ledger.n_users == 16

    def test_from_oneshot_serves_hierarchical_result(self):
        feats, tids = _mixture(64, seed=10)
        res = self._run(feats, n_groups=4)
        eng = MembershipEngine.from_oneshot(
            res, MembershipConfig(backend="jnp"))
        assert eng.state.n_clusters == TASKS
        # every seed user re-assigns into its own cluster
        out = eng.assign(res.lam, res.v)
        assert (np.asarray(out.labels) == np.asarray(res.labels)).all()

    def test_validation(self):
        feats, _ = _mixture(64)
        with pytest.raises(ValueError, match="not divisible"):
            self._run(feats, n_groups=7)
        with pytest.raises(ValueError, match="n_groups must be >= 2"):
            HierarchyConfig(n_groups=1)
        with pytest.raises(ValueError, match="assignment"):
            HierarchyConfig(n_groups=4, assignment="random")
        with pytest.raises(ValueError, match="group_clusters"):
            self._run(feats, n_groups=32, group_clusters=3)  # > N/G = 2
        with pytest.raises(ValueError, match="must be 0"):
            hierarchical_one_shot(
                feats, TASKS,
                cfg=SimilarityConfig(top_k=TOP_K, landmarks=8),
                hierarchy_cfg=HierarchyConfig(n_groups=4))
        with pytest.raises(ValueError, match="batched"):
            hierarchical_one_shot(
                feats, TASKS, cfg=SimilarityConfig(top_k=TOP_K),
                hierarchy_cfg=HierarchyConfig(n_groups=4),
                cluster_cfg=ClusterConfig(backend="numpy"))
        with pytest.raises(ValueError, match="single-host"):
            hierarchical_one_shot(
                feats, TASKS,
                cfg=SimilarityConfig(top_k=TOP_K, backend="shard_map"),
                hierarchy_cfg=HierarchyConfig(n_groups=4))

    def test_group_permutation_modes(self):
        cfg = HierarchyConfig(n_groups=4, assignment="strided")
        perm = group_permutation(16, cfg)
        np.testing.assert_array_equal(perm.reshape(4, 4)[:, 0],
                                      [0, 1, 2, 3])
        assert sorted(perm.tolist()) == list(range(16))


class TestGreedyMatchLabels:
    def test_identity_up_to_permutation(self):
        rng = np.random.default_rng(0)
        old = rng.integers(0, 4, 64)
        perm = np.array([2, 0, 3, 1])
        new = perm[old]
        matched = greedy_match_labels(new, old, 4)
        np.testing.assert_array_equal(matched, old)

    def test_unassigned_passthrough(self):
        new = np.array([0, 1, -1, 0])
        old = np.array([1, 0, 1, -1])
        matched = greedy_match_labels(new, old, 2)
        assert matched[2] == -1
        np.testing.assert_array_equal(matched[:2], [1, 0])


# ---------------------------------------------------------------------------
# fed.partition.group_stack_layout
# ---------------------------------------------------------------------------

class TestGroupStackLayout:
    def test_matches_per_group_stack_layout(self):
        rng = np.random.default_rng(1)
        g, t = 3, 4
        labels = rng.integers(0, t, 48)
        gids = np.repeat(np.arange(g), 16)
        grows, rows, slot, mask = fpart.group_stack_layout(
            jnp.asarray(labels), jnp.asarray(gids), g, t)
        assert mask.shape[:2] == (g, t)
        for gg in range(g):
            sel = gids == gg
            _, _, m_ref = fpart.stack_layout(jnp.asarray(labels[sel]), t,
                                             c_max=mask.shape[2])
            np.testing.assert_array_equal(np.asarray(mask[gg]),
                                          np.asarray(m_ref))
            np.testing.assert_array_equal(np.asarray(rows)[sel],
                                          labels[sel])

    def test_scatter_drops_invalid(self):
        labels = jnp.asarray([0, -1, 1, 2])
        gids = jnp.asarray([0, 0, 1, 5])          # gid 5 out of range
        grows, rows, slot, mask = fpart.group_stack_layout(labels, gids,
                                                           2, 3)
        stack = jnp.zeros((2, 3, int(mask.shape[2])))
        stack = stack.at[grows, rows, slot].set(1.0)
        assert float(stack.sum()) == 2.0          # users 0 and 2 only
        np.testing.assert_array_equal(np.asarray(mask), np.asarray(stack))

    def test_undersized_c_max_raises(self):
        labels = jnp.asarray([0, 0, 0])
        gids = jnp.asarray([0, 0, 0])
        with pytest.raises(ValueError, match="c_max"):
            fpart.group_stack_layout(labels, gids, 1, 1, c_max=2)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError, match="align"):
            fpart.group_stack_layout(jnp.zeros(4, jnp.int32),
                                     jnp.zeros(5, jnp.int32), 2, 2)

    def test_hierarchical_result_feeds_layout(self):
        feats, _ = _mixture(64, seed=11)
        res = hierarchical_one_shot(
            feats, TASKS, cfg=SimilarityConfig(top_k=TOP_K),
            hierarchy_cfg=HierarchyConfig(n_groups=4))
        grows, rows, slot, mask = fpart.group_stack_layout(
            res.labels, res.group_ids, 4, TASKS)
        assert int(np.asarray(mask).sum()) == 64
