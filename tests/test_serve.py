"""Continuous-batching serving engine: token identity vs sequential
decode, slot reuse, mid-stream admits, no-retrace, stats accounting, and
membership routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.launch.decode_loop import (ClusterHeads, DecodeStats, Request,
                                      ServeConfig, ServeEngine,
                                      cluster_logits, cluster_logits_fn,
                                      greedy_decode, route_requests,
                                      token_signature)
from repro.models.registry import get_model


def tiny_arch(kind: str, **kw) -> ArchConfig:
    base = dict(name=f"tiny_{kind}", arch_type="dense", d_model=64,
                n_layers=2, n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                head_dim=16, block_pattern=(kind,), param_dtype="float32",
                act_dtype="float32", scan_layers=False)
    base.update(kw)
    return ArchConfig(**base)


def build(cfg, n_clusters=3):
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    heads = ClusterHeads.init(jax.random.PRNGKey(1), params["head"],
                              n_clusters)
    return m, params, heads


def ragged_requests(rng, n, vocab, n_clusters, max_prompt=16, max_gen=8,
                    staggered=False):
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, max_prompt + 1))
        gen = int(rng.integers(1, max_gen + 1))
        arrive = int(rng.integers(1, 6)) if staggered and i >= n // 2 else 0
        reqs.append(Request(
            tokens=rng.integers(0, vocab, plen).astype(np.int32),
            gen=gen, cluster=i % n_clusters, arrive_round=arrive))
    return reqs


def assert_token_identical(m, params, heads, reqs, stats):
    for i, r in enumerate(reqs):
        base = greedy_decode(m, params, jnp.asarray(r.tokens)[None, :],
                             r.gen,
                             logits_fn=cluster_logits_fn(heads, r.cluster))
        np.testing.assert_array_equal(np.asarray(base.tokens[0]),
                                      stats.results[i].tokens,
                                      err_msg=f"request {i} diverged")


SCFG = ServeConfig(slots=4, max_len=32, prefill_chunk=4, max_prompt=16,
                   wave=3, max_gen=8)


class TestServeEngine:
    @pytest.mark.parametrize("kind,kw", [
        ("attn", {}),
        ("rwkv", {"rec_impl": "scan"}),
        ("rec", {}),
    ])
    def test_token_identity_ragged_mix(self, kind, kw):
        """8 ragged requests through 4 slots (slot reuse) must reproduce
        per-request sequential greedy decode exactly."""
        cfg = tiny_arch(kind, **kw)
        m, params, heads = build(cfg)
        rng = np.random.default_rng(7)
        reqs = ragged_requests(rng, 8, cfg.vocab, 3)
        engine = ServeEngine(m, params, heads, SCFG)
        stats = engine.serve(reqs)
        assert_token_identical(m, params, heads, reqs, stats)
        assert stats.slot_utilization > 0
        for i, r in enumerate(reqs):
            assert len(stats.results[i].tokens) == r.gen

    def test_mid_stream_admits_and_no_retrace(self):
        """Staggered arrivals join mid-decode; a second serve with a
        different ragged mix reuses every traced program."""
        cfg = tiny_arch("attn")
        m, params, heads = build(cfg)
        rng = np.random.default_rng(11)
        reqs = ragged_requests(rng, 10, cfg.vocab, 3, staggered=True)
        engine = ServeEngine(m, params, heads, SCFG)
        stats = engine.serve(reqs)
        assert_token_identical(m, params, heads, reqs, stats)
        # late arrivals must not have been admitted before their round
        assert stats.prefill_dispatches >= 2
        traces = dict(engine.traces)
        assert all(v == 1 for v in traces.values()), traces
        reqs2 = ragged_requests(rng, 6, cfg.vocab, 3, staggered=True)
        stats2 = engine.serve(reqs2)
        assert engine.traces == traces, (
            f"retraced across serve calls: {traces} -> {engine.traces}")
        assert_token_identical(m, params, heads, reqs2, stats2)

    def test_single_dispatch_wave_prefill(self):
        """One host dispatch per admission wave regardless of prompt
        lengths; the scan covers max_prompt/prefill_chunk chunks."""
        cfg = tiny_arch("attn")
        m, params, heads = build(cfg)
        rng = np.random.default_rng(3)
        reqs = ragged_requests(rng, 3, cfg.vocab, 3)  # one wave
        engine = ServeEngine(m, params, heads, SCFG)
        stats = engine.serve(reqs)
        assert stats.prefill_dispatches == 1
        assert stats.prefill_scan_steps == SCFG.max_prompt // \
            SCFG.prefill_chunk

    def test_gen_one_never_occupies_a_slot(self):
        cfg = tiny_arch("attn")
        m, params, heads = build(cfg)
        reqs = [Request(tokens=np.arange(5, dtype=np.int32) % cfg.vocab,
                        gen=1, cluster=c) for c in range(3)]
        engine = ServeEngine(m, params, heads, SCFG)
        stats = engine.serve(reqs)
        assert stats.decode_dispatches == 0
        assert_token_identical(m, params, heads, reqs, stats)

    def test_request_validation(self):
        cfg = tiny_arch("attn")
        m, params, heads = build(cfg)
        engine = ServeEngine(m, params, heads, SCFG)
        bad = [
            Request(tokens=np.zeros(17, np.int32), gen=2),      # > max_prompt
            Request(tokens=np.zeros(4, np.int32), gen=9),       # > max_gen
            Request(tokens=np.zeros(4, np.int32), gen=2, cluster=5),
        ]
        for r in bad:
            with pytest.raises(ValueError):
                engine.serve([r])

    def test_config_validation(self):
        with pytest.raises(ValueError):
            ServeConfig(prefill_chunk=5, max_prompt=16).validate()
        with pytest.raises(ValueError):
            ServeConfig(max_prompt=64, max_gen=64, max_len=100).validate()

    def test_encdec_and_windowed_rejected(self):
        cfg = tiny_arch("attn", attn_window=8)
        m, params, heads = build(cfg)
        with pytest.raises(ValueError, match="full KV"):
            ServeEngine(m, params, heads, SCFG)


class TestDecodeStats:
    def test_accounting(self):
        """tok_per_s divides the gen-1 decode-phase tokens by the decode
        timer (the first token comes out of prefill and is billed to
        ttft), not batch*gen / decode_s."""
        s = DecodeStats(tokens=jnp.zeros((4, 9), jnp.int32), prompt_len=7,
                        prefill_s=1.0, ttft_s=1.5, decode_s=2.0,
                        prefill_dispatches=7)
        assert s.tok_per_s == pytest.approx(4 * 8 / 2.0)
        assert s.total_tok_per_s == pytest.approx(4 * 9 / 3.5)

    def test_greedy_decode_counts_and_fields(self):
        cfg = tiny_arch("attn")
        m, params, _ = build(cfg)
        prompts = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2, 6)),
            jnp.int32)
        stats = greedy_decode(m, params, prompts, 3)
        assert stats.tokens.shape == (2, 3)
        assert stats.prefill_dispatches == 6
        assert stats.ttft_s >= stats.prefill_s > 0
        assert stats.decode_s > 0


class TestClusterHeads:
    def test_distinct_heads_route_distinctly(self):
        cfg = tiny_arch("attn")
        m, params, heads = build(cfg)
        hn = jnp.asarray(
            np.random.default_rng(1).standard_normal((2, cfg.d_model)),
            jnp.float32)
        l0 = cluster_logits(heads, hn, jnp.zeros(2, jnp.int32))
        l1 = cluster_logits(heads, hn, jnp.ones(2, jnp.int32))
        assert not np.allclose(np.asarray(l0), np.asarray(l1))
        mixed = cluster_logits(heads, hn, jnp.asarray([0, 1], jnp.int32))
        np.testing.assert_allclose(np.asarray(mixed[0]), np.asarray(l0[0]),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(mixed[1]), np.asarray(l1[1]),
                                   rtol=1e-6)


class TestRouting:
    def test_route_requests_recovers_seeded_clusters(self):
        """Requests drawn from two distinct token distributions route to
        the clusters their signatures seeded."""
        from repro.data.tokens import TokenTaskSpec, sample_tokens

        d, k = 32, 2
        specs = [TokenTaskSpec(vocab=64, seed=s) for s in (0, 1)]
        streams, labels = [], []
        for t, spec in enumerate(specs):
            for j in range(3):
                streams.append(sample_tokens(spec, 600, seed=10 * t + j))
                labels.append(t)
        sigs = [token_signature(s, d=d, k=k, vocab=64) for s in streams]
        lam = np.stack([s[0] for s in sigs])
        v = np.stack([s[1] for s in sigs])
        eng = MembershipEngine(MembershipConfig(backend="numpy"))
        eng.seed(lam, v, np.asarray(labels), n_clusters=2)
        got = route_requests(eng, streams, d=d, k=k, vocab=64)
        assert got.tolist() == labels

    def test_unassigned_falls_back_to_zero(self):
        class Stub:
            def assign(self, lam, v):
                return dataclasses.make_dataclass(
                    "R", ["labels", "affinity", "margin"])(
                        np.asarray([-1, 1]), None, None)

        got = route_requests(Stub(), [np.arange(40), np.arange(40)])
        assert got.tolist() == [0, 1]


class TestRecImplParity:
    """The three rec_impl serving paths are interchangeable at the model
    level (fp32 archs keep fp32 kernel compute)."""

    @pytest.mark.parametrize("kind", ["rwkv", "rec"])
    def test_pallas_matches_scan_forward_and_prefill(self, kind):
        outs = {}
        for impl in ("scan", "pallas"):
            cfg = tiny_arch(kind, rec_impl=impl)
            m = get_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            toks = jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)),
                jnp.int32)
            logits, _ = m.forward(params, {"tokens": toks})
            st = m.init_decode_state(2, 24, per_slot=True)
            valid = jnp.asarray([[True] * 8, [True] * 5 + [False] * 3])
            h, st = m.prefill_chunk(params, toks[:, :8], st, 0, valid)
            outs[impl] = (np.asarray(logits), np.asarray(h[:, :5]),
                          np.asarray(st["length"]))
        for got, want in zip(outs["pallas"], outs["scan"]):
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
