"""Tests for HAC + baselines + metrics (paper §II-C)."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import clustering as clu


def _block_similarity(sizes, in_sim=0.95, cross_sim=0.2, noise=0.02,
                      seed=0):
    rng = np.random.default_rng(seed)
    n = sum(sizes)
    labels = np.repeat(np.arange(len(sizes)), sizes)
    r = np.where(labels[:, None] == labels[None, :], in_sim, cross_sim)
    r = r + rng.uniform(-noise, noise, size=(n, n))
    r = (r + r.T) / 2
    np.fill_diagonal(r, 1.0)
    return r, labels


class TestHAC:
    @pytest.mark.parametrize("linkage", ["average", "single", "complete"])
    def test_recovers_blocks(self, linkage):
        r, true = _block_similarity([5, 5, 4])
        labels = clu.hac_clusters(r, 3, linkage)
        assert clu.clustering_accuracy(labels, true) == 1.0

    def test_paper_table1_example(self):
        """The exact matrix from paper Table I."""
        r = np.array([
            [1.00, 0.97, 0.31, 0.31, 0.32],
            [0.97, 1.00, 0.31, 0.32, 0.32],
            [0.31, 0.31, 1.00, 0.97, 0.98],
            [0.31, 0.32, 0.97, 1.00, 0.98],
            [0.32, 0.32, 0.98, 0.98, 1.00]])
        labels = clu.hac_clusters(r, 2)
        assert clu.clustering_accuracy(labels, [0, 0, 1, 1, 1]) == 1.0

    def test_dendrogram_merge_count(self):
        r, _ = _block_similarity([3, 3])
        d = clu.hac(r)
        assert len(d.merges) == 5
        assert d.n_leaves == 6

    def test_cut_extremes(self):
        r, _ = _block_similarity([4, 4])
        d = clu.hac(r)
        assert len(np.unique(clu.cut(d, 1))) == 1
        assert len(np.unique(clu.cut(d, 8))) == 8

    def test_average_linkage_heights_monotone_on_blocks(self):
        r, _ = _block_similarity([4, 4], noise=0.0)
        d = clu.hac(r, "average")
        h = d.heights()
        # within-block merges (high sim) happen before the final
        # cross-block merge (low sim)
        assert h[-1] < h[0]

    @given(n=st.integers(4, 12), t=st.integers(2, 4))
    @settings(max_examples=15, deadline=None)
    def test_cut_partitions_property(self, n, t):
        """cut() always yields exactly t non-empty clusters covering 0..N-1."""
        if t > n:
            return
        rng = np.random.default_rng(n * 100 + t)
        r = rng.uniform(0, 1, (n, n))
        r = (r + r.T) / 2
        np.fill_diagonal(r, 1.0)
        labels = clu.hac_clusters(r, t)
        assert labels.shape == (n,)
        assert len(np.unique(labels)) == t

    def test_relabel_invariance(self):
        r, true = _block_similarity([4, 3, 3], seed=3)
        perm = np.random.default_rng(1).permutation(10)
        labels_a = clu.hac_clusters(r, 3)
        labels_b = clu.hac_clusters(r[np.ix_(perm, perm)], 3)
        assert clu.adjusted_rand_index(labels_a[perm], labels_b) == \
            pytest.approx(1.0)


class TestBaselines:
    def test_random_clusters_nonempty(self):
        labels = clu.random_clusters(10, 3, rng=0)
        assert len(np.unique(labels)) == 3

    def test_random_clusters_fixed_sizes(self):
        labels = clu.random_clusters(10, 3, rng=0, cluster_sizes=[5, 3, 2])
        sizes = sorted(np.bincount(labels))
        assert sizes == [2, 3, 5]

    def test_oracle(self):
        assert (clu.oracle_clusters([7, 7, 2, 2]) ==
                np.array([1, 1, 0, 0])).all()

    def test_spectral_recovers_blocks(self):
        r, true = _block_similarity([6, 6], seed=5)
        labels = clu.spectral_clusters(r, 2, rng=0)
        assert clu.clustering_accuracy(labels, true) == 1.0

    def test_ifca_assign(self):
        losses = np.array([[0.1, 2.0], [3.0, 0.5], [0.2, 9.0]])
        assert (clu.ifca_assign(losses) == np.array([0, 1, 0])).all()


class TestMetrics:
    def test_accuracy_perfect_any_permutation(self):
        pred = np.array([2, 2, 0, 0, 1])
        true = [5, 5, 9, 9, 4]
        assert clu.clustering_accuracy(pred, true) == 1.0

    def test_accuracy_partial(self):
        pred = np.array([0, 0, 0, 1])
        true = [0, 0, 1, 1]
        assert clu.clustering_accuracy(pred, true) == 0.75

    def test_ari_bounds(self):
        assert clu.adjusted_rand_index([0, 0, 1, 1], [1, 1, 0, 0]) == \
            pytest.approx(1.0)
        low = clu.adjusted_rand_index([0, 1, 0, 1], [0, 0, 1, 1])
        assert low < 0.1
