"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, quant, tuning
from repro.kernels.assign import ops as assign_ops
from repro.kernels.assign.ref import assign_ref
from repro.kernels.eigproject import ops as proj_ops
from repro.kernels.eigproject.ref import project_norms_ref
from repro.kernels.featurize_gram import ops as fg_ops
from repro.kernels.featurize_gram.ref import featurize_gram_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import flash_ref
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram.ref import gram_ref
from repro.kernels.gram_project import ops as gp_ops
from repro.kernels.gram_project.ref import gram_project_ref
from repro.kernels.linkage import ops as link_ops
from repro.kernels.linkage.ref import linkage_step_ref


class TestGramKernel:
    @pytest.mark.parametrize("n,d", [(128, 128), (256, 128), (384, 256),
                                     (130, 96), (64, 40), (512, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_sweep(self, n, d, dtype):
        rng = np.random.default_rng(n * 7 + d)
        x = jnp.asarray(rng.standard_normal((n, d)), dtype)
        out = gram_ops.gram_matrix(x, interpret=True)
        ref = gram_ref(x)
        tol = 1e-3 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol * 10)

    def test_symmetry(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
        out = np.asarray(gram_ops.gram_matrix(x, interpret=True))
        np.testing.assert_allclose(out, out.T, atol=1e-4)


class TestEigprojectKernel:
    @pytest.mark.parametrize("d,k", [(128, 128), (256, 8), (200, 5),
                                     (384, 64), (96, 12)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_sweep(self, d, k, dtype):
        rng = np.random.default_rng(d * 3 + k)
        g = rng.standard_normal((d, d)).astype(np.float32)
        g = jnp.asarray((g + g.T) / 2, dtype)
        v = jnp.asarray(rng.standard_normal((d, k)), dtype)
        out = proj_ops.project_norms(g, v, interpret=True)
        ref = project_norms_ref(g, v)
        tol = 1e-3 if dtype == jnp.float32 else 6e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol * 10)

    def test_zero_vector_column(self):
        g = jnp.eye(128, dtype=jnp.float32)
        v = jnp.zeros((128, 8), jnp.float32)
        out = proj_ops.project_norms(g, v, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


class TestGramProjectKernel:
    """Fused Gram + cross-projection: ||(X^T X / n) v_k|| without the
    (d, d) Gram — the blockwise engine's Eq.-2 hot path."""

    @pytest.mark.parametrize("n,d,k", [(128, 128, 128), (256, 128, 8),
                                       (100, 96, 5), (64, 40, 12),
                                       (130, 200, 48)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_sweep(self, n, d, k, dtype):
        rng = np.random.default_rng(n * 5 + d + k)
        x = jnp.asarray(rng.standard_normal((n, d)), dtype)
        v = jnp.asarray(rng.standard_normal((d, k)), dtype)
        out = gp_ops.gram_project(x, v, interpret=True)
        ref = gram_project_ref(x.astype(jnp.float32),
                               v.astype(jnp.float32))
        tol = 1e-3 if dtype == jnp.float32 else 6e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol * 10)

    def test_matches_two_stage_gram_path(self):
        """Fused == gram() then project_norms() on the explicit Gram."""
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.standard_normal((96, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
        g = gram_ops.gram_matrix(x, interpret=True) / x.shape[0]
        two_stage = proj_ops.project_norms(g, v, interpret=True)
        fused = gp_ops.gram_project(x, v, interpret=True)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(two_stage),
                                   rtol=1e-3, atol=1e-4)

    def test_ragged_n_valid(self):
        rng = np.random.default_rng(2)
        x = rng.standard_normal((40, 32)).astype(np.float32)
        padded = np.zeros((64, 32), np.float32)
        padded[:40] = x
        v = jnp.asarray(rng.standard_normal((32, 4)), jnp.float32)
        out_pad = gp_ops.gram_project(jnp.asarray(padded), v, n_valid=40,
                                      interpret=True)
        out_true = gp_ops.gram_project(jnp.asarray(x), v, interpret=True)
        np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_true),
                                   rtol=1e-4, atol=1e-5)

    def test_zero_vector_column(self):
        x = jnp.asarray(np.eye(64, 32), jnp.float32)
        v = jnp.zeros((32, 8), jnp.float32)
        out = gp_ops.gram_project(x, v, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


class TestAssignKernel:
    """Fused project + trace + argmax: the MembershipEngine's arrival hot
    path (one pass over the prototype directory per newcomer)."""

    @staticmethod
    def _case(b, t, d, k, seed=0):
        rng = np.random.default_rng(seed)
        v = rng.standard_normal((b, d, k)).astype(np.float32)
        p = rng.standard_normal((t, d, d)).astype(np.float32)
        return jnp.asarray(v), jnp.asarray((p + p.transpose(0, 2, 1)) / 2)

    @pytest.mark.parametrize("b,t,d,k", [(4, 3, 16, 6), (8, 8, 32, 8),
                                         (2, 1, 128, 128), (5, 2, 40, 3)])
    def test_allclose_sweep_fp32(self, b, t, d, k):
        v, p = self._case(b, t, d, k, seed=b * 13 + t)
        aff, lab, mar = assign_ops.assign(v, p, compute_dtype="fp32",
                                          interpret=True)
        aff_r, lab_r, mar_r = assign_ref(v, p)
        np.testing.assert_allclose(np.asarray(aff), np.asarray(aff_r),
                                   rtol=1e-4, atol=1e-4)
        assert (np.asarray(lab) == np.asarray(lab_r)).all()
        np.testing.assert_allclose(np.asarray(mar), np.asarray(mar_r),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_compute_fp32_accumulate(self):
        v, p = self._case(6, 4, 64, 8, seed=5)
        aff, lab, _ = assign_ops.assign(v, p, compute_dtype="bf16",
                                        interpret=True)
        aff_r, lab_r, _ = assign_ref(v, p)
        np.testing.assert_allclose(np.asarray(aff), np.asarray(aff_r),
                                   rtol=5e-2, atol=5e-2)
        assert (np.asarray(lab) == np.asarray(lab_r)).all()

    def test_mask_excludes_clusters(self):
        v, p = self._case(4, 3, 16, 4, seed=9)
        mask = jnp.asarray([1.0, 0.0, 1.0])
        aff, lab, _ = assign_ops.assign(v, p, mask, compute_dtype="fp32",
                                        interpret=True)
        _, lab_r, _ = assign_ref(v, p, mask)
        assert not (np.asarray(lab) == 1).any()
        assert (np.asarray(lab) == np.asarray(lab_r)).all()
        assert np.isneginf(np.asarray(aff)[:, 1]).all()

    def test_tie_breaks_to_first_index(self):
        v, p = self._case(3, 1, 16, 4, seed=11)
        dup = jnp.concatenate([p, p], axis=0)        # identical prototypes
        _, lab, mar = assign_ops.assign(v, dup, compute_dtype="fp32",
                                        interpret=True)
        _, lab_r, mar_r = assign_ref(v, dup)
        assert (np.asarray(lab) == 0).all()
        assert (np.asarray(lab_r) == 0).all()
        np.testing.assert_allclose(np.asarray(mar), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(mar_r), 0.0, atol=1e-5)

    def test_single_cluster_margin_is_affinity(self):
        v, p = self._case(4, 1, 16, 4, seed=2)
        aff, lab, mar = assign_ops.assign(v, p, compute_dtype="fp32",
                                          interpret=True)
        assert (np.asarray(lab) == 0).all()
        np.testing.assert_allclose(np.asarray(mar),
                                   np.asarray(aff)[:, 0], atol=1e-5)

    def test_bad_compute_dtype_raises(self):
        v, p = self._case(1, 1, 16, 4)
        with pytest.raises(ValueError, match="compute_dtype"):
            assign_ops.assign(v, p, compute_dtype="fp16", interpret=True)


class TestFeaturizeGramKernel:
    """Fused featurize -> Gram: (X W)^T (X W) without the (n, d) feature
    matrix in HBM — the raw-ingest SignatureEngine's Eq.-1 hot path."""

    @pytest.mark.parametrize("n,m,d", [(128, 128, 128), (256, 512, 256),
                                       (100, 96, 40), (130, 300, 72),
                                       (64, 40, 12)])
    def test_allclose_sweep_fp32(self, n, m, d):
        rng = np.random.default_rng(n * 3 + m + d)
        x = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((m, d)) / np.sqrt(d),
                        jnp.float32)
        out = fg_ops.featurize_gram(x, w, interpret=True)
        ref = featurize_gram_ref(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)

    def test_bf16_compute_fp32_accumulate(self):
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.standard_normal((256, 200)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((200, 64)) / 8.0, jnp.float32)
        out = fg_ops.featurize_gram(x, w, compute_dtype="bf16",
                                    interpret=True)
        ref = np.asarray(featurize_gram_ref(x, w))
        assert out.dtype == jnp.float32
        scale = np.abs(ref).max()
        assert np.abs(np.asarray(out) - ref).max() / scale < 2e-2

    def test_matches_unfused_gram_of_features(self):
        """Fused == project with jnp, then the plain gram kernel."""
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((96, 80)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((80, 32)), jnp.float32)
        fused = fg_ops.featurize_gram(x, w, interpret=True)
        two_stage = gram_ops.gram_matrix(x @ w, interpret=True)
        np.testing.assert_allclose(np.asarray(fused),
                                   np.asarray(two_stage),
                                   rtol=1e-3, atol=1e-3)

    def test_zero_row_padding_exact(self):
        """Zero rows (ragged padding) contribute nothing to the Gram."""
        rng = np.random.default_rng(5)
        x = rng.standard_normal((40, 48)).astype(np.float32)
        padded = np.zeros((64, 48), np.float32)
        padded[:40] = x
        w = jnp.asarray(rng.standard_normal((48, 16)), jnp.float32)
        out_pad = fg_ops.featurize_gram(jnp.asarray(padded), w,
                                        interpret=True)
        out_true = fg_ops.featurize_gram(jnp.asarray(x), w, interpret=True)
        np.testing.assert_allclose(np.asarray(out_pad),
                                   np.asarray(out_true),
                                   rtol=1e-4, atol=1e-4)

    def test_symmetry_and_psd(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((64, 24)), jnp.float32)
        g = np.asarray(fg_ops.featurize_gram(x, w, interpret=True))
        np.testing.assert_allclose(g, g.T, atol=1e-4)
        assert np.linalg.eigvalsh(g).min() > -1e-3

    def test_bad_compute_dtype_rejected(self):
        x = jnp.zeros((8, 8), jnp.float32)
        w = jnp.zeros((8, 4), jnp.float32)
        with pytest.raises(ValueError, match="compute_dtype"):
            fg_ops.featurize_gram(x, w, compute_dtype="fp16")


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,s,h,hd", [(2, 256, 2, 128), (1, 128, 4, 128),
                                          (1, 512, 1, 128), (2, 256, 2, 256)])
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                               (False, 0)])
    def test_allclose_sweep(self, b, s, h, hd, causal, window):
        rng = jax.random.PRNGKey(s * 13 + h + window)
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, h, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, hd), jnp.float32)
        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                     interpret=True)

        def flat(t):
            return t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)

        ref = flash_ref(flat(q), flat(k), flat(v), causal=causal,
                        window=window)
        ref = ref.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        shape = (1, 256, 2, 128)
        q, k, v = (jax.random.normal(kk, shape, jnp.bfloat16) for kk in ks)
        out = fa_ops.flash_attention(q, k, v, interpret=True)

        def flat(t):
            return t.transpose(0, 2, 1, 3).reshape(2, 256, 128)

        ref = flash_ref(flat(q.astype(jnp.float32)),
                        flat(k.astype(jnp.float32)),
                        flat(v.astype(jnp.float32)))
        ref = ref.reshape(1, 2, 256, 128).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), rtol=0.1, atol=0.05)

    def test_unaligned_falls_back(self):
        """Non-block-aligned shapes route to the oracle (no crash)."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (jax.random.normal(kk, (1, 100, 2, 64)) for kk in ks)
        out = fa_ops.flash_attention(q, k, v, interpret=True)
        assert out.shape == (1, 100, 2, 64)


class TestTilingEdgeCases:
    """Explicit tile-plan stress: blocks that don't divide the dims,
    blocks larger than the whole dimension, single-row inputs, and the
    bf16 drift bound — the shapes an autotuned plan must survive."""

    def test_gram_block_larger_than_dims(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((130, 40)), jnp.float32)
        out = gram_ops.gram_matrix(x, block_n=512, block_d=256,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gram_ref(x)),
                                   rtol=1e-3, atol=1e-3)

    def test_gram_single_row(self):
        x = jnp.asarray(np.arange(96, dtype=np.float32)[None, :] / 96)
        out = gram_ops.gram_matrix(x, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gram_ref(x)),
                                   rtol=1e-4, atol=1e-5)

    def test_gram_non_divisible_blocks(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((200, 200)), jnp.float32)
        out = gram_ops.gram_matrix(x, block_n=128, block_d=128,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gram_ref(x)),
                                   rtol=1e-3, atol=1e-3)

    def test_eigproject_block_larger_than_dims(self):
        rng = np.random.default_rng(2)
        g = rng.standard_normal((96, 96)).astype(np.float32)
        g = jnp.asarray((g + g.T) / 2)
        v = jnp.asarray(rng.standard_normal((96, 5)), jnp.float32)
        out = proj_ops.project_norms(g, v, block_d=2048, block_k=2048,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(project_norms_ref(g, v)),
                                   rtol=1e-3, atol=1e-4)

    def test_gram_project_single_row(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((1, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
        out = gp_ops.gram_project(x, v, block_n=256, block_k=512,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(gram_project_ref(x, v)),
                                   rtol=1e-3, atol=1e-4)

    def test_featurize_gram_block_larger_than_rows(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((100, 48)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((48, 24)), jnp.float32)
        out = fg_ops.featurize_gram(x, w, block_n=1024, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(featurize_gram_ref(x, w)),
                                   rtol=1e-3, atol=1e-3)

    def test_linkage_explicit_blocks(self):
        rng = np.random.default_rng(5)
        n = 384
        ra = jnp.asarray(rng.standard_normal(n), jnp.float32)
        rb = jnp.asarray(rng.standard_normal(n), jnp.float32)
        mask = jnp.asarray((rng.random(n) > 0.3).astype(np.float32))
        ref_row, ref_idx, ref_val = linkage_step_ref(ra, rb, 2.0, 5.0, mask)
        for block in (128, 384):
            row, idx, val = link_ops.linkage_step(ra, rb, 2.0, 5.0, mask,
                                                  block=block,
                                                  interpret=True)
            np.testing.assert_allclose(np.asarray(row), np.asarray(ref_row),
                                       rtol=1e-5, atol=1e-5)
            assert int(idx) == int(ref_idx)
            np.testing.assert_allclose(float(val), float(ref_val),
                                       rtol=1e-5)

    def test_assign_single_arrival_odd_dims(self):
        rng = np.random.default_rng(6)
        v = jnp.asarray(rng.standard_normal((1, 24, 3)), jnp.float32)
        p = jnp.asarray(rng.standard_normal((3, 24, 24)), jnp.float32)
        aff, lab, mar = assign_ops.assign(v, p, compute_dtype="fp32",
                                          interpret=True,
                                          block_b=128, block_d2=8192)
        aff_r, lab_r, mar_r = assign_ref(v, p)
        np.testing.assert_allclose(np.asarray(aff), np.asarray(aff_r),
                                   rtol=1e-4, atol=1e-4)
        assert (np.asarray(lab) == np.asarray(lab_r)).all()

    def test_bf16_drift_bounded_across_kernels(self):
        """bf16 compute with fp32 accumulation stays within a relative
        drift budget of the fp32 reference at a realistic scale."""
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal((256, 96)), jnp.float32)
        ref = np.asarray(gram_ref(x))
        out = np.asarray(gram_ops.gram_matrix(x.astype(jnp.bfloat16),
                                              interpret=True))
        assert np.abs(out - ref).max() / np.abs(ref).max() < 3e-2
        v, p = TestAssignKernel._case(8, 4, 48, 6, seed=7)
        aff_b = np.asarray(assign_ops.assign(v, p, compute_dtype="bf16",
                                             interpret=True)[0])
        aff_f = np.asarray(assign_ref(v, p)[0])
        assert np.abs(aff_b - aff_f).max() / np.abs(aff_f).max() < 3e-2


class TestDispatch:
    def test_resolve_none_tracks_backend(self):
        expect = jax.default_backend() not in dispatch.LOWERED_BACKENDS
        assert dispatch.resolve_interpret(None) is expect

    def test_explicit_passthrough(self):
        assert dispatch.resolve_interpret(True) is True
        assert dispatch.resolve_interpret(False) is False

    def test_supports_lowering_consistent(self):
        assert dispatch.supports_lowering() == (
            dispatch.backend_kind() in dispatch.LOWERED_BACKENDS)


class TestTuning:
    def test_divisor_block(self):
        assert tuning.divisor_block(1024, cap=512) == 512
        assert tuning.divisor_block(384, cap=512) == 384
        assert tuning.divisor_block(640, cap=512) == 128
        assert tuning.divisor_block(128, cap=512) == 128

    def test_shape_bucket_pow2(self):
        assert tuning.shape_bucket(n=1000, d=64) == tuning.shape_bucket(
            n=1024, d=64)
        assert tuning.shape_bucket(n=1025, d=64) != tuning.shape_bucket(
            n=1024, d=64)

    def test_heuristics_cover_all_kernels(self):
        dims = {"gram": dict(n=300, d=70), "gram_project": dict(n=300, k=70),
                "featurize_gram": dict(n=300), "eigproject": dict(d=70, k=9),
                "linkage": dict(n=256), "assign": dict(b=64, d2=1024),
                "recurrent_scan": dict(s=96, d=70)}
        for kernel in tuning.KERNELS:
            blocks = tuning.heuristic_blocks(kernel, **dims[kernel])
            assert blocks, kernel
            for k, val in blocks.items():
                if isinstance(val, bool):
                    continue
                if k == "chunk":   # time tile, not a lane axis
                    assert val >= 1, (kernel, k, val)
                    continue
                assert val >= 1 and val % 128 == 0, (kernel, k, val)

    def test_unknown_kernel_rejected(self):
        with pytest.raises(ValueError, match="unknown kernel"):
            tuning.heuristic_blocks("conv", n=8)

    def test_record_overlays_heuristic(self):
        tuning.clear_cache()
        try:
            base = tuning.get_blocks("gram", n=256, d=64)
            tuning.record("gram", {"block_n": 128}, n=256, d=64)
            got = tuning.get_blocks("gram", n=256, d=64)
            assert got["block_n"] == 128
            assert got["block_d"] == base["block_d"]  # heuristic kept
        finally:
            tuning.clear_cache()

    def test_cache_persists_via_env(self, tmp_path, monkeypatch):
        cache = tmp_path / "tune" / "cache.json"
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache))
        tuning.clear_cache()
        try:
            tuning.record("assign", {"block_b": 256, "block_d2": 1024},
                          measured_s=1e-3, b=64, d2=1024)
            assert cache.exists()
            tuning.clear_cache()               # drop memory; reload disk
            hit = tuning.lookup("assign", b=64, d2=1024)
            assert hit == {"block_b": 256, "block_d2": 1024}
        finally:
            tuning.clear_cache()

    def test_autotune_picks_fastest_and_skips_invalid(self):
        tuning.clear_cache()
        calls = []

        def run(blocks):
            calls.append(dict(blocks))
            if blocks["block"] == 999:
                raise ValueError("bad divisibility")
            time.sleep(0.001 if blocks["block"] == 128 else 0.004)

        try:
            best = tuning.autotune("linkage", run,
                                   [{"block": 999}, {"block": 128},
                                    {"block": 512}],
                                   n_iter=1, warmup=0, n=512)
            assert best == {"block": 128}
            assert tuning.lookup("linkage", n=512) == {"block": 128}
        finally:
            tuning.clear_cache()

    def test_autotune_all_invalid_raises(self):
        def run(blocks):
            raise ValueError("never valid")

        with pytest.raises(ValueError, match="no valid tuning candidate"):
            tuning.autotune("linkage", run, [{"block": 7}], n=512)


class TestQuant:
    def test_int8_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        p = rng.standard_normal((5, 16, 16)).astype(np.float32) * 3
        q, sc = quant.quantize_directory(p, "int8")
        assert q.dtype == np.int8 and sc.shape == (5,)
        deq = quant.dequantize_directory(q, sc)
        # symmetric quant: per-entry error <= half a step = amax/254
        amax = np.abs(p).max(axis=(1, 2))
        err = np.abs(deq - p).max(axis=(1, 2))
        assert (err <= amax / 127).all()

    def test_zero_prototype_safe(self):
        p = np.zeros((2, 8, 8), np.float32)
        q, sc = quant.quantize_directory(p, "int8")
        assert (sc == 1.0).all()
        assert (quant.dequantize_directory(q, sc) == 0.0).all()

    def test_bf16_and_f32_have_no_scales(self):
        p = np.ones((2, 4, 4), np.float32)
        tb, sb = quant.quantize_directory(p, "bf16")
        tf, sf = quant.quantize_directory(p, "f32")
        assert sb is None and sf is None
        assert tb.dtype == jnp.bfloat16
        assert tf.dtype == np.float32

    def test_nbytes_ratio(self):
        p = np.zeros((8, 32, 32), np.float32)
        f32 = quant.directory_nbytes(*quant.quantize_directory(p, "f32"))
        i8 = quant.directory_nbytes(*quant.quantize_directory(p, "int8"))
        assert f32 == 8 * 32 * 32 * 4
        assert 3.9 < f32 / i8 <= 4.0

    def test_array_family_preserved(self):
        p_np = np.ones((2, 4, 4), np.float32)
        q_np, _ = quant.quantize_directory(p_np, "int8")
        assert isinstance(q_np, np.ndarray)
        q_j, _ = quant.quantize_directory(jnp.asarray(p_np), "int8")
        assert isinstance(q_j, jax.Array)

    def test_unknown_dtype_rejected(self):
        with pytest.raises(ValueError, match="directory dtype"):
            quant.quantize_directory(np.zeros((1, 2, 2), np.float32), "fp8")


class TestAssignQuantizedAndChunked:
    def test_int8_directory_matches_dequantized_ref(self):
        rng = np.random.default_rng(1)
        v = jnp.asarray(rng.standard_normal((6, 20, 4)), jnp.float32)
        p = rng.standard_normal((5, 20, 20)).astype(np.float32)
        p = (p + p.transpose(0, 2, 1)) / 2
        q, sc = quant.quantize_directory(jnp.asarray(p), "int8")
        aff, lab, mar = assign_ops.assign(v, q, scales=sc,
                                          compute_dtype="fp32",
                                          interpret=True)
        deq = quant.dequantize_directory(q, sc)
        aff_r, lab_r, mar_r = assign_ref(v, deq)
        np.testing.assert_allclose(np.asarray(aff), np.asarray(aff_r),
                                   rtol=1e-4, atol=1e-4)
        assert (np.asarray(lab) == np.asarray(lab_r)).all()
        np.testing.assert_allclose(np.asarray(mar), np.asarray(mar_r),
                                   rtol=1e-4, atol=1e-4)

    def test_wave_chunking_matches_single_dispatch(self, monkeypatch):
        """Waves larger than the S-footprint cap split into mapped chunks
        that must agree with the unchunked path exactly."""
        rng = np.random.default_rng(2)
        v = jnp.asarray(rng.standard_normal((96, 17, 3)), jnp.float32)
        p = jnp.asarray(rng.standard_normal((4, 17, 17)), jnp.float32)
        whole = assign_ops.assign(v, p, compute_dtype="fp32",
                                  interpret=True, block_b=32,
                                  block_d2=512)
        # Cap the per-dispatch S footprint so b=96 > chunk and the
        # lax.map path engages (512 lanes * 32 rows per chunk).
        monkeypatch.setattr(assign_ops, "_MAX_S_ELEMS", 512 * 32)
        chunked = assign_ops.assign(v, p, compute_dtype="fp32",
                                    interpret=True, block_b=32,
                                    block_d2=512)
        for a, b in zip(whole, chunked):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


class TestDoubleBuffer:
    """The DMA double-buffered streaming paths must agree with their grid
    counterparts bit-for-bit at fp32 (same accumulation order per block)."""

    def test_featurize_gram_double_buffer_parity(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((384, 128)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
        grid = fg_ops.featurize_gram(x, w, block_n=128,
                                     double_buffer=False, interpret=True)
        db = fg_ops.featurize_gram(x, w, block_n=128,
                                   double_buffer=True, interpret=True)
        np.testing.assert_allclose(np.asarray(db), np.asarray(grid),
                                   rtol=1e-6, atol=1e-6)

    def test_gram_project_double_buffer_parity(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((256, 96)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((96, 8)), jnp.float32)
        grid = gp_ops.gram_project(x, v, block_n=128, block_k=128,
                                   double_buffer=False, interpret=True)
        db = gp_ops.gram_project(x, v, block_n=128, block_k=128,
                                 double_buffer=True, interpret=True)
        np.testing.assert_allclose(np.asarray(db), np.asarray(grid),
                                   rtol=1e-6, atol=1e-6)

    def test_double_buffer_non_divisible_rows(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((200, 48)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((48, 16)), jnp.float32)
        db = fg_ops.featurize_gram(x, w, block_n=128, double_buffer=True,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(db),
                                   np.asarray(featurize_gram_ref(x, w)),
                                   rtol=1e-3, atol=1e-3)


class TestRecurrentScanKernel:
    """The serving recurrences: chunked wkv (rwkv6 time-mix) and the
    rglru linear scan, vs their sequential fp32 oracles."""

    @staticmethod
    def _wkv_inputs(rng, b, h, s, hd, scale=1.0):
        f = jnp.float32
        r = jnp.asarray(rng.standard_normal((b, s, h, hd)) * scale, f)
        k = jnp.asarray(rng.standard_normal((b, s, h, hd)) * scale, f)
        v = jnp.asarray(rng.standard_normal((b, s, h, hd)) * scale, f)
        logw = -jnp.asarray(np.exp(rng.standard_normal((b, s, h, hd))), f)
        u = jnp.asarray(rng.standard_normal((h, hd)) * scale, f)
        st = jnp.asarray(rng.standard_normal((b, h, hd, hd)) * scale, f)
        return r, k, v, logw, u, st

    @pytest.mark.parametrize("b,h,s,hd,chunk", [
        (1, 1, 32, 16, 8),      # lane padding (16 -> 128)
        (2, 2, 64, 64, 16),
        (2, 1, 48, 32, 16),     # s not divisible by chunk
        (1, 2, 16, 64, 64),     # chunk > s
    ])
    def test_wkv_fp32_vs_oracle(self, b, h, s, hd, chunk):
        from repro.kernels.recurrent_scan import ops as rs_ops
        from repro.kernels.recurrent_scan.ref import wkv_ref

        rng = np.random.default_rng(b * 100 + s + hd)
        r, k, v, logw, u, st = self._wkv_inputs(rng, b, h, s, hd)
        out, new_st = rs_ops.wkv_chunked(r, k, v, logw, u, st, chunk=chunk,
                                         compute_dtype="fp32",
                                         interpret=True)
        want, want_st = wkv_ref(r, k, v, logw, u, st)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(new_st), np.asarray(want_st),
                                   rtol=1e-4, atol=1e-4)

    def test_wkv_matches_time_mix_paths(self):
        """Kernel, chunked-jnp, and sequential time-mix agree on the same
        inputs — the three rec_impl serving paths are interchangeable."""
        from repro.kernels.recurrent_scan import ops as rs_ops
        from repro.models import rwkv6

        rng = np.random.default_rng(9)
        r, k, v, logw, u, st = self._wkv_inputs(rng, 2, 2, 64, 32)
        o_ker, s_ker = rs_ops.wkv_chunked(r, k, v, logw, u, st, chunk=16,
                                          compute_dtype="fp32",
                                          interpret=True)
        o_ref, s_ref = rwkv6.time_mix_ref(r, k, v, logw, u, st)
        o_chk, s_chk = rwkv6.time_mix_chunked(r, k, v, logw, u, st,
                                              chunk=32)
        for got, want in ((o_ker, o_ref), (s_ker, s_ref),
                          (o_ker, o_chk), (s_ker, s_chk)):
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       rtol=1e-4, atol=1e-4)

    def test_wkv_bf16_parity(self):
        """bf16 compute / fp32 accumulate stays within bf16 resolution of
        the oracle at serving-scale (~0.1) activations."""
        from repro.kernels.recurrent_scan import ops as rs_ops
        from repro.kernels.recurrent_scan.ref import wkv_ref

        rng = np.random.default_rng(11)
        r, k, v, logw, u, st = self._wkv_inputs(rng, 2, 2, 64, 32,
                                                scale=0.1)
        out, _ = rs_ops.wkv_chunked(r, k, v, logw, u, st, chunk=16,
                                    compute_dtype="bf16", interpret=True)
        want, _ = wkv_ref(r, k, v, logw, u, st)
        assert float(np.abs(np.asarray(out, np.float32)
                            - np.asarray(want)).max()) <= 1e-3

    @pytest.mark.parametrize("b,s,d,chunk,block_d", [
        (1, 32, 64, 8, 64),
        (2, 64, 160, 16, 128),   # d not lane-aligned, block smaller than d
        (2, 24, 32, 32, 256),    # chunk > s, block_d > d
    ])
    def test_linear_scan_vs_oracle(self, b, s, d, chunk, block_d):
        from repro.kernels.recurrent_scan import ops as rs_ops
        from repro.kernels.recurrent_scan.ref import linear_scan_ref

        rng = np.random.default_rng(b * 31 + s + d)
        f = jnp.float32
        log_a = -jnp.asarray(np.exp(rng.standard_normal((b, s, d)) - 1), f)
        x = jnp.asarray(rng.standard_normal((b, s, d)), f)
        h0 = jnp.asarray(rng.standard_normal((b, d)), f)
        h, h_last = rs_ops.linear_scan(log_a, x, h0, chunk=chunk,
                                       block_d=block_d, interpret=True)
        want_h, want_last = linear_scan_ref(log_a, x, h0)
        np.testing.assert_allclose(np.asarray(h), np.asarray(want_h),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last),
                                   np.asarray(want_last),
                                   rtol=1e-5, atol=1e-5)

    def test_tuning_registered(self):
        blocks = tuning.heuristic_blocks("recurrent_scan", s=256, d=512)
        assert set(blocks) == {"chunk", "block_d"}
        assert blocks["chunk"] >= 8 and blocks["block_d"] >= 128
