"""Dirty-data serving tests (ISSUE 7).

The robust-aggregator contract: ``trimmed``/``medians`` prototypes equal
``mean`` on clean tables (and under degenerate settings exactly), stay
bounded under ⌊m·f⌋ corrupted members where the mean flies off, agree
across numpy/jnp backends, and produce IDENTICAL assignment verdicts on
all three backends under corruption — the RCC-PFL failure mode (a plain
mean prototype is O(1)-breakdown) must not reach the served labels.
Also locked down: the streaming admit/evict path can never diverge from
a fresh recompute (randomized-sequence parity incl. the count->0
down-date edge), the corruption injectors are seeded and exact-count,
and the median drift statistic ignores a single poisoned prototype.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import oneshot
from repro.core.engine import ProtocolEngine
from repro.core.membership_engine import (MembershipConfig,
                                          MembershipEngine, UNASSIGNED,
                                          _protos_from_table,
                                          _protos_from_table_robust)
from repro.core.similarity import SimilarityConfig
from repro.data import synthetic as syn

BACKENDS = ("numpy", "jnp", "pallas")
N_SEED, N_TASKS, D, TOP_K = 24, 3, 16, 6
CAP, TD, TK, TT = 32, 8, 4, 3          # tiny table for aggregator tests


@pytest.fixture(scope="module")
def seed_result():
    feats, task_ids = syn.make_task_feature_mixture(
        n_users=N_SEED, n_samples=48, d=D, n_tasks=N_TASKS, seed=7)
    res = oneshot.one_shot_clustering(jnp.asarray(feats), N_TASKS,
                                      cfg=SimilarityConfig(top_k=TOP_K))
    return res, task_ids


@pytest.fixture(scope="module")
def wave():
    feats, task_ids = syn.make_task_feature_mixture(
        n_users=N_SEED + 9, n_samples=48, d=D, n_tasks=N_TASKS, seed=7)
    lam, v, _ = ProtocolEngine(SimilarityConfig(top_k=TOP_K)).signatures(
        jnp.asarray(feats[N_SEED:]))
    return lam, v, task_ids[N_SEED:]


def make_table(rng, n=20, cap=CAP, d=TD, k=TK, n_clusters=TT):
    """Random signature table: n live members over n_clusters."""
    v = rng.standard_normal((cap, d, k)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    labels = np.full(cap, UNASSIGNED, np.int32)
    labels[:n] = rng.integers(0, n_clusters, n)
    valid = np.zeros(cap, bool)
    valid[:n] = True
    return v, labels, valid


def device_protos(v, labels, valid, agg, trim_frac=0.1, mom_groups=5):
    if agg == "mean":
        p, c = _protos_from_table(jnp.asarray(v), jnp.asarray(labels),
                                  jnp.asarray(valid), n_clusters=TT)
    else:
        p, c = _protos_from_table_robust(
            jnp.asarray(v), jnp.asarray(labels), jnp.asarray(valid),
            n_clusters=TT, aggregator=agg, trim_frac=trim_frac,
            mom_groups=mom_groups)
    return np.asarray(p), np.asarray(c)


def np_protos(v, labels, valid, agg, trim_frac=0.1, mom_groups=5):
    eng = MembershipEngine(MembershipConfig(
        backend="numpy", aggregator=agg, trim_frac=trim_frac,
        mom_groups=mom_groups))
    p, c = eng._rebuild_protos(v, labels, valid, TT)
    return np.asarray(p), np.asarray(c)


class TestRobustAggregators:
    """trimmed/medians == mean on clean tables; bounded under poison."""

    @pytest.mark.parametrize("agg", ["trimmed", "medians"])
    @pytest.mark.parametrize("impl", [device_protos, np_protos])
    def test_clean_equals_mean(self, rng, agg, impl):
        # order statistics of a clean i.i.d. table are not EQUAL to its
        # mean — but with trim g=0 / one MoM group they reduce to it.
        v, labels, valid = make_table(rng)
        kw = (dict(trim_frac=0.0) if agg == "trimmed"
              else dict(mom_groups=1))
        p, c = impl(v, labels, valid, agg, **kw)
        p_mean, c_mean = np_protos(v, labels, valid, "mean")
        np.testing.assert_allclose(p, p_mean, atol=1e-5)
        np.testing.assert_array_equal(c, c_mean)

    @pytest.mark.parametrize("agg", ["mean", "trimmed", "medians"])
    def test_identical_members_exact(self, rng, agg):
        # every robust statistic of identical samples IS the sample
        v, labels, valid = make_table(rng, n=12)
        for t in range(TT):
            mem = np.flatnonzero((labels == t) & valid)
            if len(mem):
                v[mem] = v[mem[0]]
        p, _ = device_protos(v, labels, valid, agg,
                             trim_frac=0.25, mom_groups=3)
        for t in range(TT):
            mem = np.flatnonzero((labels == t) & valid)
            if len(mem):
                want = v[mem[0]] @ v[mem[0]].T
                np.testing.assert_allclose(p[t], want, atol=1e-5)

    @pytest.mark.parametrize("agg", ["trimmed", "medians"])
    def test_np_jnp_parity(self, rng, agg):
        v, labels, valid = make_table(rng, n=26)
        kw = dict(trim_frac=0.2, mom_groups=5)
        p_dev, c_dev = device_protos(v, labels, valid, agg, **kw)
        p_np, c_np = np_protos(v, labels, valid, agg, **kw)
        np.testing.assert_allclose(p_dev, p_np, atol=1e-5)
        np.testing.assert_array_equal(c_dev, c_np)

    @pytest.mark.parametrize("agg", ["trimmed", "medians"])
    def test_bounded_under_corruption(self, rng, agg):
        # floor(m * f) poisoned members: the mean moves by O(f * scale^2)
        # while the resistant statistics stay near the clean prototype.
        v, labels, valid = make_table(rng, n=30)
        p_clean, _ = np_protos(v, labels, valid, "mean")
        f, scale = 0.2, 10.0
        v_bad = v.copy()
        mem0 = np.flatnonzero((labels == 0) & valid)
        n_bad = int(np.floor(len(mem0) * f))
        assert n_bad >= 1
        v_bad[mem0[:n_bad]] = scale * rng.standard_normal(
            (n_bad, TD, TK)).astype(np.float32)
        kw = dict(trim_frac=0.25, mom_groups=2 * n_bad + 1)
        p_rob, _ = device_protos(v_bad, labels, valid, agg, **kw)
        p_mean, _ = np_protos(v_bad, labels, valid, "mean")
        dev_rob = np.linalg.norm(p_rob[0] - p_clean[0])
        dev_mean = np.linalg.norm(p_mean[0] - p_clean[0])
        assert dev_mean > 10 * dev_rob    # mean flies off, robust holds
        assert dev_rob < np.linalg.norm(p_clean[0])


class TestCorruptedVerdicts:
    """Backends agree exactly on served labels under corruption, and
    the resistant aggregators keep the oracle accuracy mean loses."""

    @pytest.mark.parametrize("agg", ["mean", "trimmed", "medians"])
    def test_backends_agree_under_corruption(self, seed_result, wave,
                                             agg):
        res, _ = seed_result
        seed_labels = np.asarray(res.labels)
        lam_c, v_c, _ = syn.byzantine_signatures(
            np.asarray(res.lam), np.asarray(res.v), 0.25,
            mode="colluding_copy", seed=5, labels=seed_labels)
        lam_w, v_w, _ = wave
        labels = []
        for backend in BACKENDS:
            eng = MembershipEngine(MembershipConfig(
                backend=backend, aggregator=agg, trim_frac=0.3,
                mom_groups=7))
            eng.seed(lam_c, v_c, seed_labels, n_clusters=N_TASKS)
            labels.append(np.asarray(eng.assign(lam_w, v_w).labels))
        for got in labels[1:]:
            np.testing.assert_array_equal(got, labels[0])

    def test_robust_recovers_oracle(self, seed_result, wave):
        res, seed_tasks = seed_result
        seed_labels = np.asarray(res.labels)
        task_of = np.array([np.bincount(
            np.asarray(seed_tasks)[seed_labels == t]).argmax()
            for t in range(N_TASKS)])
        lam_c, v_c, _ = syn.byzantine_signatures(
            np.asarray(res.lam), np.asarray(res.v), 0.25,
            mode="colluding_copy", seed=5, labels=seed_labels)
        lam_w, v_w, wave_tasks = wave

        def acc(agg):
            eng = MembershipEngine(MembershipConfig(
                backend="jnp", aggregator=agg, trim_frac=0.3,
                mom_groups=7))
            eng.seed(lam_c, v_c, seed_labels, n_clusters=N_TASKS)
            lab = np.asarray(eng.assign(lam_w, v_w).labels)
            hit = (lab >= 0) & (task_of[np.maximum(lab, 0)] == wave_tasks)
            return hit.mean()

        assert acc("trimmed") >= 0.9
        assert acc("mean") < acc("trimmed")


class TestRobustLifecycle:
    """Windowed recompute on admit/evict, and streaming-mean parity."""

    @pytest.mark.parametrize("backend", ["numpy", "jnp"])
    @pytest.mark.parametrize("agg", ["trimmed", "medians"])
    def test_admit_evict_roundtrip(self, seed_result, wave, backend,
                                   agg):
        eng = MembershipEngine(MembershipConfig(
            backend=backend, aggregator=agg, trim_frac=0.2,
            mom_groups=3))
        res, _ = seed_result
        eng.seed(np.asarray(res.lam), np.asarray(res.v),
                 np.asarray(res.labels), n_clusters=N_TASKS)
        p0 = np.asarray(eng.state.protos)
        lam_w, v_w, _ = wave
        labels = np.asarray(eng.assign(lam_w, v_w).labels)
        slots = eng.admit(lam_w, v_w, labels)
        assert not np.allclose(np.asarray(eng.state.protos), p0)
        eng.evict(slots)
        np.testing.assert_allclose(np.asarray(eng.state.protos), p0,
                                   atol=1e-5)

    @pytest.mark.parametrize("backend", ["numpy", "jnp"])
    def test_streaming_matches_recompute_randomized(self, backend):
        # Satellite: the hand-rolled numpy streaming update and the
        # jitted _proto_update must both equal a fresh recompute from
        # the table after ANY admit/evict sequence — incl. a cluster
        # emptied to count 0 (down-date edge: prototype resets to 0).
        rng = np.random.default_rng(11)
        eng = MembershipEngine(MembershipConfig(backend=backend,
                                                capacity=CAP))
        v0, labels0, valid0 = make_table(rng, n=9, n_clusters=TT)
        lam0 = rng.standard_normal((9, TK)).astype(np.float32)
        eng.seed(lam0, v0[:9], labels0[:9], n_clusters=TT)
        live = list(range(9))
        for step in range(12):
            st = eng.state
            if rng.random() < 0.5 and len(live) > 2:
                k = int(rng.integers(1, 3))
                gone = rng.choice(len(live), k, replace=False)
                eng.evict([live[g] for g in gone])
                live = [s for i, s in enumerate(live)
                        if i not in set(gone.tolist())]
            else:
                k = int(rng.integers(1, 4))
                lam_w = rng.standard_normal((k, TK)).astype(np.float32)
                v_w = rng.standard_normal((k, TD, TK)).astype(np.float32)
                lab_w = rng.integers(-1, TT, k).astype(np.int32)
                slots = eng.admit(lam_w, v_w, lab_w)
                live.extend(int(s) for s in slots)
            st = eng.state
            p_re, c_re = eng._rebuild_protos(st.v, st.labels, st.valid,
                                             TT)
            np.testing.assert_allclose(np.asarray(st.protos),
                                       np.asarray(p_re), atol=1e-4)
            np.testing.assert_allclose(np.asarray(st.counts),
                                       np.asarray(c_re), atol=1e-5)
        # empty cluster 0 completely: count -> 0, prototype -> exactly 0
        lab_live = np.asarray(eng.state.labels)[live]
        in0 = [s for s, l in zip(live, lab_live) if l == 0]
        if in0:
            eng.evict(in0)
        assert np.asarray(eng.state.counts)[0] == 0
        np.testing.assert_array_equal(
            np.asarray(eng.state.protos)[0], 0.0)


class TestInjectors:
    """Seeded, exact-count, composable corruption."""

    def test_corrupt_labels_exact_count_never_self(self, rng):
        y = rng.integers(0, 5, 40).astype(np.int32)
        out = syn.corrupt_labels(y, 0.3, 5, seed=1)
        changed = out != y
        assert changed.sum() == 12               # floor(0.3 * 40)
        assert (out[changed] != y[changed]).all()
        np.testing.assert_array_equal(
            out, syn.corrupt_labels(y, 0.3, 5, seed=1))
        assert (syn.corrupt_labels(y, 0.0, 5, seed=1) == y).all()

    def test_label_noise_rows_counts(self, rng):
        feats = rng.standard_normal((6, 10, 4)).astype(np.float32)
        tids = np.array([0, 0, 1, 1, 2, 2])
        out = syn.label_noise_rows(feats, tids, 0.3, seed=2)
        for i in range(6):
            assert (out[i] != feats[i]).any(axis=1).sum() == 3
        # single-task population: no cross-task donor, untouched
        same = syn.label_noise_rows(feats, np.zeros(6, int), 0.3, seed=2)
        np.testing.assert_array_equal(same, feats)

    def test_heavy_tail_touches_exact_users(self, rng):
        feats = rng.standard_normal((10, 8, 4)).astype(np.float32)
        out = syn.heavy_tail_noise(feats, 0.35, seed=3)
        touched = (out != feats).any(axis=(1, 2))
        assert touched.sum() == 3                # floor(0.35 * 10)

    @pytest.mark.parametrize("mode", syn.BYZANTINE_MODES)
    def test_byzantine_mask_and_honest_rows(self, rng, mode):
        lam = rng.standard_normal((12, 4)).astype(np.float32)
        v = rng.standard_normal((12, 8, 4)).astype(np.float32)
        labels = np.arange(12) % 3
        lam2, v2, mask = syn.byzantine_signatures(
            lam, v, 0.25, mode=mode, seed=4, labels=labels)
        assert mask.sum() == 3                   # floor(0.25 * 12)
        np.testing.assert_array_equal(lam2[~mask], lam[~mask])
        np.testing.assert_array_equal(v2[~mask], v[~mask])
        assert (v2[mask] != v[mask]).any()

    def test_colluding_copy_targets_neighbour(self, rng):
        lam = rng.standard_normal((12, 4)).astype(np.float32)
        v = rng.standard_normal((12, 8, 4)).astype(np.float32)
        labels = np.arange(12) % 3
        lam2, v2, mask = syn.byzantine_signatures(
            lam, v, 0.25, mode="colluding_copy", seed=4, scale=8.0,
            labels=labels)
        for i in np.flatnonzero(mask):
            vic_pool = np.flatnonzero(
                ~mask & (labels == (labels[i] + 1) % 3))
            assert any(np.allclose(v2[i], 8.0 * v[j]) for j in vic_pool)

    def test_spec_validation_and_composition(self, rng):
        with pytest.raises(ValueError):
            syn.CorruptionSpec(flip_frac=1.5)
        with pytest.raises(ValueError):
            syn.CorruptionSpec(byzantine_mode="nope")
        with pytest.raises(ValueError):
            syn.byzantine_signatures(np.zeros((4, 2)),
                                     np.zeros((4, 3, 2)), 0.5,
                                     mode="nope")
        feats = rng.standard_normal((6, 10, 4)).astype(np.float32)
        tids = np.array([0, 0, 1, 1, 2, 2])
        spec = syn.CorruptionSpec(flip_frac=0.2, heavy_tail_frac=0.5,
                                  seed=9)
        out = syn.apply_corruption(feats, tids, spec)
        assert (out != feats).any()
        np.testing.assert_array_equal(
            out, syn.apply_corruption(feats, tids, spec))
        clean = syn.apply_corruption(feats, tids, syn.CorruptionSpec())
        np.testing.assert_array_equal(clean, feats)


class TestRobustDrift:
    """Median prototype-shift ignores one poisoned cluster."""

    def test_median_stat_below_max(self, seed_result, wave):
        res, _ = seed_result
        lam_w, v_w, _ = wave

        def shift(drift_stat):
            eng = MembershipEngine(MembershipConfig(
                backend="jnp", drift_stat=drift_stat))
            eng.seed(np.asarray(res.lam), np.asarray(res.v),
                     np.asarray(res.labels), n_clusters=N_TASKS)
            # poison exactly ONE cluster's prototype via a huge admit
            eng.admit(lam_w[:1], 50.0 * np.asarray(v_w[:1]),
                      np.asarray([0], np.int32))
            return eng.drift_stats()

        s_max, s_med = shift("max"), shift("median")
        assert s_max["proto_shift"] == s_max["proto_shift_max"]
        assert s_med["proto_shift"] < s_med["proto_shift_max"]
        assert s_med["proto_shift_max"] == pytest.approx(
            s_max["proto_shift_max"])

    @pytest.mark.parametrize("kw", [dict(aggregator="nope"),
                                    dict(trim_frac=0.5),
                                    dict(trim_frac=-0.1),
                                    dict(mom_groups=0),
                                    dict(drift_stat="mean")])
    def test_config_validation(self, kw):
        with pytest.raises(ValueError):
            MembershipConfig(**kw)
