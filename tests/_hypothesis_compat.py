"""Deterministic fallback for ``hypothesis`` (absent in this container).

When hypothesis is installed the real ``given``/``settings``/``strategies``
are re-exported unchanged.  Otherwise ``@given(**kwargs)`` expands each
strategy into a small fixed sample grid and parametrizes the test over (at
most) ``_MAX_CASES`` combinations — property tests degrade to deterministic
example tests instead of erroring at collection time.
"""
from __future__ import annotations

import itertools

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import pytest

    _MAX_CASES = 8

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _Strategies:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            lo, hi = float(min_value), float(max_value)
            mid = (lo + hi) / 2.0
            return _Strategy([lo, hi, mid, lo + (hi - lo) * 0.123,
                              lo + (hi - lo) * 0.789])

        @staticmethod
        def integers(min_value, max_value, **_kw):
            lo, hi = int(min_value), int(max_value)
            span = hi - lo
            picks = {lo, hi, lo + span // 2, lo + span // 3,
                     lo + (2 * span) // 3}
            return _Strategy(sorted(picks))

    st = _Strategies()

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = sorted(strategies)
        combos = list(itertools.islice(
            itertools.product(*(strategies[n].samples for n in names)),
            _MAX_CASES))
        if len(names) == 1:
            combos = [c[0] for c in combos]

        def deco(fn):
            return pytest.mark.parametrize(
                ",".join(names), combos,
                ids=[f"case{i}" for i in range(len(combos))])(fn)
        return deco
