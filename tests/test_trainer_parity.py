"""Parity + property net for the fused MT-HFL trainer (the ISSUE's
acceptance tests).

The fused super-stack program (vmap over clusters, lax.scan over local
rounds, in-jit GPS — jnp and shard_map backends, per-round and
whole-run-scan dispatch) must reproduce the retained reference loop's
``MTHFLHistory`` to 1e-5 on synthetic users across T in {1, 2, 4},
including ragged membership and an empty cluster.  Also locked down here:
per-cluster key streams make results independent of cluster numbering, and
empty clusters report NaN instead of evaluating never-trained params.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.partition import UserData
from repro.fed import client as fclient
from repro.fed import partition as fpart
from repro.fed import trainer as ftrainer
from repro.models import mlp

SRC = str(Path(__file__).resolve().parents[1] / "src")

M, NCLS = 12, 4
CENTERS = np.random.default_rng(42).standard_normal((NCLS, M)).astype(
    np.float32)

# Per-cluster lists of per-user sample counts; [] is an EMPTY cluster.
LAYOUTS = {
    "T1": [[40, 25, 33]],
    "T2-ragged": [[40, 25], [30]],
    "T4-ragged-empty": [[40], [25, 33, 20], [], [30, 8]],
}

MCFG = mlp.PaperMLPConfig(m=M, hidden=8, n_classes=NCLS)
BASE_CFG = ftrainer.MTHFLConfig(
    global_rounds=3, local_rounds=2, local_steps=4, batch_size=8,
    client=fclient.ClientConfig(lr=0.1), seed=0)


def make_users(layout, seed=0):
    rng = np.random.default_rng(seed)
    users, labels = [], []
    uid = 0
    for t, cluster in enumerate(layout):
        for n in cluster:
            y = rng.integers(0, NCLS, n).astype(np.int32)
            x = (CENTERS[y]
                 + 0.3 * rng.standard_normal((n, M))).astype(np.float32)
            users.append(UserData(user_id=uid, task_id=t, x=x, y=y,
                                  task_classes=tuple(range(NCLS))))
            labels.append(t)
            uid += 1
    return users, np.asarray(labels)


def build_models(n_clusters, mcfg=MCFG):
    return [ftrainer.TaskModel(
        init=lambda k, c=mcfg: mlp.init(c, k),
        loss_fn=mlp.loss_fn(mcfg),
        accuracy=lambda p, x, y, c=mcfg: mlp.accuracy(c, p, x, y),
        is_common=fpart.prefix_predicate(mlp.COMMON_PREFIXES))
        for _ in range(n_clusters)]


def make_evals(n_clusters, n_classes=NCLS, seed=1):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_clusters):
        y = rng.integers(0, n_classes, 32).astype(np.int32)
        x = (CENTERS[y]
             + 0.3 * rng.standard_normal((32, M))).astype(np.float32)
        out.append((jnp.asarray(x), y))
    return out


def run(layout, fused, cfg=BASE_CFG, **cfg_overrides):
    users, labels = make_users(layout)
    n_clusters = len(layout)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    return ftrainer.train_mthfl(
        users, labels, build_models(n_clusters), make_evals(n_clusters),
        cfg, cluster_classes=[list(range(NCLS))] * n_clusters, fused=fused)


def assert_history_close(a, b, atol=1e-5):
    np.testing.assert_allclose(a.accuracy, b.accuracy, atol=atol)
    np.testing.assert_allclose(a.train_loss, b.train_loss, atol=atol)


class TestFusedParity:
    """Fused == reference to 1e-5 — the tentpole's acceptance criterion."""

    @pytest.mark.parametrize("layout", LAYOUTS.values(),
                             ids=list(LAYOUTS))
    def test_fused_matches_reference(self, layout):
        ref = run(layout, fused=False)
        fus = run(layout, fused=True)
        assert not ref.fused and fus.fused
        assert_history_close(fus, ref)

    @pytest.mark.parametrize("layout", LAYOUTS.values(),
                             ids=list(LAYOUTS))
    def test_shard_map_matches_reference(self, layout):
        ref = run(layout, fused=False)
        fus = run(layout, fused=True, backend="shard_map")
        assert_history_close(fus, ref)

    def test_scan_rounds_matches_reference(self):
        layout = LAYOUTS["T4-ragged-empty"]
        ref = run(layout, fused=False)
        for backend in ftrainer.TRAINER_BACKENDS:
            fus = run(layout, fused=True, backend=backend, scan_rounds=True)
            assert_history_close(fus, ref)

    def test_auto_uses_fused_when_stackable(self):
        hist = run(LAYOUTS["T2-ragged"], fused="auto")
        assert hist.fused


class TestEmptyClusterMasking:
    def test_empty_cluster_reports_nan(self):
        layout = LAYOUTS["T4-ragged-empty"]
        for fused in (False, True):
            hist = run(layout, fused=fused)
            assert np.isnan(hist.accuracy[:, 2]).all()
            assert np.isnan(hist.train_loss[:, 2]).all()
            keep = [0, 1, 3]
            assert np.isfinite(hist.accuracy[:, keep]).all()
            assert np.isfinite(hist.train_loss[:, keep]).all()

    def test_empty_cluster_has_no_gps_weight(self):
        """The occupied clusters must train identically whether the empty
        cluster exists or not (it must not drag its never-trained params
        into the GPS common average)."""
        users3, labels3 = make_users([[40, 25], [], [30]])
        evals3 = make_evals(3)
        with_empty = ftrainer.train_mthfl(
            users3, labels3, build_models(3), evals3, BASE_CFG,
            cluster_classes=[list(range(NCLS))] * 3, fused=True)
        # Same users, same eval sets, the empty cluster dropped: members of
        # the old cluster 2 now carry label 1.
        users2, labels2 = make_users([[40, 25], [30]])
        without = ftrainer.train_mthfl(
            users2, labels2, build_models(2), [evals3[0], evals3[2]],
            BASE_CFG, cluster_classes=[list(range(NCLS))] * 2, fused=True)
        np.testing.assert_allclose(with_empty.accuracy[:, [0, 2]],
                                   without.accuracy, atol=1e-5)
        np.testing.assert_allclose(with_empty.train_loss[:, [0, 2]],
                                   without.train_loss, atol=1e-5)


class TestClusterStreamDeterminism:
    """Per-cluster key streams derived from cfg.seed + member ids: results
    must not depend on how clusters happen to be numbered (the seed shared
    one np RNG across clusters, so iteration order leaked into results)."""

    @pytest.mark.parametrize("fused", [False, True])
    def test_reordering_clusters_permutes_history(self, fused):
        layout = [[40], [25, 33], [30, 8]]
        perm = [2, 0, 1]                       # new index of old cluster t
        users, labels = make_users(layout)
        n_clusters = len(layout)
        models, evals = build_models(n_clusters), make_evals(n_clusters)
        cc = [list(range(NCLS))] * n_clusters
        hist = ftrainer.train_mthfl(users, labels, models, evals, BASE_CFG,
                                    cluster_classes=cc, fused=fused)

        labels2 = np.asarray([perm[l] for l in labels])
        old_of_new = np.argsort(perm)
        evals2 = [evals[o] for o in old_of_new]
        hist2 = ftrainer.train_mthfl(users, labels2, models, evals2,
                                     BASE_CFG, cluster_classes=cc,
                                     fused=fused)
        np.testing.assert_allclose(hist2.accuracy[:, perm], hist.accuracy,
                                   atol=1e-5)
        np.testing.assert_allclose(hist2.train_loss[:, perm],
                                   hist.train_loss, atol=1e-5)

    def test_same_seed_reproduces(self):
        a = run(LAYOUTS["T2-ragged"], fused=True)
        b = run(LAYOUTS["T2-ragged"], fused=True)
        np.testing.assert_array_equal(a.accuracy, b.accuracy)

    def test_different_seed_differs(self):
        a = run(LAYOUTS["T2-ragged"], fused=True)
        b = run(LAYOUTS["T2-ragged"], fused=True, seed=1)
        assert not np.allclose(a.train_loss, b.train_loss)


class TestFusedApi:
    def _hetero_setup(self):
        users, labels = make_users([[40, 25], [30]])
        cc = [[0, 1, 2, 3], [0, 1]]            # 4-way vs 2-way heads
        models = [build_models(1, MCFG)[0],
                  build_models(1, mlp.PaperMLPConfig(
                      m=M, hidden=8, n_classes=2))[0]]
        evals = [make_evals(1, n_classes=4)[0], make_evals(1, n_classes=2)[0]]
        return users, labels, models, evals, cc

    def test_fused_true_heterogeneous_raises(self):
        users, labels, models, evals, cc = self._hetero_setup()
        with pytest.raises(ValueError, match="stack"):
            ftrainer.train_mthfl(users, labels, models, evals, BASE_CFG,
                                 cluster_classes=cc, fused=True)

    def test_auto_falls_back_heterogeneous(self):
        users, labels, models, evals, cc = self._hetero_setup()
        hist = ftrainer.train_mthfl(users, labels, models, evals, BASE_CFG,
                                    cluster_classes=cc, fused="auto")
        assert not hist.fused
        assert np.isfinite(hist.accuracy).all()

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            run(LAYOUTS["T1"], fused=True, backend="cuda")


SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, {testdir!r})
    import jax, numpy as np
    from test_trainer_parity import LAYOUTS, run, assert_history_close

    assert len(jax.devices()) == 4
    layout = LAYOUTS["T4-ragged-empty"]
    ref = run(layout, fused=False)
    for scan in (False, True):
        fus = run(layout, fused=True, backend="shard_map", scan_rounds=scan)
        assert_history_close(fus, ref)
    # Non-divisible cluster axis: 3 clusters over 4 devices -> padded.
    ref3 = run(LAYOUTS["T2-ragged"], fused=False)
    fus3 = run(LAYOUTS["T2-ragged"], fused=True, backend="shard_map")
    assert_history_close(fus3, ref3)
    print("TRAINER_SHARD_PARITY_OK")
""").format(testdir=str(Path(__file__).resolve().parent))


def test_shard_map_parity_4dev():
    """Fused shard_map on 4 forced host devices == reference loop,
    including a cluster count that does not divide the mesh."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "TRAINER_SHARD_PARITY_OK" in res.stdout


class TestDropoutParity:
    """Keyed straggler/dropout masks (ISSUE 7): the participation draw
    is identical host-side and in-jit, rides as a traced scalar (rate
    0.0 is bit-identical full participation), and the fused path under
    dropout still reproduces the reference loop — including a fully
    dropped cluster, which keeps its params, reports a NaN round loss,
    but is STILL evaluated."""

    def test_rate_zero_is_full_participation(self):
        key = jax.random.PRNGKey(3)
        mask = fclient.participation_mask(key, np.arange(40), 0.0)
        assert (np.asarray(mask) == 1.0).all()

    def test_host_equals_jit_and_uid_keyed(self):
        key = jax.random.PRNGKey(3)
        uids = np.array([5, 9, 2, 77])
        host = np.asarray(fclient.participation_mask(key, uids, 0.5))
        jitted = np.asarray(jax.jit(fclient.participation_mask)(
            key, jnp.asarray(uids), jnp.float32(0.5)))
        np.testing.assert_array_equal(host, jitted)
        # the draw is keyed by uid, not position: permuting the uids
        # permutes the mask
        perm = np.array([2, 0, 3, 1])
        shuffled = np.asarray(
            fclient.participation_mask(key, uids[perm], 0.5))
        np.testing.assert_array_equal(shuffled, host[perm])

    def test_rate_is_traced_not_static(self):
        traces = []

        @jax.jit
        def f(key, uids, rate):
            traces.append(1)
            return fclient.participation_mask(key, uids, rate)

        key = jax.random.PRNGKey(0)
        uids = jnp.arange(8)
        full = f(key, uids, jnp.float32(0.0))
        f(key, uids, jnp.float32(0.7))
        assert len(traces) == 1          # rate change never retraces
        assert (np.asarray(full) == 1.0).all()

    def test_fused_matches_reference_with_dropout(self):
        layout = LAYOUTS["T4-ragged-empty"]
        ref = run(layout, fused=False, dropout_frac=0.5)
        fus = run(layout, fused=True, dropout_frac=0.5)
        assert_history_close(fus, ref)
        scan = run(layout, fused=True, dropout_frac=0.5,
                   scan_rounds=True)
        assert_history_close(scan, ref)

    def test_full_cluster_dropout_nan_loss_finite_accuracy(self):
        hist = run(LAYOUTS["T4-ragged-empty"], fused=True,
                   dropout_frac=0.5)
        nonempty = [0, 1, 3]
        dropped = np.isnan(hist.train_loss[:, nonempty])
        assert dropped.any()             # seed 0 fully drops some round
        # a dropped cluster skipped training but was still evaluated
        assert np.isfinite(hist.accuracy[:, nonempty]).all()

    @pytest.mark.parametrize("bad", [1.0, -0.1])
    def test_dropout_validation(self, bad):
        with pytest.raises(ValueError, match="dropout_frac"):
            run(LAYOUTS["T1"], fused=False, dropout_frac=bad)
