"""IFCA iterative baseline: converges to the task partition on separable
data, at a per-round comm cost the one-shot algorithm pays once."""
import jax
import numpy as np

from repro.core import clustering as clu
from repro.core.oneshot import CommLedger
from repro.data import partition as dpart
from repro.fed import client as fclient
from repro.fed.ifca import IFCAConfig, run_ifca
from repro.models import mlp


def test_ifca_converges_and_costs_more():
    users = dpart.paper_fmnist_three_task(seed=0, scale=0.15)
    mcfg = mlp.PaperMLPConfig(m=784, n_classes=10)

    def label_fn(u):
        return u.y.astype(np.int32)

    cfg = IFCAConfig(n_clusters=3, rounds=4, local_steps=10,
                     client=fclient.ClientConfig(lr=0.05,
                                                 optimizer="momentum"))
    res = run_ifca(users, lambda k: mlp.init(mcfg, k),
                   mlp.loss_fn(mcfg), label_fn, cfg)
    true = [u.task_id for u in users]
    final_acc = clu.clustering_accuracy(res.assignments[-1], true)
    first_acc = clu.clustering_accuracy(res.assignments[0], true)
    # iterative clustering needs rounds to beat its (random-init) round-0
    # assignment; it should improve and end reasonably clustered
    assert final_acc >= first_acc
    assert final_acc >= 0.6

    # comm: ONE IFCA round costs more than the whole one-shot protocol
    led = CommLedger(n_users=len(users), d=784, top_k=8)
    oneshot_total = led.per_user_upload + led.per_user_download
    assert res.per_user_bytes_per_round > oneshot_total
