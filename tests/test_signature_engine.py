"""Device-resident signature ingest (the ISSUE-4 acceptance tests).

Three claims under test: (1) the streaming featurize->Gram accumulation
equals the host feature_map + batched_gram reference for every Phi kind
and backend; (2) the batched top-k subspace iteration equals the eigh
top-k on well-separated spectra, detects its own non-convergence, and
falls through to eigh at top_k=d; (3) R from the RAW-DATA entry point
matches the pre-featurized entry point to 1e-5 on all three protocol
backends (shard_map additionally at 4 forced host devices in a
subprocess)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import oneshot
from repro.core import similarity as sim
from repro.core.engine import ProtocolEngine
from repro.core.signature_engine import (SignatureConfig, SignatureEngine,
                                         subspace_residual, topk_spectrum)
from repro.data import features as feat
from repro.data import synthetic as syn

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _psd_stack(n_mats: int, d: int, decay: float = 0.7, seed: int = 0
               ) -> jnp.ndarray:
    """Random PSD stack with geometric spectra (well-separated gaps)."""
    rng = np.random.default_rng(seed)
    mats = []
    for _ in range(n_mats):
        q, _ = np.linalg.qr(rng.standard_normal((d, d)))
        lam = decay ** np.arange(d)
        mats.append((q * lam) @ q.T)
    return jnp.asarray(np.stack(mats), jnp.float32)


class TestTopkSpectrum:
    def test_parity_vs_eigh_on_random_psd(self):
        g = _psd_stack(6, 32)
        lam_e, v_e = topk_spectrum(g, 5, method="eigh")
        lam_s, v_s = topk_spectrum(g, 5, method="subspace", iters=24)
        np.testing.assert_allclose(np.asarray(lam_s), np.asarray(lam_e),
                                   rtol=1e-4, atol=1e-4)
        # eigenvectors match up to per-column sign
        dots = np.abs(np.einsum("ndk,ndk->nk", np.asarray(v_s),
                                np.asarray(v_e)))
        np.testing.assert_allclose(dots, 1.0, atol=1e-4)

    def test_tied_spectrum_eigenvalues_tolerated(self):
        """Degenerate (tied) eigenvalues: eigenVALUES still converge even
        though eigenvectors are only defined up to rotation in the tie."""
        rng = np.random.default_rng(3)
        d = 24
        q, _ = np.linalg.qr(rng.standard_normal((d, d)))
        lam = np.array([4.0, 4.0, 4.0, 2.0, 2.0, 1.0] + [0.01] * (d - 6))
        g = jnp.asarray((q * lam) @ q.T, jnp.float32)[None]
        lam_s, v_s = topk_spectrum(g, 6, method="subspace", iters=40)
        np.testing.assert_allclose(np.asarray(lam_s)[0], lam[:6],
                                   rtol=1e-3, atol=1e-3)
        # the tied pairs still residual-check: G v ~ lam v holds inside
        # any rotation of the tied block
        resid = float(jnp.max(subspace_residual(g, lam_s, v_s)))
        assert resid < 1e-3

    def test_top_k_d_falls_through_to_eigh(self):
        g = _psd_stack(3, 12)
        lam_s, v_s = topk_spectrum(g, 12, method="subspace", iters=2)
        lam_e, v_e = topk_spectrum(g, 12, method="eigh")
        # identical (not just close): the fall-through takes the exact
        # eigh path regardless of the (tiny) iteration budget
        np.testing.assert_array_equal(np.asarray(lam_s), np.asarray(lam_e))
        np.testing.assert_array_equal(np.asarray(v_s), np.asarray(v_e))

    def test_top_k_zero_means_all(self):
        g = _psd_stack(2, 8)
        lam, v = topk_spectrum(g, 0)
        assert lam.shape == (2, 8) and v.shape == (2, 8, 8)

    def test_nonconvergence_detected_by_residual(self):
        g = _psd_stack(4, 32)
        lam_bad, v_bad = topk_spectrum(g, 5, method="subspace", iters=0)
        lam_ok, v_ok = topk_spectrum(g, 5, method="subspace", iters=24)
        bad = float(jnp.max(subspace_residual(g, lam_bad, v_bad)))
        ok = float(jnp.max(subspace_residual(g, lam_ok, v_ok)))
        assert ok < 1e-3 < bad

    def test_unknown_method_rejected(self):
        with pytest.raises(ValueError, match="method"):
            topk_spectrum(_psd_stack(1, 8), 2, method="lanczos")

    def test_signatures_check_raises_on_stall(self, rng):
        raw = [rng.standard_normal((40, 24)).astype(np.float32)
               for _ in range(4)]
        eng = SignatureEngine(
            feat.FeatureConfig(kind="identity"),
            SignatureConfig(subspace_iters=0, oversample=2))
        with pytest.raises(RuntimeError, match="did not converge"):
            eng.signatures(raw, top_k=4, check=True)
        ok = SignatureEngine(feat.FeatureConfig(kind="identity"),
                             SignatureConfig(subspace_iters=30))
        lam, v, g = ok.signatures(raw, top_k=4, check=True)
        assert lam.shape == (4, 4)


class TestGramParity:
    """Streaming/chunked/fused Gram accumulation == host reference."""

    @pytest.mark.parametrize("kind,kwargs,m,probe_dim", [
        ("identity", {}, 24, None),
        ("random_projection", {"d": 16}, 40, None),
        ("pca", {"d": 12}, 32, 32),
        ("random_conv", {"d": 24, "image_hw": (8, 8, 3)}, 192, None),
    ])
    @pytest.mark.parametrize("backend,chunk", [
        ("jnp", 0), ("jnp", 13), ("pallas", 16)])
    def test_matches_host_reference(self, rng, kind, kwargs, m, probe_dim,
                                    backend, chunk):
        raw = [rng.standard_normal((n, m)).astype(np.float32)
               for n in (30, 17, 41)]
        probe = (rng.standard_normal((50, probe_dim)).astype(np.float32)
                 if probe_dim else None)
        fc = feat.FeatureConfig(kind=kind, **kwargs)
        feats = [feat.feature_map(x, fc, probe=probe) for x in raw]
        padded, nv = sim.pad_ragged(feats)
        g_ref = np.asarray(sim.batched_gram(padded, nv))
        eng = SignatureEngine(fc, SignatureConfig(backend=backend,
                                                  chunk_rows=chunk),
                              probe=probe)
        g = np.asarray(eng.grams(raw))
        np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-5)

    def test_bf16_compute_close(self, rng):
        raw = [rng.standard_normal((40, 64)).astype(np.float32)
               for _ in range(3)]
        fc = feat.FeatureConfig(kind="random_projection", d=32)
        ref = np.asarray(SignatureEngine(fc).grams(raw))
        for backend in ("jnp", "pallas"):
            g16 = np.asarray(SignatureEngine(
                fc, SignatureConfig(backend=backend, chunk_rows=16,
                                    compute_dtype="bf16")).grams(raw))
            scale = np.abs(ref).max()
            assert np.abs(g16 - ref).max() / scale < 5e-2

    def test_streaming_never_builds_feature_stack(self, rng):
        """Chunked == one-pass exactly; the accumulator is the only
        d'-sized state (the (N, n, d') stack is never formed)."""
        raw = np.asarray(rng.standard_normal((4, 37, 20)), np.float32)
        fc = feat.FeatureConfig(kind="random_projection", d=8)
        g_dense = np.asarray(SignatureEngine(fc).grams(raw))
        for chunk in (1, 5, 36, 37, 64):
            g_s = np.asarray(SignatureEngine(
                fc, SignatureConfig(chunk_rows=chunk)).grams(raw))
            np.testing.assert_allclose(g_s, g_dense, rtol=1e-5, atol=1e-6)


@pytest.fixture(scope="module")
def mixture():
    raw, task_ids = syn.make_task_feature_mixture(
        n_users=24, n_samples=48, d=96, n_tasks=3, seed=7)
    return raw, task_ids


@pytest.fixture(scope="module")
def prefeaturized_r(mixture):
    raw, _ = mixture
    fc = feat.FeatureConfig(kind="random_projection", d=32)
    feats = np.stack([feat.feature_map(x, fc) for x in raw])
    return np.asarray(ProtocolEngine(
        sim.SimilarityConfig(top_k=6)).similarity(jnp.asarray(feats)))


class TestRawEntryParity:
    """Acceptance: R from raw shards == R from pre-featurized arrays to
    1e-5 on every protocol backend."""

    FC = feat.FeatureConfig(kind="random_projection", d=32)

    @pytest.mark.parametrize("backend", ["jnp", "pallas", "shard_map"])
    def test_raw_matches_prefeaturized(self, mixture, prefeaturized_r,
                                       backend):
        raw, _ = mixture
        cfg = sim.SimilarityConfig(top_k=6, backend=backend)
        r = np.asarray(ProtocolEngine(cfg).similarity_from_raw(raw,
                                                               self.FC))
        np.testing.assert_allclose(r, prefeaturized_r, atol=1e-5)

    @pytest.mark.parametrize("sig_cfg", [
        SignatureConfig(chunk_rows=13),
        SignatureConfig(eig="eigh"),
        SignatureConfig(backend="pallas", chunk_rows=16),
    ])
    def test_ingest_modes_match(self, mixture, prefeaturized_r, sig_cfg):
        raw, _ = mixture
        backend = "pallas" if sig_cfg.backend == "pallas" else "jnp"
        cfg = sim.SimilarityConfig(top_k=6, backend=backend)
        r = np.asarray(ProtocolEngine(cfg).similarity_from_raw(
            raw, self.FC, signature_cfg=sig_cfg))
        np.testing.assert_allclose(r, prefeaturized_r, atol=1e-5)

    def test_ragged_raw_matches_prefeaturized(self, rng):
        ragged = [rng.standard_normal((n, 40)).astype(np.float32)
                  for n in (50, 21, 64, 33)]
        fc = feat.FeatureConfig(kind="random_projection", d=16)
        feats = [feat.feature_map(x, fc) for x in ragged]
        cfg = sim.SimilarityConfig(top_k=4)
        r_pre = np.asarray(ProtocolEngine(cfg).similarity(feats))
        r_raw = np.asarray(ProtocolEngine(cfg).similarity_from_raw(
            ragged, fc, signature_cfg=SignatureConfig(chunk_rows=17)))
        np.testing.assert_allclose(r_raw, r_pre, atol=1e-5)

    def test_oneshot_raw_entry_recovers_tasks(self, mixture):
        raw, task_ids = mixture
        from repro.core import clustering as clu

        res = oneshot.one_shot_clustering(
            raw, n_clusters=3, cfg=sim.SimilarityConfig(top_k=6),
            feature_cfg=self.FC,
            signature_cfg=SignatureConfig(chunk_rows=16))
        assert clu.clustering_accuracy(res.labels, task_ids) == 1.0
        assert res.ledger.top_k == 6 and res.ledger.d == 32

    def test_oneshot_pca_raw_entry(self, rng):
        raw = [rng.standard_normal((40, 24)).astype(np.float32)
               for _ in range(6)]
        probe = rng.standard_normal((60, 24)).astype(np.float32)
        fc = feat.FeatureConfig(kind="pca", d=8).bind_probe(probe)
        res = oneshot.one_shot_clustering(
            raw, n_clusters=2, cfg=sim.SimilarityConfig(top_k=4),
            feature_cfg=fc, probe=probe)
        assert np.asarray(res.labels).shape == (6,)


class TestApiGuards:
    def test_run_raw_honours_config_check(self, mixture):
        """SignatureConfig.check reaches the MAIN entry point: a stalled
        subspace iteration raises instead of silently returning wrong R."""
        raw, _ = mixture
        eng = ProtocolEngine(sim.SimilarityConfig(top_k=6))
        with pytest.raises(RuntimeError, match="did not converge"):
            eng.run_raw(raw, TestRawEntryParity.FC,
                        signature_cfg=SignatureConfig(
                            subspace_iters=0, oversample=2, check=True))
        res = eng.run_raw(raw, TestRawEntryParity.FC,
                          signature_cfg=SignatureConfig(check=True))
        assert res.similarity.shape == (24, 24)

    def test_shard_map_run_raw_check(self, mixture):
        """The convergence check also covers the sharded raw path (the
        residual is gathered out of the shard_map body)."""
        raw, _ = mixture
        eng = ProtocolEngine(sim.SimilarityConfig(top_k=6,
                                                  backend="shard_map"))
        with pytest.raises(RuntimeError, match="did not converge"):
            eng.run_raw(raw, TestRawEntryParity.FC,
                        signature_cfg=SignatureConfig(
                            backend="shard_map", subspace_iters=0,
                            oversample=2, check=True))

    def test_mesh_axis_conflict_rejected(self, mixture):
        raw, _ = mixture
        eng = ProtocolEngine(sim.SimilarityConfig(backend="shard_map"))
        with pytest.raises(ValueError, match="mesh_axis"):
            eng.run_raw(raw, TestRawEntryParity.FC,
                        signature_cfg=SignatureConfig(backend="shard_map",
                                                      mesh_axis="model"))

    def test_shard_map_grams_rejected(self, mixture):
        raw, _ = mixture
        eng = SignatureEngine(TestRawEntryParity.FC,
                              SignatureConfig(backend="shard_map"))
        with pytest.raises(ValueError, match="run_raw"):
            eng.grams(raw)

    def test_backend_conflict_rejected(self, mixture):
        raw, _ = mixture
        eng = ProtocolEngine(sim.SimilarityConfig(backend="shard_map"))
        with pytest.raises(ValueError, match="conflicts"):
            eng.run_raw(raw, TestRawEntryParity.FC,
                        signature_cfg=SignatureConfig(backend="jnp"))
        eng2 = ProtocolEngine(sim.SimilarityConfig())
        with pytest.raises(ValueError, match="conflicts"):
            eng2.run_raw(raw, TestRawEntryParity.FC,
                         signature_cfg=SignatureConfig(backend="shard_map"))

    def test_block_users_run_raw_rejected(self, mixture):
        raw, _ = mixture
        eng = ProtocolEngine(sim.SimilarityConfig(block_users=8))
        with pytest.raises(ValueError, match="block_users"):
            eng.run_raw(raw, TestRawEntryParity.FC)

    def test_oneshot_raw_knobs_require_feature_cfg(self, mixture):
        raw, _ = mixture
        with pytest.raises(ValueError, match="feature_cfg"):
            oneshot.one_shot_clustering(
                jnp.asarray(raw), 3,
                signature_cfg=SignatureConfig())

    def test_signature_config_validation(self):
        for bad in (dict(backend="cuda"), dict(chunk_rows=-1),
                    dict(eig="power"), dict(subspace_iters=-2),
                    dict(oversample=-1), dict(resid_tol=0.0),
                    dict(compute_dtype="fp16")):
            with pytest.raises(ValueError):
                SignatureConfig(**bad)

    def test_similarity_config_validation(self):
        for bad in (dict(top_k=-1), dict(eig_floor=0.0),
                    dict(impl="cuda"), dict(block_users=-3)):
            with pytest.raises(ValueError):
                sim.SimilarityConfig(**bad)

    def test_prepare_guards(self, rng):
        eng = SignatureEngine(TestRawEntryParity.FC)
        with pytest.raises(ValueError, match="ragged"):
            eng.prepare([np.zeros((4, 3), np.float32)],
                        n_valid=jnp.ones((1,)))
        with pytest.raises(ValueError, match="N, n, m"):
            eng.prepare(np.zeros((4, 3), np.float32))

    def test_feature_cfg_type_checked(self):
        with pytest.raises(TypeError, match="FeatureConfig"):
            SignatureEngine({"kind": "identity"})


RAW_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import similarity as sim
    from repro.core.engine import ProtocolEngine
    from repro.core.signature_engine import SignatureConfig
    from repro.data import features as feat
    from repro.data import synthetic as syn

    raw, task_ids = syn.make_task_feature_mixture(
        n_users=24, n_samples=48, d=96, n_tasks=3, seed=7)
    fc = feat.FeatureConfig(kind="random_projection", d=32)
    feats = np.stack([feat.feature_map(x, fc) for x in raw])
    cfg = sim.SimilarityConfig(top_k=6)
    r_ref = np.asarray(ProtocolEngine(cfg).similarity(jnp.asarray(feats)))
    r_raw = np.asarray(ProtocolEngine(
        sim.SimilarityConfig(top_k=6, backend="shard_map")
        ).similarity_from_raw(
            raw, fc, signature_cfg=SignatureConfig(backend="shard_map",
                                                   chunk_rows=16)))
    assert len(jax.devices()) == 4
    err = float(np.abs(r_raw - r_ref).max())
    assert err < 1e-5, err
    print("RAW_SHARD_PARITY_OK")
""")


def test_raw_shard_map_parity_4dev():
    """Raw ingest under shard_map at 4 forced host devices == the dense
    pre-featurized reference (the user axis genuinely sharded)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", RAW_SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "RAW_SHARD_PARITY_OK" in res.stdout
