"""Integration tests: one-shot clustering end-to-end on the paper's
experimental layouts (synthetic stand-ins, DESIGN.md §2)."""
import numpy as np
import pytest

from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import features as feat
from repro.data import partition as part
from repro.data import synthetic as syn


class TestPaperScenarios:
    def test_cifar_two_task_perfect_clustering(self):
        """Fig. 2 setup: 2 tasks x 5 users, 10% minority labels."""
        users = part.paper_cifar_two_task(n_per_user=300, seed=0)
        fc = feat.FeatureConfig(kind="random_projection", d=128)
        feats = [feat.feature_map(u.x, fc) for u in users]
        res = oneshot.one_shot_clustering(feats, n_clusters=2,
                                          cfg=SimilarityConfig(top_k=8))
        acc = clu.clustering_accuracy(res.labels, [u.task_id for u in users])
        assert acc == 1.0

    def test_cifar_block_structure_matches_table1(self):
        """In-task similarity ~1, cross-task clearly lower (Table I)."""
        users = part.paper_cifar_two_task(n_per_user=300, seed=1)
        fc = feat.FeatureConfig(kind="random_projection", d=128)
        feats = [feat.feature_map(u.x, fc) for u in users]
        res = oneshot.one_shot_clustering(feats, n_clusters=2,
                                          cfg=SimilarityConfig(top_k=8))
        r = res.similarity
        tid = np.asarray([u.task_id for u in users])
        same = r[tid[:, None] == tid[None, :]]
        cross = r[tid[:, None] != tid[None, :]]
        assert same.min() > cross.max() + 0.3

    def test_fmnist_three_task_unbalanced(self):
        """Fig. 3 setup: 3 tasks, 5/3/2 users, unbalanced samples."""
        users = part.paper_fmnist_three_task(seed=0, scale=0.25)
        feats = [u.x for u in users]          # identity Phi (FMNIST path)
        res = oneshot.one_shot_clustering(feats, n_clusters=3,
                                          cfg=SimilarityConfig(top_k=8))
        acc = clu.clustering_accuracy(res.labels, [u.task_id for u in users])
        assert acc == 1.0

    def test_cross_dataset_similarity_table2(self):
        """Table II: vehicle users from two datasets score higher with
        each other than with an unrelated-class user."""
        shared = 777
        # user 1: CIFAR-10 vehicles; user 2: CIFAR-100 vehicles (shared
        # task subspace); user 3: CIFAR-100 other classes.
        x1, _ = syn.make_task_dataset(syn.CIFAR_LIKE, [0, 1, 8, 9], 80,
                                      seed=1, task_of_class={c: 0 for c in
                                                             (0, 1, 8, 9)},
                                      shared_task_seed=shared)
        x2, _ = syn.make_task_dataset(syn.CIFAR100_LIKE, [10, 11], 120,
                                      seed=2, task_of_class={10: 0, 11: 0},
                                      shared_task_seed=shared)
        x3, _ = syn.make_task_dataset(syn.CIFAR100_LIKE, [40, 41], 120,
                                      seed=3, task_of_class={40: 1, 41: 1},
                                      shared_task_seed=shared)
        fc = feat.FeatureConfig(kind="random_projection", d=128)
        feats = [feat.feature_map(x, fc) for x in (x1, x2, x3)]
        res = oneshot.one_shot_clustering(feats, n_clusters=2,
                                          cfg=SimilarityConfig(top_k=8))
        assert res.similarity[0, 1] > res.similarity[0, 2] + 0.1

    def test_few_eigenvectors_suffice_fig4(self):
        """Fig. 4: top-5 eigenvectors already separate the tasks."""
        users = part.paper_fmnist_three_task(seed=0, scale=0.25)
        feats = [u.x for u in users]
        true = [u.task_id for u in users]
        res = oneshot.one_shot_clustering(feats, n_clusters=3,
                                          cfg=SimilarityConfig(top_k=5))
        assert clu.clustering_accuracy(res.labels, true) == 1.0


class TestCommLedger:
    def test_ledger_accounting(self):
        led = oneshot.CommLedger(n_users=10, d=784, top_k=5,
                                 model_params=101_770)
        # paper §III: (5 x 784) instead of (784 x 784)
        assert led.per_user_upload == 4 * (5 * 784 + 10)
        assert led.per_user_download == 4 * 9 * 5 * 784
        assert led.summary()["oneshot_vs_iterative_ratio"] < 0.04

    def test_arrival_accounting(self):
        """A streaming newcomer uploads one (k x d) signature and
        downloads one int32 label — independent of N, unlike the
        protocol's per-user upload which carries the O(N) relevance row."""
        led = oneshot.CommLedger(n_users=10, d=784, top_k=5)
        assert led.assign_upload == 4 * 5 * 784
        assert led.assign_download == 4
        assert led.assign_upload == led.per_user_upload - 4 * led.n_users
        big = oneshot.CommLedger(n_users=100_000, d=784, top_k=5)
        assert big.assign_upload == led.assign_upload     # N-independent
        assert big.per_user_upload > led.per_user_upload
        s = led.summary()
        assert s["assign_upload_bytes"] == led.assign_upload
        assert s["assign_download_bytes"] == 4
        assert s["assign_vs_protocol_upload_ratio"] < 1.0

    def test_arrival_accounting_tracks_dtype(self):
        fp32 = oneshot.CommLedger(n_users=10, d=64, top_k=8)
        bf16 = oneshot.CommLedger(n_users=10, d=64, top_k=8,
                                  dtype_bytes=2)
        assert bf16.assign_upload == fp32.assign_upload // 2
        assert bf16.assign_download == fp32.assign_download == 4

    def test_oneshot_result_carries_signatures(self):
        users = part.paper_fmnist_three_task(seed=0, scale=0.1)
        res = oneshot.one_shot_clustering(
            [u.x for u in users], n_clusters=3,
            cfg=SimilarityConfig(top_k=5))
        assert res.lam.shape == (len(users), 5)
        assert res.v.shape == (len(users), 784, 5)

    def test_oneshot_cheaper_than_weight_exchange(self):
        users = part.paper_fmnist_three_task(seed=0, scale=0.1)
        res = oneshot.one_shot_clustering(
            [u.x for u in users], n_clusters=3,
            cfg=SimilarityConfig(top_k=5),
            model_params=784 * 32 + 32 + 32 * 10 + 10)
        s = res.ledger.summary()
        assert s["per_user_upload_bytes"] < \
            s["iterative_per_round_upload_bytes"]


class TestFeatureMaps:
    @pytest.mark.parametrize("kind,kwargs", [
        ("identity", {}),
        ("random_projection", {"d": 64}),
        ("random_conv", {"d": 128, "image_hw": (32, 32, 3)}),
    ])
    def test_shapes(self, kind, kwargs, rng):
        x = rng.standard_normal((20, 3072)).astype(np.float32)
        fc = feat.FeatureConfig(kind=kind, **kwargs)
        out = feat.feature_map(x, fc)
        assert out.shape[0] == 20
        assert np.isfinite(out).all()

    def test_pca(self, rng):
        probe = rng.standard_normal((100, 50)).astype(np.float32)
        x = rng.standard_normal((20, 50)).astype(np.float32)
        out = feat.feature_map(x, feat.FeatureConfig(kind="pca", d=8),
                               probe=probe)
        assert out.shape == (20, 8)

    def test_shared_across_users(self, rng):
        """Phi must be identical for every user (protocol requirement)."""
        x = rng.standard_normal((10, 100)).astype(np.float32)
        fc = feat.FeatureConfig(kind="random_projection", d=16, seed=42)
        np.testing.assert_array_equal(feat.feature_map(x, fc),
                                      feat.feature_map(x, fc))
