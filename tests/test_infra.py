"""Infra tests: checkpointing, sharding rules, roofline parser, optimizers,
robustness extensions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core import similarity as sim
from repro.launch import roofline as RL
from repro.launch import sharding as SH


class TestCheckpoint:
    def _tree(self):
        return {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
                "b": jnp.ones((4,), jnp.bfloat16)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        save_checkpoint(tmp_path, 7, tree)
        like = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
        restored, step = restore_checkpoint(tmp_path, like)
        assert step == 7
        np.testing.assert_allclose(np.asarray(restored["a"]["w"]),
                                   np.arange(6.0).reshape(2, 3))
        assert restored["b"].dtype == jnp.bfloat16

    def test_retention_and_latest(self, tmp_path):
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(tmp_path, s, self._tree(), keep=2)
        assert latest_step(tmp_path) == 5
        assert len(list(tmp_path.glob("step_*.npz"))) == 2

    def test_shape_mismatch_raises(self, tmp_path):
        save_checkpoint(tmp_path, 1, self._tree())
        bad = {"a": {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)},
               "b": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
        with pytest.raises(ValueError):
            restore_checkpoint(tmp_path, bad)


class TestShardingRules:
    def setup_method(self):
        self.mesh = jax.make_mesh((1, 1), ("data", "model"))

    def test_first_fitting_falls_back(self):
        # 8 kv heads cannot shard over a 16-way axis -> the rule must
        # fall back rather than error (exercised with axis size 1 here,
        # logic verified by divisibility math).
        spec = SH.first_fitting((8,), [P("model"), P()], self.mesh)
        assert spec == P("model")  # size-1 axis always divides

    def test_divides_math(self):
        mesh16 = jax.make_mesh((1, 1), ("data", "model"))
        assert SH._divides(P("model"), (16,), mesh16)
        # a fake 16-way mesh cannot be built on 1 CPU; check the math
        # directly instead:
        class FakeMesh:
            shape = {"model": 16, "data": 16}
        assert not SH._divides(P("model"), (8,), FakeMesh())
        assert SH._divides(P("model"), (32,), FakeMesh())
        assert not SH._divides(P(("data", "model")), (64,), FakeMesh())
        assert SH._divides(P(("data", "model")), (256,), FakeMesh())

    def test_batch_specs(self):
        batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        specs = SH.batch_specs(batch, self.mesh)
        assert specs["tokens"] == P(("data",), None)


class TestRooflineParser:
    HLO = """
  %all-gather.1 = f32[1024,512]{1,0} all-gather(f32[64,512]{1,0} %p), x
  %all-reduce.2 = bf16[256]{0} all-reduce(bf16[256]{0} %q), y
  %ag-start = (f32[8]{0}) all-gather-start(f32[2]{0} %r), z
  %done = f32[8]{0} all-gather-done(%ag-start)
  %unrelated = f32[9]{0} add(f32[9]{0} %a, f32[9]{0} %b)
"""

    def test_parse_counts_and_bytes(self):
        stats = RL.parse_collectives(self.HLO)
        assert stats.counts["all-gather"] == 2
        assert stats.counts["all-reduce"] == 1
        assert stats.bytes_by_kind["all-gather"] == 1024 * 512 * 4 + 32
        assert stats.bytes_by_kind["all-reduce"] == 512

    def test_terms_and_bottleneck(self):
        roof = RL.Roofline(chips=256, hlo_flops_per_device=197e12,
                           hlo_bytes_per_device=819e9 * 2,
                           collective_bytes_per_device=50e9 * 3,
                           collective_counts={}, collective_bytes_by_kind={},
                           model_flops_global=197e12 * 256 / 2)
        assert roof.compute_term_s == pytest.approx(1.0)
        assert roof.memory_term_s == pytest.approx(2.0)
        assert roof.collective_term_s == pytest.approx(3.0)
        assert roof.bottleneck == "collective"
        assert roof.useful_flops_ratio == pytest.approx(0.5)

    def test_model_flops(self):
        assert RL.model_flops(10, 5, "train") == 300
        assert RL.model_flops(10, 5, "decode") == 100


class TestOptim:
    def test_adamw_matches_reference_step(self):
        params = {"w": jnp.asarray([1.0, -2.0])}
        grads = {"w": jnp.asarray([0.1, -0.2])}
        opt = optim.adamw(0.01, b1=0.9, b2=0.999, eps=1e-8)
        st = opt.init(params)
        upd, st = opt.update(grads, st, params)
        # first adam step: update = -lr * sign-ish (mhat/(sqrt(vhat)+eps))
        np.testing.assert_allclose(np.asarray(upd["w"]),
                                   [-0.01, 0.01], rtol=1e-4)

    def test_schedules(self):
        s = optim.warmup_cosine_schedule(1.0, warmup=10, total_steps=110)
        assert float(s(jnp.asarray(0))) == pytest.approx(0.0)
        assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
        assert float(s(jnp.asarray(110))) == pytest.approx(0.1, abs=0.05)

    def test_clip(self):
        g = {"a": jnp.asarray([3.0, 4.0])}
        clipped = optim.clip_by_global_norm(g, 1.0)
        assert float(optim.global_norm(clipped)) == pytest.approx(1.0)


class TestRobustnessExtensions:
    def test_perturb_keeps_unit_norm(self):
        v = jnp.eye(8)[:, :4]
        out = sim.perturb_eigenvectors(v, 0.1, jax.random.PRNGKey(0))
        norms = jnp.linalg.norm(out, axis=0)
        np.testing.assert_allclose(np.asarray(norms), 1.0, atol=1e-5)

    def test_zero_noise_identity(self):
        v = jnp.eye(8)[:, :4]
        out = sim.perturb_eigenvectors(v, 0.0, jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-6)

    def test_subsample_rows(self):
        x = np.random.default_rng(0).standard_normal((100, 8)
                                                     ).astype(np.float32)
        sub = sim.subsample_rows(x, 32, seed=1)
        assert sub.shape == (32, 8)
        assert sim.subsample_rows(x, 200).shape == (100, 8)

    def test_subsampled_gram_close(self):
        rng = np.random.default_rng(0)
        x = rng.standard_normal((2000, 8)).astype(np.float32)
        g_full = np.asarray(sim.gram(jnp.asarray(x)))
        sub = sim.subsample_rows(x, 500, seed=2)
        g_sub = np.asarray(sim.gram(jnp.asarray(sub)))
        assert np.abs(g_full - g_sub).max() < 0.3
