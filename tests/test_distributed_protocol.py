"""Distributed (shard_map) one-shot protocol at 8 devices == single-host
reference — subprocess-isolated so the session keeps 1 real device."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import distributed as dist
    from repro.core import similarity as sim

    rng = np.random.default_rng(0)
    # 16 users over 8 devices (2 per shard)
    feats = jnp.asarray(rng.standard_normal((16, 64, 24)), jnp.float32)
    cfg = sim.SimilarityConfig(top_k=6)
    mesh = dist.make_user_mesh("data")
    assert mesh.devices.size == 8
    r_dist = dist.distributed_similarity(feats, mesh, cfg, axis="data")
    r_ref = sim.similarity_matrix(feats, cfg)
    err = float(jnp.max(jnp.abs(r_dist - r_ref)))
    assert err < 1e-4, err
    print("DIST_PROTOCOL_OK", err)
""")


def test_distributed_similarity_8dev():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "DIST_PROTOCOL_OK" in res.stdout
