"""Manual Megatron TP+SP (shard_map) == auto-sharded reference.

Runs in a SUBPROCESS with 8 placeholder host devices so the main test
session keeps its single real CPU device (the same isolation rule the
dry-run follows).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ArchConfig
    from repro.launch import manual_tp as MT
    from repro.models import transformer as T

    cfg = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                     n_heads=8, n_kv_heads=4, head_dim=8, d_ff=128,
                     vocab=64, qk_norm=True, param_dtype="float32",
                     act_dtype="float32", remat=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    ref = float(T.loss_fn(cfg, params, batch, aux_weight=0.0))
    g_ref = jax.grad(lambda p: T.loss_fn(cfg, p, batch,
                                         aux_weight=0.0))(params)
    loss_fn, pspecs = MT.manual_loss_fn(cfg, mesh)
    with mesh:
        pp = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, P)))
        bb = jax.device_put(batch, NamedSharding(mesh, P(("data",), None)))
        out = float(jax.jit(loss_fn)(pp, bb))
        g = jax.device_get(jax.jit(jax.grad(loss_fn))(pp, bb))
    assert abs(out - ref) < 1e-4, (out, ref)
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        jnp.asarray(a, jnp.float32) - b))), g, g_ref)
    worst = max(jax.tree.leaves(diffs))
    assert worst < 1e-4, worst
    print("MANUAL_TP_OK", out, worst)
""")


def test_manual_tp_matches_auto_8dev():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "MANUAL_TP_OK" in res.stdout
