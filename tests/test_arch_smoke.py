"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import ARCH_IDS, get_arch
from repro.models import encdec
from repro.models.registry import get_model

LM_ARCHS = [a for a in ARCH_IDS if not a.startswith("paper_")]
B, S = 2, 32


def _batch(cfg, m, rng_key=1):
    toks = jax.random.randint(jax.random.PRNGKey(rng_key), (B, S), 0,
                              cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.fuse_patches:
        p = max(1, int(S * cfg.patch_frac))
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, p, cfg.d_model))
        mask = np.zeros((B, S), bool)
        mask[:, :p] = True
        batch["patch_mask"] = jnp.asarray(mask)
    if m.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_id", LM_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, arch_id):
        cfg = get_arch(arch_id, reduced=True)
        assert cfg.n_layers <= 2 and cfg.d_model <= 512
        assert cfg.n_experts <= 4
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        logits, aux = m.forward(params, _batch(cfg, m))
        assert logits.shape == (B, S, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert np.isfinite(float(aux))

    def test_one_train_step_reduces_loss_and_is_finite(self, arch_id):
        cfg = get_arch(arch_id, reduced=True)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, m)
        opt = optim.adamw(3e-3)
        state = opt.init(params)

        @jax.jit
        def step(p, s):
            loss, grads = jax.value_and_grad(lambda q: m.loss_fn(q, batch))(p)
            upd, s = opt.update(grads, s, p)
            return optim.apply_updates(p, upd), s, loss

        losses = []
        for _ in range(5):
            params, state, loss = step(params, state)
            losses.append(float(loss))
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0]

    def test_decode_step_shapes(self, arch_id):
        cfg = get_arch(arch_id, reduced=True)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        if m.is_encdec:
            frames = 0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                             (B, S, cfg.d_model))
            mem = encdec.encode(cfg, params, frames)
            state = encdec.decode_state_from_memory(cfg, params, mem,
                                                    self_len=16)
        else:
            state = m.init_decode_state(B, 64)
        tok = jnp.zeros((B, 1), jnp.int32) + 5
        logits, state2 = m.decode_step(params, tok, state)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(state2["length"]) == 1


@pytest.mark.parametrize("arch_id", ["granite_8b", "qwen3_1_7b",
                                     "rwkv6_1_6b", "recurrentgemma_9b",
                                     "phi3_5_moe", "chameleon_34b"])
def test_decode_matches_prefill(arch_id):
    """KV-cache / recurrent-state decode == teacher-forced prefill.

    MoE archs need drop-free capacity for exact equivalence: the GShard
    dispatch drops overflow tokens in prefill (capacity is per-step in
    decode), which is expected lossy behaviour, not a cache bug.
    """
    cfg = get_arch(arch_id, reduced=True)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.n_experts))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, 16), 0, cfg.vocab)
    full, _ = m.forward(params, {"tokens": toks, "labels": toks})
    state = m.init_decode_state(B, 32)
    outs = []
    for t in range(16):
        lg, state = m.decode_step(params, toks[:, t:t + 1], state)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=5e-3, rtol=1e-3)


def test_swa_variant_decode_matches_window_prefill():
    """The long_500k SWA variant: rolling-cache decode == windowed
    attention prefill."""
    cfg = dataclasses.replace(get_arch("granite_8b", reduced=True),
                              attn_window=8)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(9), (1, 24), 0, cfg.vocab)
    full, _ = m.forward(params, {"tokens": toks, "labels": toks})
    state = m.init_decode_state(1, 24)
    outs = []
    for t in range(24):
        lg, state = m.decode_step(params, toks[:, t:t + 1], state)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=5e-3, rtol=1e-3)


def test_paper_models_smoke():
    from repro.models import cnn, mlp

    ccfg = get_arch("paper_cnn", reduced=True)
    p = cnn.init(ccfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3072))
    logits = cnn.apply(ccfg, p, x)
    assert logits.shape == (4, ccfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()

    mcfg = get_arch("paper_mlp", reduced=True)
    p = mlp.init(mcfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 784))
    logits = mlp.apply(mcfg, p, x)
    assert logits.shape == (4, mcfg.n_classes)
