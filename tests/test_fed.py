"""Federated substrate tests: partition, FedAvg, hierarchy, trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import partition as dpart
from repro.data import synthetic as syn
from repro.fed import client as fclient
from repro.fed.fedavg import fedavg as _fedavg, weighted_mean as _wmean
from repro.fed import hierarchy as hier
from repro.fed import partition as fpart
from repro.fed import trainer as ftrainer
from repro.models import mlp


class TestPartition:
    def setup_method(self):
        self.params = {
            "conv1": {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)},
            "fc": {"w": jnp.ones((4, 3))},
            "head": {"w": jnp.ones((3, 10)), "b": jnp.zeros(10)},
        }

    def test_split_merge_roundtrip(self):
        pred = fpart.prefix_predicate(["conv1"])
        common, spec = fpart.split_params(self.params, pred)
        assert set(common) == {"conv1"}
        assert set(spec) == {"fc", "head"}
        merged = fpart.merge_params(common, spec)
        assert jax.tree.structure(merged) == jax.tree.structure(self.params)

    def test_every_leaf_on_exactly_one_side(self):
        pred = fpart.prefix_predicate(["conv1", "head/w"])
        common, spec = fpart.split_params(self.params, pred)
        n = len(jax.tree.leaves(common)) + len(jax.tree.leaves(spec))
        assert n == len(jax.tree.leaves(self.params))

    def test_merge_rejects_overlap(self):
        with pytest.raises(ValueError):
            fpart.merge_params({"a": jnp.ones(2)}, {"a": jnp.ones(2)})

    def test_tree_paths(self):
        paths = fpart.tree_paths(self.params)
        assert ("conv1", "w") in paths and ("head", "b") in paths


class TestFedAvg:
    def test_weighted_mean_exact(self):
        trees = [{"w": jnp.asarray([2.0])}, {"w": jnp.asarray([6.0])}]
        out = _wmean(trees, [3.0, 1.0])
        assert float(out["w"][0]) == pytest.approx(3.0)

    @given(w1=st.integers(1, 100), w2=st.integers(1, 100))
    @settings(max_examples=20, deadline=None)
    def test_convex_combination_property(self, w1, w2):
        a, b = 1.0, 5.0
        out = _fedavg([{"x": jnp.asarray([a])},
                               {"x": jnp.asarray([b])}], [w1, w2])
        v = float(out["x"][0])
        assert min(a, b) - 1e-5 <= v <= max(a, b) + 1e-5

    def test_identity_when_single_client(self):
        tree = {"w": jnp.arange(4.0)}
        out = _fedavg([tree], [17])
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(tree["w"]))


class TestHierarchy:
    def test_gps_aggregate_only_touches_common(self):
        p1 = {"common": {"w": jnp.asarray([0.0])},
              "task": {"w": jnp.asarray([1.0])}}
        p2 = {"common": {"w": jnp.asarray([2.0])},
              "task": {"w": jnp.asarray([5.0])}}
        pred = fpart.prefix_predicate(["common"])
        out = hier.gps_aggregate([p1, p2], [1.0, 1.0], pred)
        assert float(out[0]["common"]["w"][0]) == pytest.approx(1.0)
        assert float(out[1]["common"]["w"][0]) == pytest.approx(1.0)
        assert float(out[0]["task"]["w"][0]) == pytest.approx(1.0)
        assert float(out[1]["task"]["w"][0]) == pytest.approx(5.0)

    def test_masked_cluster_mean_matches_loop(self):
        rng = np.random.default_rng(0)
        u, t = 6, 2
        vals = {"w": jnp.asarray(rng.standard_normal((u, 3, 4)),
                                 jnp.float32)}
        labels = np.asarray([0, 0, 1, 1, 1, 0])
        weights = jnp.asarray(rng.uniform(1, 10, u), jnp.float32)
        onehot = jnp.asarray(np.eye(t)[labels], jnp.float32)
        out = hier.masked_cluster_mean(vals, onehot, weights)
        for c in range(t):
            idx = labels == c
            w = np.asarray(weights)[idx]
            expected = (np.asarray(vals["w"])[idx]
                        * w[:, None, None]).sum(0) / w.sum()
            np.testing.assert_allclose(np.asarray(out["w"][c]), expected,
                                       rtol=1e-5, atol=1e-5)


class TestClientUpdate:
    def test_local_update_descends(self):
        cfg = mlp.PaperMLPConfig(m=8, hidden=4, n_classes=2)
        params = mlp.init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = rng.standard_normal((64, 8)).astype(np.float32)
        y = (x[:, 0] > 0).astype(np.int32)
        batches = fclient.make_batches(x, y, 16, 20, rng)
        new_p, losses = fclient.local_update(
            params, batches, mlp.loss_fn(cfg),
            fclient.ClientConfig(lr=0.1))
        assert float(losses[-1]) < float(losses[0])


class TestMTHFLTrainer:
    def _setup(self, labels):
        users = dpart.paper_fmnist_three_task(seed=0, scale=0.15)
        tasks = dpart.FMNIST_TASKS
        cc = []
        for t in range(3):
            members = [u for u, l in zip(users, labels) if l == t]
            counts = {}
            for u in members:
                counts[tuple(u.task_classes)] = counts.get(
                    tuple(u.task_classes), 0) + 1
            cc.append(list(max(counts, key=counts.get)) if counts
                      else list(tasks[t]))

        def build(classes):
            cfg = mlp.PaperMLPConfig(m=784, n_classes=len(classes))
            return ftrainer.TaskModel(
                init=lambda k, c=cfg: mlp.init(c, k),
                loss_fn=mlp.loss_fn(cfg),
                accuracy=lambda p, x, y, c=cfg: mlp.accuracy(c, p, x, y),
                is_common=fpart.prefix_predicate(mlp.COMMON_PREFIXES))

        models = [build(c) for c in cc]
        evals = []
        for c in cc:
            task_id = [k for k, v in tasks.items()
                       if set(v) == set(c)][0]
            x, y = syn.make_task_dataset(
                syn.FMNIST_LIKE, list(c), 40, seed=99,
                task_of_class={cl: task_id for cl in c})
            lut = {cl: i for i, cl in enumerate(c)}
            evals.append((jnp.asarray(x), np.asarray(
                [lut[int(v)] for v in y], np.int32)))
        return users, models, evals, cc

    def test_oracle_clustering_learns_all_tasks(self):
        users = dpart.paper_fmnist_three_task(seed=0, scale=0.15)
        labels = clu.oracle_clusters([u.task_id for u in users])
        users, models, evals, cc = self._setup(labels)
        cfg = ftrainer.MTHFLConfig(global_rounds=6, local_rounds=1,
                                   local_steps=12, batch_size=32,
                                   client=fclient.ClientConfig(
                                       lr=0.05, optimizer="momentum"))
        hist = ftrainer.train_mthfl(users, labels, models, evals, cfg,
                                    cluster_classes=cc)
        assert hist.accuracy.shape == (6, 3)
        assert hist.accuracy[-1].min() > 0.6
        assert hist.accuracy[-1].mean() > 0.75

    def test_history_finite(self):
        users = dpart.paper_fmnist_three_task(seed=0, scale=0.15)
        labels = clu.random_clusters(len(users), 3, rng=0)
        users, models, evals, cc = self._setup(labels)
        cfg = ftrainer.MTHFLConfig(global_rounds=2, local_rounds=1,
                                   local_steps=5, batch_size=16)
        hist = ftrainer.train_mthfl(users, labels, models, evals, cfg,
                                    cluster_classes=cc)
        assert np.isfinite(hist.accuracy).all()
        assert np.isfinite(hist.train_loss).all()


class TestDistributedProtocol:
    def test_shard_map_matches_single_host(self):
        """The shard_map collective protocol == the single-host reference
        (runs on a 1-device mesh on CPU; the dry-run exercises 512)."""
        from repro.core import distributed as dist
        from repro.core import similarity as sim

        rng = np.random.default_rng(0)
        feats = jnp.asarray(rng.standard_normal((4, 64, 16)), jnp.float32)
        cfg = SimilarityConfig(top_k=8)
        mesh = dist.make_user_mesh("data")
        r_dist = dist.distributed_similarity(feats, mesh, cfg, axis="data")
        r_ref = sim.similarity_matrix(feats, cfg)
        np.testing.assert_allclose(np.asarray(r_dist), np.asarray(r_ref),
                                   rtol=1e-4, atol=1e-4)
