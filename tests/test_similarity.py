"""Unit + property tests for the similarity protocol (paper Eqs. 1-5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import similarity as sim

jax.config.update("jax_enable_x64", False)


def _feats(rng, n=64, d=16, scale=1.0):
    return jnp.asarray(rng.standard_normal((n, d)) * scale, jnp.float32)


class TestGram:
    def test_gram_matches_definition(self, rng):
        f = _feats(rng)
        g = sim.gram(f)
        expected = np.asarray(f).T @ np.asarray(f) / f.shape[0]
        np.testing.assert_allclose(np.asarray(g), expected, rtol=1e-5,
                                   atol=1e-5)

    def test_gram_psd(self, rng):
        g = sim.gram(_feats(rng))
        eig = np.linalg.eigvalsh(np.asarray(g))
        assert eig.min() > -1e-4

    def test_gram_ragged_n_valid(self, rng):
        f = np.asarray(_feats(rng, n=32))
        padded = np.zeros((64, f.shape[1]), np.float32)
        padded[:32] = f
        g_pad = sim.gram(jnp.asarray(padded), n_valid=32)
        g_true = sim.gram(jnp.asarray(f))
        np.testing.assert_allclose(np.asarray(g_pad), np.asarray(g_true),
                                   rtol=1e-5, atol=1e-5)


class TestSpectrum:
    def test_descending_order_topk(self, rng):
        g = sim.gram(_feats(rng, n=128, d=24))
        lam, v = sim.spectrum(g, top_k=8)
        assert lam.shape == (8,) and v.shape == (24, 8)
        lam_np = np.asarray(lam)
        assert (np.diff(lam_np) <= 1e-6).all()
        assert (lam_np >= 0).all()

    def test_eigen_equation(self, rng):
        g = sim.gram(_feats(rng, d=12))
        lam, v = sim.spectrum(g)
        gv = np.asarray(g) @ np.asarray(v)
        lv = np.asarray(v) * np.asarray(lam)[None, :]
        np.testing.assert_allclose(gv, lv, atol=1e-4)


class TestRelevance:
    def test_self_relevance_is_one(self, rng):
        """r(i, i) = 1: projecting your own eigenvectors recovers your own
        eigenvalues exactly (paper Eq. 2-4)."""
        f = _feats(rng, n=128, d=16)
        lam, v, g = sim.user_signature(f, sim.SimilarityConfig(top_k=8))
        lam_hat = sim.cross_project(g, v)
        r = sim.relevance(lam, lam_hat)
        assert abs(float(r) - 1.0) < 1e-4

    def test_range(self, rng):
        for i in range(5):
            f1 = _feats(rng, scale=1.0 + i)
            f2 = _feats(rng, scale=3.0 - i * 0.5)
            l1, v1, g1 = sim.user_signature(f1, sim.SimilarityConfig(top_k=4))
            lam_hat = sim.cross_project(g1, sim.user_signature(
                f2, sim.SimilarityConfig(top_k=4))[1])
            r = float(sim.relevance(l1, lam_hat))
            assert 0.0 < r <= 1.0 + 1e-6

    @given(scale=st.floats(0.1, 10.0))
    @settings(max_examples=20, deadline=None)
    def test_relevance_bounded_property(self, scale):
        """Property: relevance in (0, 1] for arbitrary PSD pairs."""
        rng = np.random.default_rng(int(scale * 1000))
        lam = jnp.asarray(np.abs(rng.standard_normal(8)) * scale + 1e-6)
        lam_hat = jnp.asarray(np.abs(rng.standard_normal(8)) + 1e-6)
        r = float(sim.relevance(lam, lam_hat))
        assert 0.0 < r <= 1.0 + 1e-6

    def test_eig_floor_guards_tiny_eigenvalues(self):
        """Paper §III: one tiny eigenvalue must not zero out the product."""
        lam = jnp.asarray([1.0, 1.0, 1.0, 1e-12])
        lam_hat = jnp.asarray([1.0, 1.0, 1.0, 1.0])
        r_floored = float(sim.relevance(lam, lam_hat, eig_floor=1e-6))
        r_raw = float(sim.relevance(lam, lam_hat, eig_floor=1e-30))
        assert r_floored > 0.02 > r_raw


class TestSimilarityMatrix:
    def test_symmetric_unit_diag(self, rng):
        feats = jnp.asarray(rng.standard_normal((6, 64, 16)), jnp.float32)
        r = sim.similarity_matrix(feats, sim.SimilarityConfig(top_k=8))
        r_np = np.asarray(r)
        np.testing.assert_allclose(r_np, r_np.T, atol=1e-5)
        np.testing.assert_allclose(np.diag(r_np), 1.0, atol=1e-4)

    def test_same_distribution_scores_higher(self, rng):
        """Block structure: same-task users >> cross-task users (Table I)."""
        basis_a = np.linalg.qr(rng.standard_normal((16, 4)))[0]
        basis_b = np.linalg.qr(rng.standard_normal((16, 4)))[0]
        users = []
        for basis in (basis_a, basis_a, basis_b, basis_b):
            z = rng.standard_normal((128, 4)).astype(np.float32)
            users.append(z @ basis.T.astype(np.float32)
                         + 0.05 * rng.standard_normal((128, 16)
                                                      ).astype(np.float32))
        r = np.asarray(sim.similarity_matrix(
            jnp.asarray(np.stack(users)), sim.SimilarityConfig(top_k=4)))
        in_task = (r[0, 1] + r[2, 3]) / 2
        cross = (r[0, 2] + r[0, 3] + r[1, 2] + r[1, 3]) / 4
        assert in_task > cross + 0.2

    def test_permutation_equivariance(self, rng):
        feats = rng.standard_normal((5, 64, 12)).astype(np.float32)
        cfg = sim.SimilarityConfig(top_k=6)
        r = np.asarray(sim.similarity_matrix(jnp.asarray(feats), cfg))
        perm = np.asarray([3, 1, 4, 0, 2])
        r_perm = np.asarray(sim.similarity_matrix(jnp.asarray(feats[perm]),
                                                  cfg))
        np.testing.assert_allclose(r_perm, r[np.ix_(perm, perm)], atol=1e-4)

    def test_rotation_invariance_of_self_block(self, rng):
        """Relevance depends on spectra: rotating the feature space of ALL
        users jointly leaves R unchanged."""
        feats = rng.standard_normal((4, 96, 12)).astype(np.float32)
        q = np.linalg.qr(rng.standard_normal((12, 12)))[0].astype(np.float32)
        cfg = sim.SimilarityConfig(top_k=6)
        r1 = np.asarray(sim.similarity_matrix(jnp.asarray(feats), cfg))
        r2 = np.asarray(sim.similarity_matrix(jnp.asarray(feats @ q), cfg))
        np.testing.assert_allclose(r1, r2, atol=5e-3)

    def test_ragged_list_input(self, rng):
        feats = [rng.standard_normal((n, 10)).astype(np.float32)
                 for n in (50, 80, 64)]
        r = sim.similarity_matrix(feats, sim.SimilarityConfig(top_k=4))
        assert r.shape == (3, 3)
        assert np.isfinite(np.asarray(r)).all()
