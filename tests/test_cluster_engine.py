"""ClusterEngine: device NN-chain HAC == numpy reference (paper §II-C).

The GPS decision layer must produce the SAME dendrogram cut whether it
runs the host reference (greedy full-matrix argmax) or the device
NN-chain ``lax.while_loop`` (jnp / pallas fused inner step) — up to
cluster relabelling and tie order.  Also guards the dendrogram
invariants the §II-C cut relies on: monotone heights per linkage, cut
edge cases, tie-order determinism, and the input validation added to
``core/clustering.py``.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.cluster_engine import (CLUSTER_BACKENDS, ClusterConfig,
                                       ClusterEngine, DeviceDendrogram)
from repro.core.similarity import SimilarityConfig

LINKAGES = ("average", "single", "complete")


def rand_sim(n, seed):
    rng = np.random.default_rng(seed)
    r = rng.uniform(0, 1, (n, n))
    r = (r + r.T) / 2
    np.fill_diagonal(r, 1.0)
    return r


def block_sim(sizes, seed=0, noise=0.02):
    rng = np.random.default_rng(seed)
    n = sum(sizes)
    lab = np.repeat(np.arange(len(sizes)), sizes)
    r = np.where(lab[:, None] == lab[None, :], 0.9, 0.2)
    r = r + rng.uniform(-noise, noise, size=(n, n))
    r = (r + r.T) / 2
    np.fill_diagonal(r, 1.0)
    return r, lab


def same_partition(a, b):
    return clu.adjusted_rand_index(np.asarray(a), np.asarray(b)) == \
        pytest.approx(1.0)


class TestDeviceParity:
    """jnp / pallas NN-chain labels == numpy greedy HAC labels."""

    @pytest.mark.parametrize("linkage", LINKAGES)
    @given(n=st.integers(4, 24), seed=st.integers(0, 3))
    @settings(max_examples=8, deadline=None)
    def test_jnp_matches_numpy_random(self, linkage, n, seed):
        r = rand_sim(n, seed)
        for t in (1, 2, max(2, n // 3), n):
            ref = clu.hac_clusters(r, t, linkage)
            dev = ClusterEngine(ClusterConfig(
                backend="jnp", linkage=linkage)).labels(r, t)
            assert isinstance(dev, jax.Array)
            assert same_partition(dev, ref), (linkage, n, seed, t)

    @pytest.mark.parametrize("linkage", LINKAGES)
    def test_pallas_matches_numpy(self, linkage):
        r = rand_sim(17, 2)
        ref = clu.hac_clusters(r, 3, linkage)
        dev = ClusterEngine(ClusterConfig(
            backend="pallas", linkage=linkage)).labels(r, 3)
        assert same_partition(dev, ref)

    def test_parity_on_tied_matrix(self):
        """Exact ties everywhere inside/across blocks: any tie order must
        still cut into the block partition."""
        r, true = block_sim([4, 4, 3], noise=0.0)
        for backend in ("jnp", "pallas"):
            dev = ClusterEngine(ClusterConfig(backend=backend)).labels(r, 3)
            assert same_partition(dev, true)

    def test_parity_on_ragged_protocol_output(self):
        """End-to-end through the ProtocolEngine on RAGGED per-user
        features (pad_ragged path): numpy and jnp cluster backends agree
        on the labels of the real (unpadded) users."""
        rng = np.random.default_rng(0)
        base = [rng.standard_normal((8, 8)) @ rng.standard_normal((8, 16))
                for _ in range(3)]
        feats = [np.asarray(base[i % 3][: 5 + (i % 4)] +
                            0.05 * rng.standard_normal((5 + (i % 4), 16)),
                            np.float32)
                 for i in range(9)]
        res_np = oneshot.one_shot_clustering(
            feats, 3, cfg=SimilarityConfig(top_k=4),
            cluster_cfg=ClusterConfig(backend="numpy"))
        res_dev = oneshot.one_shot_clustering(
            feats, 3, cfg=SimilarityConfig(top_k=4),
            cluster_cfg=ClusterConfig(backend="jnp"))
        assert isinstance(res_dev.labels, jax.Array)
        assert same_partition(res_dev.labels, res_np.labels)

    def test_device_labels_stay_on_device(self):
        """The jnp backend's R, dendrogram and labels are jax arrays —
        no host round-trip between protocol and trainer."""
        rng = np.random.default_rng(1)
        feats = jnp.asarray(rng.standard_normal((6, 10, 8)), jnp.float32)
        res = oneshot.one_shot_clustering(
            feats, 2, cfg=SimilarityConfig(top_k=4),
            cluster_cfg=ClusterConfig(backend="jnp"))
        assert isinstance(res.similarity, jax.Array)
        assert isinstance(res.labels, jax.Array)
        assert isinstance(res.dendrogram, DeviceDendrogram)


class TestDendrogramInvariants:
    @pytest.mark.parametrize("linkage", LINKAGES)
    def test_heights_monotone_numpy(self, linkage):
        r = rand_sim(20, 4)
        h = clu.hac(r, linkage).heights()
        assert np.all(np.diff(h) <= 1e-9), linkage

    @pytest.mark.parametrize("linkage", LINKAGES)
    def test_device_to_host_heights_match_greedy(self, linkage):
        r = rand_sim(18, 5)
        ref = clu.hac(r, linkage)
        dd = ClusterEngine(ClusterConfig(backend="jnp",
                                         linkage=linkage)).hac(r)
        host = dd.to_host()
        assert np.all(np.diff(host.heights()) <= 1e-6)
        assert np.allclose(np.sort(host.heights()),
                           np.sort(ref.heights()), atol=1e-5)

    @pytest.mark.parametrize("backend", ["numpy", "jnp"])
    def test_cut_extremes(self, backend):
        r = rand_sim(9, 0)
        eng = ClusterEngine(ClusterConfig(backend=backend))
        ones = np.asarray(eng.labels(r, 1))
        assert len(np.unique(ones)) == 1
        singletons = np.asarray(eng.labels(r, 9))
        assert len(np.unique(singletons)) == 9

    def test_cut_label_range(self):
        r = rand_sim(11, 3)
        for t in range(1, 12):
            lab = np.asarray(
                ClusterEngine(ClusterConfig(backend="jnp")).labels(r, t))
            assert lab.shape == (11,)
            assert set(np.unique(lab)) == set(range(t))

    def test_tie_order_determinism(self):
        """Same tied input twice -> bitwise-identical labels, host and
        device (no RNG, stable argmax/argsort tie-breaks)."""
        r, _ = block_sim([5, 5], noise=0.0)
        assert (clu.hac_clusters(r, 2) == clu.hac_clusters(r, 2)).all()
        eng = ClusterEngine(ClusterConfig(backend="jnp"))
        a = np.asarray(eng.labels(r, 2))
        b = np.asarray(eng.labels(r, 2))
        assert (a == b).all()

    def test_device_cut_out_of_range_raises(self):
        eng = ClusterEngine(ClusterConfig(backend="jnp"))
        dend = eng.hac(rand_sim(6, 0))
        with pytest.raises(ValueError, match="n_clusters"):
            eng.cut(dend, 0)
        with pytest.raises(ValueError, match="n_clusters"):
            eng.cut(dend, 7)


class TestValidation:
    def test_bad_backend_raises(self):
        with pytest.raises(ValueError, match="backend"):
            ClusterEngine(ClusterConfig(backend="torch"))

    def test_bad_linkage_raises(self):
        with pytest.raises(ValueError, match="linkage"):
            ClusterEngine(ClusterConfig(linkage="ward"))

    def test_hac_rejects_nan(self):
        r = rand_sim(6, 0)
        r[2, 3] = r[3, 2] = np.nan
        with pytest.raises(ValueError, match="NaN"):
            clu.hac(r)

    def test_hac_rejects_non_square(self):
        with pytest.raises(ValueError, match="square"):
            clu.hac(np.ones((4, 5)))

    def test_hac_rejects_asymmetric(self):
        r = rand_sim(6, 0)
        r[1, 4] += 0.5
        with pytest.raises(ValueError, match="symmetric"):
            clu.hac(r)

    def test_conflicting_linkage_args_raise(self):
        rng = np.random.default_rng(0)
        feats = jnp.asarray(rng.standard_normal((4, 6, 8)), jnp.float32)
        with pytest.raises(ValueError, match="conflicting linkages"):
            oneshot.one_shot_clustering(
                feats, 2, cfg=SimilarityConfig(top_k=4), linkage="single",
                cluster_cfg=ClusterConfig(backend="jnp"))

    def test_spectral_rejects_bad_n_clusters(self):
        r = rand_sim(6, 0)
        with pytest.raises(ValueError, match="n_clusters"):
            clu.spectral_clusters(r, 0)
        with pytest.raises(ValueError, match="n_clusters"):
            clu.spectral_clusters(r, 7)

    def test_engine_rejects_non_square_device(self):
        eng = ClusterEngine(ClusterConfig(backend="jnp"))
        with pytest.raises(ValueError, match="square"):
            eng.hac(np.ones((4, 5), np.float32))

    def test_device_hac_rejects_nan_via_step_count(self):
        """The device path skips value validation, but NaN stalls the
        NN-chain and the completion check must turn that into an error
        instead of a silently truncated dendrogram."""
        r = rand_sim(8, 0)
        r[2, 5] = r[5, 2] = np.nan
        eng = ClusterEngine(ClusterConfig(backend="jnp"))
        with pytest.raises(ValueError, match="NaN"):
            eng.hac(r)


class TestSpectralBackend:
    def test_jnp_spectral_recovers_blocks(self):
        r, true = block_sim([6, 6], seed=5)
        lab = ClusterEngine(ClusterConfig(backend="jnp")).spectral(r, 2,
                                                                   rng=0)
        assert isinstance(lab, jax.Array)
        assert same_partition(lab, true)

    def test_jnp_spectral_deterministic(self):
        r, _ = block_sim([5, 4, 3], seed=2)
        eng = ClusterEngine(ClusterConfig(backend="jnp"))
        a = np.asarray(eng.spectral(r, 3, rng=7))
        b = np.asarray(eng.spectral(r, 3, rng=7))
        assert (a == b).all()

    def test_numpy_backend_delegates(self):
        r, true = block_sim([6, 6], seed=5)
        lab = ClusterEngine(ClusterConfig(backend="numpy")).spectral(
            r, 2, rng=0)
        assert isinstance(lab, np.ndarray)
        assert same_partition(lab, true)

    def test_jnp_spectral_validates(self):
        eng = ClusterEngine(ClusterConfig(backend="jnp"))
        with pytest.raises(ValueError, match="n_clusters"):
            eng.spectral(rand_sim(5, 0), 9)


class TestTrainerConsumesDeviceLabels:
    def test_stack_layout_matches_host_loop(self):
        from repro.fed import partition as fpart

        labels = jnp.asarray([0, 2, 1, 0, 2, 2, 0], jnp.int32)
        rows, slot, mask = fpart.stack_layout(labels, 3)
        slot = np.asarray(slot)
        mask = np.asarray(mask)
        assert np.asarray(rows).tolist() == labels.tolist()
        # original user order preserved inside each cluster row
        assert slot.tolist() == [0, 0, 0, 1, 1, 2, 2]
        assert mask.shape == (3, 3)
        assert mask.sum() == 7
        assert (mask[0] == [1, 1, 1]).all()
        assert (mask[1] == [1, 0, 0]).all()

    def test_stack_layout_empty_cluster(self):
        from repro.fed import partition as fpart

        _, _, mask = fpart.stack_layout(jnp.asarray([0, 0, 2]), 3)
        assert np.asarray(mask)[1].sum() == 0

    def test_stack_layout_rejects_undersized_c_max(self):
        from repro.fed import partition as fpart

        with pytest.raises(ValueError, match="c_max"):
            fpart.stack_layout(jnp.asarray([0, 0, 0, 1]), 2, c_max=2)

    def test_stack_layout_drops_out_of_range_labels(self):
        """-1 (unassigned) and >= T labels must be dropped, not wrapped
        into cluster T-1 by jnp's negative indexing."""
        from repro.fed import partition as fpart

        labels = jnp.asarray([0, -1, 2, 0, 2, 3], jnp.int32)
        rows, slot, mask = fpart.stack_layout(labels, 3)
        mask = np.asarray(mask)
        assert mask.sum() == 4                      # only the valid four
        assert (mask[2] == [1, 1]).all()            # cluster 2 intact
        # scattering payloads through (rows, slot) drops the invalid users
        vals = jnp.zeros((3, mask.shape[1]), jnp.int32).at[rows, slot].set(
            jnp.asarray([10, 11, 12, 13, 14, 15], jnp.int32))
        assert np.asarray(vals)[2].tolist() == [12, 14]

    def test_backends_available(self):
        assert CLUSTER_BACKENDS == ("numpy", "jnp", "pallas")
