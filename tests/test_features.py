"""Feature-map substrate: the jnp Phi ports match the numpy reference,
every Phi kind is deterministic in the seed ACROSS PROCESSES (the
protocol requires all users to apply the same map), and ``FeatureConfig``
is a well-behaved hashable config (no raw probe array on it)."""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import features as feat
from repro.data import tokens as tok

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _probe(rng):
    return rng.standard_normal((60, 48)).astype(np.float32)


class TestPhiPortParity:
    """phi_params + phi_apply (the jit-able device path) == feature_map
    (the numpy reference) for every Phi kind."""

    @pytest.mark.parametrize("kind,kwargs,m", [
        ("identity", {}, 33),
        ("random_projection", {"d": 16}, 48),
        ("random_conv", {"d": 32, "image_hw": (8, 8, 3)}, 8 * 8 * 3),
        ("random_conv", {"d": 2048, "image_hw": (8, 8, 3)}, 8 * 8 * 3),
    ])
    def test_matches_numpy_reference(self, rng, kind, kwargs, m):
        x = rng.standard_normal((12, m)).astype(np.float32)
        cfg = feat.FeatureConfig(kind=kind, **kwargs)
        ref = feat.feature_map(x, cfg)
        out = np.asarray(feat.phi_apply(jnp.asarray(x),
                                        feat.phi_params(cfg, m), cfg))
        assert ref.shape == out.shape == (12, feat.phi_out_dim(cfg, m))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_pca_matches_numpy_reference(self, rng):
        probe = _probe(rng)
        x = rng.standard_normal((12, 48)).astype(np.float32)
        cfg = feat.FeatureConfig(kind="pca", d=8)
        ref = feat.feature_map(x, cfg, probe=probe)
        params = feat.phi_params(cfg, 48, probe=probe)
        out = np.asarray(feat.phi_apply(jnp.asarray(x), params, cfg))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_vmap_over_users(self, rng):
        import jax

        x = rng.standard_normal((5, 10, 48)).astype(np.float32)
        cfg = feat.FeatureConfig(kind="random_projection", d=16)
        params = feat.phi_params(cfg, 48)
        batched = np.asarray(jax.vmap(
            lambda xc: feat.phi_apply(xc, params, cfg))(jnp.asarray(x)))
        for i in range(5):
            np.testing.assert_allclose(batched[i],
                                       feat.feature_map(x[i], cfg),
                                       rtol=1e-4, atol=1e-4)


class TestFeatureConfigHygiene:
    """The satellite fix: frozen config must hash/compare cleanly."""

    def test_hashable_and_comparable(self):
        a = feat.FeatureConfig(kind="random_projection", d=16, seed=3)
        b = feat.FeatureConfig(kind="random_projection", d=16, seed=3)
        assert a == b and hash(a) == hash(b)
        assert {a: "x"}[b] == "x"                  # usable as a cache key
        assert a != dataclasses.replace(a, seed=4)

    def test_probe_rides_as_digest(self, rng):
        probe = _probe(rng)
        a = feat.FeatureConfig(kind="pca", d=8).bind_probe(probe)
        b = feat.FeatureConfig(kind="pca", d=8).bind_probe(probe.copy())
        assert a == b and hash(a) == hash(b)
        assert a.probe_digest == feat.probe_digest(probe)

    def test_probe_digest_mismatch_raises(self, rng):
        probe = _probe(rng)
        cfg = feat.FeatureConfig(kind="pca", d=8).bind_probe(probe)
        other = probe + 1.0
        with pytest.raises(ValueError, match="digest"):
            feat.phi_params(cfg, 48, probe=other)

    def test_pca_without_probe_raises(self, rng):
        x = rng.standard_normal((10, 48)).astype(np.float32)
        cfg = feat.FeatureConfig(kind="pca", d=8)
        with pytest.raises(ValueError, match="probe"):
            feat.feature_map(x, cfg)
        with pytest.raises(ValueError, match="probe"):
            feat.phi_params(cfg, 48)

    def test_validation_errors(self):
        with pytest.raises(ValueError, match="kind"):
            feat.FeatureConfig(kind="resnet")
        with pytest.raises(ValueError, match="positive"):
            feat.FeatureConfig(d=0)
        with pytest.raises(ValueError, match="image_hw"):
            feat.FeatureConfig(kind="random_conv")

    def test_d_exceeding_m_raises(self, rng):
        x = rng.standard_normal((10, 12)).astype(np.float32)
        with pytest.raises(ValueError, match="exceeds"):
            feat.feature_map(x, feat.FeatureConfig(kind="random_projection",
                                                   d=64))
        with pytest.raises(ValueError, match="exceeds"):
            feat.phi_params(feat.FeatureConfig(kind="pca", d=64), 12,
                            probe=x)


DETERMINISM_SCRIPT = textwrap.dedent("""
    import hashlib
    import numpy as np
    import jax.numpy as jnp
    from repro.data import features as feat

    rng = np.random.default_rng(123)
    x = rng.standard_normal((12, 48)).astype(np.float32)
    x_img = rng.standard_normal((12, 8 * 8 * 3)).astype(np.float32)
    probe = rng.standard_normal((60, 48)).astype(np.float32)
    parts = []
    for cfg, xx, pr in [
        (feat.FeatureConfig(kind="identity"), x, None),
        (feat.FeatureConfig(kind="random_projection", d=16, seed=5), x,
         None),
        (feat.FeatureConfig(kind="random_conv", d=32, image_hw=(8, 8, 3),
                            seed=5), x_img, None),
        (feat.FeatureConfig(kind="pca", d=8, seed=5), x, probe),
    ]:
        ref = feat.feature_map(xx, cfg, probe=pr)
        params = feat.phi_params(cfg, xx.shape[1], probe=pr)
        dev = np.asarray(feat.phi_apply(jnp.asarray(xx), params, cfg))
        parts.append(hashlib.sha256(ref.tobytes()).hexdigest())
        parts.append(hashlib.sha256(dev.tobytes()).hexdigest())
    print("|".join(parts))
""")


def _run_determinism_child() -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", DETERMINISM_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    return res.stdout.strip().splitlines()[-1]


def test_phi_deterministic_across_processes():
    """Every backend (numpy reference AND jnp port) of every Phi kind
    produces bit-identical features for the same ``FeatureConfig.seed``
    in two separate processes — Phi is genuinely shared, with no hidden
    process-local state."""
    a = _run_determinism_child()
    b = _run_determinism_child()
    assert a == b
    assert len(a.split("|")) == 8


class TestTokenSubstrate:
    """The token data substrate stays deterministic and well-shaped (it
    feeds the LM-architecture protocol path)."""

    def test_sample_tokens_deterministic(self):
        spec = tok.TokenTaskSpec(vocab=32, seed=1)
        a = tok.sample_tokens(spec, 64, seed=3)
        b = tok.sample_tokens(spec, 64, seed=3)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (64,) and a.min() >= 0 and a.max() < 32

    def test_token_features_shape(self):
        spec = tok.TokenTaskSpec(vocab=32, seed=1)
        toks = tok.sample_tokens(spec, 129, seed=0)
        f = tok.token_features(toks, d=16, window=8, vocab=32)
        assert f.shape == (128 // 8, 16)
        assert np.isfinite(f).all()

    def test_batch_iterator_yields_lm_batches(self):
        it = tok.token_batch_iterator(tok.TokenTaskSpec(vocab=16, seed=2),
                                      batch=2, seq_len=8)
        batch = next(it)
        assert batch["tokens"].shape == (2, 8)
        assert batch["labels"].shape == (2, 8)
        np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                      batch["labels"][:, :-1])
