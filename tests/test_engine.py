"""Backend parity for the ProtocolEngine (the ISSUE's acceptance tests).

Single-host dense vs Pallas vs blockwise-streaming vs shard_map must all
produce the same R matrix (1e-5) and identical HAC labels on a seeded
synthetic task mixture; shard_map is additionally exercised at 4 forced
host devices in a subprocess (jax locks the device count on first init).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clustering as clu
from repro.core import oneshot
from repro.core import similarity as sim
from repro.core.engine import ProtocolEngine
from repro.data import synthetic as syn

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(scope="module")
def mixture():
    feats, task_ids = syn.make_task_feature_mixture(
        n_users=24, n_samples=48, d=16, n_tasks=3, seed=7)
    return jnp.asarray(feats), task_ids


@pytest.fixture(scope="module")
def dense_r(mixture):
    feats, _ = mixture
    return np.asarray(ProtocolEngine(
        sim.SimilarityConfig(top_k=6)).similarity(feats))


class TestBackendParity:
    @pytest.mark.parametrize("block", [5, 8, 24, 64])
    def test_blockwise_matches_dense(self, mixture, dense_r, block):
        feats, task_ids = mixture
        cfg = sim.SimilarityConfig(top_k=6, block_users=block)
        r_blk = np.asarray(ProtocolEngine(cfg).similarity(feats))
        np.testing.assert_allclose(r_blk, dense_r, atol=1e-5)
        assert (clu.hac_clusters(r_blk, 3) ==
                clu.hac_clusters(dense_r, 3)).all()

    def test_pallas_backend_matches_dense(self, mixture, dense_r):
        feats, _ = mixture
        cfg = sim.SimilarityConfig(top_k=6, backend="pallas")
        r_p = np.asarray(ProtocolEngine(cfg).similarity(feats))
        np.testing.assert_allclose(r_p, dense_r, atol=1e-5)

    def test_pallas_blockwise_matches_dense(self, mixture, dense_r):
        feats, _ = mixture
        cfg = sim.SimilarityConfig(top_k=6, backend="pallas", block_users=7)
        r_pb = np.asarray(ProtocolEngine(cfg).similarity(feats))
        np.testing.assert_allclose(r_pb, dense_r, atol=1e-5)

    def test_shard_map_matches_dense_1dev(self, mixture, dense_r):
        feats, _ = mixture
        cfg = sim.SimilarityConfig(top_k=6, backend="shard_map")
        r_s = np.asarray(ProtocolEngine(cfg).similarity(feats))
        np.testing.assert_allclose(r_s, dense_r, atol=1e-5)

    def test_blockwise_ragged_matches_dense_ragged(self):
        rng = np.random.default_rng(3)
        ragged = [rng.standard_normal((n, 12)).astype(np.float32)
                  for n in (50, 21, 64, 33, 40)]
        cfg = sim.SimilarityConfig(top_k=4)
        r_dense = np.asarray(ProtocolEngine(cfg).similarity(ragged))
        r_blk = np.asarray(ProtocolEngine(
            dataclasses.replace(cfg, block_users=2)).similarity(ragged))
        np.testing.assert_allclose(r_blk, r_dense, atol=1e-5)

    def test_top_k_larger_than_d(self):
        """top_k > d must clamp to d on every backend (a Gram only has d
        eigenpairs) — regression: blockwise used the raw top_k to reshape."""
        rng = np.random.default_rng(9)
        feats = jnp.asarray(rng.standard_normal((6, 32, 4)), jnp.float32)
        cfg = sim.SimilarityConfig(top_k=8)        # d = 4
        r_dense = np.asarray(ProtocolEngine(cfg).similarity(feats))
        r_blk = np.asarray(ProtocolEngine(
            dataclasses.replace(cfg, block_users=3)).similarity(feats))
        np.testing.assert_allclose(r_blk, r_dense, atol=1e-5)
        res = ProtocolEngine(cfg).run(feats)
        assert res.top_k == 4

    def test_recovers_tasks_at_odd_block(self, mixture):
        feats, task_ids = mixture
        cfg = sim.SimilarityConfig(top_k=6, block_users=7)  # 24 % 7 != 0
        res = oneshot.one_shot_clustering(feats, n_clusters=3, cfg=cfg)
        assert clu.clustering_accuracy(res.labels, task_ids) == 1.0


class TestEngineApi:
    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            ProtocolEngine(sim.SimilarityConfig(backend="cuda"))

    def test_blockwise_shard_map_rejected(self):
        with pytest.raises(ValueError, match="single-host"):
            ProtocolEngine(sim.SimilarityConfig(backend="shard_map",
                                                block_users=8))

    def test_signatures_rejects_non_dense_configs(self, mixture):
        feats, _ = mixture
        for cfg in (sim.SimilarityConfig(block_users=8),
                    sim.SimilarityConfig(backend="shard_map")):
            with pytest.raises(ValueError, match="dense"):
                ProtocolEngine(cfg).signatures(feats)

    def test_ragged_with_n_valid_rejected(self, mixture):
        eng = ProtocolEngine()
        with pytest.raises(ValueError, match="ragged"):
            eng.prepare([np.zeros((4, 3), np.float32)],
                        n_valid=jnp.ones((1,)))

    def test_run_reports_dims(self, mixture):
        feats, _ = mixture
        res = ProtocolEngine(sim.SimilarityConfig(top_k=6)).run(feats)
        assert (res.n_users, res.d, res.top_k) == (24, 16, 6)
        assert res.similarity.shape == (24, 24)
        np.testing.assert_allclose(np.asarray(res.similarity),
                                   np.asarray(sim.symmetrize(res.relevance)),
                                   atol=1e-6)

    def test_oneshot_respects_n_valid(self):
        """Padded-array input must honour true counts (seed dropped them)."""
        rng = np.random.default_rng(5)
        ragged = [rng.standard_normal((n, 8)).astype(np.float32)
                  for n in (30, 17, 25)]
        res_list = oneshot.one_shot_clustering(
            ragged, 2, cfg=sim.SimilarityConfig(top_k=4))
        padded, nv = sim.pad_ragged(ragged)
        res_pad = oneshot.one_shot_clustering(
            padded, 2, cfg=sim.SimilarityConfig(top_k=4), n_valid=nv)
        np.testing.assert_allclose(res_pad.similarity, res_list.similarity,
                                   atol=1e-6)

    def test_similarity_matrix_routes_through_engine(self, mixture,
                                                     dense_r):
        feats, _ = mixture
        r = np.asarray(sim.similarity_matrix(
            feats, sim.SimilarityConfig(top_k=6, block_users=9)))
        np.testing.assert_allclose(r, dense_r, atol=1e-5)


SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import clustering as clu
    from repro.core import similarity as sim
    from repro.core.engine import ProtocolEngine
    from repro.data import synthetic as syn

    feats, task_ids = syn.make_task_feature_mixture(
        n_users=24, n_samples=48, d=16, n_tasks=3, seed=7)
    feats = jnp.asarray(feats)
    cfg = sim.SimilarityConfig(top_k=6)
    r_ref = np.asarray(ProtocolEngine(cfg).similarity(feats))
    r_blk = np.asarray(ProtocolEngine(
        sim.SimilarityConfig(top_k=6, block_users=5)).similarity(feats))
    r_dist = np.asarray(ProtocolEngine(
        sim.SimilarityConfig(top_k=6, backend="shard_map")).similarity(feats))
    assert len(jax.devices()) == 4
    for name, r in (("shard_map", r_dist), ("blockwise", r_blk)):
        err = float(np.abs(r - r_ref).max())
        assert err < 1e-5, (name, err)
        assert (clu.hac_clusters(r, 3) == clu.hac_clusters(r_ref, 3)).all(), name
    print("ENGINE_PARITY_OK")
""")


def test_three_way_parity_4dev():
    """Dense vs blockwise vs shard_map(4 devices): same R, same labels."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "ENGINE_PARITY_OK" in res.stdout


class TestRandomClustersGuard:
    def test_too_many_clusters_raises(self):
        with pytest.raises(ValueError, match="n_clusters"):
            clu.random_clusters(3, 5, rng=0)

    def test_valid_edge_ok(self):
        labels = clu.random_clusters(3, 3, rng=0)
        assert len(np.unique(labels)) == 3
