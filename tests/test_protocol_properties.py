"""Property-based tests for the one-shot similarity protocol.

Structural invariants the paper's Eqs. 1-5 imply, checked across ALL three
``ProtocolEngine`` backends (jnp / pallas / shard_map) via the
``_hypothesis_compat`` shim (real hypothesis when installed, a
deterministic sample grid otherwise):

* **Symmetry** — Eq. 5 averages the two directed views, so R == R^T.
* **Permutation equivariance** — relabeling users permutes rows/cols of R
  and nothing else (the protocol has no user-order dependence).
* **Scale invariance** — features scaled by c scale every Gram eigenvalue
  by c^2, which cancels in the min/max eigenvalue ratios (away from the
  ``eig_floor`` clamp).
* **pad_ragged round-trip** — the padded batch preserves every user's rows
  and reports the exact ``n_valid`` counts, for arbitrary ragged shapes.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import similarity as sim
from repro.core.engine import BACKENDS, ProtocolEngine


def _feats(n_users, d, seed=0, n_samples=24):
    rng = np.random.default_rng(seed)
    # A mild task mixture (two feature scales) so R has structure.
    f = rng.standard_normal((n_users, n_samples, d)).astype(np.float32)
    f[: n_users // 2] *= 1.5
    return jnp.asarray(f)


def _engine(backend, **cfg_kw):
    return ProtocolEngine(sim.SimilarityConfig(top_k=4, backend=backend,
                                               **cfg_kw))


@pytest.mark.parametrize("backend", BACKENDS)
class TestSimilarityInvariants:
    @given(n_users=st.integers(4, 12))
    @settings(max_examples=6, deadline=None)
    def test_symmetric(self, backend, n_users):
        r = np.asarray(_engine(backend).similarity(_feats(n_users, 8)))
        np.testing.assert_allclose(r, r.T, atol=1e-6)

    @given(seed=st.integers(0, 100))
    @settings(max_examples=6, deadline=None)
    def test_permutation_equivariant(self, backend, seed):
        feats = _feats(8, 8, seed=seed)
        perm = np.random.default_rng(seed + 1).permutation(8)
        r = np.asarray(_engine(backend).similarity(feats))
        r_perm = np.asarray(_engine(backend).similarity(feats[perm]))
        np.testing.assert_allclose(r_perm, r[np.ix_(perm, perm)], atol=1e-5)

    @given(scale=st.floats(0.25, 4.0))
    @settings(max_examples=6, deadline=None)
    def test_scale_invariant(self, backend, scale):
        feats = _feats(6, 8)
        eng = _engine(backend, eig_floor=1e-12)
        r = np.asarray(eng.similarity(feats))
        r_scaled = np.asarray(eng.similarity(feats * scale))
        np.testing.assert_allclose(r_scaled, r, atol=1e-4)

    def test_diagonal_is_self_similarity_one(self, backend):
        r = np.asarray(_engine(backend).similarity(_feats(6, 8)))
        np.testing.assert_allclose(np.diag(r), 1.0, atol=1e-4)


class TestPadRaggedRoundTrip:
    @given(n_users=st.integers(1, 8), base=st.integers(1, 40))
    @settings(max_examples=8, deadline=None)
    def test_round_trips_n_valid(self, n_users, base):
        rng = np.random.default_rng(base * 7 + n_users)
        counts = [int(rng.integers(1, base + 1)) for _ in range(n_users)]
        d = int(rng.integers(1, 9))
        ragged = [rng.standard_normal((n, d)).astype(np.float32)
                  for n in counts]
        padded, n_valid = sim.pad_ragged(ragged)
        assert padded.shape == (n_users, max(counts), d)
        np.testing.assert_array_equal(np.asarray(n_valid), counts)
        for i, f in enumerate(ragged):
            np.testing.assert_array_equal(np.asarray(padded[i, : counts[i]]),
                                          f)
            assert not np.asarray(padded[i, counts[i]:]).any()

    def test_padded_protocol_matches_ragged_list(self):
        """Feeding (padded, n_valid) must equal feeding the ragged list —
        the contract ``prepare`` gives every backend."""
        rng = np.random.default_rng(5)
        ragged = [rng.standard_normal((n, 6)).astype(np.float32)
                  for n in (9, 17, 4, 12)]
        eng = _engine("jnp")
        r_list = np.asarray(eng.similarity(ragged))
        padded, nv = sim.pad_ragged(ragged)
        r_pad = np.asarray(eng.similarity(padded, n_valid=nv))
        np.testing.assert_allclose(r_pad, r_list, atol=1e-6)
