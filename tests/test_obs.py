"""Telemetry subsystem tests (ISSUE 10).

The obs contract: spans nest and time monotonically (device-synced at
exit), the metrics registry has exact counter/histogram semantics and
mirrors ``CommLedger.summary()`` bit-for-bit, the event log round-trips
through JSONL on the same timeline as the trace, and — the load-bearing
half — the DISABLED path mutates nothing and never retraces a compiled
program (the jit cache-miss hook sees zero new traces on warm calls).
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.core.oneshot import CommLedger, one_shot_clustering


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with telemetry off and empty."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# ---------------------------------------------------------------- spans

class TestSpans:
    def test_nesting_parent_child_depth(self):
        with obs.scope(True):
            with obs.span("outer", impl="dense") as outer:
                with obs.span("inner") as inner:
                    pass
                with obs.span("inner2"):
                    pass
        recs = {r["name"]: r for r in obs.trace_records()}
        assert set(recs) == {"outer", "inner", "inner2"}
        assert recs["outer"]["parent"] == 0 and recs["outer"]["depth"] == 0
        assert recs["inner"]["parent"] == recs["outer"]["id"]
        assert recs["inner2"]["parent"] == recs["outer"]["id"]
        assert recs["inner"]["depth"] == 1
        assert recs["outer"]["meta"] == {"impl": "dense"}
        del outer, inner

    def test_timing_monotonic_and_contained(self):
        with obs.scope(True):
            with obs.span("outer"):
                with obs.span("inner"):
                    float(jnp.ones(64).sum())  # some real work
        recs = {r["name"]: r for r in obs.trace_records()}
        o, i = recs["outer"], recs["inner"]
        assert o["dur_us"] >= 0 and i["dur_us"] >= 0
        # child starts no earlier than parent and fits inside it
        assert i["ts_us"] >= o["ts_us"]
        assert i["ts_us"] + i["dur_us"] <= o["ts_us"] + o["dur_us"] + 1e-3
        # records share one monotonic epoch: successive spans don't step back
        with obs.scope(True):
            with obs.span("later"):
                pass
        later = [r for r in obs.trace_records() if r["name"] == "later"][0]
        assert later["ts_us"] >= o["ts_us"]

    def test_sync_blocks_device_values(self):
        with obs.scope(True):
            with obs.span("compute") as sp:
                out = sp.sync(jnp.ones((256, 256)) @ jnp.ones((256, 256)))
        assert float(out[0, 0]) == 256.0
        rec = obs.trace_records()[-1]
        assert rec["name"] == "compute" and rec["dur_us"] > 0

    def test_note_attaches_meta(self):
        with obs.scope(True):
            with obs.span("s") as sp:
                sp.note(rounds=3, backend="jnp")
        rec = obs.trace_records()[-1]
        assert rec["meta"] == {"rounds": 3, "backend": "jnp"}

    def test_threads_get_independent_stacks(self):
        def worker():
            with obs.span("worker.outer"):
                with obs.span("worker.inner"):
                    pass

        with obs.scope(True):
            with obs.span("main.outer"):
                t = threading.Thread(target=worker, name="obs-worker")
                t.start()
                t.join()
        recs = {r["name"]: r for r in obs.trace_records()}
        # the thread's root span must NOT be parented under main.outer
        assert recs["worker.outer"]["parent"] == 0
        assert recs["worker.inner"]["parent"] == recs["worker.outer"]["id"]
        assert recs["worker.outer"]["thread"] == "obs-worker"

    def test_jsonl_round_trip_and_tree(self, tmp_path):
        with obs.scope(True):
            with obs.span("root", impl="x"):
                with obs.span("leaf"):
                    pass
        p = obs.save_trace(tmp_path / "trace.jsonl")
        loaded = obs.load_trace(p)
        assert loaded == obs.trace_records()
        tree = obs.format_tree(loaded)
        root_line, leaf_line = tree.splitlines()
        assert root_line.startswith("root") and "impl=x" in root_line
        assert leaf_line.startswith("  leaf")     # indented under root

    def test_format_tree_empty(self):
        assert obs.format_tree([]) == "(no spans recorded)"


# -------------------------------------------------------------- metrics

class TestMetrics:
    def test_counter_semantics(self):
        with obs.scope(True):
            obs.count("c")
            obs.count("c", 4)
            obs.count("c", kernel="assign")
            obs.count("c", 2, kernel="assign")
            obs.count("c", kernel="hac")
        assert obs.counter_value("c") == 5
        assert obs.counter_value("c", kernel="assign") == 3
        assert obs.counter_value("c", kernel="hac") == 1
        assert obs.counter_total("c") == 9

    def test_gauge_last_value_wins(self):
        with obs.scope(True):
            obs.gauge("g", 1.5)
            obs.gauge("g", jnp.asarray(2.5))   # device scalar coerced
            obs.gauge("plan", "bm=32,bn=64", kernel="assign")
        assert obs.gauge_value("g") == 2.5
        assert isinstance(obs.gauge_value("g"), float)
        assert obs.gauge_value("plan", kernel="assign") == "bm=32,bn=64"

    def test_histogram_semantics(self):
        with obs.scope(True):
            for v in (0.5, 1.0, 3.0, 100.0):
                obs.observe("h", v)
        h = obs.snapshot()["histograms"]["h"]
        assert h["count"] == 4
        assert h["total"] == pytest.approx(104.5)
        assert h["min"] == 0.5 and h["max"] == 100.0
        assert h["mean"] == pytest.approx(104.5 / 4)
        # pow-2 buckets: <=1 -> "1", 3 -> "4", 100 -> "128"
        assert h["buckets"] == {"1": 2, "4": 1, "128": 1}

    def test_snapshot_diff(self):
        with obs.scope(True):
            obs.count("a")
            obs.gauge("g", 1)
            before = obs.snapshot()
            obs.count("a", 2)
            obs.count("b")
            obs.gauge("g", 7)
            obs.observe("h", 10.0)
            after = obs.snapshot()
        d = obs.diff(before, after)
        assert d["counters"] == {"a": 2, "b": 1}
        assert d["gauges"] == {"g": [1, 7]}
        assert d["histograms"] == {"h": {"count": 1, "total": 10.0}}
        # identical snapshots diff to nothing
        assert not any(obs.diff(after, after).values())

    def test_snapshot_round_trip(self, tmp_path):
        with obs.scope(True):
            obs.count("a", 3)
            obs.observe("h", 2.0)
        p = obs.save_snapshot(tmp_path / "snap.json")
        assert obs.load_snapshot(p) == obs.snapshot()

    def test_ledger_parity_vs_summary(self):
        """comm.* gauges mirror CommLedger.summary() exactly — the
        telemetry view of the paper's communication-cost claim."""
        ledger = CommLedger(n_users=40, d=16, top_k=6,
                            model_params=10_000, mode="streaming")
        with obs.scope(True):
            obs.record_ledger(ledger)
        s = ledger.summary()
        for k, v in s.items():
            if v is None:
                continue
            assert obs.gauge_value(f"comm.{k}") == v, k
        assert (obs.gauge_value("comm_upload_bytes")
                == s["per_user_upload_bytes"] * s["n_users"])

    def test_ledger_none_fields_skipped(self):
        ledger = CommLedger(n_users=8, d=4, top_k=2)  # model_params=0
        assert ledger.summary()["oneshot_vs_iterative_ratio"] is None
        with obs.scope(True):
            obs.record_ledger(ledger)
        assert obs.gauge_value("comm.oneshot_vs_iterative_ratio") is None


# ------------------------------------------------------------- disabled

class TestDisabledMode:
    def test_span_is_shared_noop(self):
        s1 = obs.span("a", impl="x")
        s2 = obs.span("b")
        assert s1 is s2                       # one shared object, no alloc
        with s1 as sp:
            v = sp.sync(jnp.ones(3))
            sp.note(k=1)
        assert v.shape == (3,)
        assert obs.trace_records() == []

    def test_zero_registry_mutation(self):
        obs.count("c")
        obs.gauge("g", 1)
        obs.observe("h", 2.0)
        obs.event("kind", x=1)
        obs.record_ledger(CommLedger(n_users=4, d=2, top_k=1))
        snap = obs.snapshot()
        assert snap == {"counters": {}, "gauges": {}, "histograms": {}}
        assert obs.events() == []

    def test_scope_restores_prior_state(self):
        assert not obs.enabled()
        with obs.scope(True):
            assert obs.enabled()
            with obs.scope(False):
                assert not obs.enabled()
            assert obs.enabled()
        assert not obs.enabled()

    def test_toggling_never_retraces(self):
        """The retrace guarantee: a function jitted with telemetry off is
        NOT recompiled when telemetry turns on (and vice versa), because
        the disabled path does no work inside jit boundaries."""
        @jax.jit
        def f(x):
            return (x * 2).sum()

        x = jnp.ones(17)                       # distinctive shape
        f(x).block_until_ready()               # warm with obs off
        with obs.scope(True):
            before = obs.counter_value("retrace_count")
            for _ in range(3):
                f(x).block_until_ready()       # warm calls, obs on
            assert obs.counter_value("retrace_count") == before
            f(jnp.ones((17, 2))).block_until_ready()   # genuinely new shape
            assert obs.counter_value("retrace_count") > before


# --------------------------------------------------------------- events

class TestEvents:
    def test_order_and_fields(self):
        with obs.scope(True):
            obs.event("admit", n=3, slots=[0, 1, 2])
            obs.event("evict", n=1)
        evs = obs.events()
        assert [e["kind"] for e in evs] == ["admit", "evict"]
        assert evs[0]["seq"] < evs[1]["seq"]
        assert evs[0]["t_us"] <= evs[1]["t_us"]
        assert evs[0]["n"] == 3 and evs[0]["slots"] == [0, 1, 2]

    def test_device_scalars_coerced(self):
        with obs.scope(True):
            obs.event("e", frac=jnp.asarray(0.25), n=np.int64(7))
        e = obs.events("e")[0]
        assert e["frac"] == 0.25 and isinstance(e["frac"], float)
        assert e["n"] == 7 and isinstance(e["n"], int)
        json.dumps(e)                          # JSON-able end to end

    def test_kind_filter(self):
        with obs.scope(True):
            obs.event("a")
            obs.event("b")
            obs.event("a")
        assert len(obs.events("a")) == 2
        assert len(obs.events("b")) == 1

    def test_jsonl_round_trip(self, tmp_path):
        with obs.scope(True):
            obs.event("admit", n=2)
            obs.event("recluster", label_agreement=0.75)
        p = obs.save_events(tmp_path / "events.jsonl")
        assert obs.load_events(p) == obs.events()


# --------------------------------------------- instrumented hot paths

@pytest.fixture(scope="module")
def oneshot_result():
    rng = np.random.default_rng(0)
    feats = [rng.normal(size=(24, 8)).astype(np.float32) for _ in range(12)]
    return one_shot_clustering(feats, 2)


class TestInstrumentation:
    def test_pipeline_emits_all_three_pillars(self, oneshot_result):
        obs.reset()
        res = oneshot_result
        with obs.scope(True):
            eng = MembershipEngine.from_oneshot(
                res, MembershipConfig(backend="jnp", capacity=32))
            lam = np.asarray(res.lam)[:4]
            v = np.asarray(res.v)[:4]
            wave = eng.assign(lam, v)
            eng.admit(lam, v, np.asarray(wave.labels))
            eng.drift_stats()
        names = {r["name"] for r in obs.trace_records()}
        assert {"membership.assign", "membership.admit"} <= names
        assert obs.counter_value("membership.assign_waves") == 1
        assert obs.counter_value("membership.admits") == 4   # members
        assert obs.gauge_value("directory_bytes") > 0
        assert obs.gauge_value("unassigned_frac") is not None
        snap = obs.snapshot()
        assert snap["histograms"]["assign_latency_us"]["count"] == 1
        kinds = [e["kind"] for e in obs.events()]
        assert kinds == ["seed", "assign_wave", "admit"]
        wave_ev = obs.events("assign_wave")[0]
        assert wave_ev["n"] == 4

    def test_oneshot_records_ledger_and_spans(self):
        rng = np.random.default_rng(1)
        feats = [rng.normal(size=(16, 6)).astype(np.float32)
                 for _ in range(8)]
        obs.reset()
        with obs.scope(True):
            res = one_shot_clustering(feats, 2)
        names = {r["name"] for r in obs.trace_records()}
        assert {"oneshot.run", "protocol.run", "cluster.hac"} <= names
        assert (obs.gauge_value("comm.per_user_upload_bytes")
                == res.ledger.summary()["per_user_upload_bytes"])

    def test_tile_resolution_counts_dispatches(self):
        from repro.kernels import tuning

        with obs.scope(True):
            blocks = tuning.get_blocks("assign", b=64, d2=96)
            tuning.get_blocks("assign", b=64, d2=96)
        assert blocks                          # a real tile plan came back
        assert obs.counter_value("dispatch_count") == 2
        assert obs.counter_value("kernel_calls", kernel="assign") == 2
        assert obs.gauge_value("kernel_blocks",
                               kernel="assign") is not None

    def test_disabled_pipeline_identical_and_silent(self, oneshot_result):
        """Same workload with telemetry off: same verdicts, empty obs."""
        obs.reset()
        res = oneshot_result
        eng = MembershipEngine.from_oneshot(
            res, MembershipConfig(backend="jnp", capacity=32))
        lam = np.asarray(res.lam)[:4]
        v = np.asarray(res.v)[:4]
        wave = eng.assign(lam, v)
        with obs.scope(True):
            eng2 = MembershipEngine.from_oneshot(
                res, MembershipConfig(backend="jnp", capacity=32))
            wave2 = eng2.assign(lam, v)
        np.testing.assert_array_equal(np.asarray(wave.labels),
                                      np.asarray(wave2.labels))
        # the disabled half left nothing behind but the enabled half did
        assert any(r["name"] == "membership.assign"
                   for r in obs.trace_records())
        assert obs.counter_value("membership.assign_waves") == 1

    def test_stamp_shape(self):
        s = obs.stamp()
        assert set(s) == {"obs_enabled", "dispatch_count", "retrace_count"}
        assert s["obs_enabled"] is False
