"""Pure-jnp oracle for flash attention (same layout/contract)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_ref(q, k, v, causal: bool = True, window: int = 0):
    """``q (BH, S, hd)``, ``k/v (BH, Skv, hd)``."""
    s, skv = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bsh,bth->bst", q, k,
                        preferred_element_type=jnp.float32) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(skv)[None, :]
    mask = jnp.ones((s, skv), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= (i - j) < window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bst,bth->bsh", probs, v.astype(probs.dtype)
                      ).astype(q.dtype)
