"""Blocked online-softmax (flash) attention Pallas kernel.

Causal and sliding-window variants for the training/prefill path.  Inputs
are laid out ``(BH, S, hd)`` (batch*heads flattened into the leading grid
axis).  Grid = (BH, S/bq, Skv/bkv) with the KV axis innermost; per-q-block
running max / running sum / output accumulator live in VMEM scratch across
the KV sweep (the classic FlashAttention-2 schedule, re-tiled for the MXU:
bq = bkv = 128, hd padded to a multiple of 128).

Row statistics are stored broadcast across a 128-lane scratch so every
store is lane-aligned for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bkv: int,
            n_kv: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bkv, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[:, :1]                          # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
    l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[:, :1], 1e-20)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                 causal: bool = True, window: int = 0, block_q: int = 128,
                 block_kv: int = 128, interpret: bool = False) -> jax.Array:
    """``q (BH, S, hd)``, ``k/v (BH, Skv, hd)`` -> ``(BH, S, hd)``."""
    bh, s, hd = q.shape
    skv = k.shape[1]
    if s % block_q or skv % block_kv:
        raise ValueError(f"seq {s}/{skv} not divisible by blocks")
    grid = (bh, s // block_q, skv // block_kv)
    scale = hd ** -0.5
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=block_q, bkv=block_kv, n_kv=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, LANES), jnp.float32),
                        pltpu.VMEM((block_q, LANES), jnp.float32),
                        pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
