"""Public wrapper: (B, S, H, hd) layout adapter + backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention.flash import flash_pallas
from repro.kernels.flash_attention.ref import flash_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """``q (B, S, H, hd)``, ``k/v (B, Skv, H, hd)`` -> ``(B, S, H, hd)``.

    KV heads must already be group-expanded to H (attention.py does this).
    Falls back to the jnp oracle when the sequence is not block-aligned.
    """
    b, s, h, hd = q.shape
    skv = k.shape[1]
    interpret = dispatch.resolve_interpret(interpret)

    def flat(t):
        return t.transpose(0, 2, 1, 3).reshape(b * h, t.shape[1], hd)

    qf, kf, vf = flat(q), flat(k), flat(v)
    if s % block_q or skv % block_kv or hd % 128:
        out = flash_ref(qf, kf, vf, causal=causal, window=window)
    else:
        out = flash_pallas(qf, kf, vf, causal=causal, window=window,
                           block_q=block_q, block_kv=block_kv,
                           interpret=interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
