from repro.kernels.featurize_gram.ops import featurize_gram
from repro.kernels.featurize_gram.ref import featurize_gram_ref
