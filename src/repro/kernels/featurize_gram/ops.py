"""Public wrapper for the fused featurize->Gram kernel: pad + cast.

``compute_dtype`` selects the matmul input precision: ``"fp32"`` (exact
reference path) or ``"bf16"`` (MXU-rate compute, fp32 accumulation inside
the kernel).  Zero row/col padding to block multiples leaves the valid
``(d, d)`` Gram block exact, so the wrapper slices it back out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tuning
from repro.kernels.featurize_gram.featurize_gram import featurize_gram_pallas
from repro.kernels.featurize_gram.ref import featurize_gram_ref

COMPUTE_DTYPES = ("fp32", "bf16")


def featurize_gram(x: jax.Array, w: jax.Array,
                   compute_dtype: str = "fp32", block_n: int | None = None,
                   double_buffer: bool | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """``x (n, m)``, ``w (m, d)`` -> ``(x w)^T (x w)  (d, d)`` fp32, fused.

    Rows of ``x`` beyond the true count must already be zero (zero rows
    contribute nothing to the Gram); the ``1/n`` normalization lives with
    the caller, matching ``kernels.gram``.  Unpinned ``block_n`` /
    ``double_buffer`` resolve through ``kernels.tuning`` (DMA streaming
    defaults on for lowered backends only).
    """
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"compute_dtype must be one of {COMPUTE_DTYPES}, "
                         f"got {compute_dtype!r}")
    n, m = x.shape
    d = w.shape[1]
    interpret = dispatch.resolve_interpret(interpret)
    if block_n is None or double_buffer is None:
        blocks = tuning.get_blocks("featurize_gram", n=n)
        block_n = block_n or blocks["block_n"]
        if double_buffer is None:
            double_buffer = blocks["double_buffer"]
    pad_n = (-n) % block_n
    pad_m = (-m) % 128
    pad_d = (-d) % 128
    if pad_n or pad_m:
        x = jnp.pad(x, ((0, pad_n), (0, pad_m)))
    if pad_m or pad_d:
        w = jnp.pad(w, ((0, pad_m), (0, pad_d)))
    if compute_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
        w = w.astype(jnp.bfloat16)
    else:
        x = x.astype(jnp.float32)
        w = w.astype(jnp.float32)
    out = featurize_gram_pallas(x, w, block_n=block_n,
                                double_buffer=double_buffer,
                                interpret=interpret)
    return out[:d, :d]
