"""jnp oracle for the fused featurize->Gram kernel.

``(X W)^T (X W)`` computed the obvious two-matmul way in fp32 — the
parity reference for both the Pallas kernel and the bf16 compute path.
Unnormalized, like ``kernels.gram``: callers divide by ``n_valid``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def featurize_gram_ref(x: jax.Array, w: jax.Array | None = None
                       ) -> jax.Array:
    """``x (n, m)``, ``w (m, d)`` -> ``(x w)^T (x w)  (d, d)`` fp32.

    ``w=None`` degenerates to the plain Gram ``x^T x`` (identity Phi).
    """
    f = x.astype(jnp.float32)
    if w is not None:
        f = f @ w.astype(jnp.float32)
    return f.T @ f
