"""Fused featurize -> Gram Pallas kernel (paper Eq. 1 from RAW data).

Computes ``G = (X W)^T (X W)`` for raw rows ``X (n, m)`` and a shared
projection ``W (m, d)`` without materializing the feature matrix
``F = X W`` in HBM: the kernel walks row tiles ``X_t (bn, m)``, projects
each on the MXU, and immediately contracts ``F_t^T F_t`` into a ``(d, d)``
fp32 accumulator.  ``F`` exists only one ``(bn, d)`` tile at a time in
VMEM — the fusion that lets the streaming ``SignatureEngine`` ingest raw
user shards with peak memory O(chunk * m + d^2) instead of O(n * d).

Two execution paths share the wrapper contract:

* the grid path (``double_buffer=False``): grid = (n/bn,), the Pallas
  pipeline stages each row tile automatically;
* the DMA path (``double_buffer=True``): ``X`` stays in HBM (``ANY``
  memory space) and the kernel streams it through a two-slot VMEM buffer
  with explicit ``make_async_copy`` — the copy of tile ``t+1`` overlaps
  the matmuls of tile ``t``, hiding the HBM latency of the dominant
  operand on lowered backends.

Mixed precision: the matmul inputs ride at the *input* dtype (cast to
bf16 by ``ops.featurize_gram(compute_dtype="bf16")`` for MXU-rate
compute) while both ``dot_general`` accumulations are forced to fp32 via
``preferred_element_type`` — bf16 compute, fp32 accumulate.  The fp32
reference path is the same kernel with fp32 inputs (and
``ref.featurize_gram_ref`` outside Pallas entirely).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _project_accumulate(x, w, acc_ref):
    f = jax.lax.dot_general(
        x, w,
        (((1,), (0,)), ((), ())),            # (bn, m) @ (m, d) -> (bn, d)
        preferred_element_type=jnp.float32)
    f = f.astype(x.dtype)                    # bf16 inputs -> bf16 compute
    acc_ref[...] += jax.lax.dot_general(
        f, f,
        (((0,), (0,)), ((), ())),            # contract bn: -> (d, d)
        preferred_element_type=jnp.float32)


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_steps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _project_accumulate(x_ref[...], w_ref[...], acc_ref)

    @pl.when(pl.program_id(0) == n_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_db(x_hbm, w_ref, o_ref, acc_ref, *, n_steps: int, block_n: int):
    def body(buf, sem):
        def copy_in(slot, step):
            return pltpu.make_async_copy(
                x_hbm.at[pl.ds(step * block_n, block_n), :],
                buf.at[slot], sem.at[slot])

        copy_in(0, 0).start()
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def step_fn(step, carry):
            slot = step % 2

            @pl.when(step + 1 < n_steps)
            def _prefetch():                 # overlap next copy with compute
                copy_in(1 - slot, step + 1).start()

            copy_in(slot, step).wait()
            _project_accumulate(buf[slot], w_ref[...], acc_ref)
            return carry

        jax.lax.fori_loop(0, n_steps, step_fn, 0)
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    pl.run_scoped(
        body,
        buf=pltpu.VMEM((2, block_n, x_hbm.shape[1]), x_hbm.dtype),
        sem=pltpu.SemaphoreType.DMA((2,)))


@functools.partial(jax.jit, static_argnames=("block_n", "double_buffer",
                                             "interpret"))
def featurize_gram_pallas(x: jax.Array, w: jax.Array, block_n: int = 128,
                          double_buffer: bool = False,
                          interpret: bool = False) -> jax.Array:
    """``x (n, m)``, ``w (m, d)`` -> ``(x w)^T (x w)  (d, d)`` fp32.

    ``n`` must be a ``block_n`` multiple and ``m``/``d`` lane multiples
    (128); ``ops.py`` pads.  ``W`` and the ``(d, d)`` accumulator stay
    VMEM-resident across the whole row walk (``m*d + d^2 + bn*(m+d)``
    floats — twice the ``bn*m`` term with ``double_buffer``; fine for the
    protocol's d <= 1k feature widths).
    """
    n, m = x.shape
    mw, d = w.shape
    if mw != m:
        raise ValueError(f"bad shapes x={x.shape} w={w.shape}")
    if n % block_n or m % 128 or d % 128:
        raise ValueError(f"{(n, m, d)} not divisible by ({block_n}, 128, "
                         f"128)")
    n_steps = n // block_n
    out_shape = jax.ShapeDtypeStruct((d, d), jnp.float32)
    scratch = [pltpu.VMEM((d, d), jnp.float32)]
    if double_buffer:
        return pl.pallas_call(
            functools.partial(_kernel_db, n_steps=n_steps, block_n=block_n),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),     # X streamed by DMA
                pl.BlockSpec(memory_space=pltpu.VMEM),
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(x, w)
    return pl.pallas_call(
        functools.partial(_kernel, n_steps=n_steps),
        grid=(n_steps,),
        in_specs=[
            pl.BlockSpec((block_n, m), lambda t: (t, 0)),
            pl.BlockSpec((m, d), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda t: (0, 0)),
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, w)
