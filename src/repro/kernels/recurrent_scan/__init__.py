from repro.kernels.recurrent_scan.ops import linear_scan, wkv_chunked
from repro.kernels.recurrent_scan.ref import linear_scan_ref, wkv_ref
