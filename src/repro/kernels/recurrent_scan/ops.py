"""Public wrappers for the recurrent-scan kernels: pad + pack + dispatch.

``wkv_chunked`` keeps the exact calling convention of
``models/rwkv6.py::time_mix_chunked`` (``(B, S, H, hd)`` operands, matrix
state ``(B, H, hd, hd)``) so ``rwkv_block_apply`` can route to it with
``impl="pallas"``; ``linear_scan`` is the drop-in for the RG-LRU
associative scan.  Both flatten/pad to the kernel's lane-aligned layout
(head dim / channel dim to 128-lane multiples, sequence to a chunk
multiple — zero padding is an identity state update in both recurrences,
so the pads are exact), resolve tile sizes through ``kernels.tuning``
("recurrent_scan" family) and interpret-vs-lowered through
``kernels.dispatch``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tuning
from repro.kernels.recurrent_scan.recurrent_scan import (
    linear_scan_pallas, wkv_chunked_pallas)
from repro.kernels.recurrent_scan.ref import (  # noqa: F401
    linear_scan_ref, wkv_ref)

_LANE = 128


def wkv_chunked(r, k, v, logw, u, state, *, chunk: int | None = None,
                compute_dtype: str = "bf16", interpret: bool | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Fused WKV6: ``r/k/v/logw (B, S, H, hd)``, ``u (H, hd)``,
    ``state (B, H, hd, hd)`` -> ``(out (B, S, H, hd) in r.dtype,
    final state f32)`` — the ``time_mix_chunked`` contract."""
    interpret = dispatch.resolve_interpret(interpret)
    _, s, _, hd = r.shape
    if chunk is None:
        chunk = tuning.get_blocks("recurrent_scan", s=s, d=hd)["chunk"]
    return _wkv_impl(r, k, v, logw, u, state, chunk=int(chunk),
                     compute_dtype=compute_dtype, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "compute_dtype", "interpret"))
def _wkv_impl(r, k, v, logw, u, state, *, chunk: int, compute_dtype: str,
              interpret: bool):
    b, s, h, hd = r.shape
    c = max(1, min(chunk, s))
    sp = s + (-s % c)
    hdp = hd + (-hd % _LANE)

    def pack(t):  # (B, S, H, hd) -> (B*H, Sp, hdp) f32
        t = jnp.moveaxis(t.astype(jnp.float32), 2, 1).reshape(b * h, s, hd)
        return jnp.pad(t, ((0, 0), (0, sp - s), (0, hdp - hd)))

    u2 = jnp.pad(jnp.broadcast_to(u.astype(jnp.float32)[None], (b, h, hd)
                                  ).reshape(b * h, hd),
                 ((0, 0), (0, hdp - hd)))
    s02 = jnp.pad(state.astype(jnp.float32).reshape(b * h, hd, hd),
                  ((0, 0), (0, hdp - hd), (0, hdp - hd)))
    out, st = wkv_chunked_pallas(pack(r), pack(k), pack(v), pack(logw),
                                 u2, s02, chunk=c,
                                 compute_dtype=compute_dtype,
                                 interpret=interpret)
    out = jnp.moveaxis(out[:, :s, :hd].reshape(b, h, s, hd), 1, 2)
    return out.astype(r.dtype), st[:, :hd, :hd].reshape(b, h, hd, hd)


def linear_scan(log_a, x, h0, *, chunk: int | None = None,
                block_d: int | None = None, compute_dtype: str = "fp32",
                interpret: bool | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Fused linear recurrence ``h_t = exp(log_a_t) h_{t-1} + x_t``:
    ``log_a/x (B, S, D)``, ``h0 (B, D)`` -> ``(h (B, S, D) f32,
    h_last (B, D) f32)`` — the RG-LRU scan contract."""
    interpret = dispatch.resolve_interpret(interpret)
    _, s, d = x.shape
    if chunk is None or block_d is None:
        blocks = tuning.get_blocks("recurrent_scan", s=s, d=d)
        chunk = chunk or blocks["chunk"]
        block_d = block_d or blocks["block_d"]
    return _linear_scan_impl(log_a, x, h0, chunk=int(chunk),
                             block_d=int(block_d),
                             compute_dtype=compute_dtype,
                             interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "block_d", "compute_dtype",
                                   "interpret"))
def _linear_scan_impl(log_a, x, h0, *, chunk: int, block_d: int,
                      compute_dtype: str, interpret: bool):
    b, s, d = x.shape
    c = max(1, min(chunk, s))
    sp = s + (-s % c)
    # lane-round the requested channel tile (the kernel requires 128
    # multiples), then cap it at the lane-rounded channel dim
    bd = min(block_d + (-block_d % _LANE), d + (-d % _LANE))
    dp = d + (-d % bd)

    def pad(t):
        return jnp.pad(t.astype(jnp.float32),
                       ((0, 0), (0, sp - s), (0, dp - d)))

    h, hT = linear_scan_pallas(pad(log_a), pad(x),
                               jnp.pad(h0.astype(jnp.float32),
                                       ((0, 0), (0, dp - d))),
                               chunk=c, block_d=bd,
                               compute_dtype=compute_dtype,
                               interpret=interpret)
    return h[:, :s, :d], hT[:, :d]
