"""Chunked recurrent-scan Pallas kernels: RWKV-6 WKV and RG-LRU linear
recurrence, the decode/prefill hot loops of the recurrent model zoo.

Both kernels share one shape discipline: the sequence axis is split into
chunks of ``C`` tokens, the chunk axis is the FASTEST grid dimension (so
it iterates sequentially for a fixed batch row), and the recurrent state
rides across chunk steps in an fp32 VMEM scratch accumulator — loaded
from the initial-state operand at the first chunk, flushed to the
final-state output at the last.  Within a chunk the recurrence is
closed-form: pairwise decay ratios ``exp(cum[t] - cum[s]) <= 1`` are
computed as log differences (nothing overflows because log-decays are
``<= 0``), which turns the sequential scan into matmuls.

``wkv_chunked_pallas`` is the Pallas port of
``models/rwkv6.py::time_mix_chunked`` with the (B, H) axes flattened to
grid rows and the head dim padded to the 128-lane quantum;
``linear_scan_pallas`` is the RG-LRU channel-diagonal special case
(state is a vector, the intra-chunk weight is elementwise).  Compute is
bf16 with fp32 accumulation by default (``compute_dtype="fp32"`` for the
exact path); references live in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

COMPUTE_DTYPES = ("fp32", "bf16")


def _cdtype(compute_dtype: str):
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"compute_dtype must be one of {COMPUTE_DTYPES}, "
                         f"got {compute_dtype!r}")
    return jnp.bfloat16 if compute_dtype == "bf16" else jnp.float32


# ---------------------------------------------------------------------------
# RWKV-6 WKV: matrix state per (batch, head) row
# ---------------------------------------------------------------------------

def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                o_ref, st_out_ref, st_ref, *, n_chunks: int,
                compute_dtype: str):
    cd = _cdtype(compute_dtype)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _load_state():
        st_ref[...] = s0_ref[0]

    lw = lw_ref[0]                                    # (C, hdp) f32, <= 0
    rc, kc, vc = r_ref[0], k_ref[0], v_ref[0]         # (C, hdp) f32
    cum = jnp.cumsum(lw, axis=0)
    cum_prev = cum - lw                               # cum[t-1]

    # state passthrough: o_state[t] = (r_t * exp(cum[t-1])) . S
    r_dec = (rc * jnp.exp(cum_prev)).astype(cd)
    o_state = jax.lax.dot_general(
        r_dec, st_ref[...].astype(cd), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (C, hdp_v) f32 acc

    # intra-chunk: A[t,s,d] = exp(cum[t-1,d] - cum[s,d]) for s < t (<= 1)
    diff = cum_prev[:, None, :] - cum[None, :, :]     # (C, C, hdp)
    tri = jnp.tril(jnp.ones(diff.shape[:2], bool), k=-1)[:, :, None]
    a = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    w_ts = (rc.astype(cd)[:, None] * a.astype(cd) * kc.astype(cd)[None]
            ).astype(jnp.float32).sum(axis=-1)        # (C, C) f32 acc
    o_intra = jax.lax.dot_general(
        w_ts.astype(cd), vc.astype(cd), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # bonus on the current token: (r_t . (u * k_t)) v_t
    o_bonus = ((rc * u_ref[...]) * kc).sum(axis=-1, keepdims=True) * vc

    o_ref[0] = (o_state + o_intra + o_bonus).astype(o_ref.dtype)

    # next chunk state: S' = exp(cum[C-1]) S + sum_s exp(cum[C-1]-cum[s]) k v^T
    dec_total = jnp.exp(cum[-1])                      # (hdp,)
    k_dec = (kc * jnp.exp(jnp.minimum(cum[-1][None, :] - cum, 0.0))
             ).astype(cd)
    st_ref[...] = (dec_total[:, None] * st_ref[...]
                   + jax.lax.dot_general(
                       k_dec, vc.astype(cd), (((0,), (0,)), ((), ())),
                       preferred_element_type=jnp.float32))

    @pl.when(c == n_chunks - 1)
    def _flush_state():
        st_out_ref[0] = st_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "compute_dtype", "interpret"))
def wkv_chunked_pallas(r, k, v, logw, u, state, chunk: int = 64,
                       compute_dtype: str = "bf16", interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array]:
    """``r/k/v/logw (BH, S, hdp)`` f32, ``u (BH, hdp)`` f32,
    ``state (BH, hdp, hdp)`` f32 -> ``(out (BH, S, hdp) f32, final state)``.

    ``S`` must be a ``chunk`` multiple and ``hdp`` a lane multiple of 128
    (``ops.py`` pads both; zero-padded ``logw``/``k``/``r`` rows and head
    dims are identity updates, so padding is exact).
    """
    _cdtype(compute_dtype)
    bh, s, hdp = r.shape
    if s % chunk:
        raise ValueError(f"seq {s} not a chunk multiple of {chunk}")
    if hdp % 128:
        raise ValueError(f"head dim {hdp} must be a lane multiple of 128")
    n_chunks = s // chunk
    grid = (bh, n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, hdp), lambda i, c: (i, c, 0))
    row_spec = pl.BlockSpec((1, hdp), lambda i, c: (i, 0))
    mat_spec = pl.BlockSpec((1, hdp, hdp), lambda i, c: (i, 0, 0))
    out, st = pl.pallas_call(
        functools.partial(_wkv_kernel, n_chunks=n_chunks,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, row_spec, mat_spec],
        out_specs=(seq_spec, mat_spec),
        out_shape=(jax.ShapeDtypeStruct((bh, s, hdp), jnp.float32),
                   jax.ShapeDtypeStruct((bh, hdp, hdp), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((hdp, hdp), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state)
    return out, st


# ---------------------------------------------------------------------------
# RG-LRU linear scan: per-channel diagonal state
# ---------------------------------------------------------------------------

def _linear_scan_kernel(la_ref, x_ref, h0_ref, o_ref, hT_ref, st_ref, *,
                        n_chunks: int, compute_dtype: str):
    cd = _cdtype(compute_dtype)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _load_state():
        st_ref[...] = h0_ref[...]

    la = la_ref[0]                                    # (C, bd) f32, <= 0
    xc = x_ref[0]                                     # (C, bd) f32
    cum = jnp.cumsum(la, axis=0)
    # W[t,s,d] = exp(cum[t,d] - cum[s,d]) for s <= t (diagonal incl.: ratio 1)
    diff = cum[:, None, :] - cum[None, :, :]          # (C, C, bd)
    tri = jnp.tril(jnp.ones(diff.shape[:2], bool))[:, :, None]
    w = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    h_intra = (w.astype(cd) * xc.astype(cd)[None, :, :]
               ).astype(jnp.float32).sum(axis=1)      # (C, bd) f32 acc
    h = jnp.exp(cum) * st_ref[...] + h_intra          # carry: h0 passthrough
    o_ref[0] = h.astype(o_ref.dtype)
    st_ref[...] = h[-1:, :]

    @pl.when(c == n_chunks - 1)
    def _flush_state():
        hT_ref[...] = st_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_d", "compute_dtype",
                                    "interpret"))
def linear_scan_pallas(log_a, x, h0, chunk: int = 64, block_d: int = 256,
                       compute_dtype: str = "fp32", interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array]:
    """``log_a/x (B, S, Dp)`` f32, ``h0 (B, Dp)`` f32 ->
    ``(h (B, S, Dp) f32, h_last (B, Dp) f32)``.

    ``S`` must be a ``chunk`` multiple and ``Dp`` a ``block_d`` multiple
    (lane-rounded; ``ops.py`` pads — zero ``log_a``/``x`` padding is an
    identity update, so padding is exact).
    """
    _cdtype(compute_dtype)
    b, s, dp = x.shape
    if s % chunk:
        raise ValueError(f"seq {s} not a chunk multiple of {chunk}")
    if dp % block_d or block_d % 128:
        raise ValueError(f"channel dim {dp} / block_d {block_d} must be "
                         f"lane-aligned block multiples")
    n_chunks = s // chunk
    grid = (b, dp // block_d, n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, block_d), lambda i, j, c: (i, c, j))
    row_spec = pl.BlockSpec((1, block_d), lambda i, j, c: (i, j))
    h, hT = pl.pallas_call(
        functools.partial(_linear_scan_kernel, n_chunks=n_chunks,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[seq_spec, seq_spec, row_spec],
        out_specs=(seq_spec, row_spec),
        out_shape=(jax.ShapeDtypeStruct((b, s, dp), jnp.float32),
                   jax.ShapeDtypeStruct((b, dp), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(log_a, x, h0)
    return h, hT
