"""fp32 references for the recurrent-scan kernel family.

Standalone (no imports from ``repro.models``) so the kernel tests can
diff Pallas output against a sequential oracle without dragging the full
block machinery in.  Two recurrences share the family:

* ``wkv_ref`` — the RWKV-6 time-mix state recurrence (matrix-valued
  state ``S (hd_k, hd_v)`` per head, diagonal data-dependent decay, bonus
  ``u`` on the current token).  Mirrors ``models/rwkv6.py::time_mix_ref``.
* ``linear_scan_ref`` — the RG-LRU per-channel linear recurrence
  ``h_t = exp(log_a_t) h_{t-1} + x_t``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv_ref", "linear_scan_ref"]


def wkv_ref(r, k, v, logw, u, state):
    """Sequential fp32 oracle.  ``r/k/v/logw (B, S, H, hd)``, ``u (H, hd)``,
    ``state (B, H, hd, hd)`` -> ``(out (B, S, H, hd) f32, final state f32)``."""
    r, k, v, logw = (t.astype(jnp.float32) for t in (r, k, v, logw))
    u = u.astype(jnp.float32)

    def step(s_prev, inp):
        r_t, k_t, v_t, lw_t = inp                       # (B, H, hd)
        a = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       s_prev + u[None, :, :, None] * a)
        s_new = jnp.exp(lw_t)[..., None] * s_prev + a
        return s_new, o

    seq = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    state, outs = jax.lax.scan(step, state.astype(jnp.float32), seq)
    return jnp.moveaxis(outs, 0, 1), state


def linear_scan_ref(log_a, x, h0):
    """Sequential fp32 oracle for ``h_t = exp(log_a_t) h_{t-1} + x_t``.
    ``log_a/x (B, S, D)``, ``h0 (B, D)`` -> ``(h (B, S, D) f32, h_last f32)``."""
    log_a = log_a.astype(jnp.float32)
    x = x.astype(jnp.float32)

    def step(h_prev, inp):
        la_t, x_t = inp                                 # (B, D)
        h = jnp.exp(la_t) * h_prev + x_t
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(log_a, 1, 0), jnp.moveaxis(x, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), h_last
