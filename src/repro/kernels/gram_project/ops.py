"""Public wrapper for the fused Gram-projection kernel: pad + normalize."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tuning
from repro.kernels.gram_project.gram_project import gram_project_pallas
from repro.kernels.gram_project.ref import gram_project_ref


def gram_project(x: jax.Array, v: jax.Array,
                 n_valid: jax.Array | int | None = None,
                 block_n: int | None = None, block_k: int | None = None,
                 double_buffer: bool | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """``lamhat_k = || (x^T x / n) v_k ||`` fused, ``x (n, d)``, ``v (d, k)``.

    Zero rows/cols pad ``x`` and zero rows pad ``v`` to block multiples —
    both leave the valid-column norms exact.  ``n_valid`` supports ragged
    per-user counts under a padded batch (rows >= n_valid must be zero).
    Unpinned ``block_n``/``block_k``/``double_buffer`` resolve through
    ``kernels.tuning`` (DMA double-buffering defaults on for lowered
    backends, off in interpret mode where there is nothing to overlap).
    """
    n, d = x.shape
    k = v.shape[1]
    interpret = dispatch.resolve_interpret(interpret)
    if block_n is None or block_k is None or double_buffer is None:
        blocks = tuning.get_blocks("gram_project", n=n, k=k)
        block_n = block_n or blocks["block_n"]
        block_k = block_k or blocks["block_k"]
        if double_buffer is None:
            double_buffer = blocks["double_buffer"]
    pad_n = (-n) % block_n
    pad_d = (-d) % 128
    pad_k = (-k) % block_k
    if pad_n or pad_d:
        x = jnp.pad(x, ((0, pad_n), (0, pad_d)))
    if pad_d or pad_k:
        v = jnp.pad(v, ((0, pad_d), (0, pad_k)))
    raw = gram_project_pallas(x, v, block_n=block_n, block_k=block_k,
                              double_buffer=double_buffer,
                              interpret=interpret)[:k]
    nv = n if n_valid is None else n_valid
    return raw / jnp.maximum(jnp.asarray(nv, jnp.float32), 1.0)
