"""jnp oracle for the fused Gram-projection kernel.

``||G v_k||`` with ``G = (1/n) X^T X``, computed WITHOUT forming ``G``:
``G v = (1/n) X^T (X v)`` — two skinny matmuls instead of a ``(d, d)``
intermediate.  This identity is what both the blockwise protocol backend
and the Pallas kernel exploit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_project_ref(x: jax.Array, v: jax.Array,
                     n_valid: jax.Array | int | None = None) -> jax.Array:
    """``x (n, d)``, ``v (d, k)`` -> ``|| (x^T x / n) v_k ||_2`` per column."""
    n = x.shape[0] if n_valid is None else n_valid
    n = jnp.maximum(jnp.asarray(n, jnp.float32), 1.0)
    p = x @ v                                   # (n, k)
    q = x.T @ p                                 # (d, k)
    return jnp.sqrt(jnp.sum(q * q, axis=0)) / n
