"""Fused Gram + cross-projection Pallas kernel (paper Eqs. 1-2 in one pass).

Computes ``out_k = || X^T (X v_k) ||_2`` for ``X (n, d)`` and eigenvector
columns ``v (d, k)`` without ever materializing the ``(d, d)`` Gram matrix:
``(X^T X) V = sum_t X_t^T (X_t V)`` over row tiles ``X_t (bn, d)``.

Two execution paths share the wrapper contract:

* the grid path (``double_buffer=False``): grid = (k/bk, n/bn), n
  innermost; each step loads one row tile of X and one column block of V,
  computes the (bn, bk) partial projection on the MXU, immediately
  contracts it back through ``X_t^T`` into a (d, bk) fp32 accumulator, and
  writes the column norms on the last n-step;
* the DMA path (``double_buffer=True``): grid = (k/bk,) with ``X`` left in
  HBM (``ANY`` memory space); the kernel streams row tiles through a
  two-slot VMEM buffer with explicit ``make_async_copy`` so the copy of
  tile ``t+1`` overlaps both matmuls of tile ``t`` — X is the dominant
  operand and this hides its HBM latency on lowered backends.

Neither the ``(d, d)`` Gram nor the full ``(n, k)`` projection ever
round-trips to HBM — the memory win that makes the blockwise streaming
protocol O(block * d^2) instead of O(N * d^2).

The ``1/n`` Gram normalisation and the ragged ``n_valid`` handling live in
``ops.py`` (they are cheap elementwise postprocessing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _project_accumulate(x, v, acc_ref):
    p = jax.lax.dot_general(
        x, v,
        (((1,), (0,)), ((), ())),            # (bn, d) @ (d, bk) -> (bn, bk)
        preferred_element_type=jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, p,
        (((0,), (0,)), ((), ())),            # contract bn: -> (d, bk)
        preferred_element_type=jnp.float32)


def _norms(acc_ref, o_ref):
    o_ref[...] = jnp.sqrt(
        jnp.sum(jnp.square(acc_ref[...]), axis=0,
                keepdims=True)).astype(o_ref.dtype)


def _kernel(x_ref, v_ref, o_ref, acc_ref, *, n_steps: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _project_accumulate(x_ref[...], v_ref[...], acc_ref)

    @pl.when(pl.program_id(1) == n_steps - 1)
    def _flush():
        _norms(acc_ref, o_ref)


def _kernel_db(x_hbm, v_ref, o_ref, acc_ref, *, n_steps: int, block_n: int):
    def body(buf, sem):
        def copy_in(slot, step):
            return pltpu.make_async_copy(
                x_hbm.at[pl.ds(step * block_n, block_n), :],
                buf.at[slot], sem.at[slot])

        copy_in(0, 0).start()
        acc_ref[...] = jnp.zeros_like(acc_ref)

        def step_fn(step, carry):
            slot = step % 2

            @pl.when(step + 1 < n_steps)
            def _prefetch():                 # overlap next copy with compute
                copy_in(1 - slot, step + 1).start()

            copy_in(slot, step).wait()
            _project_accumulate(buf[slot], v_ref[...], acc_ref)
            return carry

        jax.lax.fori_loop(0, n_steps, step_fn, 0)
        _norms(acc_ref, o_ref)

    pl.run_scoped(
        body,
        buf=pltpu.VMEM((2, block_n, x_hbm.shape[1]), x_hbm.dtype),
        sem=pltpu.SemaphoreType.DMA((2,)))


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "double_buffer",
                                    "interpret"))
def gram_project_pallas(x: jax.Array, v: jax.Array, block_n: int = 128,
                        block_k: int = 128, double_buffer: bool = False,
                        interpret: bool = False) -> jax.Array:
    """``x (n, d)``, ``v (d, k)`` -> ``|| x^T (x v_k) ||_2`` per column, fp32.

    ``n``/``k`` must be block multiples and ``d`` a lane multiple (128);
    ``ops.py`` pads.  The full d extent rides inside each block (VMEM:
    ``bn*d + d*bk`` floats, the ``bn*d`` term doubled under
    ``double_buffer`` — fine up to d ~ 4k).
    """
    n, d = x.shape
    dv, k = v.shape
    if dv != d:
        raise ValueError(f"bad shapes x={x.shape} v={v.shape}")
    if n % block_n or k % block_k or d % 128:
        raise ValueError(f"{(n, d, k)} not divisible by "
                         f"({block_n}, 128, {block_k})")
    n_steps = n // block_n
    out_shape = jax.ShapeDtypeStruct((1, k), jnp.float32)
    scratch = [pltpu.VMEM((d, block_k), jnp.float32)]
    if double_buffer:
        out = pl.pallas_call(
            functools.partial(_kernel_db, n_steps=n_steps, block_n=block_n),
            grid=(k // block_k,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),     # X streamed by DMA
                pl.BlockSpec((d, block_k), lambda kk: (0, kk)),
            ],
            out_specs=pl.BlockSpec((1, block_k), lambda kk: (0, kk)),
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(x, v)
    else:
        grid = (k // block_k, n_steps)
        out = pl.pallas_call(
            functools.partial(_kernel, n_steps=n_steps),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_n, d), lambda kk, t: (t, 0)),
                pl.BlockSpec((d, block_k), lambda kk, t: (0, kk)),
            ],
            out_specs=pl.BlockSpec((1, block_k), lambda kk, t: (0, kk)),
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(x, v)
    return out[0]
