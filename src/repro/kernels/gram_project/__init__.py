from repro.kernels.gram_project.ops import gram_project
from repro.kernels.gram_project.ref import gram_project_ref
