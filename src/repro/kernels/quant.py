"""Symmetric quantization for the membership directory.

The serving directory's prototype table ``(T, d, d)`` is the structure
that grows with the deployment (hierarchical serving makes it ``G * T_g``
entries): at f32 a million-entry d=32 directory is ~4 GiB.  Quantizing it
to int8 with one symmetric scale per prototype drops that 4x with no
change to the argmax verdict in practice — the assign kernel dequantizes
inside its matmul tiles (``kernels/assign``), so the f32 table never
needs to exist at serving time.

Scheme (per leading-axis entry ``t``):

  scale_t = max(|P_t|) / 127          (zero entries get scale 1)
  Q_t     = clip(round(P_t / scale_t), -127, 127)  int8
  P_t     ~ Q_t * scale_t

Symmetric (no zero point): projector entries are centred at zero, and a
symmetric code keeps the dequant a single multiply that commutes with the
affinity contraction — ``<S, Q_t> * scale_t`` is exact given ``Q_t``, so
the only error is the rounding in ``Q_t`` itself (bounded by
``scale_t / 2`` per coordinate).

bf16 is the cheap middle ground: 2x memory cut, no scales, ~3 decimal
digits kept.  Helpers work on numpy and jnp arrays alike and preserve the
input family — the numpy MembershipEngine backend stays host-side.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DIRECTORY_DTYPES", "quantize_directory", "dequantize_directory",
           "directory_nbytes"]

DIRECTORY_DTYPES = ("f32", "bf16", "int8")
_INT8_MAX = 127.0


def _xp(x):
    return jnp if isinstance(x, jax.Array) else np


def quantize_directory(p, dtype: str):
    """``(T, ...) f32 -> (table, scales | None)`` in the directory dtype.

    int8 returns per-entry symmetric scales ``(T,) f32``; f32/bf16 return
    ``scales=None`` (pure dtype cast).  All-zero entries quantize exactly
    (scale pinned to 1 so dequant returns zeros).
    """
    if dtype not in DIRECTORY_DTYPES:
        raise ValueError(f"directory dtype must be one of "
                         f"{DIRECTORY_DTYPES}, got {dtype!r}")
    xp = _xp(p)
    if dtype == "f32":
        return xp.asarray(p, xp.float32), None
    if dtype == "bf16":
        return xp.asarray(p, jnp.bfloat16), None
    p = xp.asarray(p, xp.float32)
    flat = p.reshape(p.shape[0], -1)
    amax = xp.max(xp.abs(flat), axis=1)
    scales = xp.where(amax > 0, amax / _INT8_MAX, 1.0).astype(xp.float32)
    q = xp.clip(xp.round(flat / scales[:, None]), -_INT8_MAX, _INT8_MAX)
    return q.astype(xp.int8).reshape(p.shape), scales


def dequantize_directory(q, scales=None):
    """Inverse of ``quantize_directory``: back to f32 (exact for f32/bf16
    inputs up to the cast; rounding error only for int8)."""
    xp = _xp(q)
    out = xp.asarray(q, xp.float32)
    if scales is None:
        return out
    bshape = (-1,) + (1,) * (out.ndim - 1)
    return out * xp.reshape(xp.asarray(scales, xp.float32), bshape)


def directory_nbytes(table, scales=None) -> int:
    """Serving-directory footprint in bytes (table + scales)."""
    n = int(np.asarray(table).nbytes if not isinstance(table, jax.Array)
            else table.size * table.dtype.itemsize)
    if scales is not None:
        n += int(scales.size * scales.dtype.itemsize)
    return n
