"""Public wrapper for the Gram kernel: pad-to-block, backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tuning
from repro.kernels.gram.gram import gram_pallas
from repro.kernels.gram.ref import gram_ref


def gram_matrix(x: jax.Array, block_d: int | None = None,
                block_n: int | None = None,
                interpret: bool | None = None) -> jax.Array:
    """``x (n, d)`` -> ``x^T x (d, d)`` fp32.  Zero-pads to block multiples
    (zero rows/cols do not change X^T X on the valid region).

    Unpinned block sizes resolve through ``kernels.tuning`` (autotune
    cache, else per-backend heuristics)."""
    n, d = x.shape
    interpret = dispatch.resolve_interpret(interpret)
    if block_d is None or block_n is None:
        blocks = tuning.get_blocks("gram", n=n, d=d)
        block_n = block_n or blocks["block_n"]
        block_d = block_d or blocks["block_d"]
    pad_n = (-n) % block_n
    pad_d = (-d) % block_d
    if pad_n or pad_d:
        x = jnp.pad(x, ((0, pad_n), (0, pad_d)))
    out = gram_pallas(x, block_d=block_d, block_n=block_n,
                      interpret=interpret)
    return out[:d, :d]
