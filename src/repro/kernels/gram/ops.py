"""Public wrapper for the Gram kernel: pad-to-block, backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram.gram import gram_pallas
from repro.kernels.gram.ref import gram_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gram_matrix(x: jax.Array, block_d: int = 128, block_n: int = 128,
                interpret: bool | None = None) -> jax.Array:
    """``x (n, d)`` -> ``x^T x (d, d)`` fp32.  Zero-pads to block multiples
    (zero rows/cols do not change X^T X on the valid region)."""
    n, d = x.shape
    interpret = (not _is_tpu()) if interpret is None else interpret
    pad_n = (-n) % block_n
    pad_d = (-d) % block_d
    if pad_n or pad_d:
        x = jnp.pad(x, ((0, pad_n), (0, pad_d)))
    out = gram_pallas(x, block_d=block_d, block_n=block_n,
                      interpret=interpret)
    return out[:d, :d]
