"""Tiled X^T X Pallas kernel (the Eq.-1 Gram hot spot).

Computes ``C = X^T X`` for ``X (n, d)`` as a 3-D grid matmul:
grid = (d/bd_i, d/bd_j, n/bn); each step loads two (bn, bd) tiles of X into
VMEM, accumulates ``x_i^T x_j`` into an fp32 VMEM scratch on the MXU, and
writes the (bd_i, bd_j) output tile on the last n-step.  The n axis is the
innermost grid dimension, so the accumulator is live for exactly one output
tile at a time.

MXU alignment: block sizes default to 128 (v5e systolic array); ops.py
pads inputs that are not block-divisible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_i_ref, x_j_ref, o_ref, acc_ref, *, n_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_i_ref[...], x_j_ref[...],
        (((0,), (0,)), ((), ())),            # contract over the n axis
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_d", "block_n", "interpret"))
def gram_pallas(x: jax.Array, block_d: int = 128, block_n: int = 128,
                interpret: bool = False) -> jax.Array:
    """``x (n, d)`` -> ``x.T @ x (d, d)`` in fp32."""
    n, d = x.shape
    if n % block_n or d % block_d:
        raise ValueError(f"shape {(n, d)} not divisible by blocks "
                         f"({block_n}, {block_d})")
    grid = (d // block_d, d // block_d, n // block_n)
    return pl.pallas_call(
        functools.partial(_kernel, n_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, block_d), jnp.float32)],
        interpret=interpret,
    )(x, x)
