"""jnp oracle for the fused cluster-assignment kernel.

A newcomer's cluster identity is decided from its SHARED signature alone:
given the cluster directory's prototype projectors ``P_t = mean_{i in t}
V_i V_i^T`` and the newcomer's top-k eigenvectors ``V_b (d, k)``, the
affinity is the mean squared alignment of the newcomer's signature
subspace with the cluster's mean projector,

    a(b, t) = trace(V_b^T P_t V_b) / k  in [0, 1],

maximized over t.  That is O(T * k * d^2) per newcomer — no training
rounds, no loss probing against T cluster models (IFCA), and no O(N^2)
protocol re-run.  The fused kernel (``assign.py``) does the batched
project + trace + argmax in one pass; this module is the fp32 reference.

Tie-breaking matches ``jnp.argmax`` (first index wins).  The margin is
``best - second_best`` affinity — the confidence statistic the
``MembershipEngine`` thresholds into the ``unassigned`` bucket; with a
single cluster it degenerates to the affinity itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -jnp.inf


def assign_ref(v: jax.Array, protos: jax.Array,
               mask: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``v (B, d, k)``, ``protos (T, d, d)`` -> ``(affinity (B, T),
    labels (B,) i32, margin (B,))``, all fp32.

    ``mask (T,)`` (bool/float) marks live clusters; dead prototypes get
    ``-inf`` affinity and can never win the argmax.
    """
    v = v.astype(jnp.float32)
    k = v.shape[-1]
    aff = jnp.einsum("bdk,tde,bek->bt", v, protos.astype(jnp.float32),
                     v) / k
    if mask is not None:
        aff = jnp.where(mask.astype(bool)[None, :], aff, _NEG)
    labels = jnp.argmax(aff, axis=1).astype(jnp.int32)
    best = jnp.max(aff, axis=1)
    if aff.shape[1] == 1:
        return aff, labels, best
    cols = jnp.arange(aff.shape[1], dtype=jnp.int32)
    second = jnp.max(jnp.where(cols[None, :] == labels[:, None], _NEG, aff),
                     axis=1)
    return aff, labels, best - second
