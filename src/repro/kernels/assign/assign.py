"""Fused wave-batched project + trace + argmax Pallas kernels for cluster
assignment, with in-tile directory dequantization.

The affinity is a Frobenius inner product: ``tr(V_b^T P_t V_b) =
<V_b V_b^T, P_t>``.  Flattening the wave's signature projectors
``S (B, d^2)`` and the directory ``P (T, d^2)`` turns the whole wave's
scoring into ONE matmul ``A = S P^T`` — MXU-shaped on TPU, and a few
grid steps (instead of ``B x T``) in interpret mode.

``assign_wave_pallas`` tiles that matmul over ``(B/bb, d^2/bd2)`` with
the directory axis resident (``T`` is small), and fuses the verdict
epilogue into the final reduction tile: per-prototype dequant scale,
liveness mask, the affinity row write, and the running
(best, second-best, argmax) — labels and confidence margins leave the
kernel ready-made, the ``(B, T)`` affinity never round-trips through HBM
for its reduction.  The directory rides in as f32, bf16, or int8 with
symmetric per-prototype scales (``kernels/quant``): the dequant is a
single epilogue multiply because the scale commutes with the
contraction, so a million-entry int8 directory is scored without ever
materializing its f32 form.

``assign_one_pallas`` is the PR-5 per-arrival kernel (grid over
prototypes, SMEM running best) — kept as the benchmark baseline and for
single-arrival serving where building ``S`` is not worth it.

Tie-breaking matches ``jnp.argmax`` (first index wins) in both kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

COMPUTE_DTYPES = ("fp32", "bf16")


# ---------------------------------------------------------------------------
# Wave-batched kernel: one matmul for the whole wave, fused verdict epilogue
# ---------------------------------------------------------------------------

def _wave_kernel(s_ref, p_ref, sc_ref, m_ref, aff_ref, lab_ref, mar_ref,
                 acc_ref, *, n_d2: int, n_clusters: int, compute_dtype: str):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = s_ref[...]                                       # (bb, bd2) f32
    p = p_ref[...]                                       # (tp, bd2) f32/bf16/i8
    if compute_dtype == "bf16":
        s, p = s.astype(jnp.bfloat16), p.astype(jnp.bfloat16)
    else:
        s, p = s.astype(jnp.float32), p.astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        s, p, (((1,), (1,)), ((), ())),                  # contract d^2
        preferred_element_type=jnp.float32)              # (bb, tp) f32 acc

    @pl.when(c == n_d2 - 1)
    def _epilogue():
        a = acc_ref[...] * sc_ref[...]                   # per-proto dequant
        a = jnp.where(m_ref[...] > 0.5, a, -jnp.inf)     # dead/padded protos
        aff_ref[...] = a
        best = jnp.max(a, axis=1, keepdims=True)
        cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        lab = jnp.min(jnp.where(a == best, cols, a.shape[1]), axis=1,
                      keepdims=True)                     # first index wins
        if n_clusters == 1:
            # one-cluster directory: no runner-up, margin degenerates to
            # the affinity itself (matching assign_ref)
            mar = best
        else:
            mar = best - jnp.max(jnp.where(cols == lab, -jnp.inf, a),
                                 axis=1, keepdims=True)
        lab_ref[...] = jnp.broadcast_to(lab, lab_ref.shape)
        mar_ref[...] = jnp.broadcast_to(mar, mar_ref.shape)


@functools.partial(jax.jit,
                   static_argnames=("n_clusters", "block_b", "block_d2",
                                    "compute_dtype", "interpret"))
def assign_wave_pallas(s: jax.Array, protos_flat: jax.Array,
                       scales: jax.Array, mask: jax.Array,
                       n_clusters: int, block_b: int = 128,
                       block_d2: int = 512, compute_dtype: str = "bf16",
                       interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``s (B, D2)`` f32 wave projectors, ``protos_flat (Tp, D2)`` in the
    directory dtype, ``scales (1, Tp)`` f32, ``mask (1, Tp)`` f32 ->
    ``(affinity (B, Tp) f32 RAW, labels (B,) i32, margin (B,) f32 RAW)``.

    ``B``/``D2`` must be block multiples and ``Tp`` a lane multiple
    (``ops.py`` pads; zero rows/cols and zero-masked prototypes are
    exact).  ``n_clusters`` is the count of REAL directory entries — it
    only gates the one-cluster margin degeneracy.  The ``/k``
    normalisation is cheap postprocessing in ``ops.py``.
    """
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"compute_dtype must be one of {COMPUTE_DTYPES}, "
                         f"got {compute_dtype!r}")
    b, d2 = s.shape
    tp = protos_flat.shape[0]
    if protos_flat.shape[1] != d2:
        raise ValueError(f"bad shapes s={s.shape} "
                         f"protos_flat={protos_flat.shape}")
    if b % block_b or d2 % block_d2:
        raise ValueError(f"(B, D2)={(b, d2)} not divisible by blocks "
                         f"({block_b}, {block_d2})")
    if tp % 128:
        raise ValueError(f"padded directory axis {tp} must be a lane "
                         f"multiple of 128")
    grid = (b // block_b, d2 // block_d2)
    row_spec = pl.BlockSpec((1, tp), lambda i, c: (0, 0))
    aff, lab, mar = pl.pallas_call(
        functools.partial(_wave_kernel, n_d2=grid[1], n_clusters=n_clusters,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_d2), lambda i, c: (i, c)),
            pl.BlockSpec((tp, block_d2), lambda i, c: (0, c)),
            row_spec,
            row_spec,
        ],
        out_specs=(pl.BlockSpec((block_b, tp), lambda i, c: (i, 0)),
                   pl.BlockSpec((block_b, 128), lambda i, c: (i, 0)),
                   pl.BlockSpec((block_b, 128), lambda i, c: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((b, tp), jnp.float32),
                   jax.ShapeDtypeStruct((b, 128), jnp.int32),
                   jax.ShapeDtypeStruct((b, 128), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((block_b, tp), jnp.float32)],
        interpret=interpret,
    )(s, protos_flat, scales, mask)
    return aff, lab[:, 0], mar[:, 0]


# ---------------------------------------------------------------------------
# Per-arrival kernel (PR-5): grid over prototypes, SMEM running best
# ---------------------------------------------------------------------------

def _kernel(v_ref, p_ref, m_ref, aff_ref, lab_ref, mar_ref,
            bval_ref, bsec_ref, bidx_ref, *, n_steps: int,
            compute_dtype: str):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        bval_ref[0] = -jnp.inf
        bsec_ref[0] = -jnp.inf
        bidx_ref[0] = 0

    v = v_ref[...]                                       # (d, k) fp32
    p = p_ref[...]                                       # (d, d) fp32
    if compute_dtype == "bf16":
        w = jax.lax.dot_general(
            p.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (d, k) fp32 acc
    else:
        w = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    a = jnp.sum(w * v)                                   # trace(V^T P V)
    a = jnp.where(m_ref[t] > 0.5, a, -jnp.inf)
    aff_ref[t] = a

    prev_best = bval_ref[0]

    @pl.when(a > prev_best)
    def _new_best():
        bsec_ref[0] = prev_best
        bval_ref[0] = a
        bidx_ref[0] = t

    @pl.when((a <= prev_best) & (a > bsec_ref[0]))
    def _new_second():
        bsec_ref[0] = a

    @pl.when(t == n_steps - 1)
    def _flush():
        lab_ref[0] = bidx_ref[0]
        # A one-cluster directory has no runner-up; the margin degenerates
        # to the affinity itself (matching the reference).
        mar_ref[0] = (bval_ref[0] if n_steps == 1
                      else bval_ref[0] - bsec_ref[0])


@functools.partial(jax.jit,
                   static_argnames=("n_clusters", "compute_dtype",
                                    "interpret"))
def assign_one_pallas(v: jax.Array, protos_flat: jax.Array,
                      mask: jax.Array, n_clusters: int,
                      compute_dtype: str = "bf16", interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``v (d, k)``, ``protos_flat (T*d, d)``, ``mask (T,)`` ->
    ``(affinity (T,) f32 RAW trace, label i32, margin f32 RAW)``.

    ``d`` and ``k`` must be lane multiples (128); ``ops.py`` pads (zero
    rows/columns leave every trace exact).  Affinities are raw traces —
    the ``/k`` normalisation is cheap postprocessing in ``ops.py``.
    """
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"compute_dtype must be one of {COMPUTE_DTYPES}, "
                         f"got {compute_dtype!r}")
    d, k = v.shape
    if protos_flat.shape != (n_clusters * d, d):
        raise ValueError(f"bad shapes v={v.shape} "
                         f"protos_flat={protos_flat.shape} T={n_clusters}")
    if d % 128 or k % 128:
        raise ValueError(f"(d, k)={(d, k)} must be lane multiples of 128")
    grid = (n_clusters,)
    scalar_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    aff, lab, mar = pl.pallas_call(
        functools.partial(_kernel, n_steps=n_clusters,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, k), lambda t: (0, 0)),
            pl.BlockSpec((d, d), lambda t: (t, 0)),
            scalar_spec,
        ],
        out_specs=(scalar_spec, scalar_spec, scalar_spec),
        out_shape=(jax.ShapeDtypeStruct((n_clusters,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32),
                        pltpu.SMEM((1,), jnp.float32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(v.astype(jnp.float32), protos_flat.astype(jnp.float32),
      mask.astype(jnp.float32))
    return aff, lab[0], mar[0]
