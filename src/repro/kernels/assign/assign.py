"""Fused project + trace + argmax Pallas kernel for cluster assignment.

One newcomer's assignment visits every cluster prototype once:

grid = (T,): each step loads the newcomer's eigenvector block ``V (d, k)``
(resident across steps) and one prototype ``P_t (d, d)``, computes the
projection ``P_t V`` on the MXU (bf16 inputs / fp32 accumulation via
``preferred_element_type`` when ``compute_dtype="bf16"``), contracts it
against ``V`` on the VPU into the trace ``sum((P_t V) * V)``, and folds
the scalar into a running (best, second-best, argmax) kept in SMEM.  The
final step flushes the label and the confidence margin — the ``(T,)``
affinity row never round-trips through HBM for its reduction.

Tie-breaking matches ``jnp.argmax`` (first index wins): only a strictly
greater affinity displaces the running best.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

COMPUTE_DTYPES = ("fp32", "bf16")


def _kernel(v_ref, p_ref, m_ref, aff_ref, lab_ref, mar_ref,
            bval_ref, bsec_ref, bidx_ref, *, n_steps: int,
            compute_dtype: str):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        bval_ref[0] = -jnp.inf
        bsec_ref[0] = -jnp.inf
        bidx_ref[0] = 0

    v = v_ref[...]                                       # (d, k) fp32
    p = p_ref[...]                                       # (d, d) fp32
    if compute_dtype == "bf16":
        w = jax.lax.dot_general(
            p.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (d, k) fp32 acc
    else:
        w = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    a = jnp.sum(w * v)                                   # trace(V^T P V)
    a = jnp.where(m_ref[t] > 0.5, a, -jnp.inf)
    aff_ref[t] = a

    prev_best = bval_ref[0]

    @pl.when(a > prev_best)
    def _new_best():
        bsec_ref[0] = prev_best
        bval_ref[0] = a
        bidx_ref[0] = t

    @pl.when((a <= prev_best) & (a > bsec_ref[0]))
    def _new_second():
        bsec_ref[0] = a

    @pl.when(t == n_steps - 1)
    def _flush():
        lab_ref[0] = bidx_ref[0]
        # A one-cluster directory has no runner-up; the margin degenerates
        # to the affinity itself (matching the reference).
        mar_ref[0] = (bval_ref[0] if n_steps == 1
                      else bval_ref[0] - bsec_ref[0])


@functools.partial(jax.jit,
                   static_argnames=("n_clusters", "compute_dtype",
                                    "interpret"))
def assign_one_pallas(v: jax.Array, protos_flat: jax.Array,
                      mask: jax.Array, n_clusters: int,
                      compute_dtype: str = "bf16", interpret: bool = True
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``v (d, k)``, ``protos_flat (T*d, d)``, ``mask (T,)`` ->
    ``(affinity (T,) f32 RAW trace, label i32, margin f32 RAW)``.

    ``d`` and ``k`` must be lane multiples (128); ``ops.py`` pads (zero
    rows/columns leave every trace exact).  Affinities are raw traces —
    the ``/k`` normalisation is cheap postprocessing in ``ops.py``.
    """
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"compute_dtype must be one of {COMPUTE_DTYPES}, "
                         f"got {compute_dtype!r}")
    d, k = v.shape
    if protos_flat.shape != (n_clusters * d, d):
        raise ValueError(f"bad shapes v={v.shape} "
                         f"protos_flat={protos_flat.shape} T={n_clusters}")
    if d % 128 or k % 128:
        raise ValueError(f"(d, k)={(d, k)} must be lane multiples of 128")
    grid = (n_clusters,)
    scalar_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    aff, lab, mar = pl.pallas_call(
        functools.partial(_kernel, n_steps=n_clusters,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, k), lambda t: (0, 0)),
            pl.BlockSpec((d, d), lambda t: (t, 0)),
            scalar_spec,
        ],
        out_specs=(scalar_spec, scalar_spec, scalar_spec),
        out_shape=(jax.ShapeDtypeStruct((n_clusters,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32),
                        pltpu.SMEM((1,), jnp.float32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(v.astype(jnp.float32), protos_flat.astype(jnp.float32),
      mask.astype(jnp.float32))
    return aff, lab[0], mar[0]
