from repro.kernels.assign.ops import assign, assign_looped
from repro.kernels.assign.ref import assign_ref
