from repro.kernels.assign.ops import assign
from repro.kernels.assign.ref import assign_ref
