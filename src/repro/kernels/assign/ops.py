"""Public wrapper for the fused assignment kernel: pad + batch + normalize.

The default path (``assign``) scores the whole wave with ONE tiled matmul
(``assign_wave_pallas``): the wave's signature projectors are flattened to
``S (B, d^2)`` and contracted against the flattened directory, with the
argmax/margin verdict fused into the kernel's last reduction tile.  Tile
sizes come from ``kernels.tuning`` (autotuned cache or per-backend
heuristics) unless pinned by the caller; long waves are chunked so the
flattened ``S`` never exceeds a bounded footprint.  The directory may be
pre-quantized (``kernels.quant``): pass the int8/bf16 table as ``protos``
and the per-prototype ``scales`` — dequantization happens inside the
kernel's epilogue.

``assign_looped`` is the previous generation (``lax.map`` of a
per-arrival kernel, one grid launch per arrival) kept as the benchmark
baseline.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tuning
from repro.kernels.assign.assign import assign_one_pallas, assign_wave_pallas
from repro.kernels.assign.ref import assign_ref  # noqa: F401

_LANE = 128
# Cap on flattened-S elements per kernel dispatch (~64 MiB f32); longer
# waves are split into equal chunks and mapped.
_MAX_S_ELEMS = 1 << 24


def assign(v: jax.Array, protos: jax.Array, mask: jax.Array | None = None,
           compute_dtype: str = "bf16", interpret: bool | None = None, *,
           scales: jax.Array | None = None, block_b: int | None = None,
           block_d2: int | None = None
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched fused assignment: ``v (B, d, k)``, ``protos (T, d, d)`` ->
    ``(affinity (B, T), labels (B,) i32, margin (B,))`` — same contract
    (and ``/k`` normalisation) as ``assign_ref``.

    ``protos`` may be f32, bf16, or int8; int8 requires the matching
    per-prototype ``scales (T,)`` from ``quant.quantize_directory`` (the
    dequant multiply rides in the kernel epilogue and is exact given the
    quantized table).  ``mask (T,)`` marks live clusters (dead ones can
    never win the argmax).  ``block_b``/``block_d2`` pin tile sizes;
    left unset they resolve through the autotune cache / heuristics.
    """
    interpret = dispatch.resolve_interpret(interpret)
    b, d, _ = v.shape
    if block_b is None or block_d2 is None:
        blocks = tuning.get_blocks("assign", b=b, d2=d * d)
        block_b = block_b or blocks["block_b"]
        block_d2 = block_d2 or blocks["block_d2"]
    return _assign_impl(v, protos, scales, mask, compute_dtype=compute_dtype,
                        interpret=interpret, block_b=block_b,
                        block_d2=block_d2)


@partial(jax.jit, static_argnames=("compute_dtype", "interpret", "block_b",
                                   "block_d2"))
def _assign_impl(v, protos, scales, mask, *, compute_dtype: str,
                 interpret: bool, block_b: int, block_d2: int):
    b, d, k = v.shape
    t = protos.shape[0]
    d2 = d * d
    m = (jnp.ones((t,), jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    sc = (jnp.ones((t,), jnp.float32) if scales is None
          else scales.astype(jnp.float32))

    # Pad the directory axis to a lane multiple and the flattened-feature
    # axis to a block multiple; zeros are exact (padded prototypes are
    # also mask-dead, padded features contribute zero to every trace).
    tp = t + (-t % _LANE)
    d2p = d2 + (-d2 % block_d2)
    p_flat = jnp.pad(protos.reshape(t, d2), ((0, tp - t), (0, d2p - d2)))
    sc_row = jnp.pad(sc, (0, tp - t), constant_values=1.0)[None, :]
    m_row = jnp.pad(m, (0, tp - t))[None, :]

    def score(v_c):
        s = jnp.einsum("bdk,bek->bde", v_c, v_c).reshape(v_c.shape[0], d2)
        s = jnp.pad(s, ((0, 0), (0, d2p - d2)))
        return assign_wave_pallas(s, p_flat, sc_row, m_row, n_clusters=t,
                                  block_b=block_b, block_d2=block_d2,
                                  compute_dtype=compute_dtype,
                                  interpret=interpret)

    chunk = max(block_b, _MAX_S_ELEMS // d2p // block_b * block_b)
    v = v.astype(jnp.float32)
    if b <= chunk:
        bp = b + (-b % block_b)
        aff, lab, mar = score(jnp.pad(v, ((0, bp - b), (0, 0), (0, 0))))
    else:
        bp = b + (-b % chunk)
        aff, lab, mar = jax.lax.map(
            score, jnp.pad(v, ((0, bp - b), (0, 0), (0, 0))
                           ).reshape(bp // chunk, chunk, d, k))
        aff = aff.reshape(bp, tp)
        lab = lab.reshape(bp)
        mar = mar.reshape(bp)
    return aff[:b, :t] / k, lab[:b], mar[:b] / k


@partial(jax.jit, static_argnames=("compute_dtype", "interpret"))
def assign_looped(v: jax.Array, protos: jax.Array,
                  mask: jax.Array | None = None, compute_dtype: str = "bf16",
                  interpret: bool | None = None
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Previous-generation assignment (one kernel launch per arrival via
    ``lax.map``) — kept as the benchmark baseline for the wave kernel.
    Same contract as ``assign``."""
    if interpret is None:  # inside jit: resolve statically, no tracer leak
        interpret = dispatch.resolve_interpret(None)
    b, d, k = v.shape
    t = protos.shape[0]
    m = (jnp.ones((t,), jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    pad_d = (-d) % _LANE
    pad_k = (-k) % _LANE
    v = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, pad_d), (0, pad_k)))
    protos_flat = jnp.pad(protos.astype(jnp.float32),
                          ((0, 0), (0, pad_d), (0, pad_d))
                          ).reshape(t * (d + pad_d), d + pad_d)

    def one(v_b):
        return assign_one_pallas(v_b, protos_flat, m, n_clusters=t,
                                 compute_dtype=compute_dtype,
                                 interpret=interpret)

    aff, labels, margin = jax.lax.map(one, v)
    return aff / k, labels, margin / k
