"""Public wrapper for the fused assignment kernel: pad + batch + normalize."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.assign.assign import assign_one_pallas
from repro.kernels.assign.ref import assign_ref  # noqa: F401


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("compute_dtype", "interpret"))
def assign(v: jax.Array, protos: jax.Array, mask: jax.Array | None = None,
           compute_dtype: str = "bf16", interpret: bool | None = None
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched fused assignment: ``v (B, d, k)``, ``protos (T, d, d)`` ->
    ``(affinity (B, T), labels (B,) i32, margin (B,))`` — same contract
    (and ``/k`` normalisation) as ``assign_ref``.

    ``d``/``k`` are zero-padded to lane multiples of 128 (padded rows and
    columns contribute exactly zero to every trace); the wave rides
    through ``lax.map``, so the whole wave is ONE dispatch.  ``mask (T,)``
    marks live clusters (dead ones can never win the argmax).
    """
    interpret = (not _is_tpu()) if interpret is None else interpret
    b, d, k = v.shape
    t = protos.shape[0]
    m = (jnp.ones((t,), jnp.float32) if mask is None
         else mask.astype(jnp.float32))
    pad_d = (-d) % 128
    pad_k = (-k) % 128
    v = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, pad_d), (0, pad_k)))
    protos_flat = jnp.pad(protos.astype(jnp.float32),
                          ((0, 0), (0, pad_d), (0, pad_d))
                          ).reshape(t * (d + pad_d), d + pad_d)

    def one(v_b):
        return assign_one_pallas(v_b, protos_flat, m, n_clusters=t,
                                 compute_dtype=compute_dtype,
                                 interpret=interpret)

    aff, labels, margin = jax.lax.map(one, v)
    return aff / k, labels, margin / k
