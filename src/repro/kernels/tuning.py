"""Block-size selection for the Pallas kernels: static heuristics plus a
measured-sweep autotuner with a persistent on-disk cache.

Every kernel wrapper (``kernels/*/ops.py``) resolves its tile sizes here
when the caller does not pin them:

  1. **Cache hit** — an entry keyed ``kernel x shape-bucket x backend``
     (backend = platform + device kind, via ``kernels.dispatch``), filled
     by a previous ``autotune`` sweep.  Cached tiles measured on one
     device class are never replayed on another.
  2. **Heuristic default** — when tuning is off (no cache entry), a
     static per-backend rule: on TPU, MXU-friendly 128-512 tiles; on CPU
     (interpret mode) the grid-step count IS the cost, so tiles grow to
     the whole (lane-rounded) dimension and the grid collapses toward a
     single step.

The sweep (``autotune``) times caller-supplied candidates and records the
winner.  Set ``REPRO_TUNE_CACHE=/path/to/cache.json`` to persist results
across processes (``benchmarks/bench_kernels.py --tune`` populates it);
without the env var the sweep still caches in-memory for the process.

Shape buckets round every dimension up to a power of two, so one sweep at
``n=2048`` serves ``n in (1025..2048]`` — tile choice is insensitive to
sub-bucket variation and the sweep cost stays bounded.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, Iterable

from repro.kernels import dispatch

__all__ = ["KERNELS", "shape_bucket", "cache_key", "cache_path",
           "heuristic_blocks", "get_blocks", "autotune", "lookup",
           "record", "clear_cache", "divisor_block"]

_ENV = "REPRO_TUNE_CACHE"
_LANE = 128

#: Kernel families the tuner knows tile heuristics for.
KERNELS = ("gram", "gram_project", "featurize_gram", "eigproject",
           "linkage", "assign", "recurrent_scan")

# In-memory overlay of the on-disk cache (survives the process even when
# REPRO_TUNE_CACHE is unset — "tuning on" without persistence).
_mem: dict[str, dict] = {}
_loaded_from: str | None = None


def _round_lane(x: int) -> int:
    """Round up to the 128-lane quantum (minimum one lane group)."""
    return max(_LANE, ((int(x) + _LANE - 1) // _LANE) * _LANE)


def _pow2_ceil(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def divisor_block(n: int, cap: int = 4096) -> int:
    """Largest lane-multiple block <= ``cap`` that divides ``n`` exactly
    (for kernels like ``linkage`` whose rows are padded once up front and
    cannot re-pad per call).  ``n`` must itself be a lane multiple."""
    if n % _LANE:
        raise ValueError(f"row length {n} is not a lane multiple of {_LANE}")
    for b in range(min(cap, n), _LANE - 1, -_LANE):
        if n % b == 0:
            return b
    return _LANE


def shape_bucket(**dims: int) -> str:
    """Canonical bucket string: dims sorted by name, pow2-ceiled."""
    return ",".join(f"{k}={_pow2_ceil(v)}" for k, v in sorted(dims.items()))


def _backend_tag() -> str:
    return f"{dispatch.backend_kind()}:{dispatch.device_kind()}"


def cache_key(kernel: str, **dims: int) -> str:
    return f"{kernel}|{_backend_tag()}|{shape_bucket(**dims)}"


def cache_path() -> Path | None:
    p = os.environ.get(_ENV, "")
    return Path(p) if p else None


def _load_disk() -> None:
    """Merge the on-disk cache under the in-memory overlay (memory wins:
    it holds this process's fresher sweeps)."""
    global _loaded_from
    p = cache_path()
    tag = str(p) if p else None
    if tag == _loaded_from:
        return
    _loaded_from = tag
    if p is None or not p.exists():
        return
    try:
        disk = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError):
        return
    for k, v in disk.items():
        _mem.setdefault(k, v)


def _persist() -> None:
    p = cache_path()
    if p is None:
        return
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(json.dumps(_mem, indent=2, sort_keys=True) + "\n")
    tmp.replace(p)


def clear_cache() -> None:
    """Drop the in-memory cache (tests; does not touch the disk file)."""
    global _loaded_from
    _mem.clear()
    _loaded_from = None


def lookup(kernel: str, **dims: int) -> dict | None:
    """Tuned blocks for this kernel/backend/bucket, or None."""
    _load_disk()
    hit = _mem.get(cache_key(kernel, **dims))
    return dict(hit["blocks"]) if hit else None


def record(kernel: str, blocks: dict, measured_s: float | None = None,
           sweep: dict | None = None, **dims: int) -> None:
    """Store a sweep winner; persists when REPRO_TUNE_CACHE is set."""
    entry: dict = {"blocks": dict(blocks)}
    if measured_s is not None:
        entry["measured_s"] = measured_s
    if sweep:
        entry["sweep"] = sweep
    _load_disk()
    _mem[cache_key(kernel, **dims)] = entry
    _persist()


# ---------------------------------------------------------------------------
# Static heuristics — the defaults when tuning is off
# ---------------------------------------------------------------------------

def heuristic_blocks(kernel: str, **dims: int) -> dict:
    """Per-backend static tile defaults.

    Lowered backends (TPU/GPU) get MXU/SM-friendly 128-512 tiles — big
    enough to amortize the pipeline, small enough that double-buffered
    operands fit VMEM.  CPU interpret mode has no VMEM and pays a fixed
    Python cost PER GRID STEP, so tiles grow to the lane-rounded full
    dimension (capped) and the grid collapses toward one step.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}: one of {KERNELS}")
    lowered = dispatch.supports_lowering()

    def tile(dim: int, accel_cap: int, interp_cap: int) -> int:
        cap = accel_cap if lowered else interp_cap
        return min(_round_lane(dim), cap)

    if kernel == "gram":
        return {"block_n": tile(dims["n"], 512, 4096),
                "block_d": tile(dims["d"], 256, 2048)}
    if kernel == "gram_project":
        return {"block_n": tile(dims["n"], 512, 4096),
                "block_k": tile(dims["k"], 256, 2048),
                "double_buffer": lowered}
    if kernel == "featurize_gram":
        return {"block_n": tile(dims["n"], 512, 4096),
                "double_buffer": lowered}
    if kernel == "eigproject":
        return {"block_d": tile(dims["d"], 256, 2048),
                "block_k": tile(dims["k"], 256, 2048)}
    if kernel == "linkage":
        return {"block": divisor_block(dims["n"],
                                       cap=512 if lowered else 4096)}
    if kernel == "recurrent_scan":
        # chunk = time tile (the sequential grid axis — its square drives
        # the intra-chunk pairwise-decay footprint).  Lowered backends
        # amortize the O(chunk^2) tile on the MXU, so bigger wins; the
        # interpreter executes it eagerly, so the quadratic dominates and
        # small chunks win.  block_d = channel tile.
        chunk = max(8, min(64 if lowered else 16, _pow2_ceil(dims["s"])))
        return {"chunk": chunk,
                "block_d": tile(dims["d"], 256, 1024)}
    # assign: rows = arrival wave, lanes = flattened d*d directory axis
    return {"block_b": tile(dims["b"], 256, 1024),
            "block_d2": tile(dims["d2"], 512, 8192)}


def get_blocks(kernel: str, **dims: int) -> dict:
    """The resolved tile plan: heuristic defaults overlaid by any tuned
    cache entry for this kernel x backend x shape-bucket."""
    blocks = heuristic_blocks(kernel, **dims)
    hit = lookup(kernel, **dims)
    if hit:
        blocks.update(hit)
    dispatch.record_dispatch(kernel, blocks)
    return blocks


# ---------------------------------------------------------------------------
# The measured sweep
# ---------------------------------------------------------------------------

def autotune(kernel: str, run: Callable[[dict], None],
             candidates: Iterable[dict], n_iter: int = 3, warmup: int = 1,
             **dims: int) -> dict:
    """Time ``run(blocks)`` over candidate tile plans, cache the winner.

    ``run`` must execute the kernel end-to-end and block until ready.
    Candidates that raise ``ValueError`` (invalid divisibility for the
    shape) are skipped.  Returns the winning blocks; the measured sweep
    is recorded under the kernel/backend/bucket cache key and persisted
    when ``REPRO_TUNE_CACHE`` is set.
    """
    results: dict[str, float] = {}
    best: tuple[float, dict] | None = None
    for cand in candidates:
        cand = dict(cand)
        try:
            for _ in range(warmup):
                run(cand)
            t0 = time.perf_counter()
            for _ in range(n_iter):
                run(cand)
            dt = (time.perf_counter() - t0) / n_iter
        except ValueError:
            continue
        results[json.dumps(cand, sort_keys=True)] = dt
        if best is None or dt < best[0]:
            best = (dt, cand)
    if best is None:
        raise ValueError(f"no valid tuning candidate for {kernel} {dims}")
    record(kernel, best[1], measured_s=best[0], sweep=results, **dims)
    return best[1]
