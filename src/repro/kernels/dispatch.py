"""Shared Pallas execution dispatch: one place that decides lowered vs
interpret execution for every kernel family.

Every ``kernels/*/ops.py`` wrapper used to carry its own copy-pasted
``_is_tpu()`` helper and defaulted to interpret mode everywhere but TPU.
That left GPU hosts interpreting (Pallas has a Triton lowering there) and
scattered the policy across six files.  This module is now the single
source of truth:

  ``interpret=None``  ->  lowered on TPU (Mosaic) and GPU (Triton),
                          interpret-mode fallback on CPU (no Pallas
                          lowering exists there — this is what keeps CI
                          green off-accelerator).
  ``interpret=bool``  ->  explicit override, passed through untouched.

``device_kind()`` feeds the autotuner's cache key (``kernels/tuning.py``)
and the roofline hardware table (``launch/roofline.py``): tuned block
sizes measured on one device class must never be replayed on another.
"""
from __future__ import annotations

import jax

from repro import obs

__all__ = ["LOWERED_BACKENDS", "backend_kind", "supports_lowering",
           "resolve_interpret", "device_kind", "record_dispatch"]

#: Platforms with a real Pallas lowering: TPU via Mosaic, GPU via Triton.
LOWERED_BACKENDS = ("tpu", "gpu")


def backend_kind() -> str:
    """The JAX default backend platform: ``"tpu" | "gpu" | "cpu"``."""
    return jax.default_backend()


def supports_lowering() -> bool:
    """True when Pallas can compile (not interpret) on this host."""
    return backend_kind() in LOWERED_BACKENDS


def resolve_interpret(interpret: bool | None) -> bool:
    """The one interpret-mode policy: auto-detect unless overridden.

    ``None`` resolves to lowered execution on TPU/GPU and interpret mode
    on CPU; an explicit bool wins unconditionally (CI parity tests pin
    ``interpret=True`` so kernel bodies execute everywhere).
    """
    return (not supports_lowering()) if interpret is None else bool(interpret)


def device_kind() -> str:
    """Hardware model string of device 0 (e.g. ``"TPU v5e"``, ``"cpu"``).

    Cache keys and the roofline hardware table key on this, not on the
    coarse platform name — a v4 and a v5e want different tiles.
    """
    return jax.devices()[0].device_kind


def record_dispatch(kernel: str, blocks: dict | None = None) -> None:
    """Telemetry tap for kernel dispatches (``tuning.get_blocks`` calls
    this at tile-resolution time — host-side, before the jitted impl, so
    the disabled path adds no work inside any jit boundary).

    Feeds ``dispatch_count`` (stack-wide total), per-kernel
    ``kernel_calls{kernel=..}`` counters, and a ``kernel_blocks`` gauge
    holding the resolved tile plan id.
    """
    if not obs.enabled():
        return
    obs.count("dispatch_count")
    obs.count("kernel_calls", kernel=kernel)
    if blocks:
        plan = ",".join(f"{k}={blocks[k]}" for k in sorted(blocks))
        obs.gauge("kernel_blocks", plan, kernel=kernel)
