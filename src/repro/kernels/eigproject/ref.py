"""Pure-jnp oracle for the eigprojection kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def project_norms_ref(g: jax.Array, v: jax.Array) -> jax.Array:
    proj = g.astype(jnp.float32) @ v.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(proj * proj, axis=0))
