"""Public wrapper for the eigprojection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tuning
from repro.kernels.eigproject.eigproject import project_norms_pallas
from repro.kernels.eigproject.ref import project_norms_ref


def project_norms(g: jax.Array, v: jax.Array, block_d: int | None = None,
                  block_k: int | None = None, interpret: bool | None = None
                  ) -> jax.Array:
    """``lamhat = ||G v_k||`` per column.  Pads to block multiples; the
    padded G rows/cols are zero so norms over the valid columns are exact.

    Unpinned block sizes resolve through ``kernels.tuning``."""
    d = g.shape[0]
    k = v.shape[1]
    interpret = dispatch.resolve_interpret(interpret)
    if block_d is None or block_k is None:
        blocks = tuning.get_blocks("eigproject", d=d, k=k)
        block_d = block_d or blocks["block_d"]
        block_k = block_k or blocks["block_k"]
    pad_d = (-d) % block_d
    pad_k = (-k) % block_k
    if pad_d:
        g = jnp.pad(g, ((0, pad_d), (0, pad_d)))
        v = jnp.pad(v, ((0, pad_d), (0, 0)))
    if pad_k:
        v = jnp.pad(v, ((0, 0), (0, pad_k)))
    out = project_norms_pallas(g, v, block_d=block_d, block_k=block_k,
                               interpret=interpret)
    return out[:k]
