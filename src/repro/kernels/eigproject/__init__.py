from repro.kernels.eigproject.ops import project_norms
