"""Fused Gram-projection + column-norm kernel (paper Eq. 2).

Computes ``lamhat_k = || G v_k ||_2`` for all k eigenvector columns in one
pass: grid = (k/bk, d/bd_row, d/bd_in); each step multiplies a (bd_row,
bd_in) tile of G with a (bd_in, bk) tile of V into an fp32 row-block
accumulator; when a row-block's inner reduction completes, its squared
values are added to the per-column sum-of-squares accumulator, and the
final step writes ``sqrt``.  The (d, bk) intermediate ``G @ V`` never
round-trips to HBM — that is the fusion win over the two-op jnp form.

Grid order: k-block outermost, then row-blocks, inner-dim innermost, so
both accumulators are live for one (k-block) at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(g_ref, v_ref, o_ref, prod_acc, sq_acc, *, n_row: int,
            n_inner: int):
    r = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when((r == 0) & (c == 0))
    def _init_sq():
        sq_acc[...] = jnp.zeros_like(sq_acc)

    @pl.when(c == 0)
    def _init_prod():
        prod_acc[...] = jnp.zeros_like(prod_acc)

    prod_acc[...] += jax.lax.dot_general(
        g_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(c == n_inner - 1)
    def _accum_sq():
        sq_acc[...] += jnp.sum(jnp.square(prod_acc[...]), axis=0,
                               keepdims=True)

    @pl.when((r == n_row - 1) & (c == n_inner - 1))
    def _flush():
        o_ref[...] = jnp.sqrt(sq_acc[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_k",
                                             "interpret"))
def project_norms_pallas(g: jax.Array, v: jax.Array, block_d: int = 128,
                         block_k: int = 128, interpret: bool = False
                         ) -> jax.Array:
    """``g (d, d)``, ``v (d, k)`` -> ``||g @ v||_2`` per column, ``(k,)``."""
    d, d2 = g.shape
    dv, k = v.shape
    if d != d2 or dv != d:
        raise ValueError(f"bad shapes g={g.shape} v={v.shape}")
    if d % block_d or k % block_k:
        raise ValueError(f"{(d, k)} not divisible by ({block_d}, {block_k})")
    n_row = d // block_d
    n_inner = d // block_d
    grid = (k // block_k, n_row, n_inner)
    out = pl.pallas_call(
        functools.partial(_kernel, n_row=n_row, n_inner=n_inner),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, block_d), lambda kk, r, c: (r, c)),
            pl.BlockSpec((block_d, block_k), lambda kk, r, c: (c, kk)),
        ],
        out_specs=pl.BlockSpec((1, block_k), lambda kk, r, c: (0, kk)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, block_k), jnp.float32),
                        pltpu.VMEM((1, block_k), jnp.float32)],
        interpret=interpret,
    )(g, v)
    return out[0]
