from repro.kernels.linkage.ops import linkage_step
from repro.kernels.linkage.ref import linkage_step_ref, lance_williams
