"""Public wrapper for the fused linkage-step kernel: pad + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, tuning
from repro.kernels.linkage.linkage import linkage_step_pallas
from repro.kernels.linkage.ref import linkage_step_ref  # noqa: F401


def linkage_step(row_a: jax.Array, row_b: jax.Array,
                 size_a: jax.Array, size_b: jax.Array,
                 mask: jax.Array, linkage: str = "average",
                 block: int | None = None, interpret: bool | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Lance-Williams update + masked argmax of one linkage row.

    ``row_a``/``row_b`` ``(n,)`` f32 with ``n`` a multiple of 128 (the
    ``ClusterEngine`` pads its matrix once up front), ``mask (n,)`` bool
    or float.  Returns ``(new_row (n,), argmax i32, max f32)`` — the same
    contract as ``linkage_step_ref``.  An unpinned ``block`` resolves
    through ``kernels.tuning`` (largest dividing lane multiple under the
    backend cap — the rows cannot re-pad per call).
    """
    interpret = dispatch.resolve_interpret(interpret)
    n = row_a.shape[-1]
    if block is None:
        block = tuning.get_blocks("linkage", n=n)["block"]
    new_row, idx, val = linkage_step_pallas(
        row_a.astype(jnp.float32), row_b.astype(jnp.float32),
        size_a, size_b, mask.astype(jnp.float32), linkage=linkage,
        block=block, interpret=interpret)
    return new_row, idx, val
