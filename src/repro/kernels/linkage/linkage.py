"""Fused Lance-Williams row-update + masked-argmax Pallas kernel.

The NN-chain HAC inner loop is memory-bound: each step reads two
``(N,)`` linkage rows, writes one combined row, and immediately needs
that row's masked argmax.  Done naively that is three passes over the
row; this kernel does all of it in one sweep of column tiles:

grid = (n / block,): each step loads one ``(1, block)`` tile of the two
source rows and the mask, computes the Lance-Williams combination on the
VPU, writes the updated tile, and folds the tile's max/argmax into a
running best kept in SMEM.  The final step flushes the winning
``(value, index)`` pair — the row never revisits HBM for the reduction.

Tie-breaking matches ``jnp.argmax`` (first index wins): within a tile the
argmax picks the smallest column, and across tiles only a strictly
greater max displaces the running best.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.linkage.ref import LINKAGES


def _kernel(na_ref, nb_ref, a_ref, b_ref, m_ref, row_ref, val_ref, idx_ref,
            bval_ref, bidx_ref, *, linkage: str, n_steps: int, block: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        bval_ref[0] = -jnp.inf
        bidx_ref[0] = 0

    a = a_ref[...]                                     # (1, block)
    b = b_ref[...]
    if linkage == "average":
        na, nb = na_ref[0], nb_ref[0]
        new = (na * a + nb * b) / (na + nb)
    elif linkage == "single":
        new = jnp.maximum(a, b)
    else:  # complete
        new = jnp.minimum(a, b)
    new = jnp.where(m_ref[...] > 0.5, new, -jnp.inf)
    row_ref[...] = new

    tile_max = jnp.max(new)
    cols = jax.lax.broadcasted_iota(jnp.int32, new.shape, 1)
    tile_arg = jnp.min(jnp.where(new == tile_max, cols, block))

    @pl.when(tile_max > bval_ref[0])
    def _update():
        bval_ref[0] = tile_max
        bidx_ref[0] = tile_arg + t * block

    @pl.when(t == n_steps - 1)
    def _flush():
        val_ref[0] = bval_ref[0]
        idx_ref[0] = bidx_ref[0]


@functools.partial(jax.jit,
                   static_argnames=("linkage", "block", "interpret"))
def linkage_step_pallas(row_a: jax.Array, row_b: jax.Array,
                        size_a: jax.Array, size_b: jax.Array,
                        mask: jax.Array, linkage: str = "average",
                        block: int = 512, interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``row_a/row_b/mask (n,)`` -> ``(new_row (n,), argmax, max)``.

    ``n`` must be a multiple of ``block`` (itself a lane multiple of 128);
    ``ops.py`` pads.  ``mask`` is float (1.0 keep / 0.0 drop); sizes ride
    in SMEM as ``(1,)`` scalars.
    """
    if linkage not in LINKAGES:
        raise ValueError(f"linkage must be one of {LINKAGES}, got {linkage!r}")
    n = row_a.shape[-1]
    if n % block or block % 128:
        raise ValueError(f"n={n} must be a multiple of block={block} "
                         f"(a lane multiple of 128)")
    grid = (n // block,)
    scalar_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    row_spec = pl.BlockSpec((1, block), lambda t: (0, t))
    new_row, val, idx = pl.pallas_call(
        functools.partial(_kernel, linkage=linkage, n_steps=grid[0],
                          block=block),
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, row_spec, row_spec, row_spec],
        out_specs=(row_spec, scalar_spec, scalar_spec),
        out_shape=(jax.ShapeDtypeStruct((1, n), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.float32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(size_a, jnp.float32).reshape(1),
      jnp.asarray(size_b, jnp.float32).reshape(1),
      row_a.reshape(1, n), row_b.reshape(1, n), mask.reshape(1, n))
    return new_row[0], idx[0], val[0]
