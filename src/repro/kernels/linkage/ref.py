"""jnp oracle for the fused HAC linkage-step kernel.

One NN-chain inner step over a similarity-linkage matrix does two things
to a single row: a Lance-Williams combination of the two merging
clusters' rows, and a masked argmax of the result (the merged cluster's
nearest neighbour / the chain-extension target).  Fusing them means the
updated row is consumed for its argmax while still in registers instead
of round-tripping through memory twice.

Similarity semantics (higher = closer), so linkages are mirrored:

  average : (na * a + nb * b) / (na + nb)      (UPGMA, convex combination)
  single  : max(a, b)                          (closest members)
  complete: min(a, b)                          (farthest members)

Passing the SAME row for ``a`` and ``b`` makes the update an identity for
every linkage, which is how the chain-extension step reuses this kernel
as a pure masked argmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LINKAGES = ("average", "single", "complete")


def lance_williams(row_a: jax.Array, row_b: jax.Array, size_a: jax.Array,
                   size_b: jax.Array, linkage: str) -> jax.Array:
    """Combine two clusters' linkage rows (similarity semantics)."""
    if linkage == "average":
        return (size_a * row_a + size_b * row_b) / (size_a + size_b)
    if linkage == "single":
        return jnp.maximum(row_a, row_b)
    if linkage == "complete":
        return jnp.minimum(row_a, row_b)
    raise ValueError(f"linkage must be one of {LINKAGES}, got {linkage!r}")


def linkage_step_ref(row_a: jax.Array, row_b: jax.Array,
                     size_a: jax.Array, size_b: jax.Array,
                     mask: jax.Array, linkage: str = "average"
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``(new_row, argmax, max)`` of the masked Lance-Williams update.

    ``row_a``/``row_b`` ``(n,)`` f32, ``size_a``/``size_b`` scalars,
    ``mask (n,)`` bool (False entries become ``-inf`` and can never win
    the argmax).  Ties resolve to the smallest index, matching
    ``jnp.argmax``.
    """
    new = lance_williams(row_a, row_b,
                         jnp.asarray(size_a, row_a.dtype),
                         jnp.asarray(size_b, row_a.dtype), linkage)
    new = jnp.where(mask, new, -jnp.inf)
    idx = jnp.argmax(new).astype(jnp.int32)
    return new, idx, new[idx]
