"""Self-contained optimizers (no optax dependency)."""
from repro.optim.optimizers import (sgd, momentum, adamw, OptState,
                                    Optimizer, apply_updates,
                                    cosine_schedule, constant_schedule,
                                    warmup_cosine_schedule, global_norm,
                                    clip_by_global_norm)
