"""Minimal, pytree-native optimizer library.

An ``Optimizer`` is a pair of pure functions (init, update) over parameter
pytrees, mirroring the optax interface shape so call-sites stay idiomatic,
but fully self-contained.  All state lives in pytrees so optimizers compose
with pjit sharding (state inherits param sharding) and with scan-stacked
layer parameters unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


class OptState(NamedTuple):
    step: jax.Array
    inner: PyTree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], tuple[PyTree, OptState]]
    """update(grads, state, params) -> (updates, new_state); updates are
    ADDED to params by ``apply_updates`` (they already contain the -lr)."""


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, tree)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1
                    ) -> Schedule:
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine_schedule(lr: float, warmup: int, total_steps: int,
                           final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(1, total_steps - warmup), final_frac)
    def f(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(1, warmup)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return f


def _as_schedule(lr: float | Schedule) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def sgd(lr: float | Schedule) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32), inner=())

    def update(grads, state, params):
        del params
        lr_t = sched(state.step)
        updates = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return updates, OptState(step=state.step + 1, inner=())

    return Optimizer(init, update)


def momentum(lr: float | Schedule, beta: float = 0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        vel = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), inner=vel)

    def update(grads, state, params):
        del params
        lr_t = sched(state.step)
        vel = jax.tree.map(lambda v, g: beta * v + g.astype(jnp.float32),
                           state.inner, grads)
        updates = jax.tree.map(lambda v: -lr_t * v, vel)
        return updates, OptState(step=state.step + 1, inner=vel)

    return Optimizer(init, update)


def adamw(lr: float | Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        inner = {"m": jax.tree.map(zeros, params),
                 "v": jax.tree.map(zeros, params)}
        return OptState(step=jnp.zeros((), jnp.int32), inner=inner)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(state.step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state.inner["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state.inner["v"], grads)

        def upd(m_, v_, p):
            mhat = m_ / bc1
            vhat = v_ / bc2
            return -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                            + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, m, v, params)
        return updates, OptState(step=step, inner={"m": m, "v": v})

    return Optimizer(init, update)
