"""Launch layer: production meshes, sharding rules, train/serve steps,
multi-pod dry-run, roofline analysis."""
