"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state).

Target: TPU v5e.  Single pod = 16 x 16 = 256 chips (data x model);
multi-pod = 2 x 16 x 16 = 512 chips (pod x data x model).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "batch_axes", "fsdp_axis", "tensor_axis"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axis(mesh: jax.sharding.Mesh) -> str | None:
    return "data" if "data" in mesh.axis_names else None


def tensor_axis(mesh: jax.sharding.Mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
