"""Roofline-term derivation from a compiled dry-run artifact.

Per (arch x shape x mesh):

  compute_term    = HLO_FLOPs_global  / (chips * PEAK_FLOPS)
  memory_term     = HLO_bytes_global  / (chips * HBM_BW)
  collective_term = collective_bytes_global / (chips * LINK_BW)

``compiled.cost_analysis()`` provides per-device FLOPs / bytes accessed
(the SPMD module is the per-device program), so global = per_device *
chips and the two formulations coincide.  Collective bytes are NOT in
cost_analysis: we parse the optimized HLO (``compiled.as_text()``) and sum
the shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (using max(result, operand) bytes per op —
a ring-transfer proxy, documented in EXPERIMENTS.md).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+\[[\d,]*\][^ ]*|\([^)]*\))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every TYPE[dims] occurrence in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_per_device: int
    counts: dict[str, int]
    bytes_by_kind: dict[str, int]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    bytes_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:          # async pair: count the -start only
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        result_text, kind = m.groups()
        result_bytes = _shape_bytes(result_text)
        # operand shapes appear in the argument list after the op name
        args = line[m.end():]
        operand_bytes = _shape_bytes(args)
        counts[kind] += 1
        bytes_by_kind[kind] += max(result_bytes, operand_bytes)
    return CollectiveStats(
        bytes_per_device=sum(bytes_by_kind.values()),
        counts={k: v for k, v in counts.items() if v},
        bytes_by_kind={k: v for k, v in bytes_by_kind.items() if v})


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6ND for training (fwd+bwd), 2ND for inference."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_params_active * tokens


@dataclasses.dataclass
class Roofline:
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    collective_counts: dict[str, int]
    collective_bytes_by_kind: dict[str, int]
    model_flops_global: float

    @property
    def compute_term_s(self) -> float:
        return self.hlo_flops_per_device / PEAK_FLOPS

    @property
    def memory_term_s(self) -> float:
        return self.hlo_bytes_per_device / HBM_BW

    @property
    def collective_term_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_term_s,
                 "memory": self.memory_term_s,
                 "collective": self.collective_term_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_counts": self.collective_counts,
            "collective_bytes_by_kind": self.collective_bytes_by_kind,
            "model_flops_global": self.model_flops_global,
            "compute_term_s": self.compute_term_s,
            "memory_term_s": self.memory_term_s,
            "collective_term_s": self.collective_term_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, chips: int, model_flops_global: float) -> Roofline:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):     # some backends return [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        chips=chips,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=byts,
        collective_bytes_per_device=float(stats.bytes_per_device),
        collective_counts=stats.counts,
        collective_bytes_by_kind=stats.bytes_by_kind,
        model_flops_global=model_flops_global,
    )


def memory_summary(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:              # pragma: no cover
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out
