"""Roofline modeling: hardware table, compiled-artifact analysis, and
analytic per-kernel cost models for the Pallas tile sweep.

Two consumers share this module:

* ``launch/dryrun.py`` — per (arch x shape x mesh) terms from a compiled
  module::

    compute_term    = HLO_FLOPs_global  / (chips * peak_flops)
    memory_term     = HLO_bytes_global  / (chips * hbm_bw)
    collective_term = collective_bytes_global / (chips * link_bw)

  ``compiled.cost_analysis()`` provides per-device FLOPs / bytes accessed
  (the SPMD module is the per-device program), so global = per_device *
  chips and the two formulations coincide.  Collective bytes are NOT in
  cost_analysis: we parse the optimized HLO (``compiled.as_text()``) and
  sum the shape bytes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute (using max(result, operand) bytes per
  op — a ring-transfer proxy, documented in EXPERIMENTS.md).

* ``benchmarks/bench_roofline.py`` — per (kernel x shape x tile plan)
  analytic FLOP/byte counts (``kernel_costs``) against the HOST device's
  roof (``detect_hardware``), the measurement loop that justifies the
  ``kernels/tuning.py`` tile heuristics.

Hardware peaks live in ``HW_TABLE`` keyed by device kind (the
``kernels.dispatch.device_kind()`` string), with a CPU entry so the
interpret-mode host still gets a (rough) roof; unknown kinds fall back by
platform.  ``peak_flops`` may be overridden per call (the
``--peak-flops`` benchmark flag) for hosts whose kind string is missing.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.kernels import dispatch


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peaks: dense-matmul FLOP/s (bf16 where the unit has one),
    main-memory bandwidth, and per-link interconnect bandwidth."""

    name: str
    peak_flops: float
    hbm_bw: float
    link_bw: float


V5E = HardwareSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)

#: Device-kind -> peaks.  Keys are matched as lowercase substrings of
#: ``jax.devices()[0].device_kind`` (e.g. "TPU v5 lite" matches "v5 lite").
HW_TABLE: dict[str, HardwareSpec] = {
    "v5 lite": V5E,
    "v5e": V5E,
    "v5p": HardwareSpec("tpu-v5p", peak_flops=459e12, hbm_bw=2765e9,
                        link_bw=100e9),
    "v4": HardwareSpec("tpu-v4", peak_flops=275e12, hbm_bw=1228e9,
                       link_bw=50e9),
    "v6": HardwareSpec("tpu-v6e", peak_flops=918e12, hbm_bw=1640e9,
                       link_bw=100e9),
    "a100": HardwareSpec("gpu-a100", peak_flops=312e12, hbm_bw=1555e9,
                         link_bw=300e9),
    "h100": HardwareSpec("gpu-h100", peak_flops=989e12, hbm_bw=3350e9,
                         link_bw=450e9),
    # Interpret-mode host: one AVX-ish core-complex worth of f32 matmul
    # and a DDR-class memory system.  Deliberately round numbers — the
    # CPU roof only ranks tile plans, it is not a performance claim.
    "cpu": HardwareSpec("cpu", peak_flops=2e11, hbm_bw=50e9, link_bw=10e9),
}

# Backwards-compatible module constants (the original v5e-only model).
PEAK_FLOPS = V5E.peak_flops
HBM_BW = V5E.hbm_bw
LINK_BW = V5E.link_bw


def detect_hardware(peak_flops: float | None = None) -> HardwareSpec:
    """The host device's ``HardwareSpec`` by device-kind substring match,
    falling back to the platform name ("cpu"/"gpu"/"tpu"), then to the
    v5e reference.  ``peak_flops`` overrides the matmul peak (the
    ``--peak-flops`` flag for unlisted hosts)."""
    kind = dispatch.device_kind().lower()
    hw = None
    for key, spec in HW_TABLE.items():
        if key in kind:
            hw = spec
            break
    if hw is None:
        platform = dispatch.backend_kind()
        hw = HW_TABLE.get(platform, V5E)
        if platform == "gpu" and "gpu" not in HW_TABLE:   # pragma: no cover
            hw = HW_TABLE["a100"]
    if peak_flops is not None:
        hw = dataclasses.replace(hw, name=f"{hw.name}-custom",
                                 peak_flops=float(peak_flops))
    return hw


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+\[[\d,]*\][^ ]*|\([^)]*\))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every TYPE[dims] occurrence in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_per_device: int
    counts: dict[str, int]
    bytes_by_kind: dict[str, int]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    bytes_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:          # async pair: count the -start only
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        result_text, kind = m.groups()
        result_bytes = _shape_bytes(result_text)
        # operand shapes appear in the argument list after the op name
        args = line[m.end():]
        operand_bytes = _shape_bytes(args)
        counts[kind] += 1
        bytes_by_kind[kind] += max(result_bytes, operand_bytes)
    return CollectiveStats(
        bytes_per_device=sum(bytes_by_kind.values()),
        counts={k: v for k, v in counts.items() if v},
        bytes_by_kind={k: v for k, v in bytes_by_kind.items() if v})


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6ND for training (fwd+bwd), 2ND for inference."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_params_active * tokens


@dataclasses.dataclass
class Roofline:
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    collective_counts: dict[str, int]
    collective_bytes_by_kind: dict[str, int]
    model_flops_global: float
    hw: HardwareSpec = V5E

    @property
    def compute_term_s(self) -> float:
        return self.hlo_flops_per_device / self.hw.peak_flops

    @property
    def memory_term_s(self) -> float:
        return self.hlo_bytes_per_device / self.hw.hbm_bw

    @property
    def collective_term_s(self) -> float:
        return self.collective_bytes_per_device / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_term_s,
                 "memory": self.memory_term_s,
                 "collective": self.collective_term_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "chips": self.chips,
            "hw": self.hw.name,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_counts": self.collective_counts,
            "collective_bytes_by_kind": self.collective_bytes_by_kind,
            "model_flops_global": self.model_flops_global,
            "compute_term_s": self.compute_term_s,
            "memory_term_s": self.memory_term_s,
            "collective_term_s": self.collective_term_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, chips: int, model_flops_global: float,
            hw: HardwareSpec = V5E) -> Roofline:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):     # some backends return [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        chips=chips,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=byts,
        collective_bytes_per_device=float(stats.bytes_per_device),
        collective_counts=stats.counts,
        collective_bytes_by_kind=stats.bytes_by_kind,
        model_flops_global=model_flops_global,
        hw=hw,
    )


def memory_summary(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:              # pragma: no cover
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out


# ---------------------------------------------------------------------------
# Analytic kernel cost models — the tile-sweep measurement loop
# ---------------------------------------------------------------------------

def kernel_costs(kernel: str, blocks: dict | None = None,
                 itemsize: int = 4, **dims: int) -> dict[str, float]:
    """Analytic ``{"flops", "bytes"}`` for one kernel dispatch under a
    tile plan.

    FLOPs are tile-independent (the useful work); bytes are NOT — a tile
    plan that re-streams an operand per output block pays for it here,
    which is exactly why the sweep can rank plans before timing them.
    ``itemsize`` is the streamed-operand element size (4 f32, 2 bf16,
    1 int8 directory).  Dims follow the ``kernels.tuning`` vocabulary.
    """
    b = dict(blocks or {})
    if kernel == "gram":
        n, d = dims["n"], dims["d"]
        bd = b.get("block_d", 128)
        # each of the (d/bd)^2 output tiles streams two (n, bd) panels
        tiles = max(1, -(-d // bd)) ** 2
        return {"flops": 2.0 * n * d * d,
                "bytes": tiles * 2.0 * n * bd * itemsize + d * d * 4.0}
    if kernel == "gram_project":
        n, d, k = dims["n"], dims["d"], dims["k"]
        bk = b.get("block_k", 128)
        kblocks = max(1, -(-k // bk))
        # X re-streams once per k-block; V rides per (k, n) grid step
        return {"flops": 4.0 * n * d * k,
                "bytes": (kblocks * n * d + n // max(b.get("block_n", 128),
                                                     1) * d * k) * itemsize
                + k * 4.0}
    if kernel == "featurize_gram":
        n, m, d = dims["n"], dims["m"], dims["d"]
        return {"flops": 2.0 * n * m * d + 2.0 * n * d * d,
                "bytes": (n * m + m * d) * itemsize + d * d * 4.0}
    if kernel == "eigproject":
        d, k = dims["d"], dims["k"]
        bd = b.get("block_d", 128)
        bk = b.get("block_k", 128)
        kblocks = max(1, -(-k // bk))
        rowblocks = max(1, -(-d // bd))
        # G re-streams per k-block; V re-streams per row-block
        return {"flops": 2.0 * d * d * k,
                "bytes": (kblocks * d * d + rowblocks * d * k) * itemsize
                + k * 4.0}
    if kernel == "linkage":
        n = dims["n"]
        # two source rows + mask in, one row out, plus the fused reduction
        return {"flops": 5.0 * n, "bytes": 4.0 * n * 4.0}
    if kernel == "assign":
        bb, d2, t = dims["b"], dims["d2"], dims.get("t", 128)
        bbk = b.get("block_b", 128)
        rowblocks = max(1, -(-bb // bbk))
        # S streams once; the directory re-streams per wave row-block
        return {"flops": 2.0 * bb * d2 * t,
                "bytes": bb * d2 * 4.0 + rowblocks * t * d2 * itemsize
                + bb * (t + 2) * 4.0}
    raise ValueError(f"no cost model for kernel {kernel!r}")


def kernel_roofline(kernel: str, blocks: dict | None = None,
                    hw: HardwareSpec | None = None, itemsize: int = 4,
                    **dims: int) -> dict[str, Any]:
    """Roofline terms for one kernel dispatch: analytic costs against the
    host (or given) hardware roof, plus the bound classification and the
    time floor the tile plan cannot beat."""
    hw = hw or detect_hardware()
    costs = kernel_costs(kernel, blocks, itemsize=itemsize, **dims)
    compute_s = costs["flops"] / hw.peak_flops
    memory_s = costs["bytes"] / hw.hbm_bw
    return {
        "kernel": kernel, "hw": hw.name, "blocks": dict(blocks or {}),
        "flops": costs["flops"], "bytes": costs["bytes"],
        "compute_term_s": compute_s, "memory_term_s": memory_s,
        "roof_s": max(compute_s, memory_s),
        "bound": "compute" if compute_s >= memory_s else "memory",
        "arithmetic_intensity": (costs["flops"] / costs["bytes"]
                                 if costs["bytes"] else 0.0),
    }
