"""Telemetry CLI: render, diff and profile recorded runs.

  # run a tiny instrumented pipeline and render its trace + metrics
  PYTHONPATH=src python -m repro.launch.obs report --quick

  # render previously recorded artifacts
  PYTHONPATH=src python -m repro.launch.obs report \
      --trace trace.jsonl --metrics metrics.json --events events.jsonl

  # diff two metric snapshots (e.g. before/after a perf change)
  PYTHONPATH=src python -m repro.launch.obs compare before.json after.json

  # wrap any launch entry point in a jax.profiler trace (Perfetto) with
  # obs spans emitted as TraceAnnotations
  PYTHONPATH=src python -m repro.launch.obs profile \
      --logdir /tmp/jax-trace -- repro.launch.dryrun --quick
"""
from __future__ import annotations

import argparse
import json
import runpy
import sys
from pathlib import Path

import numpy as np

from repro import obs


def _quick_workload(outdir: Path) -> dict[str, Path]:
    """A tiny instrumented end-to-end run: one-shot clustering, a
    membership assign/admit wave and a drift check — enough to exercise
    spans, metrics and events — recorded under ``outdir``."""
    from repro.core.membership_engine import (MembershipConfig,
                                              MembershipEngine)
    from repro.core.oneshot import one_shot_clustering

    rng = np.random.default_rng(0)
    feats = [rng.normal(size=(16, 6)).astype(np.float32) for _ in range(10)]
    obs.reset()
    with obs.scope(True):
        res = one_shot_clustering(feats, 2)
        eng = MembershipEngine.from_oneshot(
            res, MembershipConfig(backend="jnp", capacity=24))
        lam = np.asarray(res.lam)[:4]
        v = np.asarray(res.v)[:4]
        wave = eng.assign(lam, v)
        eng.admit(lam, v, np.asarray(wave.labels))
        eng.drift_stats()
    outdir.mkdir(parents=True, exist_ok=True)
    paths = {
        "trace": obs.save_trace(outdir / "trace.jsonl"),
        "metrics": obs.save_snapshot(outdir / "metrics.json"),
        "events": obs.save_events(outdir / "events.jsonl"),
    }
    return paths


def _metric_table(snap: dict) -> str:
    lines = []
    counters = snap.get("counters", {})
    if counters:
        lines.append("counters:")
        for k in sorted(counters):
            lines.append(f"  {k:<44s} {counters[k]:>12g}")
    gauges = snap.get("gauges", {})
    if gauges:
        lines.append("gauges:")
        for k in sorted(gauges):
            v = gauges[k]
            v = f"{v:g}" if isinstance(v, (int, float)) else str(v)
            lines.append(f"  {k:<44s} {v:>12s}")
    hists = snap.get("histograms", {})
    if hists:
        lines.append("histograms:")
        lines.append(f"  {'name':<34s} {'count':>7s} {'mean':>11s} "
                     f"{'min':>11s} {'max':>11s}")
        for k in sorted(hists):
            h = hists[k]
            lines.append(f"  {k:<34s} {h['count']:>7d} {h['mean']:>11.1f} "
                         f"{h['min']:>11.1f} {h['max']:>11.1f}")
    return "\n".join(lines) if lines else "(empty registry)"


def _event_summary(events: list[dict], show: int = 8) -> str:
    if not events:
        return "(no events)"
    by_kind: dict[str, int] = {}
    for e in events:
        by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
    lines = ["by kind: " + ", ".join(f"{k}={n}"
                                     for k, n in sorted(by_kind.items()))]
    for e in events[:show]:
        rest = {k: v for k, v in e.items()
                if k not in ("seq", "t_us", "kind")}
        lines.append(f"  [{e['seq']:>4d}] t={e['t_us'] / 1e3:9.2f}ms "
                     f"{e['kind']:<16s} {rest}")
    if len(events) > show:
        lines.append(f"  ... {len(events) - show} more")
    return "\n".join(lines)


def cmd_report(args) -> None:
    trace_p, metrics_p, events_p = args.trace, args.metrics, args.events
    if args.quick:
        paths = _quick_workload(Path(args.out))
        trace_p = trace_p or paths["trace"]
        metrics_p = metrics_p or paths["metrics"]
        events_p = events_p or paths["events"]
        print(f"recorded quick run under {args.out}")
    if not (trace_p or metrics_p or events_p):
        raise SystemExit("report: pass --trace/--metrics/--events or "
                         "--quick to record a run first")
    if trace_p:
        print("== trace ==")
        print(obs.format_tree(obs.load_trace(trace_p)))
    if metrics_p:
        print("== metrics ==")
        print(_metric_table(obs.load_snapshot(metrics_p)))
    if events_p:
        print("== events ==")
        print(_event_summary(obs.load_events(events_p)))


def cmd_compare(args) -> None:
    before = obs.load_snapshot(args.before)
    after = obs.load_snapshot(args.after)
    delta = obs.diff(before, after)
    if args.json:
        print(json.dumps(delta, indent=2, sort_keys=True))
        return
    if delta["counters"]:
        print("counter deltas:")
        for k, v in sorted(delta["counters"].items()):
            print(f"  {k:<44s} {v:>+12g}")
    if delta["gauges"]:
        print("gauge transitions:")
        for k, (old, new) in sorted(delta["gauges"].items()):
            print(f"  {k:<44s} {old} -> {new}")
    if delta["histograms"]:
        print("histogram growth:")
        for k, d in sorted(delta["histograms"].items()):
            print(f"  {k:<44s} +{d['count']} obs, +{d['total']:.1f} total")
    if not any(delta.values()):
        print("no differences")


def cmd_profile(args) -> None:
    module, mod_args = args.module[0], args.module[1:]
    obs.configure(profiler=True)
    obs.enable()
    sys.argv = [module] + mod_args
    print(f"profiling `{module} {' '.join(mod_args)}` -> {args.logdir}")
    with obs.profile_trace(args.logdir):
        runpy.run_module(module, run_name="__main__")
    obs.disable()
    print(f"trace written to {args.logdir} — open in Perfetto "
          f"(ui.perfetto.dev) or tensorboard --logdir")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="repro.launch.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="render a run's trace tree + "
                                       "metric table + event summary")
    rp.add_argument("--trace", help="span JSONL (obs.save_trace)")
    rp.add_argument("--metrics", help="metrics snapshot JSON")
    rp.add_argument("--events", help="event JSONL (obs.save_events)")
    rp.add_argument("--quick", action="store_true",
                    help="record a tiny instrumented run first")
    rp.add_argument("--out", default="/tmp/repro_obs_quick",
                    help="artifact dir for --quick")
    rp.set_defaults(fn=cmd_report)

    cp = sub.add_parser("compare", help="diff two metric snapshots")
    cp.add_argument("before")
    cp.add_argument("after")
    cp.add_argument("--json", action="store_true")
    cp.set_defaults(fn=cmd_compare)

    pp = sub.add_parser("profile", help="run a module under "
                                        "jax.profiler.start_trace")
    pp.add_argument("--logdir", default="/tmp/repro_jax_trace")
    pp.add_argument("module", nargs=argparse.REMAINDER,
                    help="-- module [args...]")
    pp.set_defaults(fn=cmd_profile)

    args = ap.parse_args(argv)
    if args.cmd == "profile":
        args.module = [a for a in args.module if a != "--"]
        if not args.module:
            raise SystemExit("profile: give a module to run, e.g. "
                             "`profile -- repro.launch.dryrun --quick`")
    args.fn(args)


if __name__ == "__main__":
    main()
