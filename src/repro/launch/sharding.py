"""Logical-axis sharding rules with divisibility fallback (MaxText-style).

Every parameter / state / input leaf gets an ordered list of CANDIDATE
PartitionSpecs (most-parallel first); ``first_fitting`` picks the first one
whose every named mesh axis evenly divides the corresponding dimension.
GQA kv-heads that don't divide the 16-way model axis therefore fall back to
head-dim sharding, then to replication, instead of erroring — the paper
pool's heterogeneous head counts make this mandatory.

Conventions:
  * params: tensor-parallel on "model" (output dim of up-projections, input
    dim of down-projections), FSDP on "data" over the other big dim,
    stacked layer axes never sharded (scan).
  * activations (``shard_fn``): batch on ("pod","data"); mode "seq" also
    shards the sequence dim on "model" between blocks (memory), mode
    "tensor" shards d_model on "model", mode "dp" leaves only batch.
  * KV caches: batch -> data; kv-heads -> model (else head_dim -> model);
    batch=1 long-context falls back to window/seq -> data.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib

PyTree = Any

__all__ = ["ShardingOptions", "first_fitting", "param_specs", "state_specs",
           "batch_specs", "make_shard_fn", "named", "attach"]


@dataclasses.dataclass(frozen=True)
class ShardingOptions:
    fsdp: bool = True
    activation_mode: str = "seq"      # dp | seq | tensor | megatron
    # "megatron" = Megatron-LM sequence parallelism: block-boundary
    # residuals (the remat-saved tensors) are SEQ-sharded over "model"
    # (16x activation memory saving), while block INTERIORS are
    # constrained replicated-over-model so XLA keeps the qkv/ffn matmuls
    # tensor-parallel (sharded weights) and inserts all-gather/reduce-
    # scatter at the two boundaries — instead of gathering full f32
    # weights per use, which is what a blanket seq constraint causes
    # (measured 18 GB/layer/device; §Perf it-6).


def _divides(spec: P, shape: tuple[int, ...], mesh: Mesh) -> bool:
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        if dim % total:
            return False
    return True


def first_fitting(shape: tuple[int, ...], candidates: Sequence[P],
                  mesh: Mesh) -> P:
    for spec in candidates:
        if len(spec) > len(shape):
            continue
        if _divides(spec, shape, mesh):
            return spec
    return P()


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

# 2-D weights whose OUTPUT dim is tensor-parallel ("model").
_OUT_SHARDED = {"wq", "wk", "wv", "wg", "wr", "w_up", "w_gate", "w_in_x",
                "w_in_y", "w_a", "w_i", "mix_a1", "w_a1", "router",
                "frame_proj", "patch_proj"}
# 2-D weights whose INPUT dim is tensor-parallel.
_IN_SHARDED = {"wo", "w_down", "w_out"}


def _param_candidates(path: tuple[str, ...], shape: tuple[int, ...],
                      opts: ShardingOptions) -> list[P]:
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    # number of leading stacked-layer axes (scan over groups / enc / dec)
    n_stack = len(shape) - _base_rank(path, shape)
    lead = (None,) * n_stack
    fsdp = "data" if opts.fsdp else None

    if name == "embed":
        return [P("model", fsdp), P("model", None), P(None, "model"), P()]
    if name == "head":
        return [P(fsdp, "model"), P(None, "model"), P("model", None), P()]

    base = len(shape) - n_stack
    if parent == "channel" and name == "wv":          # rwkv channel (f, d)
        return [P(*lead, "model", fsdp), P(*lead, "model", None), P()]
    if name in _IN_SHARDED and base == 2:
        return [P(*lead, "model", fsdp), P(*lead, "model", None), P()]
    if name in _OUT_SHARDED and base == 2:
        return [P(*lead, fsdp, "model"), P(*lead, None, "model"), P()]
    if base == 3 and name in ("w_up", "w_gate", "w_down"):
        # MoE expert stacks (E, d_in, d_out): expert-parallel on "model",
        # FSDP over the d_model dim.
        if name == "w_down":
            return [P(*lead, "model", None, fsdp),
                    P(*lead, "model", None, None), P()]
        return [P(*lead, "model", fsdp, None),
                P(*lead, "model", None, None), P()]
    # everything else (norm scales, biases, mixing vectors, conv weights,
    # decay params): replicated.
    return [P()]


def _base_rank(path: tuple[str, ...], shape: tuple[int, ...]) -> int:
    """Rank of the leaf EXCLUDING stacked layer axes."""
    name = path[-1]
    stacked = any(p in ("groups", "enc", "dec") for p in path[:-1])
    parent = path[-2] if len(path) > 1 else ""
    if name in ("embed", "head", "frame_proj", "patch_proj", "final_norm",
                "enc_norm"):
        return len(shape)
    base = {
        "mu_x": 1, "mu": 2, "mix_a1": 2, "mix_a2": 3, "w0": 1, "w_a1": 2,
        "w_a2": 2, "u": 2, "ln_x": 1, "ln1": 1, "ln2": 1, "ln3": 1,
        "mu_k": 1, "mu_r": 1, "conv_w": 2, "conv_b": 1, "b_a": 1, "b_i": 1,
        "lam": 1, "q_norm": 1, "k_norm": 1, "router": 2,
    }.get(name)
    if base is None:
        # generic matrices: 2-D, except MoE expert stacks which are 3-D
        if name in ("w_up", "w_gate", "w_down") and len(shape) - (
                1 if stacked else 0) == 3:
            base = 3
        else:
            base = 2
    return base if stacked or base == len(shape) else len(shape)


def _paths_and_leaves(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for keypath, leaf in flat:
        path = tuple(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        out.append((path, leaf))
    return out, treedef


def param_specs(params_shape: PyTree, mesh: Mesh,
                opts: ShardingOptions | None = None) -> PyTree:
    """Pytree of PartitionSpecs matching a params shape-tree."""
    opts = opts or ShardingOptions()
    flat, treedef = _paths_and_leaves(params_shape)
    specs = []
    for path, leaf in flat:
        cands = _param_candidates(path, tuple(leaf.shape), opts)
        specs.append(first_fitting(tuple(leaf.shape), cands, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Decode-state specs
# ---------------------------------------------------------------------------

def _state_candidates(path: tuple[str, ...], shape: tuple[int, ...],
                      mesh: Mesh) -> list[P]:
    name = path[-1]
    if name == "length":
        return [P()]
    data = "data" if "data" in mesh.axis_names else None
    if name in ("k", "v", "mem_k", "mem_v"):
        # (..., B, S, K, hd) possibly with leading stacked layer axis.
        # Preference: kv-head parallel (collective-free GQA grouping), then
        # SEQ parallel (flash-decode style: partial attention per shard +
        # softmax combine), then head-dim parallel (contraction sharding —
        # measured 40x worse collective on GQA kv=8, §Perf it-4).
        lead = (None,) * (len(shape) - 4)
        return [
            P(*lead, data, None, "model", None),     # kv-head parallel
            P(*lead, data, "model", None, None),     # seq parallel
            P(*lead, data, None, None, "model"),     # head-dim parallel
            P(*lead, None, ("data", "model"), None, None),  # B=1: seq on all
            P(*lead, None, "model", None, None),
            P(*lead, None, None, "model", None),
            P(*lead, None, None, None, "model"),
            P(),
        ]
    if name == "wkv":
        # (..., B, H, hdk, hdv)
        lead = (None,) * (len(shape) - 4)
        return [P(*lead, data, "model", None, None),
                P(*lead, None, "model", None, None), P()]
    if name in ("shift_att", "shift_ffn", "h"):
        lead = (None,) * (len(shape) - 2)
        return [P(*lead, data, "model"), P(*lead, None, "model"), P()]
    if name == "conv":
        lead = (None,) * (len(shape) - 3)
        return [P(*lead, data, None, "model"),
                P(*lead, None, None, "model"), P()]
    return [P()]


def state_specs(state_shape: PyTree, mesh: Mesh) -> PyTree:
    flat, treedef = _paths_and_leaves(state_shape)
    specs = [first_fitting(tuple(l.shape),
                           _state_candidates(p, tuple(l.shape), mesh), mesh)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batch specs + activation constraints
# ---------------------------------------------------------------------------

def batch_specs(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    b_axes = mesh_lib.batch_axes(mesh)

    def spec(leaf):
        cands = [P(b_axes, *(None,) * (len(leaf.shape) - 1)), P()]
        return first_fitting(tuple(leaf.shape), cands, mesh)

    return jax.tree.map(spec, batch_shape)


def make_shard_fn(mesh: Mesh, opts: ShardingOptions | None = None
                  ) -> Callable[[jax.Array, str], jax.Array]:
    """Activation-constraint callback handed to the model stacks."""
    opts = opts or ShardingOptions()
    b_axes = mesh_lib.batch_axes(mesh)

    def shard(x: jax.Array, name: str) -> jax.Array:
        if x.ndim < 2:
            return x
        rest = (None,) * (x.ndim - 3)
        if name == "logits":
            cands = [P(b_axes, *rest, None, "model"), P()]
        elif name == "interior":
            if opts.activation_mode != "megatron":
                return x
            cands = [P(b_axes, *(None,) * (x.ndim - 1)), P()]
        elif name == "kv_cache":
            # (B, S, K, hd): mirror the state-spec preference order so the
            # in-step cache keeps the input sharding (no involuntary
            # gather around the dynamic_update_slice).
            cands = [P("data", None, "model", None),
                     P("data", "model", None, None),
                     P("data", None, None, "model"),
                     P(None, ("data", "model"), None, None),
                     P(None, "model", None, None), P()]
        elif name.startswith("attn_logits"):
            # (B, H, 1, S).  If the kv-head count divides the model axis
            # the cache is head-sharded -> shard H (collective-free).
            # Otherwise the cache is seq-sharded -> shard S so XLA does a
            # partial softmax + small combine instead of gathering KV.
            try:
                n_kv = int(name.split(":")[1])
            except (IndexError, ValueError):
                n_kv = 0
            msize = mesh.shape.get("model", 1)
            mid = (None,) * (x.ndim - 3)  # (B, K[, G, 1], S) / (B, H, 1, S)
            dsize = mesh.shape.get("data", 1)
            batch_shardable = x.shape[0] % dsize == 0
            if n_kv and n_kv % msize == 0 and batch_shardable:
                cands = [P("data", "model", *mid, None),
                         P("data", None, *mid, "model"), P()]
            elif n_kv and n_kv % msize == 0:
                # B=1 long-context: the cache fell back to seq-over-all —
                # keep the logits aligned with it
                cands = [P(None, None, *mid, ("data", "model")),
                         P(None, "model", *mid, None),
                         P(None, None, *mid, "model"), P()]
            else:
                cands = [P("data", None, *mid, "model"),
                         P(None, None, *mid, ("data", "model")),
                         P(None, None, *mid, "model"), P()]
        elif opts.activation_mode in ("seq", "megatron") and x.ndim >= 3:
            cands = [P(b_axes, *rest, "model", None),
                     P(b_axes, *rest, None, None), P()]
        elif opts.activation_mode == "tensor":
            cands = [P(b_axes, *rest, None, "model"),
                     P(b_axes, *rest, None, None), P()]
        else:
            cands = [P(b_axes, *(None,) * (x.ndim - 1)), P()]
        spec = first_fitting(tuple(x.shape), cands, mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return shard


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def named(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def attach(shape_tree: PyTree, specs: PyTree, mesh: Mesh) -> PyTree:
    """ShapeDtypeStructs with NamedShardings attached (for .lower())."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        shape_tree, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))
