"""Manual tensor + sequence parallelism via shard_map (Megatron-SP).

EXPERIMENTS.md §Perf it-6 showed XLA auto-SPMD cannot be coaxed into
Megatron sequence parallelism with sharding constraints alone: a blanket
seq constraint makes it replicate weights per use (~18 GB/layer/device of
f32 gathers on deepseek-67b), while boundary/interior constraints add
full-h all-reduces in backward.  This module does it MANUALLY with
explicit collectives inside shard_map — the collective schedule is then
exactly Megatron's, by construction:

  per block (all inside shard_map over ("data","model")):
    h_seq (B_loc, S/TP, d)
    g  = all_gather(LN(h_seq), "model")        # seq -> full   [AG  S·d/TP]
    qkv/attn with LOCAL heads (H/TP per device)
    a  = psum_scatter(attn @ wo_loc, "model")  # full -> seq   [RS  S·d/TP]
    h_seq += a;   same AG/matmul/RS pattern for the (Swi)GLU FFN

  embed: table sharded on d; token lookup local; all_to_all swaps the
  d-shard for a seq-shard (bytes S·d/TP — no full-h gather).
  loss: vocab-parallel cross-entropy (head sharded on vocab; softmax
  normalizer and label logit combined with two tiny psums — Megatron's
  parallel CE).

Differentiable end-to-end (shard_map collectives have transposes), scanned
over layers with remat, AdamW outside.  Used by ``dryrun --block-impl
manual`` for dense archs; correctness-tested against the auto path on an
8-device CPU mesh (tests/test_manual_tp.py, subprocess).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import ArchConfig
from repro.models import layers as L

PyTree = Any

__all__ = ["param_specs_manual", "make_manual_train_step", "manual_loss_fn"]

NEG = -1e30


# ---------------------------------------------------------------------------
# Parameter specs (what shard_map expects per leaf)
# ---------------------------------------------------------------------------

def param_specs_manual(cfg: ArchConfig, fsdp: bool = True) -> PyTree:
    """Specs for the dense-transformer param tree from
    ``repro.models.transformer.init`` (scan-stacked ``groups``).

    Tensor-parallel on "model": wq/wk/wv/w_up/w_gate output dim, wo/w_down
    input dim; embed and head sharded on d / vocab; FSDP shards the other
    big dim on "data".
    """
    d_ax = "data" if fsdp else None
    # KV projections: REPLICATED across TP ranks (Megatron's GQA rule —
    # each rank recomputes the small KV projection and selects the kv
    # heads its local q-heads group onto; kv=8 @ TP=16 would otherwise
    # shard head_dim, which it-4 measured as pathological).
    blk = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "attn": {
            "wq": P(None, d_ax, "model"),
            "wk": P(None, d_ax, None),
            "wv": P(None, d_ax, None),
            "wo": P(None, "model", d_ax),
        },
        "ffn": {
            "w_up": P(None, d_ax, "model"),
            "w_gate": P(None, d_ax, "model"),
            "w_down": P(None, "model", d_ax),
        },
    }
    if cfg.qk_norm:
        blk["attn"]["q_norm"] = P(None, None)
        blk["attn"]["k_norm"] = P(None, None)
    return {
        "embed": P(None, "model"),          # d-sharded (lookup stays local)
        "groups": {"0": blk},
        "rest": {},
        "final_norm": P(None),
        "head": P(d_ax, "model"),           # vocab-parallel head
    }


# ---------------------------------------------------------------------------
# Manual block (runs INSIDE shard_map; arrays are per-device shards)
# ---------------------------------------------------------------------------

def _attention_local(q, k, v, causal_chunk: int = 512):
    """Causal chunked attention over LOCAL heads (full seq on device)."""
    from repro.models.attention import chunked_attention

    return chunked_attention(q, k, v, causal=True, chunk=causal_chunk)


def _block(h_seq, bp, cfg: ArchConfig, tp_axis: str):
    """One dense block in manual TP+SP.  ``h_seq (B_loc, S/TP, d)``."""
    tp = jax.lax.psum(1, tp_axis)
    b = h_seq.shape[0]

    # ---- attention sub-block ----
    hn = L.rms_norm(h_seq, bp["ln1"])
    g = jax.lax.all_gather(hn, tp_axis, axis=1, tiled=True)  # (B, S, d)
    s_full = g.shape[1]
    h_loc = cfg.n_heads // tp
    q = (g @ bp["attn"]["wq"]).reshape(b, s_full, h_loc, cfg.head_dim)
    # KV projections are replicated; select the kv head each LOCAL q-head
    # groups onto (global q index = rank*h_loc + j).
    k = (g @ bp["attn"]["wk"]).reshape(b, s_full, cfg.n_kv_heads,
                                       cfg.head_dim)
    v = (g @ bp["attn"]["wv"]).reshape(b, s_full, cfg.n_kv_heads,
                                       cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, bp["attn"]["q_norm"])
        k = L.rms_norm(k, bp["attn"]["k_norm"])
    positions = jnp.arange(s_full)[None, :]
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    rank = jax.lax.axis_index(tp_axis)
    group_size = cfg.n_heads // cfg.n_kv_heads
    kv_idx = (rank * h_loc + jnp.arange(h_loc)) // group_size
    k = jnp.take(k, kv_idx, axis=2)                  # (B, S, h_loc, hd)
    v = jnp.take(v, kv_idx, axis=2)
    a = _attention_local(q, k, v).reshape(b, s_full, -1)
    a_part = a @ bp["attn"]["wo"]                    # partial over TP
    a_seq = jax.lax.psum_scatter(a_part, tp_axis, scatter_dimension=1,
                                 tiled=True)         # (B, S/TP, d)
    h_seq = h_seq + a_seq.astype(h_seq.dtype)

    # ---- FFN sub-block ----
    hn2 = L.rms_norm(h_seq, bp["ln2"])
    g2 = jax.lax.all_gather(hn2, tp_axis, axis=1, tiled=True)
    up = g2 @ bp["ffn"]["w_up"]
    gate = jax.nn.silu(g2 @ bp["ffn"]["w_gate"])
    f_part = (gate * up) @ bp["ffn"]["w_down"]
    f_seq = jax.lax.psum_scatter(f_part, tp_axis, scatter_dimension=1,
                                 tiled=True)
    return h_seq + f_seq.astype(h_seq.dtype)


def _vocab_parallel_ce(h_seq, head_loc, labels_seq, tp_axis: str):
    """Megatron parallel cross-entropy.

    ``h_seq (B, S/TP, d)`` full-d; ``head_loc (d, V/TP)``;
    ``labels_seq (B, S/TP)`` global label ids.  Two scalar-field psums:
    the running max and the sumexp; the label logit is selected with a
    local mask + psum.
    """
    logits = (h_seq @ head_loc).astype(jnp.float32)      # (B, T, V/TP)
    vshard = logits.shape[-1]
    vstart = jax.lax.axis_index(tp_axis) * vshard
    # max is for numerical stability only -> constant under AD.  pmax has
    # no differentiation rule, so take the max over an all_gather (which
    # does) under stop_gradient.
    m_local = jnp.max(logits, axis=-1)                    # (B, T)
    m_all = jax.lax.all_gather(jax.lax.stop_gradient(m_local), tp_axis)
    m = jnp.max(m_all, axis=0)
    sumexp = jax.lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                          tp_axis)
    local_ids = labels_seq - vstart
    in_shard = (local_ids >= 0) & (local_ids < vshard)
    safe = jnp.clip(local_ids, 0, vshard - 1)
    lbl = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    lbl = jax.lax.psum(jnp.where(in_shard, lbl, 0.0), tp_axis)
    nll = jnp.log(sumexp) + m - lbl
    return nll


def _embed_seq_sharded(embed_loc, tokens, tp_axis: str):
    """d-sharded lookup -> all_to_all -> seq-sharded full-d activations."""
    tp = jax.lax.psum(1, tp_axis)
    del tp
    h_dshard = jnp.take(embed_loc, tokens, axis=0)       # (B, S, d/TP)
    # tiled all_to_all: split the seq axis into TP chunks, concatenate the
    # received d-shards (source-rank-major = global d order) ->
    # (B, S/TP, d).  The tiled form has a working VJP (the untiled one
    # trips a cotangent-layout bug in jax 0.8).
    return jax.lax.all_to_all(h_dshard, tp_axis, split_axis=1,
                              concat_axis=2, tiled=True)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def manual_loss_fn(cfg: ArchConfig, mesh: Mesh, dp_axes=("data",),
                   tp_axis: str = "model"):
    """Returns loss(params, batch) with the manual TP+SP forward inside
    shard_map.  Params follow ``param_specs_manual`` layouts."""
    pspecs = param_specs_manual(cfg)
    if len(cfg.rest_kinds) or cfg.block_pattern != ("attn",) \
            or cfg.n_experts or cfg.encoder_layers:
        raise ValueError("manual TP path supports dense decoders only")

    def fwd_loss(params, tokens, labels):
        # everything here is per-device shards
        tp = jax.lax.psum(1, tp_axis)
        h = _embed_seq_sharded(params["embed"], tokens, tp_axis)
        h = h.astype(jnp.bfloat16 if cfg.act_dtype == "bfloat16"
                     else jnp.float32)

        def body(h, gp):
            bp = gp["0"]
            if True:  # FSDP: gather the data-sharded dim per use
                bp = jax.tree.map(lambda x: x, bp)
                bp = _fsdp_gather(bp, dp_axes[-1], pspecs["groups"]["0"])
            return _block(h, bp, cfg, tp_axis), None

        fn = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            h, _ = jax.lax.scan(fn, h, params["groups"])
        else:  # unrolled (the dry-run's scan-correction variants)
            for i in range(cfg.n_layers):
                h, _ = fn(h, jax.tree.map(lambda x: x[i], params["groups"]))
        h = L.rms_norm(h, params["final_norm"])
        # Megatron: the sequence-parallel region ends BEFORE the LM head —
        # gather full seq so every TP rank holds the SAME rows, then the
        # vocab-parallel CE psums combine vocab shards of identical rows.
        h = jax.lax.all_gather(h, tp_axis, axis=1, tiled=True)  # (B, S, d)
        head = jax.lax.all_gather(params["head"], dp_axes[-1], axis=0,
                                  tiled=True)
        nll = _vocab_parallel_ce(h, head, labels, tp_axis)      # (B, S)
        # nll is identical across TP ranks; average over the data axes.
        n_dp = jax.lax.psum(1, dp_axes)
        return jax.lax.psum(jnp.mean(nll), dp_axes) / n_dp

    in_specs = (pspecs,
                P(dp_axes, None),        # tokens (replicated over model)
                P(dp_axes, None))
    fn = shard_map(fwd_loss, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)

    def loss(params, batch):
        return fn(params, batch["tokens"], batch["labels"])

    return loss, pspecs


def _fsdp_gather(bp: PyTree, dp_axis: str, specs: PyTree) -> PyTree:
    """all_gather each FSDP-sharded (data-axis) param dim before use."""

    def gather(x, spec):
        for dim, entry in enumerate(spec):
            if entry == dp_axis or (isinstance(entry, tuple)
                                    and dp_axis in entry):
                return jax.lax.all_gather(x, dp_axis, axis=dim - 1,
                                          tiled=True)
        return x

    # specs have a leading layer axis (None); the scanned slice drops it,
    # hence ``dim - 1`` above.
    return jax.tree.map(gather, bp, specs,
                        is_leaf=lambda x: isinstance(x, P))


def make_manual_train_step(cfg: ArchConfig, mesh: Mesh,
                           optimizer: optim.Optimizer):
    loss_fn, pspecs = manual_loss_fn(cfg, mesh,
                                     dp_axes=tuple(
                                         a for a in ("pod", "data")
                                         if a in mesh.axis_names))

    def train_step(params, opt_state, batch):
        loss_val, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state2 = optimizer.update(grads, opt_state, params)
        params2 = optim.apply_updates(params, updates)
        return params2, opt_state2, {"loss": loss_val}

    return train_step, pspecs
