import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# Placeholder host devices exist ONLY in this dry-run entry point; smoke
# tests and benchmarks see the single real CPU device.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
for the production meshes and record memory/cost/roofline artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all combos
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite_8b \
      --shape train_4k --mesh pod --verbose
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multipod --skip-existing

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json
(read by benchmarks/roofline reporting and EXPERIMENTS.md).
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro import optim
from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_arch
from repro.launch import mesh as ML
from repro.launch import roofline as RL
from repro.launch import sharding as SH
from repro.launch import steps as ST

LM_ARCHS = [a for a in ARCH_IDS if not a.startswith("paper_")]
OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _build_lowered(cfg, shape, mesh, opts, block_impl: str = "auto"):
    params_shape = ST.abstract_params(cfg)
    batch_shape = ST.input_specs(cfg, shape)
    bspecs = SH.batch_specs(batch_shape, mesh)
    batch_in = SH.attach(batch_shape, bspecs, mesh)

    if block_impl == "manual" and shape.kind == "train":
        # Manual Megatron TP+SP via shard_map (dense decoders only).
        # Params stay scan-STACKED even for the unrolled scan-correction
        # variants (the manual path slices them in a python loop).
        from repro.launch import manual_tp as MT

        stacked_cfg = dataclasses.replace(cfg, scan_layers=True)
        params_shape = ST.abstract_params(stacked_cfg)
        optimizer = optim.adamw(1e-4)
        step, mspecs = MT.make_manual_train_step(cfg, mesh, optimizer)
        params_in = SH.attach(params_shape, mspecs, mesh)
        opt_shape = ST.abstract_opt_state(cfg, optimizer, params_shape)
        opt_in = SH.attach(opt_shape, _opt_specs(opt_shape, mspecs), mesh)
        with mesh:
            return jax.jit(step).lower(params_in, opt_in, batch_in)

    pspecs = SH.param_specs(params_shape, mesh, opts)
    params_in = SH.attach(params_shape, pspecs, mesh)

    if shape.kind == "train":
        optimizer = optim.adamw(1e-4)
        opt_shape = ST.abstract_opt_state(cfg, optimizer, params_shape)
        opt_in = SH.attach(opt_shape, _opt_specs(opt_shape, pspecs), mesh)
        step = ST.make_train_step(cfg, mesh, optimizer, opts,
                                  param_specs=pspecs)
        with mesh:
            return jax.jit(step).lower(params_in, opt_in, batch_in)
    if shape.kind == "prefill":
        step = ST.make_prefill_step(cfg, mesh, opts)
        with mesh:
            return jax.jit(step).lower(params_in, batch_in)
    state_shape = ST.abstract_decode_state(cfg, shape)
    sspecs = SH.state_specs(state_shape, mesh)
    state_in = SH.attach(state_shape, sspecs, mesh)
    step = ST.make_serve_step(cfg, mesh, opts)
    with mesh:
        return jax.jit(step).lower(params_in, state_in, batch_in)


def _metrics(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    stats = RL.parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(stats.bytes_per_device),
            "coll_counts": stats.counts,
            "coll_bytes_by_kind": stats.bytes_by_kind}


def run_one(arch_id: str, shape_name: str, mesh_kind: str,
            opts: SH.ShardingOptions | None = None,
            verbose: bool = False, attn_impl: str | None = None,
            block_impl: str = "auto") -> dict:
    shape = INPUT_SHAPES[shape_name]
    base_cfg = get_arch(arch_id)
    cfg = ST.variant_for_shape(base_cfg, shape)
    variant = "swa" if cfg is not base_cfg else "base"
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    mesh = ML.make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    chips = mesh.devices.size
    opts = opts or SH.ShardingOptions()

    # --- The artifact: full-depth scanned program. ----------------------
    t0 = time.perf_counter()
    lowered = _build_lowered(cfg, shape, mesh, opts, block_impl)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = RL.memory_summary(compiled)
    raw = _metrics(compiled)

    # --- Scan correction: XLA cost_analysis counts a while body ONCE, so
    # derive the per-layer-group cost from two UNROLLED shallow variants
    # and extrapolate to full depth (EXPERIMENTS.md §Dry-run notes).
    pat_len = len(cfg.block_pattern)
    if cfg.encoder_layers:
        cfg1 = dataclasses.replace(cfg, scan_layers=False, n_layers=1,
                                   encoder_layers=1)
        cfg2 = dataclasses.replace(cfg, scan_layers=False, n_layers=2,
                                   encoder_layers=2)
        extra_groups = cfg.n_layers - 1.0
    else:
        cfg1 = dataclasses.replace(cfg, scan_layers=False, n_layers=pat_len)
        cfg2 = dataclasses.replace(cfg, scan_layers=False,
                                   n_layers=2 * pat_len)
        extra_groups = (cfg.n_groups - 1.0
                        + len(cfg.rest_kinds) / pat_len)
    m1 = _metrics(_build_lowered(cfg1, shape, mesh, opts,
                                 block_impl).compile())
    m2 = _metrics(_build_lowered(cfg2, shape, mesh, opts,
                                 block_impl).compile())

    def corr(key):
        per_group = m2[key] - m1[key]
        return m1[key] + extra_groups * per_group

    corrected = {k: corr(k) for k in ("flops", "bytes", "coll_bytes")}
    coll_counts = {
        k: int(round(m1["coll_counts"].get(k, 0)
                     + extra_groups * (m2["coll_counts"].get(k, 0)
                                       - m1["coll_counts"].get(k, 0))))
        for k in set(m1["coll_counts"]) | set(m2["coll_counts"])}

    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    mf = RL.model_flops(cfg.n_active_params(), tokens, shape.kind)
    roof = RL.Roofline(
        chips=chips,
        hlo_flops_per_device=corrected["flops"],
        hlo_bytes_per_device=corrected["bytes"],
        collective_bytes_per_device=corrected["coll_bytes"],
        collective_counts=coll_counts,
        collective_bytes_by_kind=m2["coll_bytes_by_kind"],
        model_flops_global=mf,
    )

    result = {
        "arch": arch_id, "arch_name": cfg.name, "shape": shape_name,
        "mesh": mesh_kind, "chips": chips, "kind": shape.kind,
        "variant": variant,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.to_dict(),
        "roofline_raw_scanned": {k: raw[k]
                                 for k in ("flops", "bytes", "coll_bytes")},
        "scan_correction": {"extra_groups": extra_groups,
                            "g1": {k: m1[k] for k in
                                   ("flops", "bytes", "coll_bytes")},
                            "g2": {k: m2[k] for k in
                                   ("flops", "bytes", "coll_bytes")}},
        "sharding": {"fsdp": opts.fsdp,
                     "activation_mode": opts.activation_mode},
        "status": "ok",
    }
    if verbose:
        print(json.dumps(result, indent=2))
        print(compiled.memory_analysis())
    return result


def _opt_specs(opt_shape, pspecs):
    """Optimizer state inherits each param's spec; scalars replicated."""
    from jax.sharding import PartitionSpec as P

    inner = opt_shape.inner
    if isinstance(inner, dict) and set(inner) == {"m", "v"}:
        inner_specs = {"m": pspecs, "v": pspecs}
    elif inner == ():
        inner_specs = ()
    else:  # momentum: velocity tree mirrors params
        inner_specs = pspecs
    return type(opt_shape)(step=P(), inner=inner_specs)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default=None, choices=["pod", "multipod"])
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--activation-mode", default="seq",
                    choices=["dp", "seq", "tensor", "megatron"])
    ap.add_argument("--attn-impl", default=None,
                    choices=["jnp", "chunked", "pallas"])
    ap.add_argument("--block-impl", default="auto",
                    choices=["auto", "manual"])
    ap.add_argument("--tag", default="", help="suffix for artifact files")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else LM_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [args.mesh] if args.mesh else ["pod", "multipod"]
    opts = SH.ShardingOptions(fsdp=bool(args.fsdp),
                              activation_mode=args.activation_mode)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                out = OUT_DIR / f"{arch}__{shape}__{mesh_kind}{tag}.json"
                if args.skip_existing and out.exists():
                    print(f"[skip] {out.name}")
                    continue
                label = f"{arch} x {shape} x {mesh_kind}"
                try:
                    t0 = time.perf_counter()
                    result = run_one(arch, shape, mesh_kind, opts,
                                     args.verbose, args.attn_impl,
                                     args.block_impl)
                    dt = time.perf_counter() - t0
                    print(f"[ok]   {label}  ({dt:.1f}s, "
                          f"bottleneck={result['roofline']['bottleneck']})",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    result = {"arch": arch, "shape": shape,
                              "mesh": mesh_kind, "status": "fail",
                              "error": f"{type(e).__name__}: {e}",
                              "traceback": traceback.format_exc()[-4000:]}
                    failures.append(label)
                    print(f"[FAIL] {label}: {type(e).__name__}: {e}",
                          flush=True)
                out.write_text(json.dumps(result, indent=2))
    if failures:
        print(f"\n{len(failures)} FAILURES:\n  " + "\n  ".join(failures))
        return 1
    print("\nall dry-runs passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
