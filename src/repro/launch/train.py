"""Training launcher: runs real steps of any `--arch` on the available
devices (CPU here; production mesh on TPU), with checkpointing.

This is the driver a single pod would run; `dryrun.py` proves the same
step function lowers at production scale.  On CPU use a REDUCED config
(`--reduced`, default) — full configs are dry-run-only in this container.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b \
      --steps 20 --batch 4 --seq 128
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, optim
from repro.checkpoint import restore_checkpoint, save_checkpoint, latest_step
from repro.configs.base import get_arch
from repro.data import tokens as tok
from repro.models.registry import get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=bool(args.reduced))
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")
    optimizer = optim.adamw(optim.warmup_cosine_schedule(
        args.lr, warmup=max(1, args.steps // 10), total_steps=args.steps))
    opt_state = optimizer.init(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"restored step {start} from {args.ckpt_dir}")

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: m.loss_fn(p, batch))(params)
        grads = optim.clip_by_global_norm(grads, 1.0)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return optim.apply_updates(params, updates), opt_state, loss

    spec = tok.TokenTaskSpec(vocab=min(cfg.vocab, 256), seed=0)
    it = tok.token_batch_iterator(spec, args.batch, args.seq, seed=1)

    t0 = obs.now()    # monotonic perf_counter — never time.time for rates
    for i in range(start, args.steps):
        raw = next(it)
        batch = {"tokens": jnp.asarray(raw["tokens"] % cfg.vocab),
                 "labels": jnp.asarray(raw["labels"] % cfg.vocab)}
        if cfg.fuse_patches:
            p = max(1, int(args.seq * cfg.patch_frac))
            batch["patch_embeds"] = jnp.zeros((args.batch, p, cfg.d_model),
                                              jnp.float32)
            mask = np.zeros((args.batch, args.seq), bool)
            mask[:, :p] = True
            batch["patch_mask"] = jnp.asarray(mask)
        if m.is_encdec:
            batch["frames"] = 0.1 * jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, args.seq, cfg.d_model))
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            tps = args.batch * args.seq / max(obs.now() - t0, 1e-9)
            print(f"step {i:5d}  loss {float(loss):.4f}  ({tps:.0f} tok/s)")
            t0 = obs.now()
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, (params, opt_state))
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, (params, opt_state))
        print(f"final checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
