"""Serving launcher: batched greedy decoding for any decoder `--arch`.

  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b \
      --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_arch
from repro.launch.decode_loop import greedy_decode
from repro.models.registry import get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=bool(args.reduced))
    m = get_model(cfg)
    if m.is_encdec:
        raise SystemExit("decoder-only serving; use examples for enc-dec")
    params = m.init(jax.random.PRNGKey(0))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    stats = greedy_decode(m, params, prompts, args.gen)
    print(f"prefill: {args.prompt_len} tok in {stats.prefill_s:.2f}s")
    print(f"decode: {args.gen} x {args.batch} in {stats.decode_s:.2f}s "
          f"({stats.tok_per_s:.0f} tok/s)")
    print("sample:", stats.tokens[0].tolist()[:24])


if __name__ == "__main__":
    main()
