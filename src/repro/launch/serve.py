"""Serving launcher: batched greedy decoding for any decoder `--arch`.

  PYTHONPATH=src python -m repro.launch.serve --arch granite_8b \
      --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models.registry import get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=bool(args.reduced))
    m = get_model(cfg)
    if m.is_encdec:
        raise SystemExit("decoder-only serving; use examples for enc-dec")
    params = m.init(jax.random.PRNGKey(0))
    step = jax.jit(m.decode_step)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    state = m.init_decode_state(args.batch, args.prompt_len + args.gen)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, state = step(params, prompts[:, t:t + 1], state)
    print(f"prefill: {args.prompt_len} tok in {time.time() - t0:.2f}s")
    tokens = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tokens]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, state = step(params, tokens, state)
        tokens = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tokens)
    dt = time.time() - t0
    print(f"decode: {args.gen} x {args.batch} in {dt:.2f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.0f} tok/s)")
    print("sample:", jnp.concatenate(out, 1)[0].tolist()[:24])


if __name__ == "__main__":
    main()
