"""Serving launcher: cluster-routed continuous-batching decode for any
decoder ``--arch``.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b \
      --requests 16 --slots 8 --clusters 4

``--mode static`` runs the old uniform-batch per-token baseline
(``greedy_decode``) on the same request mix for comparison; the default
``continuous`` mode runs the slot scheduler with single-dispatch chunked
prefill and membership-routed per-cluster heads.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import obs
from repro.configs.base import get_arch
from repro.launch.decode_loop import (ClusterHeads, Request, ServeConfig,
                                      ServeEngine, cluster_logits_fn,
                                      greedy_decode)
from repro.models.registry import get_model


def _make_requests(rng: np.random.Generator, n: int, vocab: int,
                   max_prompt: int, max_gen: int, clusters: int
                   ) -> list[Request]:
    """A ragged multi-tenant mix: prompt lengths and generation budgets
    vary per request; cluster ids round-robin over the directory."""
    reqs = []
    for i in range(n):
        plen = int(rng.integers(max(4, max_prompt // 4), max_prompt + 1))
        gen = int(rng.integers(max(2, max_gen // 4), max_gen + 1))
        reqs.append(Request(
            tokens=rng.integers(0, vocab, size=plen).astype(np.int32),
            gen=gen, cluster=i % clusters,
            arrive_round=0 if i < n // 2 else int(rng.integers(0, 8))))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", type=int, default=1)
    ap.add_argument("--mode", choices=["continuous", "static"],
                    default="continuous")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--wave", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--events", default=None,
                    help="record the obs event stream (wave_admitted/"
                         "slot_freed/request_done) to this JSONL")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.events:
        obs.reset()
        obs.enable()

    cfg = get_arch(args.arch, reduced=bool(args.reduced))
    m = get_model(cfg)
    if m.is_encdec:
        raise SystemExit("decoder-only serving; use examples for enc-dec")
    params = m.init(jax.random.PRNGKey(0))
    heads = ClusterHeads.init(jax.random.PRNGKey(1), params["head"],
                              n_clusters=args.clusters)

    rng = np.random.default_rng(args.seed)
    reqs = _make_requests(rng, args.requests, cfg.vocab, args.prompt_len,
                          args.gen, args.clusters)
    total_tok = sum(r.gen for r in reqs)

    if args.mode == "static":
        # old path: pad everything to a uniform batch, per-token dispatch,
        # one cluster at a time
        t0 = obs.now()
        for t in range(args.clusters):
            batch = [r for r in reqs if r.cluster == t]
            if not batch:
                continue
            plen = max(len(r.tokens) for r in batch)
            gen = max(r.gen for r in batch)
            prompts = np.zeros((len(batch), plen), np.int32)
            for j, r in enumerate(batch):
                prompts[j, plen - len(r.tokens):] = r.tokens  # left pad
            stats = greedy_decode(m, params, jax.numpy.asarray(prompts),
                                  gen, logits_fn=cluster_logits_fn(heads, t))
            print(f"cluster {t}: batch {len(batch)} prefill {plen} tok "
                  f"({stats.prefill_dispatches} dispatches) ttft "
                  f"{stats.ttft_s * 1e3:.1f}ms decode {stats.tok_per_s:.0f} "
                  f"tok/s")
        wall = obs.now() - t0
        print(f"static: {total_tok} tok (upper bound) in {wall:.2f}s")
        if args.events:
            obs.save_events(args.events)
            print(f"wrote {len(obs.events())} event(s) to {args.events}")
            obs.disable()
        return

    scfg = ServeConfig(slots=args.slots, wave=args.wave,
                       prefill_chunk=args.prefill_chunk,
                       max_prompt=args.prompt_len, max_gen=args.gen,
                       max_len=args.prompt_len + args.gen)
    engine = ServeEngine(m, params, heads, scfg)
    stats = engine.serve(reqs)
    print(f"continuous: {stats.total_tokens} tok in {stats.wall_s:.2f}s "
          f"({stats.aggregate_tok_per_s:.0f} tok/s aggregate)")
    print(f"  decode rounds {stats.decode_rounds}, slot utilization "
          f"{stats.slot_utilization:.2f}, mean ttft "
          f"{stats.mean_ttft_s * 1e3:.1f}ms")
    print(f"  prefill dispatches {stats.prefill_dispatches} "
          f"({stats.prefill_scan_steps} scan chunks each), decode "
          f"dispatches {stats.decode_dispatches}, traces {stats.traces}")
    print("sample:", stats.results[0].tokens.tolist()[:24])

    if args.events:
        obs.save_events(args.events)
        print(f"wrote {len(obs.events())} event(s) to {args.events}")
        obs.disable()


if __name__ == "__main__":
    main()
