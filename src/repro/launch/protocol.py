"""Protocol launcher: run the one-shot clustering engine at scale.

Drives the SAME ``ProtocolEngine`` the library uses, on synthetic
multi-task feature mixtures, with the backend chosen on the command line —
the protocol-side analogue of ``launch/train.py`` / ``launch/serve.py``:

  # dense single host
  PYTHONPATH=src python -m repro.launch.protocol --users 256

  # blockwise streaming: 4096 users on one CPU host, O(block*d^2) Grams
  PYTHONPATH=src python -m repro.launch.protocol --users 4096 \\
      --block-users 256 --dim 64 --samples 32

  # shard_map over 8 forced host devices
  PYTHONPATH=src python -m repro.launch.protocol --users 512 \\
      --backend shard_map --devices 8

  # RAW-DATA entry point: device-resident ingest (SignatureEngine) —
  # Phi + Gram streamed in row chunks, batched top-k subspace iteration
  PYTHONPATH=src python -m repro.launch.protocol --users 512 \\
      --raw-dim 256 --feature random_projection --dim 64 --chunk-rows 32

  # hierarchical two-level protocol: 16384 users in 64 edge groups,
  # O(G * (N/G)^2) relevance entries instead of O(N^2)
  PYTHONPATH=src python -m repro.launch.protocol --users 16384 \\
      --groups 64 --group-clusters 8 --cluster-backend jnp

  # landmark/Nystrom-sketched flat path: O(N * m) scored entries
  PYTHONPATH=src python -m repro.launch.protocol --users 4096 \\
      --landmarks 128

``--devices N`` forces N host platform devices and MUST act before jax
initializes, so all repro/jax imports happen inside ``main`` after the
flag is set.
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=256)
    ap.add_argument("--samples", type=int, default=64)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--backend", default="jnp",
                    choices=["jnp", "pallas", "shard_map"])
    ap.add_argument("--cluster-backend", default="numpy",
                    choices=["numpy", "jnp", "pallas"],
                    help="GPS decision layer: host reference HAC or the "
                         "device NN-chain (keeps R on-device)")
    ap.add_argument("--linkage", default="average",
                    choices=["average", "single", "complete"])
    ap.add_argument("--block-users", type=int, default=0,
                    help="> 0 enables blockwise streaming (single host)")
    ap.add_argument("--landmarks", type=int, default=0,
                    help="> 0 enables the Nystrom-sketched flat path: "
                         "score m landmarks, complete R (single host)")
    ap.add_argument("--groups", type=int, default=0,
                    help="> 0 enables the hierarchical two-level "
                         "protocol with this many edge groups")
    ap.add_argument("--group-clusters", type=int, default=0,
                    help="clusters cut per edge group (0 = --tasks)")
    ap.add_argument("--group-batch", type=int, default=0,
                    help="edge groups per dispatch (0 = all at once)")
    ap.add_argument("--raw-dim", type=int, default=0,
                    help="> 0 enables the RAW-DATA entry point: users hand "
                         "raw m-dim shards and the SignatureEngine "
                         "featurizes on-device (m = this value)")
    ap.add_argument("--feature", default="random_projection",
                    choices=["identity", "random_projection"],
                    help="shared Phi for the raw entry point")
    ap.add_argument("--chunk-rows", type=int, default=0,
                    help="> 0 streams raw ingest in row chunks of this "
                         "size (peak memory independent of --samples)")
    ap.add_argument("--eig", default="subspace",
                    choices=["subspace", "eigh"],
                    help="raw-path eigensolver: batched top-k subspace "
                         "iteration (O(d^2 k iters)) or exact eigh")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (shard_map demos)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.core import clustering as clu
    from repro.core import oneshot
    from repro.core.cluster_engine import ClusterConfig
    from repro.core.signature_engine import SignatureConfig
    from repro.core.similarity import SimilarityConfig
    from repro.data.features import FeatureConfig
    from repro.data import synthetic as syn

    raw_mode = args.raw_dim > 0
    hier_mode = args.groups > 0
    mix_dim = args.raw_dim if raw_mode else args.dim
    feats, task_ids = syn.make_task_feature_mixture(
        args.users, args.samples, mix_dim, args.tasks, seed=args.seed)
    cfg = SimilarityConfig(top_k=args.top_k, backend=args.backend,
                           block_users=args.block_users,
                           landmarks=args.landmarks)
    ccfg = ClusterConfig(backend=args.cluster_backend, linkage=args.linkage)
    hierarchy_cfg = None
    if hier_mode:
        from repro.core.hierarchy import HierarchyConfig

        hierarchy_cfg = HierarchyConfig(n_groups=args.groups,
                                        group_clusters=args.group_clusters,
                                        group_batch=args.group_batch)
    feature_cfg = signature_cfg = None
    sig_dim = args.dim
    if raw_mode:
        from repro.data.features import phi_out_dim

        feature_cfg = FeatureConfig(kind=args.feature, d=args.dim,
                                    seed=args.seed)
        sig_dim = phi_out_dim(feature_cfg, mix_dim)   # identity: d' = m
        signature_cfg = SignatureConfig(backend=args.backend,
                                        chunk_rows=args.chunk_rows,
                                        eig=args.eig)
    print(f"{args.users} users x {args.samples} samples x "
          f"{'m=%d -> d=%d (%s)' % (mix_dim, sig_dim, args.feature) if raw_mode else 'd=%d' % args.dim}, "
          f"{args.tasks} tasks | backend={args.backend} "
          f"cluster_backend={args.cluster_backend} "
          f"block_users={args.block_users} landmarks={args.landmarks} "
          f"groups={args.groups} raw={raw_mode} "
          f"chunk_rows={args.chunk_rows} devices={len(jax.devices())}")

    t0 = time.perf_counter()
    res = oneshot.one_shot_clustering(
        feats if raw_mode else jax.numpy.asarray(feats),
        n_clusters=args.tasks, cfg=cfg, cluster_cfg=ccfg,
        feature_cfg=feature_cfg, signature_cfg=signature_cfg,
        hierarchy_cfg=hierarchy_cfg)
    labels = np.asarray(res.labels)           # host sync for reporting only
    dt = time.perf_counter() - t0
    acc = clu.clustering_accuracy(labels, task_ids)
    sizes = np.bincount(labels, minlength=args.tasks)
    print(f"protocol + HAC: {dt:.2f}s | clustering accuracy {acc:.1%} | "
          f"cluster sizes {sizes.tolist()}")
    led = res.ledger.summary()
    scope = (f"(per-user view WITHIN its {args.users // args.groups}-user "
             f"edge group) " if hier_mode else "")
    print(f"per-user upload {scope}"
          f"{led['per_user_upload_bytes'] / 1024:.1f} KiB, "
          f"download {led['per_user_download_bytes'] / 2**20:.2f} MiB, "
          f"GPS total {led['gps_total_bytes'] / 2**20:.2f} MiB")
    if hier_mode:
        entries = int(np.asarray(res.entry_counts).size)
        print(f"directory: {args.groups} groups -> {entries} entries -> "
              f"{args.tasks} global clusters | global stage "
              f"{entries}x{entries} signature-only relevance")


if __name__ == "__main__":
    main()
