"""jit-able train_step / serve_step builders + ShapeDtypeStruct input specs
for every (architecture x input shape) combination.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every model input (the dry-run lowers against them — no allocation).
Decode shapes lower ``serve_step`` (ONE token against a seq_len cache /
recurrent state); train/prefill shapes lower ``train_step``.

For `long_500k`, full-attention archs are lowered with their
sliding-window variant (``attn_window = long_context_window``) — the
sub-quadratic path DESIGN.md §Shape-skips describes; SSM/hybrid archs run
their native O(1)-state decode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ArchConfig, InputShape
from repro.launch import sharding as SH
from repro.models import encdec
from repro.models.registry import ModelBundle, get_model

PyTree = Any

__all__ = ["variant_for_shape", "input_specs", "make_train_step",
           "make_serve_step", "abstract_params", "abstract_opt_state",
           "abstract_decode_state"]


def variant_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Long-context decode on a full-attention arch -> SWA variant."""
    needs_swa = (shape.name == "long_500k" and cfg.encoder_layers == 0
                 and "attn" in cfg.block_pattern and cfg.local_window == 0
                 and cfg.attn_window == 0)
    if needs_swa:
        return dataclasses.replace(cfg, attn_window=cfg.long_context_window)
    return cfg


# ---------------------------------------------------------------------------
# Abstract (no-allocation) pytrees
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig) -> PyTree:
    m = get_model(cfg)
    return jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))


def abstract_opt_state(cfg: ArchConfig, optimizer: optim.Optimizer,
                       params_shape: PyTree) -> PyTree:
    return jax.eval_shape(optimizer.init, params_shape)


def abstract_decode_state(cfg: ArchConfig, shape: InputShape) -> PyTree:
    m = get_model(cfg)
    b = shape.global_batch
    if m.is_encdec:
        return jax.eval_shape(
            lambda: encdec.init_decode_state(cfg, b, shape.seq_len))
    return jax.eval_shape(
        lambda: m.init_decode_state(b, shape.seq_len))


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for the step's data inputs."""
    b = shape.global_batch
    s = shape.seq_len
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok),
                 "labels": jax.ShapeDtypeStruct((b, s), tok)}
        if cfg.encoder_layers:
            # enc-dec: frames into the encoder, tokens into the decoder.
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.bfloat16)
        if cfg.fuse_patches:
            p = max(1, int(s * cfg.patch_frac))
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, p, cfg.d_model), jnp.bfloat16)
            specs["patch_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
        return specs
    # decode: one new token
    return {"tokens": jax.ShapeDtypeStruct((b, 1), tok)}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh, optimizer: optim.Optimizer,
                    opts: SH.ShardingOptions | None = None,
                    param_specs=None) -> Callable:
    m = get_model(cfg)
    shard = SH.make_shard_fn(mesh, opts)

    def train_step(params, opt_state, batch):
        def loss(p):
            return m.loss_fn(p, batch, shard)

        loss_val, grads = jax.value_and_grad(loss)(params)
        if param_specs is not None:
            # Pin gradients to the parameter sharding: the backward pass
            # then emits reduce-scatters into the FSDP layout instead of
            # full-tensor f32 all-reduces (+slice) — measured 6 GB/step on
            # the deepseek embed/head grads alone (§Perf it-6).
            from jax.sharding import NamedSharding

            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, s)), grads, param_specs)
        updates, opt_state2 = optimizer.update(grads, opt_state, params)
        params2 = optim.apply_updates(params, updates)
        return params2, opt_state2, {"loss": loss_val}

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh,
                      opts: SH.ShardingOptions | None = None) -> Callable:
    """Inference-prefill: forward only, logits for the LAST position only
    (full-seq 32k x 256k-vocab logits would be a ~0.5 TB tensor)."""
    from repro.models import encdec, transformer

    shard = SH.make_shard_fn(mesh, opts)
    fwd = encdec.forward if cfg.encoder_layers else transformer.forward

    def prefill_step(params, batch):
        logits, _ = fwd(cfg, params, batch, shard, last_only=True)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh,
                    opts: SH.ShardingOptions | None = None) -> Callable:
    m = get_model(cfg)
    shard = SH.make_shard_fn(mesh, opts)

    def serve_step(params, state, batch):
        logits, state2 = m.decode_step(params, batch["tokens"], state, shard)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, state2

    return serve_step
