"""Membership serving launcher: seed protocol -> streaming arrival waves.

Drives the full online lifecycle the ``MembershipEngine`` owns: run the
one-shot protocol on a seed population, build the cluster directory, then
stream synthetic arrival waves with churn (evictions) and task drift
(newcomers from a subspace the seed never saw), reporting per-wave
assignment accuracy vs the oracle, the unassigned fraction, and every
drift-triggered re-cluster event:

  # 64 seed users, 6 waves of 16 arrivals, 4 evictions per wave
  PYTHONPATH=src python -m repro.launch.membership --seed-users 64 \\
      --waves 6 --wave-size 16 --evict 4

  # drift: from wave 3 on, half of each wave comes from an unseen task
  PYTHONPATH=src python -m repro.launch.membership --drift-frac 0.5 \\
      --drift-after 3 --backend jnp

  # fused pallas assignment kernel
  PYTHONPATH=src python -m repro.launch.membership --backend pallas

  # hierarchical seeding: cluster 512 seed users in 8 edge groups
  # (core.hierarchy) — the directory serves the result unchanged
  PYTHONPATH=src python -m repro.launch.membership --seed-users 512 \\
      --seed-groups 8

The loop also maintains the trainer-side ``(T, C_max)`` super-stack
layout through ``fed.partition.admit_layout`` — the warm-start hook that
slots admitted arrivals into the existing stack without retracing the
fused trainer.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed-users", type=int, default=64)
    ap.add_argument("--seed-groups", type=int, default=0,
                    help="> 0 clusters the seed via the hierarchical "
                         "two-level protocol (this many edge groups) "
                         "instead of the flat O(N^2) path")
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--waves", type=int, default=6)
    ap.add_argument("--wave-size", type=int, default=16)
    ap.add_argument("--evict", type=int, default=4,
                    help="members evicted (churn) after each wave")
    ap.add_argument("--drift-frac", type=float, default=0.0,
                    help="fraction of each post --drift-after wave drawn "
                         "from a task the seed never saw")
    ap.add_argument("--drift-after", type=int, default=3)
    ap.add_argument("--backend", default="jnp",
                    choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--margin-floor", type=float, default=0.05)
    ap.add_argument("--unassigned-frac", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.core import clustering as clu
    from repro.core import oneshot
    from repro.core.engine import ProtocolEngine
    from repro.core.membership_engine import (MembershipConfig,
                                              MembershipEngine)
    from repro.core.similarity import SimilarityConfig
    from repro.data import synthetic as syn
    from repro.fed import partition as fpart

    # One mixture over tasks+1 subspaces: the extra task is the DRIFT
    # source — it exists in the generator so drift arrivals share its
    # subspace, but no seed user is drawn from it.
    n_total = args.seed_users + args.waves * args.wave_size
    feats_all, tids_all = syn.make_task_feature_mixture(
        2 * n_total, args.samples, args.dim, args.tasks + 1,
        seed=args.seed)
    seed_pool = np.flatnonzero(tids_all < args.tasks)
    drift_pool = np.flatnonzero(tids_all == args.tasks)
    seed_idx = seed_pool[:args.seed_users]
    arrival_pool = seed_pool[args.seed_users:]

    scfg = SimilarityConfig(top_k=args.top_k)
    hierarchy_cfg = None
    if args.seed_groups:
        from repro.core.hierarchy import HierarchyConfig

        hierarchy_cfg = HierarchyConfig(n_groups=args.seed_groups)
    t0 = time.time()
    res = oneshot.one_shot_clustering(jnp.asarray(feats_all[seed_idx]),
                                      n_clusters=args.tasks, cfg=scfg,
                                      hierarchy_cfg=hierarchy_cfg)
    seed_labels = np.asarray(res.labels)
    seed_tasks = tids_all[seed_idx]
    seed_acc = clu.clustering_accuracy(seed_labels, seed_tasks)
    how = (f"hierarchical ({args.seed_groups} groups)" if args.seed_groups
           else "one-shot")
    print(f"seed: {args.seed_users} users, {how} protocol + HAC in "
          f"{time.time() - t0:.2f}s, clustering accuracy {seed_acc:.1%}")

    # cluster id -> oracle task id (majority vote over the seed).
    task_of_cluster = np.full(args.tasks, -1)
    for t in range(args.tasks):
        members = seed_tasks[seed_labels == t]
        if len(members):
            task_of_cluster[t] = np.bincount(members).argmax()

    cfg = MembershipConfig(
        backend=args.backend, margin_floor=args.margin_floor,
        recluster_unassigned_frac=args.unassigned_frac,
        capacity=2 * n_total)
    engine = MembershipEngine.from_oneshot(res, cfg)
    led = res.ledger
    print(f"directory: T={engine.state.n_clusters}, capacity "
          f"{engine.state.capacity}, backend={args.backend} | arrival "
          f"upload {led.assign_upload / 1024:.1f} KiB vs protocol "
          f"per-user upload {led.per_user_upload / 1024:.1f} KiB")

    # Trainer-side warm-start layout: headroom for every arrival, so the
    # (T, C_max) stack shape survives all waves without a retrace.
    # ``stack_coord`` maps each directory slot to its stack cell so
    # evictions free their columns and admits refill the holes.
    c_max = int(np.bincount(seed_labels, minlength=args.tasks).max()) \
        + args.waves * args.wave_size
    rows0, slots0, stack_mask = fpart.stack_layout(res.labels, args.tasks,
                                                   c_max=c_max)
    stack_shape = stack_mask.shape
    stack_coord = {i: (int(r), int(c)) for i, (r, c)
                   in enumerate(zip(np.asarray(rows0), np.asarray(slots0)))}

    sig_engine = ProtocolEngine(scfg)
    rng = np.random.default_rng(args.seed)
    live_slots = list(range(args.seed_users))
    next_arrival = 0
    for w in range(args.waves):
        n_drift = (int(args.drift_frac * args.wave_size)
                   if w >= args.drift_after else 0)
        take = args.wave_size - n_drift
        idx = list(arrival_pool[next_arrival:next_arrival + take])
        next_arrival += take
        idx += list(rng.choice(drift_pool, n_drift, replace=False))
        wave_f, wave_t = feats_all[idx], tids_all[idx]

        lam_w, v_w, _ = sig_engine.signatures(jnp.asarray(wave_f))
        t0 = time.time()
        out = engine.assign(lam_w, v_w)
        labels = np.asarray(out.labels)
        dt = time.time() - t0
        slots = engine.admit(lam_w, v_w, labels)
        live_slots.extend(int(s) for s in slots)

        assigned = labels >= 0
        known = wave_t < args.tasks
        hits = task_of_cluster[labels[assigned & known]] == \
            wave_t[assigned & known]
        acc = hits.mean() if hits.size else float("nan")
        rows, slot, stack_mask = fpart.admit_layout(stack_mask,
                                                    jnp.asarray(labels))
        for s, r, c, lb in zip(slots, np.asarray(rows), np.asarray(slot),
                               labels):
            if lb >= 0:                      # unassigned never enter it
                stack_coord[int(s)] = (int(r), int(c))
        stats = engine.drift_stats()
        event = engine.maybe_recluster()
        if event:
            # a relabel invalidates the column assignment; rebuild at the
            # SAME (T, C_max) — shape-stable, so still no retrace (the
            # trainer must re-scatter its per-user payloads, not
            # recompile)
            live = np.asarray(engine.state.valid) \
                & (np.asarray(engine.state.labels) >= 0)
            live_idx = np.flatnonzero(live)
            r2, c2, stack_mask = fpart.stack_layout(
                jnp.asarray(np.asarray(engine.state.labels)[live_idx]),
                args.tasks, c_max=c_max)
            stack_coord = {int(s): (int(r), int(c)) for s, r, c
                           in zip(live_idx, np.asarray(r2),
                                  np.asarray(c2))}
        print(f"wave {w}: {args.wave_size} arrivals "
              f"({n_drift} drift) assigned in {dt * 1e3:.1f} ms | "
              f"accuracy {acc:.1%} | unassigned "
              f"{stats['unassigned_frac']:.1%} | proto shift "
              f"{stats['proto_shift']:.3f}"
              + (" | RECLUSTER (stack re-scattered, not retraced)"
                 if event else ""))

        if args.evict and len(live_slots) > args.evict:
            gone = rng.choice(len(live_slots), args.evict, replace=False)
            evicted = [live_slots[g] for g in gone]
            engine.evict(evicted)
            for s in evicted:                # free the stack columns too
                if s in stack_coord:
                    stack_mask = stack_mask.at[stack_coord.pop(s)].set(0.0)
            live_slots = [s for i, s in enumerate(live_slots)
                          if i not in set(gone.tolist())]

    assert stack_mask.shape == stack_shape     # no retrace ever needed
    n_in_stack = int(np.asarray(stack_mask).sum())
    final = engine.drift_stats()
    assert n_in_stack == final["n_members"] - engine.state.n_unassigned
    print(f"final: {final['n_members']} members ({n_in_stack} in the "
          f"stack), {final['n_reclusters']} re-cluster events, stack "
          f"shape {stack_shape} unchanged (fused trainer never retraced)")


if __name__ == "__main__":
    main()
