"""Membership serving launcher: seed protocol -> streaming arrival waves.

Drives the full online lifecycle the ``MembershipEngine`` owns: run the
one-shot protocol on a seed population, build the cluster directory, then
stream synthetic arrival waves with churn (evictions) and task drift
(newcomers from a subspace the seed never saw), reporting per-wave
assignment accuracy vs the oracle, the unassigned fraction, and every
drift-triggered re-cluster event.

Dirty-data scenarios (``data.synthetic`` injectors) turn the launcher
into a robustness harness.  Each cell of the scenario matrix is a
(scenario, arrival-pattern) pair:

  scenario      what is corrupted
  ------------  -----------------------------------------------------
  clean         nothing — the PR-5/6 serving loop
  label-noise   ``--corrupt-frac`` of every arrival's feature rows are
                swapped with rows from a different task (mislabelled
                client data entering the Gram signature)
  byzantine     ``--corrupt-frac`` of each wave uploads adversarial
                signatures (``--byzantine-mode``); colluding attackers
                poison admitted prototypes toward the NEXT cluster
  drift         half of each late wave arrives from a task the seed
                never saw (the PR-5 drift path, as a matrix cell)

  arrivals      wave sizes
  ------------  -----------------------------------------------------
  steady        ``--wave-size`` every wave
  bursty        alternating half / one-and-a-half waves (same total)

  # one cell, full per-wave trace
  PYTHONPATH=src python -m repro.launch.membership --scenario byzantine \\
      --aggregator trimmed --corrupt-frac 0.2

  # the whole 4 x 2 matrix, one summary row per cell (+ JSON dump)
  PYTHONPATH=src python -m repro.launch.membership --matrix \\
      --aggregator medians --json /tmp/matrix.json

  # CI smoke: tiny population, 3 waves
  PYTHONPATH=src python -m repro.launch.membership --scenario label-noise \\
      --quick

Accuracy is measured over HONEST arrivals from seed-known tasks only —
Byzantine uploads and drift newcomers have no oracle cluster to be right
about; what matters is whether they drag honest assignments down.

The loop also maintains the trainer-side ``(T, C_max)`` super-stack
layout through ``fed.partition.admit_layout`` — the warm-start hook that
slots admitted arrivals into the existing stack without retracing the
fused trainer.
"""
from __future__ import annotations

import argparse
import json
import zlib

import numpy as np

from repro import obs

SCENARIOS = ("clean", "label-noise", "byzantine", "drift")
ARRIVAL_PATTERNS = ("steady", "bursty")


def wave_plan(pattern: str, waves: int, wave_size: int) -> list[int]:
    """Per-wave arrival counts; every pattern admits the same total."""
    if pattern == "steady":
        return [wave_size] * waves
    lo = wave_size // 2
    hi = 2 * wave_size - lo
    sizes = [lo if w % 2 == 0 else hi for w in range(waves)]
    sizes[-1] += waves * wave_size - sum(sizes)   # odd-length tail
    return sizes


def run_cell(args, scenario: str, arrivals: str,
             verbose: bool = True) -> dict:
    """One (scenario, arrival-pattern) cell: seed -> waves -> summary."""
    import jax.numpy as jnp

    from repro.core import clustering as clu
    from repro.core import oneshot
    from repro.core.engine import ProtocolEngine
    from repro.core.membership_engine import (MembershipConfig,
                                              MembershipEngine)
    from repro.core.similarity import SimilarityConfig
    from repro.data import synthetic as syn
    from repro.fed import partition as fpart

    # Corruption streams are decoupled from the data stream so every cell
    # serves the SAME population (crc32: stable across processes).
    cseed = zlib.crc32(f"{scenario}|{arrivals}|{args.seed}".encode())
    drift_frac = (args.drift_frac or 0.5) if scenario == "drift" else 0.0
    sizes = wave_plan(arrivals, args.waves, args.wave_size)

    # One mixture over tasks+1 subspaces: the extra task is the DRIFT
    # source — it exists in the generator so drift arrivals share its
    # subspace, but no seed user is drawn from it.
    n_total = args.seed_users + sum(sizes)
    feats_all, tids_all = syn.make_task_feature_mixture(
        2 * n_total, args.samples, args.dim, args.tasks + 1,
        seed=args.seed)
    seed_pool = np.flatnonzero(tids_all < args.tasks)
    drift_pool = np.flatnonzero(tids_all == args.tasks)
    seed_idx = seed_pool[:args.seed_users]
    arrival_pool = seed_pool[args.seed_users:]

    scfg = SimilarityConfig(top_k=args.top_k)
    hierarchy_cfg = None
    if args.seed_groups:
        from repro.core.hierarchy import HierarchyConfig

        hierarchy_cfg = HierarchyConfig(n_groups=args.seed_groups)
    t0 = obs.now()
    res = oneshot.one_shot_clustering(jnp.asarray(feats_all[seed_idx]),
                                      n_clusters=args.tasks, cfg=scfg,
                                      hierarchy_cfg=hierarchy_cfg)
    seed_labels = np.asarray(res.labels)
    seed_tasks = tids_all[seed_idx]
    seed_acc = clu.clustering_accuracy(seed_labels, seed_tasks)
    if verbose:
        how = (f"hierarchical ({args.seed_groups} groups)"
               if args.seed_groups else "one-shot")
        print(f"seed: {args.seed_users} users, {how} protocol + HAC in "
              f"{obs.now() - t0:.2f}s, clustering accuracy "
              f"{seed_acc:.1%}")

    # cluster id -> oracle task id (majority vote over the seed) and the
    # inverse map the colluding attack needs to aim at a NEIGHBOUR.
    task_of_cluster = np.full(args.tasks, -1)
    for t in range(args.tasks):
        members = seed_tasks[seed_labels == t]
        if len(members):
            task_of_cluster[t] = np.bincount(members).argmax()
    cluster_of_task = np.arange(args.tasks)
    for t, tau in enumerate(task_of_cluster):
        if tau >= 0:
            cluster_of_task[tau] = t

    cfg = MembershipConfig(
        backend=args.backend, margin_floor=args.margin_floor,
        recluster_unassigned_frac=args.unassigned_frac,
        capacity=2 * n_total, aggregator=args.aggregator)
    engine = MembershipEngine.from_oneshot(res, cfg)
    led = res.ledger
    if verbose:
        print(f"directory: T={engine.state.n_clusters}, capacity "
              f"{engine.state.capacity}, backend={args.backend}, "
              f"aggregator={args.aggregator} | arrival upload "
              f"{led.assign_upload / 1024:.1f} KiB vs protocol per-user "
              f"upload {led.per_user_upload / 1024:.1f} KiB")

    # Trainer-side warm-start layout: headroom for every arrival, so the
    # (T, C_max) stack shape survives all waves without a retrace.
    # ``stack_coord`` maps each directory slot to its stack cell so
    # evictions free their columns and admits refill the holes.  Sized
    # for the worst case — a poisoned-directory recluster can pile EVERY
    # live member into one cluster, not just the benign-drift spread.
    c_max = args.seed_users + sum(sizes)
    rows0, slots0, stack_mask = fpart.stack_layout(res.labels, args.tasks,
                                                   c_max=c_max)
    stack_shape = stack_mask.shape
    stack_coord = {i: (int(r), int(c)) for i, (r, c)
                   in enumerate(zip(np.asarray(rows0), np.asarray(slots0)))}

    sig_engine = ProtocolEngine(scfg)
    rng = np.random.default_rng(args.seed)
    live_slots = list(range(args.seed_users))
    next_arrival = 0
    acc_traj: list[float] = []
    recluster_waves: list[int] = []
    for w, wave_size in enumerate(sizes):
        n_drift = (int(drift_frac * wave_size)
                   if w >= args.drift_after else 0)
        take = wave_size - n_drift
        idx = list(arrival_pool[next_arrival:next_arrival + take])
        next_arrival += take
        idx += list(rng.choice(drift_pool, n_drift, replace=False))
        wave_f, wave_t = feats_all[idx], tids_all[idx]

        if scenario == "label-noise":
            wave_f = syn.label_noise_rows(wave_f, wave_t,
                                          args.corrupt_frac,
                                          seed=cseed + w)

        lam_w, v_w, _ = sig_engine.signatures(jnp.asarray(wave_f))
        byz = np.zeros(wave_size, bool)
        if scenario == "byzantine":
            lam_w, v_w, byz = syn.byzantine_signatures(
                np.asarray(lam_w), np.asarray(v_w), args.corrupt_frac,
                mode=args.byzantine_mode, seed=cseed + w,
                labels=cluster_of_task[np.minimum(wave_t,
                                                  args.tasks - 1)])

        t0 = obs.now()
        out = engine.assign(lam_w, v_w)
        labels = np.asarray(out.labels)
        dt = obs.now() - t0
        slots = engine.admit(lam_w, v_w, labels)
        live_slots.extend(int(s) for s in slots)

        assigned = labels >= 0
        honest = assigned & (wave_t < args.tasks) & ~byz
        hits = task_of_cluster[labels[honest]] == wave_t[honest]
        acc = float(hits.mean()) if hits.size else float("nan")
        acc_traj.append(acc)
        rows, slot, stack_mask = fpart.admit_layout(stack_mask,
                                                    jnp.asarray(labels))
        for s, r, c, lb in zip(slots, np.asarray(rows), np.asarray(slot),
                               labels):
            if lb >= 0:                      # unassigned never enter it
                stack_coord[int(s)] = (int(r), int(c))
        stats = engine.drift_stats()
        event = engine.maybe_recluster()
        if event:
            recluster_waves.append(w)
            # a relabel invalidates the column assignment; rebuild at the
            # SAME (T, C_max) — shape-stable, so still no retrace (the
            # trainer must re-scatter its per-user payloads, not
            # recompile)
            live = np.asarray(engine.state.valid) \
                & (np.asarray(engine.state.labels) >= 0)
            live_idx = np.flatnonzero(live)
            r2, c2, stack_mask = fpart.stack_layout(
                jnp.asarray(np.asarray(engine.state.labels)[live_idx]),
                args.tasks, c_max=c_max)
            stack_coord = {int(s): (int(r), int(c)) for s, r, c
                           in zip(live_idx, np.asarray(r2),
                                  np.asarray(c2))}
        if verbose:
            print(f"wave {w}: {wave_size} arrivals "
                  f"({n_drift} drift, {int(byz.sum())} byzantine) "
                  f"assigned in {dt * 1e3:.1f} ms | honest accuracy "
                  f"{acc:.1%} | unassigned "
                  f"{stats['unassigned_frac']:.1%} | proto shift "
                  f"{stats['proto_shift']:.3f}"
                  + (" | RECLUSTER (stack re-scattered, not retraced)"
                     if event else ""))

        if args.evict and len(live_slots) > args.evict:
            gone = rng.choice(len(live_slots), args.evict, replace=False)
            evicted = [live_slots[g] for g in gone]
            engine.evict(evicted)
            for s in evicted:                # free the stack columns too
                if s in stack_coord:
                    stack_mask = stack_mask.at[stack_coord.pop(s)].set(0.0)
            live_slots = [s for i, s in enumerate(live_slots)
                          if i not in set(gone.tolist())]

    assert stack_mask.shape == stack_shape     # no retrace ever needed
    n_in_stack = int(np.asarray(stack_mask).sum())
    final = engine.drift_stats()
    assert n_in_stack == final["n_members"] - engine.state.n_unassigned
    if verbose:
        print(f"final: {final['n_members']} members ({n_in_stack} in the "
              f"stack), {final['n_reclusters']} re-cluster events, stack "
              f"shape {stack_shape} unchanged (fused trainer never "
              f"retraced)")
    traj = np.asarray(acc_traj)
    return {
        "scenario": scenario,
        "arrivals": arrivals,
        "aggregator": args.aggregator,
        "backend": args.backend,
        "corrupt_frac": (args.corrupt_frac
                         if scenario in ("label-noise", "byzantine")
                         else 0.0),
        "byzantine_mode": (args.byzantine_mode
                           if scenario == "byzantine" else None),
        "seed_accuracy": float(seed_acc),
        "accuracy_per_wave": [float(a) for a in acc_traj],
        "mean_accuracy": (float(np.nanmean(traj))
                          if np.isfinite(traj).any() else float("nan")),
        "unassigned_frac": float(final["unassigned_frac"]),
        "recluster_waves": recluster_waves,
        "n_reclusters": int(final["n_reclusters"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed-users", type=int, default=64)
    ap.add_argument("--seed-groups", type=int, default=0,
                    help="> 0 clusters the seed via the hierarchical "
                         "two-level protocol (this many edge groups) "
                         "instead of the flat O(N^2) path")
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--waves", type=int, default=6)
    ap.add_argument("--wave-size", type=int, default=16)
    ap.add_argument("--evict", type=int, default=4,
                    help="members evicted (churn) after each wave")
    ap.add_argument("--drift-frac", type=float, default=0.0,
                    help="fraction of each post --drift-after wave drawn "
                         "from a task the seed never saw (drift scenario "
                         "defaults to 0.5)")
    ap.add_argument("--drift-after", type=int, default=3)
    ap.add_argument("--backend", default="jnp",
                    choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--margin-floor", type=float, default=0.05)
    ap.add_argument("--unassigned-frac", type=float, default=0.25)
    ap.add_argument("--scenario", default="clean", choices=SCENARIOS)
    ap.add_argument("--arrivals", default="steady",
                    choices=ARRIVAL_PATTERNS)
    ap.add_argument("--matrix", action="store_true",
                    help="run every (scenario, arrivals) cell and print "
                         "one summary row per cell")
    ap.add_argument("--aggregator", default="mean",
                    choices=["mean", "trimmed", "medians"])
    ap.add_argument("--corrupt-frac", type=float, default=0.2,
                    help="corrupted fraction for label-noise (rows per "
                         "user) / byzantine (users per wave)")
    ap.add_argument("--byzantine-mode", default="colluding_copy",
                    choices=["sign_flip", "random_subspace",
                             "colluding_copy"])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: 32 seed users, 3 waves of 8")
    ap.add_argument("--json", default=None,
                    help="write cell summaries to this path")
    ap.add_argument("--events", default=None,
                    help="record the obs event stream (admit/evict/"
                         "assign-wave/drift-trip/recluster) to this JSONL")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.quick:
        args.seed_users, args.samples = 32, 16
        args.waves, args.wave_size, args.evict = 3, 8, 2
        args.drift_after = 1

    if args.events:
        obs.reset()
        obs.enable()

    if args.matrix:
        cells = []
        for scenario in SCENARIOS:
            for arrivals in ARRIVAL_PATTERNS:
                cell = run_cell(args, scenario, arrivals, verbose=False)
                cells.append(cell)
                print(f"{scenario:>12} x {arrivals:<7} | honest acc "
                      f"{cell['mean_accuracy']:.1%} | unassigned "
                      f"{cell['unassigned_frac']:.1%} | reclusters "
                      f"{cell['n_reclusters']} (waves "
                      f"{cell['recluster_waves']})")
    else:
        cells = [run_cell(args, args.scenario, args.arrivals,
                          verbose=True)]

    if args.json:
        with open(args.json, "w") as fh:
            json.dump(cells, fh, indent=2)
        print(f"wrote {len(cells)} cell(s) to {args.json}")

    if args.events:
        obs.save_events(args.events)
        print(f"wrote {len(obs.events())} event(s) to {args.events}")
        obs.disable()


if __name__ == "__main__":
    main()
