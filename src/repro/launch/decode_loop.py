"""Shared prefill + greedy KV-cache decode loop.

``launch/serve.py`` and ``examples/serve_demo.py`` both drive the same
serving contract — teacher-forced prefill fills the cache token by token,
then ``decode_step`` generates greedily — so the loop lives once, here.
A blocked prefill kernel would batch the first phase on TPU; the contract
(and therefore this loop's timings) is identical.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

__all__ = ["DecodeStats", "greedy_decode"]


@dataclasses.dataclass(frozen=True)
class DecodeStats:
    """One serving run: generated tokens + phase wall-clock."""

    tokens: jax.Array          # (batch, gen) greedy continuations
    prompt_len: int
    prefill_s: float
    decode_s: float

    @property
    def tok_per_s(self) -> float:
        b, g = self.tokens.shape
        return b * g / max(self.decode_s, 1e-9)


def greedy_decode(model, params, prompts: jax.Array, gen: int
                  ) -> DecodeStats:
    """Prefill ``prompts (batch, prompt_len)`` through a fresh decode
    state, then generate ``gen`` tokens greedily.  Returns the tokens
    (the first one is argmax of the last prefill logits) and timings."""
    batch, prompt_len = prompts.shape
    state = model.init_decode_state(batch, prompt_len + gen)
    step = jax.jit(model.decode_step)

    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        logits, state = step(params, prompts[:, t:t + 1], state)
    jax.block_until_ready(logits)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    tokens = jnp.concatenate(out, axis=1)
    jax.block_until_ready(tokens)
    return DecodeStats(tokens=tokens, prompt_len=prompt_len,
                       prefill_s=prefill_s, decode_s=time.time() - t0)
