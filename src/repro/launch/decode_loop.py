"""Cluster-routed continuous-batching LM serving.

Three layers, slowest to fastest:

* ``greedy_decode`` — the uniform-batch baseline: one jitted dispatch per
  token for prefill AND decode.  Kept as the reference path (and the
  benchmark baseline) with honest phase accounting: ``decode_s`` covers
  the ``gen - 1`` post-first-token steps (the first generated token is
  argmaxed from the last prefill logits inside the prefill window), and
  time-to-first-token is reported explicitly.
* ``ClusterHeads`` / ``cluster_logits`` — per-cluster output heads plus a
  low-rank adapter over the GPS-shared trunk: the multi-task serving
  surface.  One gather per batch row selects its cluster's parameters
  INSIDE the jit, so requests from different clusters share one program.
* ``ServeEngine`` — the continuous-batching slot scheduler:

    - admission waves run a single-dispatch chunked teacher-forced
      prefill (ONE ``lax.scan`` over ``max_prompt / prefill_chunk``
      chunks — dispatches drop O(prompt_len) -> O(1) per wave);
    - decode holds a fixed ``(slots, max_len)`` state; every round steps
      ALL slots with per-slot lengths and per-slot cluster ids; finished
      requests free their slot and queued requests are admitted by
      scattering the wave's prefilled state into free slots — all through
      traced masks/lengths, so admits/frees/ragged mixes NEVER retrace
      (the same traced-scalar pattern as ``MTHFLConfig.dropout_frac``;
      ``ServeEngine.traces`` counts actual traces to prove it).

  Cluster ids come from ``MembershipEngine.assign`` over
  ``data/tokens.py::token_features`` signatures (``route_requests``) —
  routing costs one signature + one directory matmul per request, vs
  IFCA's per-cluster loss probe through every cluster's full model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

PyTree = Any

__all__ = ["DecodeStats", "greedy_decode", "ClusterHeads", "cluster_logits",
           "cluster_logits_fn", "Request", "RequestResult", "ServeConfig",
           "ServeStats", "ServeEngine", "token_signature", "route_requests"]


# ---------------------------------------------------------------------------
# Uniform-batch baseline (per-token dispatch) + honest stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeStats:
    """One serving run: generated tokens + phase wall-clock.

    ``prefill_s`` covers the teacher-forced prompt forward; ``ttft_s``
    additionally includes the first-token argmax (time-to-first-token);
    ``decode_s`` covers exactly the ``gen - 1`` incremental steps that
    produce tokens 2..gen — so ``tok_per_s`` divides the tokens that
    phase actually produced, not ``batch * gen``.
    """

    tokens: jax.Array          # (batch, gen) greedy continuations
    prompt_len: int
    prefill_s: float
    ttft_s: float
    decode_s: float
    prefill_dispatches: int    # counted jitted dispatches in prefill

    @property
    def tok_per_s(self) -> float:
        """Decode-phase throughput over the steps ``decode_s`` covers."""
        b, g = self.tokens.shape
        return b * (g - 1) / max(self.decode_s, 1e-9)

    @property
    def total_tok_per_s(self) -> float:
        """End-to-end throughput incl. prefill + first token."""
        b, g = self.tokens.shape
        return b * g / max(self.ttft_s + self.decode_s, 1e-9)


def greedy_decode(model, params, prompts: jax.Array, gen: int,
                  logits_fn: Callable[[jax.Array], jax.Array] | None = None
                  ) -> DecodeStats:
    """Prefill ``prompts (batch, prompt_len)`` through a fresh decode
    state ONE TOKEN PER DISPATCH, then generate ``gen`` tokens greedily.

    ``logits_fn(hn (B, d)) -> (B, V)`` swaps the stock LM head for a
    custom readout (e.g. one cluster's head/adapter via
    ``cluster_logits_fn``) while keeping the identical trunk — the
    sequential baseline the slot scheduler is verified token-identical
    against.
    """
    batch, prompt_len = prompts.shape
    state = model.init_decode_state(batch, prompt_len + gen)
    if logits_fn is None:
        step = jax.jit(model.decode_step)
    else:
        if model.decode_hidden is None:
            raise ValueError("logits_fn needs a decoder bundle exposing "
                             "decode_hidden")

        def _step(p, toks, st):
            hn, st = model.decode_hidden(p, toks, st)
            return logits_fn(hn[:, 0])[:, None, :], st

        step = jax.jit(_step)

    t0 = time.perf_counter()
    logits = None
    for t in range(prompt_len):
        logits, state = step(params, prompts[:, t:t + 1], state)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    ttft_s = time.perf_counter() - t0
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, state = step(params, tok, state)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    tokens = jnp.concatenate(out, axis=1)
    jax.block_until_ready(tokens)
    return DecodeStats(tokens=tokens, prompt_len=prompt_len,
                       prefill_s=prefill_s, ttft_s=ttft_s,
                       decode_s=time.perf_counter() - t0,
                       prefill_dispatches=prompt_len)


# ---------------------------------------------------------------------------
# Per-cluster heads/adapters over the GPS-shared trunk
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClusterHeads:
    """Per-cluster serving parameters: a full output head plus a low-rank
    residual adapter on the final hidden, both selected PER ROW inside
    the jit.  The trunk (embeddings + blocks) stays shared — the GPS
    split of the MT-HFL trainer."""

    head: jax.Array       # (T, d, vocab)
    adapter_a: jax.Array  # (T, d, rank)
    adapter_b: jax.Array  # (T, rank, d)

    @property
    def n_clusters(self) -> int:
        return self.head.shape[0]

    @classmethod
    def init(cls, rng: jax.Array, base_head: jax.Array, n_clusters: int,
             rank: int = 4, scale: float = 0.05) -> "ClusterHeads":
        """Distinct per-cluster heads = shared base + seeded noise (stand-in
        for per-cluster fine-tuned heads from ``_train_fused``)."""
        d, v = base_head.shape
        k1, k2, k3 = jax.random.split(rng, 3)
        f32 = jnp.float32
        return cls(
            head=(base_head.astype(f32)[None]
                  + scale * jax.random.normal(k1, (n_clusters, d, v), f32)),
            adapter_a=scale * jax.random.normal(k2, (n_clusters, d, rank),
                                                f32),
            adapter_b=scale * jax.random.normal(k3, (n_clusters, rank, d),
                                                f32),
        )


def cluster_logits(heads: ClusterHeads, hn: jax.Array, cids: jax.Array
                   ) -> jax.Array:
    """Routed readout: ``hn (B, d)`` normed hidden, ``cids (B,)`` cluster
    ids -> ``(B, vocab)`` logits through each row's cluster head/adapter."""
    hf = hn.astype(jnp.float32)
    wa = jnp.take(heads.adapter_a, cids, axis=0)      # (B, d, r)
    wb = jnp.take(heads.adapter_b, cids, axis=0)      # (B, r, d)
    wh = jnp.take(heads.head, cids, axis=0)           # (B, d, V)
    delta = jnp.einsum("br,brd->bd", jnp.einsum("bd,bdr->br", hf, wa), wb)
    return jnp.einsum("bd,bdv->bv", hf + delta, wh)


def cluster_logits_fn(heads: ClusterHeads, cluster: int
                      ) -> Callable[[jax.Array], jax.Array]:
    """A ``greedy_decode(logits_fn=...)`` readout pinned to one cluster —
    op-for-op identical to the engine's routed path."""
    def fn(hn):
        cids = jnp.full((hn.shape[0],), cluster, jnp.int32)
        return cluster_logits(heads, hn, cids)
    return fn


# ---------------------------------------------------------------------------
# Cluster routing from token-statistics signatures
# ---------------------------------------------------------------------------

def token_signature(tokens: np.ndarray, d: int = 32, k: int = 2,
                    window: int = 16, vocab: int | None = None
                    ) -> tuple[np.ndarray, np.ndarray]:
    """One request's (lam (k,), v (d, k)) signature from its prompt token
    statistics: ``token_features`` windows -> Gram -> top-k eigenpairs.
    This is the entire per-request routing upload — O(d^2), independent
    of any cluster model (vs IFCA's T full-model loss probes)."""
    from repro.data.tokens import token_features

    x = token_features(np.asarray(tokens, np.int64), d=d, window=window,
                       vocab=vocab)
    if x.shape[0] == 0:
        return np.zeros(k, np.float32), np.zeros((d, k), np.float32)
    g = x.T @ x / x.shape[0]
    w, u = np.linalg.eigh(g.astype(np.float64))
    return (w[-k:][::-1].astype(np.float32),
            np.ascontiguousarray(u[:, -k:][:, ::-1]).astype(np.float32))


def route_requests(membership, token_streams: Sequence[np.ndarray],
                   d: int = 32, k: int = 2, window: int = 16,
                   vocab: int | None = None) -> np.ndarray:
    """Route a batch of requests to cluster ids through a seeded
    ``MembershipEngine``: signatures -> ``assign`` -> labels.  Unassigned
    verdicts (label -1, below the affinity/margin floors) fall back to
    cluster 0 rather than stalling the request."""
    sigs = [token_signature(t, d=d, k=k, window=window, vocab=vocab)
            for t in token_streams]
    lam = np.stack([s[0] for s in sigs])
    v = np.stack([s[1] for s in sigs])
    labels = np.asarray(membership.assign(lam, v).labels)
    return np.where(labels < 0, 0, labels).astype(np.int32)


# ---------------------------------------------------------------------------
# The continuous-batching slot scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static shapes of the serving program.  Everything here is baked
    into the traced programs; everything per-request rides in as traced
    arrays, so one trace serves every admit wave / ragged mix."""

    slots: int = 8             # S: concurrent decode rows
    max_len: int = 256         # per-slot KV/state capacity (prompt + gen)
    prefill_chunk: int = 16    # C: tokens per prefill scan step
    max_prompt: int = 64       # P: admission-wave prompt pad (mult of C)
    wave: int = 4              # W: requests prefilled per admission wave
    max_gen: int = 64          # cap on generated tokens per request

    def validate(self) -> None:
        if self.max_prompt % self.prefill_chunk:
            raise ValueError(f"max_prompt {self.max_prompt} must be a "
                             f"multiple of prefill_chunk "
                             f"{self.prefill_chunk}")
        if self.max_prompt + self.max_gen > self.max_len:
            raise ValueError(f"max_prompt + max_gen "
                             f"{self.max_prompt + self.max_gen} exceeds "
                             f"max_len {self.max_len}")
        if min(self.slots, self.wave, self.prefill_chunk, self.max_gen) < 1:
            raise ValueError("slots/wave/prefill_chunk/max_gen must be >= 1")


@dataclasses.dataclass(frozen=True)
class Request:
    tokens: np.ndarray         # (prompt_len,) i32 prompt
    gen: int                   # tokens to generate (>= 1)
    cluster: int = 0           # routed cluster id (see route_requests)
    arrive_round: int = 0      # earliest decode round it may be admitted


@dataclasses.dataclass(frozen=True)
class RequestResult:
    tokens: np.ndarray         # (gen,) generated tokens
    ttft_s: float              # admission wall-clock -> first token
    done_s: float              # wall-clock when the request completed
    cluster: int


@dataclasses.dataclass(frozen=True)
class ServeStats:
    results: list[RequestResult]
    wall_s: float
    decode_rounds: int
    prefill_dispatches: int    # counted host->device prefill dispatches
    decode_dispatches: int     # counted decode-round dispatches
    prefill_scan_steps: int    # chunks per wave inside the one dispatch
    slot_utilization: float    # mean active-slot fraction per decode round
    traces: dict[str, int]     # trace counts per jitted program

    @property
    def total_tokens(self) -> int:
        return int(sum(len(r.tokens) for r in self.results))

    @property
    def aggregate_tok_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def mean_ttft_s(self) -> float:
        return float(np.mean([r.ttft_s for r in self.results]))


class ServeEngine:
    """Continuous-batching decode over a fixed slot grid.

    Three jitted programs, each traced ONCE (shapes are pinned by
    ``ServeConfig``; per-request variation rides in as traced data):

      _prefill(params, heads, tokens (W,P), lengths (W,), cids (W,))
          -> (first token (W,), wave state)   [one lax.scan over P/C chunks]
      _admit(slot_state, wave_state, slot_ids (W,))
          -> slot_state with wave rows scattered into free slots
      _decode(params, heads, slot_state, cur_tok (S,), cids (S,),
              active (S,)) -> (next token (S,), slot_state)

    The host loop only makes scheduling decisions (which request enters
    which free slot) over tiny (S,) arrays.
    """

    def __init__(self, model, params, heads: ClusterHeads,
                 cfg: ServeConfig | None = None):
        cfg = cfg or ServeConfig()
        cfg.validate()
        if model.prefill_chunk is None or model.decode_hidden is None:
            raise ValueError("ServeEngine needs a decoder-only bundle "
                             "(prefill_chunk/decode_hidden)")
        if model.cfg.attn_window or model.cfg.local_window:
            raise ValueError("slot scheduling serves full KV caches only "
                             "(sliding-window archs unsupported)")
        self.model = model
        self.params = params
        self.heads = heads
        self.cfg = cfg
        self.traces = {"prefill": 0, "admit": 0, "decode": 0}
        self._build()

    # -- traced programs ----------------------------------------------------

    def _build(self) -> None:
        model, scfg = self.model, self.cfg
        s_slots, w = scfg.slots, scfg.wave
        c, p = scfg.prefill_chunk, scfg.max_prompt
        n_chunks = p // c
        d_model = model.cfg.d_model
        self.prefill_scan_steps = n_chunks

        def prefill_fn(params, heads, tokens, lengths, cids):
            self.traces["prefill"] += 1          # runs at trace time only
            from repro.models import layers as L

            state = model.init_decode_state(w, scfg.max_len, per_slot=True)
            h_dt = state["length"].dtype  # placeholder; h_last in f32
            del h_dt
            tok_chunks = tokens.reshape(w, n_chunks, c).transpose(1, 0, 2)
            pos = jnp.arange(p, dtype=jnp.int32).reshape(n_chunks, c)
            h_last0 = jnp.zeros((w, d_model), jnp.float32)

            def chunk_body(carry, inp):
                st, h_last = carry
                tok_c, pos_c = inp               # (W, C), (C,)
                valid = pos_c[None, :] < lengths[:, None]
                h, st = model.prefill_chunk(params, tok_c, st, pos_c[0],
                                            valid)
                # keep each row's hidden at its LAST VALID position
                in_chunk = lengths[:, None] - 1 - pos_c[0]
                g = jnp.take_along_axis(
                    h, jnp.clip(in_chunk, 0, c - 1)[:, :, None], axis=1
                )[:, 0].astype(jnp.float32)
                h_last = jnp.where((in_chunk >= 0) & (in_chunk < c), g,
                                   h_last)
                return (st, h_last), None

            (state, h_last), _ = jax.lax.scan(chunk_body, (state, h_last0),
                                              (tok_chunks, pos))
            hn = L.rms_norm(
                h_last.astype(jnp.asarray(params["final_norm"]).dtype),
                params["final_norm"])
            first = jnp.argmax(cluster_logits(heads, hn, cids),
                               axis=-1).astype(jnp.int32)
            return first, state

        def admit_fn(slot_state, wave_state, slot_ids):
            self.traces["admit"] += 1

            def put(slot_leaf, wave_leaf, batch_axis):
                pads = []
                for a, (ss, ws) in enumerate(zip(slot_leaf.shape,
                                                 wave_leaf.shape)):
                    pads.append((0, 0) if a == batch_axis else (0, ss - ws))
                if any(pad != (0, 0) for pad in pads):
                    wave_leaf = jnp.pad(wave_leaf, pads)
                wave_leaf = wave_leaf.astype(slot_leaf.dtype)
                if batch_axis == 0:
                    return slot_leaf.at[slot_ids].set(wave_leaf, mode="drop")
                return slot_leaf.at[:, slot_ids].set(wave_leaf, mode="drop")

            out = dict(slot_state)
            out["length"] = put(slot_state["length"], wave_state["length"], 0)
            out["rest"] = jax.tree.map(lambda a, b: put(a, b, 0),
                                       slot_state["rest"],
                                       wave_state["rest"])
            if "groups" in slot_state:
                # scan-stacked groups carry a leading layer-group axis;
                # the batch axis sits at position 1
                out["groups"] = jax.tree.map(lambda a, b: put(a, b, 1),
                                             slot_state["groups"],
                                             wave_state["groups"])
            if "groups_unrolled" in slot_state:
                out["groups_unrolled"] = jax.tree.map(
                    lambda a, b: put(a, b, 0),
                    slot_state["groups_unrolled"],
                    wave_state["groups_unrolled"])
            return out

        def decode_fn(params, heads, slot_state, cur_tok, cids, active):
            self.traces["decode"] += 1
            hn, new_state = model.decode_hidden(params, cur_tok[:, None],
                                                slot_state)
            nxt = jnp.argmax(cluster_logits(heads, hn[:, 0], cids),
                             axis=-1).astype(jnp.int32)
            # frozen (inactive) slots: length stays, token stays — their
            # compute is masked out, their state is overwritten on admit
            new_state["length"] = jnp.where(active,
                                            slot_state["length"] + 1,
                                            slot_state["length"])
            return jnp.where(active, nxt, cur_tok), new_state

        self._prefill = jax.jit(prefill_fn)
        self._admit = jax.jit(admit_fn, donate_argnums=(0,))
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))
        self._init_slots = jax.jit(
            lambda: model.init_decode_state(s_slots, scfg.max_len,
                                            per_slot=True))

    # -- host scheduling loop ----------------------------------------------

    def _check(self, requests: Sequence[Request]) -> None:
        scfg = self.cfg
        t = self.heads.n_clusters
        for i, r in enumerate(requests):
            n = len(np.asarray(r.tokens))
            if not 1 <= n <= scfg.max_prompt:
                raise ValueError(f"request {i}: prompt len {n} outside "
                                 f"[1, {scfg.max_prompt}]")
            if not 1 <= r.gen <= scfg.max_gen:
                raise ValueError(f"request {i}: gen {r.gen} outside "
                                 f"[1, {scfg.max_gen}]")
            if n + r.gen > scfg.max_len:
                raise ValueError(f"request {i}: prompt+gen {n + r.gen} "
                                 f"exceeds max_len {scfg.max_len}")
            if not 0 <= r.cluster < t:
                raise ValueError(f"request {i}: cluster {r.cluster} outside "
                                 f"directory [0, {t})")

    def serve(self, requests: Sequence[Request]) -> ServeStats:
        """Run every request to completion, admitting continuously as
        slots free up.  Returns per-request tokens + latencies and the
        counted dispatch/trace/utilization telemetry."""
        with obs.span("serve.run", n_requests=len(requests),
                      slots=self.cfg.slots):
            stats = self._serve(requests)
        if obs.enabled():
            obs.count("serve.requests", len(stats.results))
            obs.count("serve.prefill_dispatches", stats.prefill_dispatches)
            obs.count("serve.decode_dispatches", stats.decode_dispatches)
            obs.gauge("serve.slot_utilization", stats.slot_utilization)
            for r in stats.results:
                obs.observe("serve.ttft_us", r.ttft_s * 1e6)
        return stats

    def _serve(self, requests: Sequence[Request]) -> ServeStats:
        self._check(requests)
        scfg = self.cfg
        s_slots, w, p = scfg.slots, scfg.wave, scfg.max_prompt
        n_req = len(requests)

        t_start = time.perf_counter()
        slot_state = self._init_slots()
        active = np.zeros(s_slots, bool)
        slot_req = np.full(s_slots, -1, np.int64)
        remaining = np.zeros(s_slots, np.int64)
        cur_tok = np.zeros(s_slots, np.int32)
        cids = np.zeros(s_slots, np.int32)
        out_toks: list[list[int]] = [[] for _ in range(n_req)]
        ttft = np.zeros(n_req)
        done = np.zeros(n_req)
        pending = list(range(n_req))
        rounds = prefill_dispatches = decode_dispatches = 0
        active_slot_rounds = 0

        while True:
            free = np.flatnonzero(~active)
            avail = [i for i in pending
                     if requests[i].arrive_round <= rounds]
            if len(avail) and len(free):
                take = avail[:min(w, len(free))]
                tokens = np.zeros((w, p), np.int32)
                lengths = np.zeros(w, np.int32)
                wcids = np.zeros(w, np.int32)
                for j, i in enumerate(take):
                    tk = np.asarray(requests[i].tokens, np.int32)
                    tokens[j, :len(tk)] = tk
                    lengths[j] = len(tk)
                    wcids[j] = requests[i].cluster
                first, wave_state = self._prefill(
                    self.params, self.heads, jnp.asarray(tokens),
                    jnp.asarray(lengths), jnp.asarray(wcids))
                first = np.asarray(first)
                prefill_dispatches += 1
                now = time.perf_counter() - t_start
                slot_ids = np.full(w, s_slots, np.int32)  # default: dropped
                for j, i in enumerate(take):
                    pending.remove(i)
                    out_toks[i].append(int(first[j]))
                    ttft[i] = now
                    if requests[i].gen == 1:
                        done[i] = now      # complete; never occupies a slot
                        if obs.enabled():
                            obs.event("request_done", request=i,
                                      ttft_s=now, done_s=now, n_tokens=1)
                        continue
                    s = int(free[j])
                    slot_ids[j] = s
                    active[s] = True
                    slot_req[s] = i
                    remaining[s] = requests[i].gen - 1
                    cur_tok[s] = first[j]
                    cids[s] = requests[i].cluster
                slot_state = self._admit(slot_state, wave_state,
                                         jnp.asarray(slot_ids))
                if obs.enabled():
                    obs.event("wave_admitted", round=rounds,
                              n_admitted=len(take),
                              free_slots=int((~active).sum()))
                continue                   # admit again while possible
            if not active.any():
                if not pending:
                    break
                rounds += 1                # idle: wait for arrivals
                continue

            nxt, slot_state = self._decode(
                self.params, self.heads, slot_state, jnp.asarray(cur_tok),
                jnp.asarray(cids), jnp.asarray(active))
            nxt = np.asarray(nxt)
            decode_dispatches += 1
            rounds += 1
            active_slot_rounds += int(active.sum())
            now = time.perf_counter() - t_start
            for s in np.flatnonzero(active):
                i = int(slot_req[s])
                out_toks[i].append(int(nxt[s]))
                remaining[s] -= 1
                if remaining[s] == 0:
                    done[i] = now
                    active[s] = False
                    slot_req[s] = -1
                    if obs.enabled():
                        obs.event("slot_freed", slot=int(s), request=i,
                                  round=rounds)
                        obs.event("request_done", request=i,
                                  ttft_s=float(ttft[i]), done_s=now,
                                  n_tokens=len(out_toks[i]))
                else:
                    cur_tok[s] = nxt[s]

        wall = time.perf_counter() - t_start
        results = [RequestResult(tokens=np.asarray(out_toks[i], np.int32),
                                 ttft_s=float(ttft[i]),
                                 done_s=float(done[i]),
                                 cluster=requests[i].cluster)
                   for i in range(n_req)]
        util = (active_slot_rounds / (decode_dispatches * s_slots)
                if decode_dispatches else 0.0)
        return ServeStats(results=results, wall_s=wall,
                          decode_rounds=rounds,
                          prefill_dispatches=prefill_dispatches,
                          decode_dispatches=decode_dispatches,
                          prefill_scan_steps=self.prefill_scan_steps,
                          slot_utilization=util, traces=dict(self.traces))
