"""Synthetic token/embedding streams for the LM-architecture substrate.

Two uses:
  1. Training data for the transformer archs (``token_batch_iterator``):
     per-task Markov token sources so that MT-HFL over LMs has real task
     structure (users on the same "domain" share a transition matrix).
  2. Per-user feature matrices for the similarity protocol on token data
     (``token_features``): mean-pooled fixed-random-embedding windows — the
     LM analogue of the paper's fixed conv Phi (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["TokenTaskSpec", "sample_tokens", "token_features",
           "token_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class TokenTaskSpec:
    vocab: int = 256
    order_rank: int = 8       # rank of the task's transition structure
    logit_scale: float = 3.0  # transition sharpness (higher = more domain
    seed: int = 0             # signal in the bigram statistics)


def _task_logits(spec: TokenTaskSpec) -> tuple[np.ndarray, np.ndarray]:
    """Low-rank bigram logits ``L = U V^T`` identifying the task."""
    rng = np.random.default_rng((spec.seed, 17))
    u = rng.standard_normal((spec.vocab, spec.order_rank)).astype(np.float32)
    v = rng.standard_normal((spec.vocab, spec.order_rank)).astype(np.float32)
    return u * (spec.logit_scale / np.sqrt(spec.order_rank)), v


def sample_tokens(spec: TokenTaskSpec, n_tokens: int,
                  seed: int = 0) -> np.ndarray:
    """Sample one stream from the task's bigram model (Gumbel trick)."""
    u, v = _task_logits(spec)
    rng = np.random.default_rng((seed, 19))
    out = np.empty(n_tokens, dtype=np.int32)
    tok = int(rng.integers(spec.vocab))
    for t in range(n_tokens):
        logits = u[tok] @ v.T                      # (vocab,)
        g = rng.gumbel(size=spec.vocab).astype(np.float32)
        tok = int(np.argmax(logits + g))
        out[t] = tok
    return out


def token_features(tokens: np.ndarray, d: int = 128, window: int = 16,
                   seed: int = 7, vocab: int | None = None) -> np.ndarray:
    """Phi for token data: fixed random BIGRAM embedding, mean-pooled.

    Each adjacent pair (t_i, t_{i+1}) maps to ``e1[t_i] * e2[t_{i+1}]``
    (elementwise product of two fixed random embeddings — a randomized
    bigram co-occurrence sketch), mean-pooled over short windows.  Domains
    that differ in transition structure then differ in feature
    second-moments, which is what the Gram-spectrum protocol keys on.
    The tables are seeded, hence shared across users, as required.
    """
    rng = np.random.default_rng((seed, 23))
    vocab = vocab or (int(tokens.max()) + 1)
    e1 = rng.standard_normal((vocab, d)).astype(np.float32)
    e2 = rng.standard_normal((vocab, d)).astype(np.float32)
    pair = e1[tokens[:-1]] * e2[tokens[1:]] / np.sqrt(d)
    n_win = len(pair) // window
    pair = pair[: n_win * window].reshape(n_win, window, d)
    return pair.mean(axis=1)


def token_batch_iterator(spec: TokenTaskSpec, batch: int, seq_len: int,
                         seed: int = 0) -> Iterator[dict[str, np.ndarray]]:
    """Infinite iterator of LM batches ``{tokens, labels}`` (next-token)."""
    stream_seed = 0
    while True:
        toks = np.stack([
            sample_tokens(spec, seq_len + 1, seed=(seed, stream_seed, b))
            for b in range(batch)])
        stream_seed += 1
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
