"""Feature maps Phi for the similarity protocol (paper Eq. 1).

The paper uses the identity map for FMNIST (m=784 is informative) and an
ImageNet-pretrained ResNet18 for CIFAR-10 (m=3072 raw pixels are not).
Offline we provide four fixed, *shared* maps — the protocol only needs Phi
to be common across users and informative:

  * identity          : Phi(x) = x                       (FMNIST path)
  * random_projection : x W,  W (m, d) fixed Gaussian / sqrt(d)  (JL)
  * random_conv       : fixed random-init 2-layer conv net -> GAP features
                        (pretrained-feature surrogate; CIFAR path)
  * pca               : top-d PCA basis fit on a public probe set

All maps are deterministic in ``FeatureConfig.seed`` so every user applies
the *same* Phi, as the protocol requires.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FeatureConfig", "feature_map"]


@dataclasses.dataclass(frozen=True)
class FeatureConfig:
    kind: str = "random_projection"   # identity|random_projection|random_conv|pca
    d: int = 256                      # output feature dimension
    seed: int = 7
    image_hw: tuple[int, int, int] | None = None  # (H, W, C) for random_conv
    probe: np.ndarray | None = None   # public probe set for pca


def _rp_matrix(m: int, d: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng((seed, 11))
    return (rng.standard_normal((m, d)) / np.sqrt(d)).astype(np.float32)


def _conv_params(c_in: int, seed: int) -> dict:
    rng = np.random.default_rng((seed, 13))

    def he(shape, fan_in):
        return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)
                ).astype(np.float32)

    return {
        "w1": he((5, 5, c_in, 32), 5 * 5 * c_in),
        "w2": he((5, 5, 32, 64), 5 * 5 * 32),
    }


@partial(jax.jit, static_argnames=("hw",))
def _random_conv_features(x_flat: jax.Array, w1: jax.Array, w2: jax.Array,
                          hw: tuple[int, int, int]) -> jax.Array:
    h, w, c = hw
    x = x_flat.reshape((-1, h, w, c))
    dn = jax.lax.conv_dimension_numbers(x.shape, w1.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    y = jax.lax.conv_general_dilated(x, w1, (2, 2), "SAME",
                                     dimension_numbers=dn)
    y = jax.nn.relu(y)
    dn2 = jax.lax.conv_dimension_numbers(y.shape, w2.shape,
                                         ("NHWC", "HWIO", "NHWC"))
    y = jax.lax.conv_general_dilated(y, w2, (2, 2), "SAME",
                                     dimension_numbers=dn2)
    y = jax.nn.relu(y)
    # 4x4 average-pooled grid -> flattened feature vector (pretrained-GAP
    # surrogate): keeps spatial second-moment structure, d = 16*64 = 1024.
    gh = max(1, y.shape[1] // 4)
    gw = max(1, y.shape[2] // 4)
    y = jax.lax.reduce_window(y, 0.0, jax.lax.add,
                              (1, gh, gw, 1), (1, gh, gw, 1), "VALID")
    y = y / (gh * gw)
    return y.reshape((y.shape[0], -1))


def feature_map(x: np.ndarray, cfg: FeatureConfig) -> np.ndarray:
    """Apply Phi to a user's raw data ``x (n, m)`` -> ``(n, d')``."""
    if cfg.kind == "identity":
        return np.asarray(x, dtype=np.float32)
    if cfg.kind == "random_projection":
        w = _rp_matrix(x.shape[1], cfg.d, cfg.seed)
        return np.asarray(x, dtype=np.float32) @ w
    if cfg.kind == "random_conv":
        if cfg.image_hw is None:
            raise ValueError("random_conv needs image_hw=(H, W, C)")
        p = _conv_params(cfg.image_hw[2], cfg.seed)
        feats = _random_conv_features(jnp.asarray(x, dtype=jnp.float32),
                                      jnp.asarray(p["w1"]),
                                      jnp.asarray(p["w2"]), cfg.image_hw)
        feats = np.asarray(feats)
        if cfg.d and cfg.d < feats.shape[1]:
            w = _rp_matrix(feats.shape[1], cfg.d, cfg.seed + 1)
            feats = feats @ w
        return feats
    if cfg.kind == "pca":
        if cfg.probe is None:
            raise ValueError("pca needs a public probe set")
        probe = np.asarray(cfg.probe, dtype=np.float32)
        mu = probe.mean(0, keepdims=True)
        _, _, vt = np.linalg.svd(probe - mu, full_matrices=False)
        basis = vt[: cfg.d].T
        return (np.asarray(x, dtype=np.float32) - mu) @ basis
    raise ValueError(f"unknown feature map kind {cfg.kind!r}")
