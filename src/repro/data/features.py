"""Feature maps Phi for the similarity protocol (paper Eq. 1).

The paper uses the identity map for FMNIST (m=784 is informative) and an
ImageNet-pretrained ResNet18 for CIFAR-10 (m=3072 raw pixels are not).
Offline we provide four fixed, *shared* maps — the protocol only needs Phi
to be common across users and informative:

  * identity          : Phi(x) = x                       (FMNIST path)
  * random_projection : x W,  W (m, d) fixed Gaussian / sqrt(d)  (JL)
  * random_conv       : fixed random-init 2-layer conv net -> GAP features
                        (pretrained-feature surrogate; CIFAR path)
  * pca               : top-d PCA basis fit on a public probe set

All maps are deterministic in ``FeatureConfig.seed`` so every user applies
the *same* Phi, as the protocol requires.

Two execution forms share the same parameters:

  * ``feature_map(x, cfg, probe=...)`` — the host numpy reference, one
    user at a time (the original ingest path, kept as the parity oracle).
  * ``phi_params(cfg, m, probe=...)`` + ``phi_apply(x, params, cfg)`` —
    the split the device-resident ``SignatureEngine`` uses: parameters are
    fixed host arrays derived from the seed (and the public probe for
    ``pca``), application is pure jit-able jnp that vmaps over users and
    streams over row chunks.

``FeatureConfig`` is a frozen *hashable* dataclass: the ``pca`` probe set
is NOT stored on it (a raw ndarray field breaks ``__eq__``/``hash`` with
"ambiguous truth value" the moment configs are compared or cached) — the
config records only a digest of the probe, and callers pass the array
explicitly where Phi is built.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FeatureConfig", "feature_map", "probe_digest",
           "phi_params", "phi_apply", "phi_out_dim", "PHI_KINDS"]

PHI_KINDS = ("identity", "random_projection", "random_conv", "pca")


def probe_digest(probe: np.ndarray) -> str:
    """Stable content digest of a public probe set (shape + fp32 bytes)."""
    arr = np.ascontiguousarray(np.asarray(probe, dtype=np.float32))
    h = hashlib.sha256()
    h.update(repr(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class FeatureConfig:
    """Which shared Phi every user applies (hashable, probe-free).

    ``probe_digest`` optionally pins the ``pca`` probe content: when set,
    any probe array passed alongside this config must hash to it (guards
    against two callers silently fitting Phi on different "public" sets).
    Use :func:`probe_digest` to compute it.
    """

    kind: str = "random_projection"   # identity|random_projection|random_conv|pca
    d: int = 256                      # output feature dimension
    seed: int = 7
    image_hw: tuple[int, int, int] | None = None  # (H, W, C) for random_conv
    probe_digest: str | None = None   # content digest of the pca probe set

    def __post_init__(self):
        if self.kind not in PHI_KINDS:
            raise ValueError(f"unknown feature map kind {self.kind!r}; "
                             f"expected one of {PHI_KINDS}")
        if self.d <= 0:
            raise ValueError(f"feature dim d must be positive, got {self.d}")
        if self.kind == "random_conv" and self.image_hw is None:
            raise ValueError("random_conv needs image_hw=(H, W, C)")
        if self.image_hw is not None:
            object.__setattr__(self, "image_hw", tuple(self.image_hw))

    def bind_probe(self, probe: np.ndarray) -> "FeatureConfig":
        """Pin this config to a concrete probe set (content digest)."""
        return dataclasses.replace(self, probe_digest=probe_digest(probe))


def _check_probe(cfg: FeatureConfig, probe: np.ndarray | None) -> np.ndarray:
    if probe is None:
        raise ValueError("pca needs a public probe set: pass probe=... "
                         "explicitly (FeatureConfig no longer carries the "
                         "array, only its digest)")
    if cfg.probe_digest is not None:
        got = probe_digest(probe)
        if got != cfg.probe_digest:
            raise ValueError(
                f"probe content digest {got} does not match the one pinned "
                f"on FeatureConfig ({cfg.probe_digest}) — Phi must be fit "
                "on the same public set for every user")
    return np.asarray(probe, dtype=np.float32)


def _check_dim(cfg: FeatureConfig, m: int, what: str = "input") -> None:
    if cfg.d > m:
        raise ValueError(
            f"feature dim d={cfg.d} exceeds {what} dim m={m}: "
            f"{cfg.kind!r} only projects down — lower d or use identity")


def _rp_matrix(m: int, d: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng((seed, 11))
    return (rng.standard_normal((m, d)) / np.sqrt(d)).astype(np.float32)


def _conv_params(c_in: int, seed: int) -> dict:
    rng = np.random.default_rng((seed, 13))

    def he(shape, fan_in):
        return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)
                ).astype(np.float32)

    return {
        "w1": he((5, 5, c_in, 32), 5 * 5 * c_in),
        "w2": he((5, 5, 32, 64), 5 * 5 * 32),
    }


@partial(jax.jit, static_argnames=("hw",))
def _random_conv_features(x_flat: jax.Array, w1: jax.Array, w2: jax.Array,
                          hw: tuple[int, int, int]) -> jax.Array:
    h, w, c = hw
    x = x_flat.reshape((-1, h, w, c))
    dn = jax.lax.conv_dimension_numbers(x.shape, w1.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    y = jax.lax.conv_general_dilated(x, w1, (2, 2), "SAME",
                                     dimension_numbers=dn)
    y = jax.nn.relu(y)
    dn2 = jax.lax.conv_dimension_numbers(y.shape, w2.shape,
                                         ("NHWC", "HWIO", "NHWC"))
    y = jax.lax.conv_general_dilated(y, w2, (2, 2), "SAME",
                                     dimension_numbers=dn2)
    y = jax.nn.relu(y)
    # 4x4 average-pooled grid -> flattened feature vector (pretrained-GAP
    # surrogate): keeps spatial second-moment structure, d = 16*64 = 1024.
    gh = max(1, y.shape[1] // 4)
    gw = max(1, y.shape[2] // 4)
    y = jax.lax.reduce_window(y, 0.0, jax.lax.add,
                              (1, gh, gw, 1), (1, gh, gw, 1), "VALID")
    y = y / (gh * gw)
    return y.reshape((y.shape[0], -1))


# ---------------------------------------------------------------------------
# Parameter / application split (device ingest path)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=64)
def _cached_params(cfg: FeatureConfig, m: int) -> dict:
    """Seed-deterministic Phi parameters for the probe-free kinds."""
    if cfg.kind == "identity":
        return {}
    if cfg.kind == "random_projection":
        _check_dim(cfg, m)
        return {"w": _rp_matrix(m, cfg.d, cfg.seed)}
    # random_conv: conv filters + (optionally) a secondary projection from
    # the conv feature width down to d.
    p = _conv_params(cfg.image_hw[2], cfg.seed)
    conv_dim = _conv_out_dim(cfg.image_hw)
    if cfg.d and cfg.d < conv_dim:
        p = dict(p, w_rp=_rp_matrix(conv_dim, cfg.d, cfg.seed + 1))
    return p


def _conv_out_dim(hw: tuple[int, int, int]) -> int:
    """Flat width of ``_random_conv_features`` without running the convs."""
    h, w, _ = hw
    # Two stride-2 SAME convs: ceil(ceil(h/2)/2); then a VALID gh-pool.
    h2 = -(-(-(-h // 2)) // 2)
    w2 = -(-(-(-w // 2)) // 2)
    gh, gw = max(1, h2 // 4), max(1, w2 // 4)
    return (h2 // gh) * (w2 // gw) * 64


def phi_params(cfg: FeatureConfig, m: int,
               probe: np.ndarray | None = None) -> dict:
    """Host-side Phi parameters, deterministic in ``cfg.seed`` (and the
    probe content for ``pca``).  Everything downstream — numpy reference
    and jnp device path alike — applies these exact arrays, which is what
    makes Phi shared across users and identical across processes."""
    if cfg.kind == "pca":
        probe = _check_probe(cfg, probe)
        _check_dim(cfg, probe.shape[1], what="probe")
        mu = probe.mean(0, keepdims=True)
        _, _, vt = np.linalg.svd(probe - mu, full_matrices=False)
        return {"mu": mu, "basis": np.ascontiguousarray(vt[: cfg.d].T)}
    return _cached_params(cfg, m)


def phi_out_dim(cfg: FeatureConfig, m: int,
                probe: np.ndarray | None = None) -> int:
    """Output feature dimension d' of Phi for input dim ``m``."""
    if cfg.kind == "identity":
        return m
    if cfg.kind == "random_projection":
        return cfg.d
    if cfg.kind == "pca":
        if probe is not None:
            return min(cfg.d, np.asarray(probe).shape[0],
                       np.asarray(probe).shape[1])
        return cfg.d
    conv_dim = _conv_out_dim(cfg.image_hw)
    return cfg.d if (cfg.d and cfg.d < conv_dim) else conv_dim


def phi_apply(x: jax.Array, params: dict, cfg: FeatureConfig) -> jax.Array:
    """Pure-jnp Phi on one chunk ``x (n, m)`` -> ``(n, d')``.

    Jit-able (``cfg`` is hashable: pass it as a static argument) and
    vmap-able over a user axis; the streaming ``SignatureEngine`` calls it
    per row-chunk so the full feature stack never materializes.
    """
    x = x.astype(jnp.float32)
    if cfg.kind == "identity":
        return x
    if cfg.kind == "random_projection":
        return x @ params["w"]
    if cfg.kind == "pca":
        return (x - params["mu"]) @ params["basis"]
    feats = _random_conv_features(x, jnp.asarray(params["w1"]),
                                  jnp.asarray(params["w2"]), cfg.image_hw)
    if "w_rp" in params:
        feats = feats @ params["w_rp"]
    return feats


# ---------------------------------------------------------------------------
# Numpy reference (host ingest path — the parity oracle)
# ---------------------------------------------------------------------------

def feature_map(x: np.ndarray, cfg: FeatureConfig,
                probe: np.ndarray | None = None) -> np.ndarray:
    """Apply Phi to a user's raw data ``x (n, m)`` -> ``(n, d')``."""
    x = np.asarray(x, dtype=np.float32)
    if cfg.kind == "identity":
        return x
    if cfg.kind == "random_projection":
        _check_dim(cfg, x.shape[1])
        w = _rp_matrix(x.shape[1], cfg.d, cfg.seed)
        return x @ w
    if cfg.kind == "random_conv":
        p = phi_params(cfg, x.shape[1])
        feats = np.asarray(_random_conv_features(
            jnp.asarray(x), jnp.asarray(p["w1"]), jnp.asarray(p["w2"]),
            cfg.image_hw))
        if "w_rp" in p:
            feats = feats @ p["w_rp"]
        return feats
    # pca
    p = phi_params(cfg, x.shape[1], probe=probe)
    return (x - p["mu"]) @ p["basis"]
