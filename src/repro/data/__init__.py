"""Data substrate: synthetic federated datasets, partitioners, feature maps."""
from repro.data.synthetic import (SyntheticImageSpec, make_task_dataset,
                                  CIFAR_LIKE, FMNIST_LIKE, CIFAR100_LIKE)
from repro.data.partition import (UserSpec, federated_split,
                                  paper_cifar_two_task, paper_fmnist_three_task)
from repro.data.features import (feature_map, FeatureConfig, probe_digest,
                                 phi_params, phi_apply, phi_out_dim)
