"""Federated task partitioning (paper §III experimental settings).

Reproduces the paper's user/task layouts:

  * ``paper_cifar_two_task``: CIFAR-10 split into task A = {plane, car,
    ship, truck} and task B = {bird, cat, deer, dog, frog, horse}; 5 users
    per task, each with 10% minority labels from the other task (Fig. 2).
  * ``paper_fmnist_three_task``: Fashion-MNIST split into clothes / shoes /
    bags; 5 + 3 + 2 users, unbalanced sample counts, minority labels from
    other tasks (Fig. 3).

and a general ``federated_split`` for arbitrary task maps.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.data import synthetic as syn

__all__ = ["UserSpec", "UserData", "federated_split",
           "paper_cifar_two_task", "paper_fmnist_three_task",
           "CIFAR_TASKS", "FMNIST_TASKS"]

# Class-index conventions mirroring the real label sets.
# CIFAR-10: 0 plane, 1 car, 2 bird, 3 cat, 4 deer, 5 dog, 6 frog, 7 horse,
#           8 ship, 9 truck
CIFAR_TASKS: dict[int, Sequence[int]] = {
    0: (0, 1, 8, 9),              # vehicles
    1: (2, 3, 4, 5, 6, 7),        # animals
}
# Fashion-MNIST: 0 tshirt, 1 trouser, 2 pullover, 3 dress, 4 coat,
#                5 sandal, 6 shirt, 7 sneaker, 8 bag, 9 ankle-boot
FMNIST_TASKS: dict[int, Sequence[int]] = {
    0: (0, 1, 2, 3, 4, 6),        # clothes
    1: (5, 7, 9),                 # shoes
    2: (8,),                      # bags
}


@dataclasses.dataclass(frozen=True)
class UserSpec:
    """How to build one user's local dataset."""

    user_id: int
    task_id: int
    majority_labels: tuple[int, ...]
    minority_labels: tuple[int, ...]
    n_majority: int
    n_minority: int


@dataclasses.dataclass
class UserData:
    user_id: int
    task_id: int
    x: np.ndarray                 # (n_i, m) flat features
    y: np.ndarray                 # (n_i,) class labels
    task_classes: tuple[int, ...]  # label set of this user's task

    @property
    def n(self) -> int:
        return len(self.y)

    def local_label(self) -> np.ndarray:
        """Labels remapped to 0..C_task-1 for the task-specific head."""
        lut = {c: i for i, c in enumerate(self.task_classes)}
        return np.asarray([lut.get(int(c), 0) for c in self.y],
                          dtype=np.int32)


def _task_of_class(tasks: Mapping[int, Sequence[int]]) -> dict[int, int]:
    out: dict[int, int] = {}
    for t, classes in tasks.items():
        for c in classes:
            out[c] = t
    return out


def federated_split(spec: syn.SyntheticImageSpec,
                    tasks: Mapping[int, Sequence[int]],
                    users: Sequence[UserSpec],
                    seed: int = 0) -> list[UserData]:
    """Materialise per-user datasets from user specs."""
    toc = _task_of_class(tasks)
    out = []
    for u in users:
        maj = list(u.majority_labels)
        mino = list(u.minority_labels)
        n_maj = [max(1, u.n_majority // len(maj))] * len(maj)
        n_min = ([max(0, u.n_minority // max(1, len(mino)))] * len(mino)
                 if mino and u.n_minority > 0 else [0] * len(mino))
        x, y = syn.make_task_dataset(
            spec, maj + mino, n_maj + n_min,
            seed=(seed, 31, u.user_id), task_of_class=toc)
        out.append(UserData(user_id=u.user_id, task_id=u.task_id, x=x, y=y,
                            task_classes=tuple(tasks[u.task_id])))
    return out


def paper_cifar_two_task(n_per_user: int = 1000, minority_frac: float = 0.10,
                         seed: int = 0,
                         users_per_task: tuple[int, int] = (5, 5)
                         ) -> list[UserData]:
    """Fig. 2 layout: 2 tasks x 5 users, 10% minority labels."""
    specs = []
    uid = 0
    for task, n_users in enumerate(users_per_task):
        other = 1 - task
        for _ in range(n_users):
            specs.append(UserSpec(
                user_id=uid, task_id=task,
                majority_labels=tuple(CIFAR_TASKS[task]),
                minority_labels=tuple(CIFAR_TASKS[other]),
                n_majority=int(n_per_user * (1 - minority_frac)),
                n_minority=int(n_per_user * minority_frac)))
            uid += 1
    return federated_split(syn.CIFAR_LIKE, CIFAR_TASKS, specs, seed=seed)


def paper_fmnist_three_task(seed: int = 0, scale: float = 1.0
                            ) -> list[UserData]:
    """Fig. 3 layout: 3 tasks, 5/3/2 users, unbalanced sample counts.

    Task 0 (clothes) has the most samples, task 2 (bags) the fewest, and
    only two users carry it — the regime where random clustering has high
    variance (paper §III).
    """
    layout = [  # (task, n_users, n_majority, n_minority)
        (0, 5, int(1200 * scale), int(120 * scale)),
        (1, 3, int(600 * scale), int(60 * scale)),
        (2, 2, int(300 * scale), int(30 * scale)),
    ]
    specs = []
    uid = 0
    for task, n_users, n_maj, n_min in layout:
        others = [c for t, cs in FMNIST_TASKS.items() if t != task for c in cs]
        for _ in range(n_users):
            specs.append(UserSpec(
                user_id=uid, task_id=task,
                majority_labels=tuple(FMNIST_TASKS[task]),
                minority_labels=tuple(others),
                n_majority=n_maj, n_minority=n_min))
            uid += 1
    return federated_split(syn.FMNIST_LIKE, FMNIST_TASKS, specs, seed=seed)
