"""Synthetic stand-ins for the paper's datasets (offline container).

The clustering algorithm exploits one property of the real data: *users who
train on the same task draw samples from the same distribution, and
different tasks have different second-moment structure* (different Gram
spectra).  We generate class-conditional data that reproduces exactly that
property with controllable strength, at the real datasets' shapes:

  * ``CIFAR_LIKE``     32x32x3 -> m=3072, 10 classes (paper Fig. 2 source)
  * ``FMNIST_LIKE``    28x28   -> m=784, 10 classes (paper Fig. 3 source)
  * ``CIFAR100_LIKE``  32x32x3 -> m=3072, 100 classes (paper Table II)

Generator: every class ``c`` has a mean image ``mu_c`` and a low-rank
covariance ``B_c B_c^T + sigma^2 I``; classes belonging to the same *task*
share a task-level subspace (a rotation of a common basis), so same-task
users have close Gram spectra while cross-task users differ — the structure
Table I of the paper displays.  For Table II we give semantically-"matched"
class groups across two datasets shared subspaces, reproducing the
cross-dataset experiment.

All generation is numpy (host-side data pipeline), deterministic in the
seed, and cheap enough for tests.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["SyntheticImageSpec", "make_task_dataset", "class_mean",
           "make_task_feature_mixture",
           "CorruptionSpec", "BYZANTINE_MODES", "corrupt_labels",
           "label_noise_rows", "heavy_tail_noise", "byzantine_signatures",
           "apply_corruption",
           "CIFAR_LIKE", "FMNIST_LIKE", "CIFAR100_LIKE"]


def make_task_feature_mixture(n_users: int, n_samples: int, d: int,
                              n_tasks: int, seed: int = 0,
                              noise: float = 0.05, rank: int | None = None
                              ) -> tuple[np.ndarray, np.ndarray]:
    """Seeded multi-task USER FEATURES at protocol scale.

    Each task owns a random rank-``rank`` subspace of R^d; every user draws
    ``n_samples`` feature rows from its task's subspace plus isotropic
    noise — the minimal structure the one-shot protocol exploits, cheap
    enough for thousand-user engine tests and the launch CLI.

    Returns ``(features (n_users, n_samples, d) float32,
    task_ids (n_users,) int32)`` with users round-robined over tasks.
    """
    rng = np.random.default_rng(seed)
    rank = rank or max(2, d // 8)
    bases = [np.linalg.qr(rng.standard_normal((d, rank)))[0]
             .astype(np.float32) for _ in range(n_tasks)]
    task_ids = (np.arange(n_users) % n_tasks).astype(np.int32)
    feats = np.empty((n_users, n_samples, d), np.float32)
    for i, t in enumerate(task_ids):
        z = rng.standard_normal((n_samples, rank)).astype(np.float32)
        eps = rng.standard_normal((n_samples, d)).astype(np.float32)
        feats[i] = z @ bases[t].T + noise * eps
    return feats, task_ids


@dataclasses.dataclass(frozen=True)
class SyntheticImageSpec:
    """Shape + structure parameters of one synthetic dataset family."""

    name: str
    m: int                     # flat feature dimension (pixels)
    n_classes: int
    subspace_rank: int = 16    # rank of the class-conditional covariance
    task_scale: float = 3.0    # strength of the task-level component
    class_scale: float = 2.0   # strength of the class-level component
    mean_scale: float = 8.0    # strength of the class mean (in-task-subspace)
    noise: float = 0.25        # isotropic pixel noise
    base_seed: int = 1234      # identifies the dataset family (mu_c, B_c)


CIFAR_LIKE = SyntheticImageSpec("cifar10-like", m=3072, n_classes=10)
FMNIST_LIKE = SyntheticImageSpec("fmnist-like", m=784, n_classes=10)
CIFAR100_LIKE = SyntheticImageSpec("cifar100-like", m=3072, n_classes=100,
                                   base_seed=4321)


def _orthonormal(rng: np.random.Generator, m: int, r: int) -> np.ndarray:
    q, _ = np.linalg.qr(rng.standard_normal((m, r)))
    return q[:, :r].astype(np.float32)


def class_mean(spec: SyntheticImageSpec, cls: int, task_basis: np.ndarray
               ) -> np.ndarray:
    """Per-class mean image, living INSIDE the task subspace.

    Same-task classes share their mean subspace (their means are related,
    as real same-task classes are); the mean direction within the subspace
    is dataset+class specific.  This is what lets the protocol match
    semantically-similar classes ACROSS datasets (paper Table II).
    """
    rng = np.random.default_rng((spec.base_seed, 51929, cls))
    w = rng.standard_normal(task_basis.shape[1]).astype(np.float32)
    w /= max(np.linalg.norm(w), 1e-9)
    return spec.mean_scale * task_basis @ w


def _class_basis(spec: SyntheticImageSpec, cls: int,
                 task_of_class: dict[int, int] | None,
                 shared_task_seed: int | None) -> tuple[np.ndarray, np.ndarray]:
    """(task_basis, class_basis) for one class.

    Classes of the same task share ``task_basis``; ``shared_task_seed``
    lets two *different datasets* share a task subspace (Table II:
    "vehicles" in CIFAR-10 and CIFAR-100 look alike).
    """
    task = task_of_class.get(cls, 0) if task_of_class else 0
    tseed = shared_task_seed if shared_task_seed is not None else spec.base_seed
    t_rng = np.random.default_rng((tseed, 7919, task))
    c_rng = np.random.default_rng((spec.base_seed, 104729, cls))
    tb = _orthonormal(t_rng, spec.m, spec.subspace_rank)
    cb = _orthonormal(c_rng, spec.m, spec.subspace_rank // 2)
    return tb, cb


def make_task_dataset(spec: SyntheticImageSpec,
                      labels: Sequence[int],
                      n_per_class: Sequence[int] | int,
                      seed: int = 0,
                      task_of_class: dict[int, int] | None = None,
                      shared_task_seed: int | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Sample a labelled dataset ``(X (n, m), y (n,))``.

    ``labels``: which classes to draw.  ``n_per_class``: samples per class
    (scalar or per-label list).  ``task_of_class`` maps class -> task id so
    same-task classes share their dominant covariance subspace.
    """
    rng = np.random.default_rng(seed)
    if isinstance(n_per_class, int):
        n_per_class = [n_per_class] * len(labels)
    xs, ys = [], []
    for cls, n in zip(labels, n_per_class):
        if n <= 0:
            continue
        tb, cb = _class_basis(spec, cls, task_of_class, shared_task_seed)
        mu = class_mean(spec, cls, tb)
        zt = rng.standard_normal((n, tb.shape[1])).astype(np.float32)
        zc = rng.standard_normal((n, cb.shape[1])).astype(np.float32)
        eps = rng.standard_normal((n, spec.m)).astype(np.float32)
        x = (mu[None, :]
             + spec.task_scale * zt @ tb.T
             + spec.class_scale * zc @ cb.T
             + spec.noise * eps)
        xs.append(x)
        ys.append(np.full(n, cls, dtype=np.int32))
    x = np.concatenate(xs, axis=0)
    y = np.concatenate(ys, axis=0)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


# ---------------------------------------------------------------------------
# Dirty-data injectors (ISSUE 7): label noise, Byzantine signatures,
# heavy-tailed pixel noise — seeded, composable, host-side like the rest
# of the data pipeline.  RCC-PFL (PAPERS.md, arxiv 2503.19886) is the
# motivating threat model: clustered serving breaks first through its
# aggregation statistics, so the generators here produce exactly the
# dirty inputs the robust MembershipEngine aggregators must survive.
# ---------------------------------------------------------------------------

BYZANTINE_MODES = ("sign_flip", "random_subspace", "colluding_copy")


@dataclasses.dataclass(frozen=True)
class CorruptionSpec:
    """One composable, fully-seeded description of dirty client data.

    Attributes:
      flip_frac: label noise — the fraction of every user's rows drawn
        from another task's distribution (``label_noise_rows``; a user
        whose labels are wrong trains/uploads statistics mixing tasks).
      byzantine_frac: fraction of users whose signature upload is
        adversarially replaced (``byzantine_signatures``).
      byzantine_mode: "sign_flip" (coordinate reflection of the user's
        own eigenvectors), "random_subspace" (a fresh random orthonormal
        basis) or "colluding_copy" (all attackers upload the SAME scaled
        copy of an honest victim's signature — the coordinated attack
        that steers a mean prototype hardest).
      byzantine_scale: magnitude multiplier of the colluding upload; an
        adversarial client obeys no norm protocol, which is exactly why
        a mean prototype has breakdown point 0.
      heavy_tail_frac: fraction of users whose pixels get additive
        Student-t noise (``heavy_tail_noise``).
      heavy_tail_scale / heavy_tail_df: scale and degrees-of-freedom of
        that noise (df <= 2 has infinite variance).
      seed: root seed; every injector derives its own independent
        stream from it, so corruption is reproducible and composable.
    """

    flip_frac: float = 0.0
    byzantine_frac: float = 0.0
    byzantine_mode: str = "colluding_copy"
    byzantine_scale: float = 8.0
    heavy_tail_frac: float = 0.0
    heavy_tail_scale: float = 3.0
    heavy_tail_df: float = 2.0
    seed: int = 0

    def __post_init__(self):
        for name in ("flip_frac", "byzantine_frac", "heavy_tail_frac"):
            val = getattr(self, name)
            if not 0.0 <= val <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {val}")
        if self.byzantine_mode not in BYZANTINE_MODES:
            raise ValueError(f"byzantine_mode must be one of "
                             f"{BYZANTINE_MODES}, got "
                             f"{self.byzantine_mode!r}")
        if self.byzantine_scale <= 0:
            raise ValueError(f"byzantine_scale must be positive, got "
                             f"{self.byzantine_scale}")
        if self.heavy_tail_df <= 0:
            raise ValueError(f"heavy_tail_df must be positive, got "
                             f"{self.heavy_tail_df}")
        if self.heavy_tail_scale < 0:
            raise ValueError(f"heavy_tail_scale must be >= 0, got "
                             f"{self.heavy_tail_scale}")

    def _rng(self, stream: str) -> np.random.Generator:
        """An independent generator per injector, derived from ``seed``
        (zlib.crc32, not ``hash`` — string hashing is process-salted)."""
        import zlib

        return np.random.default_rng(
            np.random.SeedSequence((self.seed, zlib.crc32(stream.encode()))))


def corrupt_labels(y: np.ndarray, flip_frac: float, n_classes: int,
                   seed: int = 0) -> np.ndarray:
    """Uniform label noise: flip ``floor(flip_frac * len(y))`` labels to a
    uniformly-random *different* class.  The classic noisy-label model
    for per-sample training targets (``fed.trainer`` eval sets etc.)."""
    y = np.asarray(y)
    rng = np.random.default_rng(seed)
    n_flip = int(np.floor(flip_frac * len(y)))
    out = y.copy()
    if n_flip == 0:
        return out
    idx = rng.choice(len(y), n_flip, replace=False)
    # shift by a nonzero offset mod n_classes: never maps to itself
    offs = rng.integers(1, max(n_classes, 2), size=n_flip)
    out[idx] = (out[idx] + offs) % n_classes
    return out


def label_noise_rows(feats: np.ndarray, task_ids: np.ndarray,
                     flip_frac: float, seed: int = 0) -> np.ndarray:
    """Data-level label noise at the serving layer: for EVERY user,
    replace ``floor(flip_frac * n)`` of its feature rows with rows from
    a random user of a *different* task — what a client whose samples
    are mislabelled contributes to its Gram signature.  Users of tasks
    with no cross-task partner are left untouched."""
    feats = np.asarray(feats)
    task_ids = np.asarray(task_ids)
    rng = np.random.default_rng(seed)
    n_users, n_rows = feats.shape[0], feats.shape[1]
    n_bad = int(np.floor(flip_frac * n_rows))
    out = feats.copy()
    if n_bad == 0:
        return out
    for i in range(n_users):
        donors = np.flatnonzero(task_ids != task_ids[i])
        if not len(donors):
            continue
        j = int(rng.choice(donors))
        rows = rng.choice(n_rows, n_bad, replace=False)
        src = rng.choice(n_rows, n_bad, replace=True)
        out[i, rows] = feats[j, src]
    return out


def heavy_tail_noise(feats: np.ndarray, frac_users: float,
                     scale: float = 3.0, df: float = 2.0,
                     seed: int = 0) -> np.ndarray:
    """Additive Student-t pixel noise on ``floor(frac_users * N)`` users
    (df <= 2: infinite variance — the heavy-tailed regime a mean
    statistic cannot average away)."""
    feats = np.asarray(feats)
    rng = np.random.default_rng(seed)
    out = feats.copy()
    n_bad = int(np.floor(frac_users * feats.shape[0]))
    if n_bad == 0:
        return out
    bad = rng.choice(feats.shape[0], n_bad, replace=False)
    noise = rng.standard_t(df, size=(n_bad,) + feats.shape[1:])
    out[bad] = out[bad] + scale * noise.astype(feats.dtype)
    return out


def byzantine_signatures(lam: np.ndarray, v: np.ndarray, frac: float,
                         mode: str = "colluding_copy", seed: int = 0,
                         scale: float = 8.0,
                         labels: np.ndarray | None = None
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Replace ``floor(frac * N)`` users' signature uploads adversarially.

    Modes (``BYZANTINE_MODES``):
      * ``sign_flip`` — reflect the user's own eigenvectors through a
        random ±1 coordinate pattern (a cheap subspace distortion an
        attacker can apply without knowing anything else).
      * ``random_subspace`` — upload a fresh random orthonormal basis.
      * ``colluding_copy`` — ALL attackers upload the same
        ``scale``-multiplied copy of an honest victim's signature; with
        ``labels`` given, attackers assigned to cluster ``t`` copy a
        victim from cluster ``(t+1) % T`` — the coordinated directory-
        poisoning attack that steers every mean prototype toward a
        *neighbouring* cluster's subspace (breakdown-point-0 demo).

    Returns ``(lam', v', byz_mask)`` — copies; honest rows untouched.
    """
    if mode not in BYZANTINE_MODES:
        raise ValueError(f"mode must be one of {BYZANTINE_MODES}, "
                         f"got {mode!r}")
    lam = np.asarray(lam, np.float32).copy()
    v = np.asarray(v, np.float32).copy()
    rng = np.random.default_rng(seed)
    n, d, k = v.shape
    n_byz = int(np.floor(frac * n))
    mask = np.zeros(n, bool)
    if n_byz == 0:
        return lam, v, mask
    byz = rng.choice(n, n_byz, replace=False)
    mask[byz] = True
    honest = np.flatnonzero(~mask)
    if mode == "sign_flip":
        for i in byz:
            signs = rng.choice([-1.0, 1.0], size=d).astype(np.float32)
            v[i] = signs[:, None] * v[i]
    elif mode == "random_subspace":
        for i in byz:
            q, _ = np.linalg.qr(rng.standard_normal((d, k)))
            v[i] = q.astype(np.float32)
    else:                                           # colluding_copy
        if labels is not None and len(honest):
            labels = np.asarray(labels)
            n_clusters = int(labels.max()) + 1
            # per-cluster victim from the NEXT cluster (honest member)
            victims = np.full(n_clusters, -1)
            for t in range(n_clusters):
                pool = honest[labels[honest] == (t + 1) % n_clusters]
                if len(pool):
                    victims[t] = int(rng.choice(pool))
            for i in byz:
                vic = victims[labels[i]]
                if vic < 0:
                    vic = int(rng.choice(honest))
                lam[i] = lam[vic]
                v[i] = scale * v[vic]
        else:
            vic = int(rng.choice(honest)) if len(honest) else int(byz[0])
            lam[byz] = lam[vic]
            v[byz] = scale * v[vic]
    return lam, v, mask


def apply_corruption(feats: np.ndarray, task_ids: np.ndarray,
                     spec: CorruptionSpec) -> np.ndarray:
    """Compose the FEATURE-level injectors (label-noise row mixing, then
    heavy-tailed pixel noise) on a user-feature batch; the signature-
    level Byzantine replacement applies after featurization via
    ``byzantine_signatures`` (signatures are what Byzantine clients
    actually control).  Each stage draws an independent stream from
    ``spec.seed``."""
    out = np.asarray(feats)
    if spec.flip_frac > 0:
        out = label_noise_rows(
            out, task_ids, spec.flip_frac,
            seed=spec._rng("label_noise").integers(2**31))
    if spec.heavy_tail_frac > 0:
        out = heavy_tail_noise(
            out, spec.heavy_tail_frac, spec.heavy_tail_scale,
            spec.heavy_tail_df,
            seed=spec._rng("heavy_tail").integers(2**31))
    return out
