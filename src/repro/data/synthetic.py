"""Synthetic stand-ins for the paper's datasets (offline container).

The clustering algorithm exploits one property of the real data: *users who
train on the same task draw samples from the same distribution, and
different tasks have different second-moment structure* (different Gram
spectra).  We generate class-conditional data that reproduces exactly that
property with controllable strength, at the real datasets' shapes:

  * ``CIFAR_LIKE``     32x32x3 -> m=3072, 10 classes (paper Fig. 2 source)
  * ``FMNIST_LIKE``    28x28   -> m=784, 10 classes (paper Fig. 3 source)
  * ``CIFAR100_LIKE``  32x32x3 -> m=3072, 100 classes (paper Table II)

Generator: every class ``c`` has a mean image ``mu_c`` and a low-rank
covariance ``B_c B_c^T + sigma^2 I``; classes belonging to the same *task*
share a task-level subspace (a rotation of a common basis), so same-task
users have close Gram spectra while cross-task users differ — the structure
Table I of the paper displays.  For Table II we give semantically-"matched"
class groups across two datasets shared subspaces, reproducing the
cross-dataset experiment.

All generation is numpy (host-side data pipeline), deterministic in the
seed, and cheap enough for tests.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["SyntheticImageSpec", "make_task_dataset", "class_mean",
           "make_task_feature_mixture",
           "CIFAR_LIKE", "FMNIST_LIKE", "CIFAR100_LIKE"]


def make_task_feature_mixture(n_users: int, n_samples: int, d: int,
                              n_tasks: int, seed: int = 0,
                              noise: float = 0.05, rank: int | None = None
                              ) -> tuple[np.ndarray, np.ndarray]:
    """Seeded multi-task USER FEATURES at protocol scale.

    Each task owns a random rank-``rank`` subspace of R^d; every user draws
    ``n_samples`` feature rows from its task's subspace plus isotropic
    noise — the minimal structure the one-shot protocol exploits, cheap
    enough for thousand-user engine tests and the launch CLI.

    Returns ``(features (n_users, n_samples, d) float32,
    task_ids (n_users,) int32)`` with users round-robined over tasks.
    """
    rng = np.random.default_rng(seed)
    rank = rank or max(2, d // 8)
    bases = [np.linalg.qr(rng.standard_normal((d, rank)))[0]
             .astype(np.float32) for _ in range(n_tasks)]
    task_ids = (np.arange(n_users) % n_tasks).astype(np.int32)
    feats = np.empty((n_users, n_samples, d), np.float32)
    for i, t in enumerate(task_ids):
        z = rng.standard_normal((n_samples, rank)).astype(np.float32)
        eps = rng.standard_normal((n_samples, d)).astype(np.float32)
        feats[i] = z @ bases[t].T + noise * eps
    return feats, task_ids


@dataclasses.dataclass(frozen=True)
class SyntheticImageSpec:
    """Shape + structure parameters of one synthetic dataset family."""

    name: str
    m: int                     # flat feature dimension (pixels)
    n_classes: int
    subspace_rank: int = 16    # rank of the class-conditional covariance
    task_scale: float = 3.0    # strength of the task-level component
    class_scale: float = 2.0   # strength of the class-level component
    mean_scale: float = 8.0    # strength of the class mean (in-task-subspace)
    noise: float = 0.25        # isotropic pixel noise
    base_seed: int = 1234      # identifies the dataset family (mu_c, B_c)


CIFAR_LIKE = SyntheticImageSpec("cifar10-like", m=3072, n_classes=10)
FMNIST_LIKE = SyntheticImageSpec("fmnist-like", m=784, n_classes=10)
CIFAR100_LIKE = SyntheticImageSpec("cifar100-like", m=3072, n_classes=100,
                                   base_seed=4321)


def _orthonormal(rng: np.random.Generator, m: int, r: int) -> np.ndarray:
    q, _ = np.linalg.qr(rng.standard_normal((m, r)))
    return q[:, :r].astype(np.float32)


def class_mean(spec: SyntheticImageSpec, cls: int, task_basis: np.ndarray
               ) -> np.ndarray:
    """Per-class mean image, living INSIDE the task subspace.

    Same-task classes share their mean subspace (their means are related,
    as real same-task classes are); the mean direction within the subspace
    is dataset+class specific.  This is what lets the protocol match
    semantically-similar classes ACROSS datasets (paper Table II).
    """
    rng = np.random.default_rng((spec.base_seed, 51929, cls))
    w = rng.standard_normal(task_basis.shape[1]).astype(np.float32)
    w /= max(np.linalg.norm(w), 1e-9)
    return spec.mean_scale * task_basis @ w


def _class_basis(spec: SyntheticImageSpec, cls: int,
                 task_of_class: dict[int, int] | None,
                 shared_task_seed: int | None) -> tuple[np.ndarray, np.ndarray]:
    """(task_basis, class_basis) for one class.

    Classes of the same task share ``task_basis``; ``shared_task_seed``
    lets two *different datasets* share a task subspace (Table II:
    "vehicles" in CIFAR-10 and CIFAR-100 look alike).
    """
    task = task_of_class.get(cls, 0) if task_of_class else 0
    tseed = shared_task_seed if shared_task_seed is not None else spec.base_seed
    t_rng = np.random.default_rng((tseed, 7919, task))
    c_rng = np.random.default_rng((spec.base_seed, 104729, cls))
    tb = _orthonormal(t_rng, spec.m, spec.subspace_rank)
    cb = _orthonormal(c_rng, spec.m, spec.subspace_rank // 2)
    return tb, cb


def make_task_dataset(spec: SyntheticImageSpec,
                      labels: Sequence[int],
                      n_per_class: Sequence[int] | int,
                      seed: int = 0,
                      task_of_class: dict[int, int] | None = None,
                      shared_task_seed: int | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Sample a labelled dataset ``(X (n, m), y (n,))``.

    ``labels``: which classes to draw.  ``n_per_class``: samples per class
    (scalar or per-label list).  ``task_of_class`` maps class -> task id so
    same-task classes share their dominant covariance subspace.
    """
    rng = np.random.default_rng(seed)
    if isinstance(n_per_class, int):
        n_per_class = [n_per_class] * len(labels)
    xs, ys = [], []
    for cls, n in zip(labels, n_per_class):
        if n <= 0:
            continue
        tb, cb = _class_basis(spec, cls, task_of_class, shared_task_seed)
        mu = class_mean(spec, cls, tb)
        zt = rng.standard_normal((n, tb.shape[1])).astype(np.float32)
        zc = rng.standard_normal((n, cb.shape[1])).astype(np.float32)
        eps = rng.standard_normal((n, spec.m)).astype(np.float32)
        x = (mu[None, :]
             + spec.task_scale * zt @ tb.T
             + spec.class_scale * zc @ cb.T
             + spec.noise * eps)
        xs.append(x)
        ys.append(np.full(n, cls, dtype=np.int32))
    x = np.concatenate(xs, axis=0)
    y = np.concatenate(ys, axis=0)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]
