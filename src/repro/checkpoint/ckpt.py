"""Sharding-aware npz checkpointing.

Pytrees are flattened to ``path -> array`` with ``/``-joined keys and
stored as compressed npz plus a json manifest (treedef + dtypes + step).
On restore, arrays are device_put against the provided shardings (or left
on host).  Works for params, optimizer state, and the MT-HFL trainer's
per-LPS models; multi-host gather is ``jax.device_get`` on addressable
shards (single-process per the dry-run setup).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import numpy as np

PyTree = Any

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for keypath, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in keypath)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "biufc":   # bf16 etc: store as f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_checkpoint(ckpt_dir: str | Path, step: int, tree: PyTree,
                    keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    path = ckpt_dir / f"step_{step:08d}.npz"
    np.savez_compressed(path, **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "keys": sorted(flat)}
    (ckpt_dir / f"step_{step:08d}.json").write_text(json.dumps(manifest))
    # retention
    ckpts = sorted(ckpt_dir.glob("step_*.npz"))
    for old in ckpts[:-keep]:
        old.unlink(missing_ok=True)
        old.with_suffix(".json").unlink(missing_ok=True)
    return path


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    ckpts = sorted(ckpt_dir.glob("step_*.npz"))
    if not ckpts:
        return None
    return int(ckpts[-1].stem.split("_")[1])


def restore_checkpoint(ckpt_dir: str | Path, like: PyTree,
                       step: int | None = None,
                       shardings: PyTree | None = None) -> tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shape/dtype template).

    Returns (tree, step).  ``shardings`` (same structure) device_puts each
    leaf with its NamedSharding; otherwise arrays stay host-side jnp.
    """
    ckpt_dir = Path(ckpt_dir)
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step:08d}.npz")
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (keypath, leaf), sh in zip(flat_like, shard_flat):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in keypath)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        arr = jax.numpy.asarray(arr).astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
