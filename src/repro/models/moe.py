"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

GShard/Switch-style: tokens pick top-k experts; each expert processes at
most ``capacity`` tokens (overflow dropped); dispatch/combine are one-hot
einsums so the compiled FLOPs reflect *active* experts only and XLA's SPMD
partitioner turns the ``(tokens -> expert)`` reshuffles into all-to-alls
when the expert axis is sharded (DESIGN.md §6).

Experts are stacked ``(E, d_model, d_ff)`` (leading layer axis added by the
scan'd stack), sharded on the mesh ``model`` axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Any

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden dim
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    mlp_variant: str = "swiglu"
    dispatch_chunk: int = 1024
    # ^ tokens are dispatched in chunks of this size with per-chunk expert
    # capacity (Switch/GShard "groups").  A single global dispatch would
    # cost T*E*C*d with C ~ T/E — QUADRATIC in tokens (T=1M at train_4k
    # made the dispatch 50x the expert matmuls, EXPERIMENTS.md §Perf it-1);
    # chunking makes it linear: T*E*Cc*d with Cc ~ chunk/E.


def moe_init(rng, cfg: MoEConfig, dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(rng, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff

    def stack(key, d_in, d_out):
        keys = jax.random.split(key, e)
        return jnp.stack([L.dense_init(k, d_in, d_out, dtype) for k in keys])

    p = {"router": L.dense_init(ks[0], d, e, dtype),
         "w_up": stack(ks[1], d, f),
         "w_down": stack(ks[2], f, d)}
    if cfg.mlp_variant == "swiglu":
        p["w_gate"] = stack(ks[3], d, f)
    return p


def moe_apply(params: PyTree, cfg: MoEConfig, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """``x (B, S, d)`` -> ``(out (B, S, d), aux_loss scalar)``.

    Tokens are processed in dispatch chunks ("groups") of
    ``cfg.dispatch_chunk`` with per-chunk capacity; dispatch/combine are
    one-hot einsums so XLA SPMD turns the token->expert reshuffle into
    all-to-alls when the expert axis is sharded.  aux_loss is the standard
    load-balancing loss (mean routed fraction x mean router prob, scaled
    by E), computed over ALL tokens.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k

    logits = (xt @ params["router"]).astype(jnp.float32)      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (T, k)
    # Renormalize the selected gates (standard for top-k>1).
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- chunked dispatch ------------------------------------------------
    tc = min(cfg.dispatch_chunk, t)
    if t % tc:
        tc = t  # fall back to one group for odd tiny shapes
    g = t // tc
    capacity = max(1, int(cfg.capacity_factor * k * tc / e))
    capacity = min(capacity, tc)

    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)        # (T, k, E)
    sel_g = sel.reshape(g, tc * k, e)
    pos = jnp.cumsum(sel_g, axis=1) * sel_g - 1               # slot in expert
    pos = pos.reshape(g, tc, k, e)
    keep = (pos >= 0) & (pos < capacity)
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, 0), capacity,
                             dtype=x.dtype)                   # (g,tc,k,E,C)
    slot_oh = slot_oh * keep[..., None].astype(x.dtype)
    sel_f = sel.reshape(g, tc, k, e).astype(x.dtype)
    dispatch = jnp.einsum("gtke,gtkec->gtec", sel_f, slot_oh)
    combine = jnp.einsum("gtk,gtke,gtkec->gtec",
                         gate_vals.reshape(g, tc, k).astype(x.dtype),
                         sel_f, slot_oh)

    xg = xt.reshape(g, tc, d)
    xe = jnp.einsum("gtd,gtec->gecd", xg, dispatch)           # (g, E, C, d)
    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    if cfg.mlp_variant == "swiglu":
        gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                      params["w_gate"]))
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])    # (g, E, C, d)
    out = jnp.einsum("gecd,gtec->gtd", ye, combine).reshape(b, s, d)

    # Load-balance aux loss (Switch eq. 4), global over tokens.
    frac_tokens = jnp.mean(sel[:, 0].astype(jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * mean_probs)
    return out, aux
