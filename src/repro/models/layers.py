"""Shared transformer building blocks (pure JAX, pytree params).

Conventions:
  * params are nested dicts of arrays; stacked-layer params carry a leading
    ``L`` axis and are consumed by ``jax.lax.scan``.
  * activations run in ``cfg.act_dtype`` (bf16 by default), norms/softmax
    accumulate in fp32.
  * initializers take an explicit rng and fan-in; everything deterministic.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["rms_norm", "rms_norm_init", "dense_init", "mlp_init", "mlp_apply",
           "embed_init", "rope", "trunc_normal"]


def trunc_normal(rng, shape, std, dtype=jnp.float32):
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    return trunc_normal(rng, (d_in, d_out), (2.0 / (d_in + d_out)) ** 0.5,
                        dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return trunc_normal(rng, (vocab, d), d ** -0.5, dtype)


def rms_norm_init(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Feed-forward (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, variant: str = "swiglu",
             dtype=jnp.float32) -> PyTree:
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if variant == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(params: PyTree, x: jax.Array, variant: str = "swiglu"
              ) -> jax.Array:
    up = x @ params["w_up"]
    if variant == "swiglu":
        gate = jax.nn.silu(x @ params["w_gate"])
        h = gate * up
    else:  # gelu
        h = jax.nn.gelu(up)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
         ) -> jax.Array:
    """Apply RoPE.  ``x (..., S, H, hd)``, ``positions (..., S)``."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq       # (..., S, half)
    ang = ang[..., None, :]                                     # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)
