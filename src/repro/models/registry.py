"""Model registry: dispatch an ArchConfig to its stack (decoder / enc-dec)
and expose a uniform bundle used by launcher, dry-run, and smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer

PyTree = Any

__all__ = ["ModelBundle", "get_model"]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    forward: Callable          # (params, batch, shard) -> (logits, aux)
    loss_fn: Callable          # (params, batch, shard) -> scalar
    init_decode_state: Callable
    decode_step: Callable      # (params, tokens, state, shard) -> (logits, st)
    is_encdec: bool


def get_model(cfg: ArchConfig) -> ModelBundle:
    if cfg.encoder_layers > 0:
        return ModelBundle(
            cfg=cfg,
            init=lambda rng: encdec.init(cfg, rng),
            forward=lambda p, b, s=None: encdec.forward(
                cfg, p, b, s or (lambda x, n: x)),
            loss_fn=lambda p, b, s=None: encdec.loss_fn(
                cfg, p, b, s or (lambda x, n: x)),
            init_decode_state=lambda batch, max_len: encdec.init_decode_state(
                cfg, batch, max_len),
            decode_step=lambda p, t, st, s=None: encdec.decode_step(
                cfg, p, t, st, s or (lambda x, n: x)),
            is_encdec=True,
        )
    return ModelBundle(
        cfg=cfg,
        init=lambda rng: transformer.init(cfg, rng),
        forward=lambda p, b, s=None: transformer.forward(
            cfg, p, b, s or (lambda x, n: x)),
        loss_fn=lambda p, b, s=None: transformer.loss_fn(
            cfg, p, b, s or (lambda x, n: x)),
        init_decode_state=lambda batch, max_len: transformer.init_decode_state(
            cfg, batch, max_len),
        decode_step=lambda p, t, st, s=None: transformer.decode_step(
            cfg, p, t, st, s or (lambda x, n: x)),
        is_encdec=False,
    )
