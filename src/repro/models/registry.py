"""Model registry: dispatch an ArchConfig to its stack (decoder / enc-dec)
and expose a uniform bundle used by launcher, dry-run, and smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer

PyTree = Any

__all__ = ["ModelBundle", "get_model"]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable
    forward: Callable          # (params, batch, shard) -> (logits, aux)
    loss_fn: Callable          # (params, batch, shard) -> scalar
    init_decode_state: Callable  # (batch, max_len, per_slot=False) -> state
    decode_step: Callable      # (params, tokens, state, shard) -> (logits, st)
    is_encdec: bool
    # Serving fast path (decoder-only; None for enc-dec):
    decode_hidden: Callable | None = None   # -> (normed hidden (B,1,d), st)
    prefill_chunk: Callable | None = None   # (params, tokens (B,C), state,
    #                                          start, valid) -> (h (B,C,d), st)


def get_model(cfg: ArchConfig) -> ModelBundle:
    if cfg.encoder_layers > 0:
        return ModelBundle(
            cfg=cfg,
            init=lambda rng: encdec.init(cfg, rng),
            forward=lambda p, b, s=None: encdec.forward(
                cfg, p, b, s or (lambda x, n: x)),
            loss_fn=lambda p, b, s=None: encdec.loss_fn(
                cfg, p, b, s or (lambda x, n: x)),
            init_decode_state=lambda batch, max_len, per_slot=False:
                encdec.init_decode_state(cfg, batch, max_len),
            decode_step=lambda p, t, st, s=None: encdec.decode_step(
                cfg, p, t, st, s or (lambda x, n: x)),
            is_encdec=True,
        )
    return ModelBundle(
        cfg=cfg,
        init=lambda rng: transformer.init(cfg, rng),
        forward=lambda p, b, s=None: transformer.forward(
            cfg, p, b, s or (lambda x, n: x)),
        loss_fn=lambda p, b, s=None: transformer.loss_fn(
            cfg, p, b, s or (lambda x, n: x)),
        init_decode_state=lambda batch, max_len, per_slot=False:
            transformer.init_decode_state(cfg, batch, max_len, per_slot),
        decode_step=lambda p, t, st, s=None: transformer.decode_step(
            cfg, p, t, st, s or (lambda x, n: x)),
        is_encdec=False,
        decode_hidden=lambda p, t, st, s=None: transformer.decode_hidden(
            cfg, p, t, st, s or (lambda x, n: x)),
        prefill_chunk=lambda p, t, st, start, valid, s=None:
            transformer.prefill_chunk(cfg, p, t, st, start, valid,
                                      s or (lambda x, n: x)),
    )
