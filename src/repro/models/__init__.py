"""Model zoo: unified transformer stack + paper CNN/MLP."""
from repro.models.registry import get_model, ModelBundle
