"""Encoder-decoder transformer (seamless-m4t backbone).

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB
per the assignment carve-out: ``batch["frames"] (B, S_src, d_model)`` are
precomputed frame embeddings.  The encoder is a bidirectional transformer
over frames; the decoder is a causal transformer with cross-attention.

Decode: ``encode()`` runs once; per-layer cross-attention K/V are
precomputed from the encoder output (``decode_state_from_memory``) and the
decoder then generates one token per ``decode_step`` against (a) the cross
memory of length S_src and (b) its own self-attention cache.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L

PyTree = Any
ShardFn = Callable[[jax.Array, str], jax.Array]

__all__ = ["init", "forward", "loss_fn", "encode", "init_decode_state",
           "decode_step"]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


def _id_shard(x, name):
    del name
    return x


def _acfg(cfg: ArchConfig, causal: bool) -> A.AttnConfig:
    return A.AttnConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                        qk_norm=cfg.qk_norm, causal=causal,
                        rope_theta=cfg.rope_theta, impl=cfg.attn_impl)


def _enc_block_init(cfg, rng, dtype):
    ks = jax.random.split(rng, 2)
    return {"ln1": L.rms_norm_init(cfg.d_model, dtype),
            "attn": A.attn_init(ks[0], _acfg(cfg, False), dtype),
            "ln2": L.rms_norm_init(cfg.d_model, dtype),
            "ffn": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                              cfg.mlp_variant, dtype)}


def _dec_block_init(cfg, rng, dtype):
    ks = jax.random.split(rng, 3)
    return {"ln1": L.rms_norm_init(cfg.d_model, dtype),
            "self": A.attn_init(ks[0], _acfg(cfg, True), dtype),
            "ln2": L.rms_norm_init(cfg.d_model, dtype),
            "cross": A.attn_init(ks[1], _acfg(cfg, False), dtype),
            "ln3": L.rms_norm_init(cfg.d_model, dtype),
            "ffn": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                              cfg.mlp_variant, dtype)}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init(cfg: ArchConfig, rng: jax.Array) -> PyTree:
    dtype = _dt(cfg.param_dtype)
    n_enc, n_dec = cfg.encoder_layers, cfg.n_layers
    keys = jax.random.split(rng, 4 + n_enc + n_dec)
    return {
        "frame_proj": L.dense_init(keys[0], cfg.d_model, cfg.d_model, dtype),
        "embed": L.embed_init(keys[1], cfg.vocab, cfg.d_model, dtype),
        "enc": _stack([_enc_block_init(cfg, keys[4 + i], dtype)
                       for i in range(n_enc)]),
        "enc_norm": L.rms_norm_init(cfg.d_model, dtype),
        "dec": _stack([_dec_block_init(cfg, keys[4 + n_enc + i], dtype)
                       for i in range(n_dec)]),
        "final_norm": L.rms_norm_init(cfg.d_model, dtype),
        "head": L.dense_init(keys[2], cfg.d_model, cfg.vocab, dtype),
    }


def encode(cfg: ArchConfig, params: PyTree, frames: jax.Array,
           shard: ShardFn = _id_shard) -> jax.Array:
    h = (frames.astype(_dt(cfg.act_dtype)) @ params["frame_proj"])
    h = shard(h, "activation")
    b, s = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    acfg = _acfg(cfg, False)

    def body(h, bp):
        a = A.attention(bp["attn"], acfg, L.rms_norm(h, bp["ln1"]), positions)
        h = h + shard(a, "residual")
        f = L.mlp_apply(bp["ffn"], L.rms_norm(h, bp["ln2"]), cfg.mlp_variant)
        return h + shard(f, "residual"), None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        h, _ = jax.lax.scan(fn, h, params["enc"])
    else:
        for i in range(cfg.encoder_layers):
            h, _ = fn(h, jax.tree.map(lambda x: x[i], params["enc"]))
    return L.rms_norm(h, params["enc_norm"])


def forward(cfg: ArchConfig, params: PyTree, batch: dict,
            shard: ShardFn = _id_shard, last_only: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    memory = encode(cfg, params, batch["frames"], shard)
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    h = shard(h.astype(_dt(cfg.act_dtype)), "activation")
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    self_cfg = _acfg(cfg, True)
    cross_cfg = _acfg(cfg, False)

    def body(h, bp):
        a = A.attention(bp["self"], self_cfg, L.rms_norm(h, bp["ln1"]),
                        positions)
        h = h + shard(a, "residual")
        c = A.attention(bp["cross"], cross_cfg, L.rms_norm(h, bp["ln2"]),
                        positions, kv_x=memory)
        h = h + shard(c, "residual")
        f = L.mlp_apply(bp["ffn"], L.rms_norm(h, bp["ln3"]), cfg.mlp_variant)
        return h + shard(f, "residual"), None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        h, _ = jax.lax.scan(fn, h, params["dec"])
    else:
        for i in range(cfg.n_layers):
            h, _ = fn(h, jax.tree.map(lambda x: x[i], params["dec"]))
    if last_only:
        h = h[:, -1:, :]
    h = L.rms_norm(h, params["final_norm"])
    logits = shard(h @ params["head"], "logits")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ArchConfig, params: PyTree, batch: dict,
            shard: ShardFn = _id_shard) -> jax.Array:
    logits, _ = forward(cfg, params, batch, shard)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, src_len: int,
                      self_len: int = 1024) -> PyTree:
    """Decode state with UNINITIALIZED cross memory (dry-run shape source).

    ``decode_state_from_memory`` fills ``mem_k/mem_v`` from a real encoder
    pass.
    """
    dtype = _dt(cfg.act_dtype)
    n_dec = cfg.n_layers
    kv = (n_dec, batch, src_len, cfg.n_kv_heads, cfg.head_dim)
    self_cache = _stack([A.init_cache(_acfg(cfg, True), batch, self_len,
                                      dtype) for _ in range(n_dec)])
    return {"mem_k": jnp.zeros(kv, dtype), "mem_v": jnp.zeros(kv, dtype),
            "self": self_cache, "length": jnp.zeros((), jnp.int32)}


def decode_state_from_memory(cfg: ArchConfig, params: PyTree,
                             memory: jax.Array, self_len: int = 1024
                             ) -> PyTree:
    cross_cfg = _acfg(cfg, False)

    def kv(bp):
        return A.memory_kv(bp["cross"], cross_cfg, memory)

    mem_k, mem_v = jax.vmap(kv, in_axes=(0,))(params["dec"])
    state = init_decode_state(cfg, memory.shape[0], memory.shape[1])
    state["mem_k"], state["mem_v"] = mem_k.astype(state["mem_k"].dtype), \
        mem_v.astype(state["mem_v"].dtype)
    return state


def decode_step(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
                state: PyTree, shard: ShardFn = _id_shard
                ) -> tuple[jax.Array, PyTree]:
    h = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg.act_dtype))
    h = shard(h, "activation")
    length = state["length"]
    self_cfg = _acfg(cfg, True)
    cross_cfg = _acfg(cfg, False)

    def body(h, inp):
        bp, cache, mk, mv = inp
        a, new_cache = A.decode_step(bp["self"], self_cfg,
                                     L.rms_norm(h, bp["ln1"]), cache, length)
        h = h + a
        c = A.cross_decode(bp["cross"], cross_cfg,
                           L.rms_norm(h, bp["ln2"]), mk, mv)
        h = h + c
        f = L.mlp_apply(bp["ffn"], L.rms_norm(h, bp["ln3"]), cfg.mlp_variant)
        return h + f, new_cache

    if cfg.scan_layers:
        h, new_self = jax.lax.scan(
            body, h, (params["dec"], state["self"], state["mem_k"],
                      state["mem_v"]))
    else:
        caches = []
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda x: x[i],
                              (params["dec"], state["self"],
                               state["mem_k"], state["mem_v"]))
            h, c = body(h, sl)
            caches.append(c)
        new_self = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    new_state = dict(state)
    new_state["self"] = new_self
    new_state["length"] = length + 1
    h = L.rms_norm(h, params["final_norm"])
    logits = shard(h @ params["head"], "logits")
    return logits, new_state
