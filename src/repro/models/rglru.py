"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)                 # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)                 # input gate
    log a_t = -c * r_t * softplus(Lambda)        # a_t = a^(c r_t), a=sig(-L)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` (log-depth on TPU) over the
linear recurrence; decode is the O(1) single-step update.  The full
"recurrent block" wraps the RG-LRU with a causal depthwise conv1d (width 4)
and a GeGLU-style gating branch, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Any

__all__ = ["RGLRUConfig", "rglru_block_init", "rglru_block_apply",
           "rglru_block_step", "init_rglru_state"]


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int = 0             # defaults to d_model
    conv_width: int = 4
    c: float = 8.0
    impl: str = "scan"         # scan (associative) | pallas (fused chunked)

    @property
    def rnn_dim(self) -> int:
        return self.d_rnn or self.d_model


def rglru_block_init(rng, cfg: RGLRUConfig, dtype=jnp.float32) -> PyTree:
    d, dr = cfg.d_model, cfg.rnn_dim
    ks = jax.random.split(rng, 7)
    # Lambda init so that a = sigmoid(Lambda) in (0.9, 0.999) (paper init).
    lam = jnp.log(jnp.exp(jnp.linspace(2.2, 6.9, dr)) - 1.0)  # inv softplus
    return {
        "w_in_x": L.dense_init(ks[0], d, dr, dtype),
        "w_in_y": L.dense_init(ks[1], d, dr, dtype),
        "conv_w": L.trunc_normal(ks[2], (cfg.conv_width, dr),
                                 (1.0 / cfg.conv_width) ** 0.5, dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": L.dense_init(ks[3], dr, dr, dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_i": L.dense_init(ks[4], dr, dr, dtype),
        "b_i": jnp.zeros((dr,), dtype),
        "lam": lam.astype(dtype),
        "w_out": L.dense_init(ks[5], dr, d, dtype),
    }


def _gates(params, u):
    """u (B, S, dr) -> (log_a, gated input) both fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ params["w_i"].astype(jnp.float32)
                       + params["b_i"].astype(jnp.float32))
    log_a = -8.0 * r * jax.nn.softplus(params["lam"].astype(jnp.float32))
    a2 = jnp.exp(2.0 * log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * uf)
    return log_a, x_in


def _conv1d_causal(params, u, conv_state=None):
    """Depthwise causal conv, width W.  conv_state (B, W-1, dr) carries
    context across calls (decode)."""
    w = params["conv_w"].astype(u.dtype)            # (W, dr)
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)        # (B, S+W-1, dr)
    out = sum(full[:, i : i + u.shape[1], :] * w[i] for i in range(width))
    new_state = full[:, -(width - 1):, :]
    return out + params["conv_b"].astype(u.dtype), new_state


def init_rglru_state(cfg: RGLRUConfig, batch: int, dtype=jnp.float32) -> PyTree:
    return {
        "h": jnp.zeros((batch, cfg.rnn_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_dim), dtype),
    }


def rglru_block_apply(params: PyTree, cfg: RGLRUConfig, x: jax.Array,
                      state: PyTree | None = None,
                      valid: jax.Array | None = None
                      ) -> tuple[jax.Array, PyTree]:
    """Training/prefill.  ``x (B, S, d)`` -> (y (B, S, d), new state).

    ``valid (B, S)`` bool marks live positions for ragged right-padded
    chunks (serving prefill): pad positions are identity updates
    (``log_a``/``x_in`` zeroed => a=1, input 0) and the conv carry is
    gathered at each row's last valid inputs, so the final state equals a
    per-row unpadded run.  Pad-position outputs are garbage.
    """
    b, s, _ = x.shape
    if state is None:
        state = init_rglru_state(cfg, b)
    y_branch = jax.nn.gelu(x @ params["w_in_y"])
    u_in = x @ params["w_in_x"]
    u, conv_state = _conv1d_causal(params, u_in, state["conv"])
    log_a, x_in = _gates(params, u)
    if valid is not None:
        vm = valid[:, :, None]
        log_a = jnp.where(vm, log_a, 0.0)
        x_in = jnp.where(vm, x_in, 0.0)
        # conv carry = the last (W-1) VALID conv inputs per row: token p
        # sits at index p + W - 1 of [prev_carry | u_in], so a row with
        # n valid tokens wants indices n .. n + W - 2 (n = 0 keeps the
        # incoming carry untouched).
        width = params["conv_w"].shape[0]
        full = jnp.concatenate(
            [state["conv"].astype(u_in.dtype), u_in], axis=1)
        n_valid = jnp.sum(valid.astype(jnp.int32), axis=1)
        idx = n_valid[:, None] + jnp.arange(width - 1)[None, :]
        conv_state = jnp.take_along_axis(full, idx[..., None], axis=1)

    if cfg.impl == "pallas" and s > 1:
        from repro.kernels.recurrent_scan import ops as rs_ops

        h, h_last = rs_ops.linear_scan(log_a, x_in, state["h"])
    else:
        # h_t = exp(log_a_t) h_{t-1} + x_in_t  via associative scan, with
        # the incoming carry folded into the first element.
        x_in = x_in.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * state["h"])

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 + a2, jnp.exp(a2) * b1 + b2

        _, h = jax.lax.associative_scan(combine, (log_a, x_in), axis=1)
        h_last = h[:, -1, :]
    out = (h.astype(x.dtype) * y_branch) @ params["w_out"]
    new_state = {"h": h_last, "conv": conv_state}
    return out, new_state


def rglru_block_step(params: PyTree, cfg: RGLRUConfig, x: jax.Array,
                     state: PyTree) -> tuple[jax.Array, PyTree]:
    """Decode: ``x (B, 1, d)`` with O(1) state."""
    y_branch = jax.nn.gelu(x @ params["w_in_y"])
    u = x @ params["w_in_x"]
    u, conv_state = _conv1d_causal(params, u, state["conv"])
    log_a, x_in = _gates(params, u)
    h = jnp.exp(log_a[:, 0, :]) * state["h"] + x_in[:, 0, :]
    out = (h[:, None, :].astype(x.dtype) * y_branch) @ params["w_out"]
    return out, {"h": h, "conv": conv_state}
