"""The paper's Fashion-MNIST MLP (§III).

FC(784->32) + ReLU, FC(32->C) + log-softmax, NLL loss.  The first layer is
the common representation in the 3-task experiment (Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["PaperMLPConfig", "init", "apply", "loss_fn", "accuracy",
           "COMMON_PREFIXES"]

COMMON_PREFIXES = ("fc1",)


@dataclasses.dataclass(frozen=True)
class PaperMLPConfig:
    m: int = 784
    hidden: int = 32
    n_classes: int = 10


def init(cfg: PaperMLPConfig, rng: jax.Array) -> PyTree:
    k1, k2 = jax.random.split(rng)
    s1 = jnp.sqrt(2.0 / cfg.m)
    s2 = jnp.sqrt(2.0 / cfg.hidden)
    return {
        "fc1": {"w": jax.random.normal(k1, (cfg.m, cfg.hidden)) * s1,
                "b": jnp.zeros((cfg.hidden,))},
        "head": {"w": jax.random.normal(k2, (cfg.hidden, cfg.n_classes)) * s2,
                 "b": jnp.zeros((cfg.n_classes,))},
    }


def apply(cfg: PaperMLPConfig, params: PyTree, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["head"]["w"] + params["head"]["b"]


def loss_fn(cfg: PaperMLPConfig):
    def f(params: PyTree, batch: dict) -> jax.Array:
        logits = apply(cfg, params, batch["x"])
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1)
        return jnp.mean(nll)
    return f


def accuracy(cfg: PaperMLPConfig, params: PyTree, x, y) -> float:
    logits = apply(cfg, params, x)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))
