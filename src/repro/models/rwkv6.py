"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent token-shift and
per-channel data-dependent decay, attention-free.

Time-mix recurrence per head (key dim hd_k = value dim hd_v = 64):

    a_t   = k_t v_t^T                      (rank-1 update)
    o_t   = r_t (S_t + diag(u) a_t)        (readout w/ bonus on current)
    S_t+1 = diag(w_t) S_t + a_t            (data-dependent diagonal decay)

Three implementations with one contract:
  * ``time_mix_ref``    : lax.scan over time — the oracle.
  * ``time_mix_chunked``: TPU-native chunked form — intra-chunk pairwise
    decay ratios ``exp(cumlog[t-1]-cumlog[s]) <= 1`` (computed as log
    differences so nothing overflows), inter-chunk state carried by a scan
    over chunks.  This turns the sequential recurrence into MXU matmuls —
    the hardware adaptation of the paper-pool's GPU WKV kernel (DESIGN.md §5).
  * ``time_mix_step``   : single-token decode (O(1) state).

Channel-mix is the RWKV squared-ReLU FFN with token shift.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Any

__all__ = ["RWKVConfig", "rwkv_block_init", "rwkv_block_apply",
           "rwkv_block_step", "init_rwkv_state", "time_mix_ref",
           "time_mix_chunked"]

MIX_NAMES = ("r", "k", "v", "w", "g")


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    d_ff: int
    head_dim: int = 64
    lora_mix: int = 32          # rank of the token-shift ddlerp LoRA
    lora_decay: int = 64        # rank of the decay LoRA
    chunk: int = 64             # chunk length for the parallel form
    impl: str = "chunked"       # chunked | scan (oracle) | pallas (fused)

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def rwkv_block_init(rng, cfg: RWKVConfig, dtype=jnp.float32) -> PyTree:
    d, hd = cfg.d_model, cfg.head_dim
    h = cfg.n_heads
    ks = jax.random.split(rng, 16)
    u_init = jnp.linspace(-1.0, 1.0, hd, dtype=jnp.float32)
    return {
        "time": {
            "mu_x": jnp.full((d,), 0.5, dtype),
            "mu": jnp.full((5, d), 0.5, dtype),
            "mix_a1": L.dense_init(ks[0], d, 5 * cfg.lora_mix, dtype),
            "mix_a2": L.trunc_normal(ks[1], (5, cfg.lora_mix, d), 0.01, dtype),
            "w0": jnp.full((d,), -2.0, dtype),   # decay bias (pre -exp(exp))
            "w_a1": L.dense_init(ks[2], d, cfg.lora_decay, dtype),
            "w_a2": L.trunc_normal(ks[3], (cfg.lora_decay, d), 0.01, dtype),
            "u": jnp.tile(u_init[None, :], (h, 1)).astype(dtype),
            "wr": L.dense_init(ks[4], d, d, dtype),
            "wk": L.dense_init(ks[5], d, d, dtype),
            "wv": L.dense_init(ks[6], d, d, dtype),
            "wg": L.dense_init(ks[7], d, d, dtype),
            "wo": L.dense_init(ks[8], d, d, dtype),
            "ln_x": L.rms_norm_init(d, dtype),
        },
        "channel": {
            "mu_k": jnp.full((d,), 0.5, dtype),
            "mu_r": jnp.full((d,), 0.5, dtype),
            "wk": L.dense_init(ks[9], d, cfg.d_ff, dtype),
            "wv": L.dense_init(ks[10], cfg.d_ff, d, dtype),
            "wr": L.dense_init(ks[11], d, d, dtype),
        },
        "ln1": L.rms_norm_init(d, dtype),
        "ln2": L.rms_norm_init(d, dtype),
    }


# ---------------------------------------------------------------------------
# Token shift + projections
# ---------------------------------------------------------------------------

def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Sequence-shift: y_t = x_{t-1}; y_0 = prev (carry from last step)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(tp: PyTree, x: jax.Array, x_prev_tok: jax.Array
            ) -> dict[str, jax.Array]:
    """Data-dependent token-shift mix for the five branches (Finch eq. 2-4)."""
    xx = x_prev_tok - x
    xbase = x + xx * tp["mu_x"]
    lora = jnp.tanh(xbase @ tp["mix_a1"])                     # (B,S,5*r)
    b, s, _ = lora.shape
    lora = lora.reshape(b, s, 5, -1)
    delta = jnp.einsum("bsnr,nrd->bsnd", lora, tp["mix_a2"])  # (B,S,5,d)
    out = {}
    for i, name in enumerate(MIX_NAMES):
        mix = tp["mu"][i] + delta[:, :, i, :]
        out[name] = x + xx * mix
    return out


def _rkvwg(tp: PyTree, mixed: dict, h: int, hd: int):
    """Project the mixed branches -> per-head r, k, v, decay logs, gate."""
    b, s, d = mixed["r"].shape
    r = (mixed["r"] @ tp["wr"]).reshape(b, s, h, hd)
    k = (mixed["k"] @ tp["wk"]).reshape(b, s, h, hd)
    v = (mixed["v"] @ tp["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(mixed["g"] @ tp["wg"])
    w_raw = tp["w0"] + jnp.tanh(mixed["w"] @ tp["w_a1"]) @ tp["w_a2"]
    # log-decay in (-inf, 0): log w = -exp(w_raw)  (w = exp(-exp(raw)))
    logw = -jnp.exp(jnp.clip(w_raw.astype(jnp.float32), -8.0, 5.0))
    logw = logw.reshape(b, s, h, hd)
    return r, k, v, logw, g


# ---------------------------------------------------------------------------
# WKV6 core: three equivalent implementations
# ---------------------------------------------------------------------------

def time_mix_ref(r, k, v, logw, u, state):
    """Oracle: scan over time.  r/k/v/logw (B,S,H,hd), u (H,hd),
    state (B,H,hd,hd).  Returns (out (B,S,H,hd), final state)."""

    def step(s_prev, inp):
        r_t, k_t, v_t, lw_t = inp                       # (B,H,hd)
        a = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       s_prev + u[None, :, :, None] * a)
        s_new = jnp.exp(lw_t)[..., None] * s_prev + a
        return s_new, o

    rs, ks_, vs, lws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    state, outs = jax.lax.scan(step, state, (rs, ks_, vs, lws))
    return jnp.moveaxis(outs, 0, 1), state


def time_mix_chunked(r, k, v, logw, u, state, chunk: int = 64):
    """Chunked parallel form (matmul-dominant, overflow-safe).

    Within a chunk of length C (fp32):
      cum[t]  = sum_{s<=t} logw_s                       (per key dim)
      inter-token weight A[t,s,d] = exp(cum[t-1]-cum[s]) for s<t  (<=1)
      state passthrough uses exp(cum[t-1]) (<=1)
      chunk state update uses exp(cum[C-1]-cum[s]) (<=1)
    """
    b, s, h, hd = r.shape
    c = min(chunk, s)
    if s % c:
        raise ValueError(f"seq {s} not divisible by chunk {c}")
    n = s // c

    def resh(t):
        return t.reshape(b, n, c, h, hd).astype(jnp.float32)

    r_, k_, v_, lw = map(resh, (r, k, v, logw))

    def per_chunk(s0, inp):
        rc, kc, vc, lwc = inp                            # (B,C,H,hd)
        cum = jnp.cumsum(lwc, axis=1)                    # (B,C,H,hd)
        cum_prev = cum - lwc                             # cum[t-1]
        # state passthrough: o_state[t] = (r_t * exp(cum[t-1])) . S0
        r_dec = rc * jnp.exp(cum_prev)
        o_state = jnp.einsum("bchk,bhkv->bchv", r_dec, s0)
        # intra-chunk: A[t,s,d] = exp(cum[t-1,d]-cum[s,d]) for s < t
        diff = cum_prev[:, :, None] - cum[:, None, :, :, :]   # (B,C,C,H,hd)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, :, :, None, None]
        a = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        w_ts = jnp.einsum("bthk,btshk,bshk->btsh", rc, a, kc)  # (B,C,C,H)
        o_intra = jnp.einsum("btsh,bshv->bthv", w_ts, vc)
        # bonus on the current token
        o_bonus = (jnp.einsum("bchk,bchk->bch", rc * u[None, None], kc)
                   [..., None] * vc)
        # next chunk state: S' = exp(cum[C-1]) S0 + sum_s exp(cum[C-1]-cum[s]) k_s v_s^T
        dec_total = jnp.exp(cum[:, -1])                   # (B,H,hd)
        k_dec = kc * jnp.exp(jnp.minimum(cum[:, -1][:, None] - cum, 0.0))
        s_new = (dec_total[..., None] * s0
                 + jnp.einsum("bshk,bshv->bhkv", k_dec, vc))
        return s_new, o_state + o_intra + o_bonus

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r_, k_, v_, lw))
    state, outs = jax.lax.scan(per_chunk, state.astype(jnp.float32), inputs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return out.astype(r.dtype), state


def init_rwkv_state(cfg: RWKVConfig, batch: int, dtype=jnp.float32) -> PyTree:
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), dtype),
        "shift_att": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_ffn": jnp.zeros((batch, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# Full block (train / decode)
# ---------------------------------------------------------------------------

def _time_mix_out(tp, cfg: RWKVConfig, o, g, b, s):
    o = o.reshape(b, s, cfg.d_model)
    # per-head group norm (rms variant) then gate
    oh = o.reshape(b, s, cfg.n_heads, cfg.head_dim)
    ohf = oh.astype(jnp.float32)
    var = jnp.mean(jnp.square(ohf), axis=-1, keepdims=True)
    oh = (ohf * jax.lax.rsqrt(var + 1e-6)).astype(o.dtype)
    o = oh.reshape(b, s, cfg.d_model) * tp["ln_x"]
    return (o * g) @ tp["wo"]


def _last_valid(t: jax.Array, valid: jax.Array, fallback: jax.Array
                ) -> jax.Array:
    """Gather ``t (B, S, d)`` at each row's last valid position; rows with
    no valid token keep ``fallback (B, d)`` (the incoming carry)."""
    s = t.shape[1]
    last = jnp.max(jnp.where(valid, jnp.arange(s)[None, :], -1), axis=1)
    picked = jnp.take_along_axis(
        t, jnp.clip(last, 0)[:, None, None], axis=1)[:, 0]
    return jnp.where((last >= 0)[:, None], picked, fallback.astype(t.dtype))


def rwkv_block_apply(params: PyTree, cfg: RWKVConfig, x: jax.Array,
                     state: PyTree | None = None,
                     valid: jax.Array | None = None
                     ) -> tuple[jax.Array, PyTree]:
    """Training/prefill: ``x (B, S, d)`` -> (y, final recurrent state).

    ``valid (B, S)`` bool marks live positions for ragged right-padded
    chunks (the serving prefill): pad positions become identity state
    updates (``k``/``logw`` zeroed => decay 1, rank-1 update 0) and the
    token-shift carries come from each row's LAST VALID position, so the
    final state equals a per-row unpadded run.  Outputs at pad positions
    are garbage and must be ignored by the caller.
    """
    b, s, d = x.shape
    if state is None:
        state = init_rwkv_state(cfg, b)
    tp, cp = params["time"], params["channel"]

    # --- time mix ---
    xn = L.rms_norm(x, params["ln1"])
    mixed = _ddlerp(tp, xn, _shift(xn, state["shift_att"]))
    r, k, v, logw, g = _rkvwg(tp, mixed, cfg.n_heads, cfg.head_dim)
    if valid is not None:
        vm = valid[:, :, None, None]
        k = jnp.where(vm, k, jnp.zeros((), k.dtype))
        logw = jnp.where(vm, logw, jnp.zeros((), logw.dtype))
    u = tp["u"].astype(jnp.float32)
    if cfg.impl == "pallas" and s > 1:
        from repro.kernels.recurrent_scan import ops as rs_ops

        # bf16 tiles only when the model itself runs bf16 activations;
        # fp32 archs keep fp32 compute (oracle-tight)
        cd = "bf16" if x.dtype == jnp.bfloat16 else "fp32"
        o, wkv = rs_ops.wkv_chunked(r, k, v, logw, u, state["wkv"],
                                    chunk=cfg.chunk, compute_dtype=cd)
    elif cfg.impl == "chunked" and s > 1:
        o, wkv = time_mix_chunked(r, k, v, logw, u, state["wkv"], cfg.chunk)
    else:
        o, wkv = time_mix_ref(r, k, v, logw, u, state["wkv"])
    o = o.astype(x.dtype)
    x = x + _time_mix_out(tp, cfg, o, g, b, s).astype(x.dtype)

    # --- channel mix ---
    xn2 = L.rms_norm(x, params["ln2"])
    shifted = _shift(xn2, state["shift_ffn"])
    xk = xn2 + (shifted - xn2) * cp["mu_k"]
    xr = xn2 + (shifted - xn2) * cp["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ cp["wk"]))
    out = (kk @ cp["wv"]) * jax.nn.sigmoid(xr @ cp["wr"])
    x = x + out.astype(x.dtype)

    if valid is None:
        new_state = {"wkv": wkv, "shift_att": xn[:, -1, :],
                     "shift_ffn": xn2[:, -1, :]}
    else:
        new_state = {"wkv": wkv,
                     "shift_att": _last_valid(xn, valid, state["shift_att"]),
                     "shift_ffn": _last_valid(xn2, valid,
                                              state["shift_ffn"])}
    return x, new_state


def rwkv_block_step(params: PyTree, cfg: RWKVConfig, x: jax.Array,
                    state: PyTree) -> tuple[jax.Array, PyTree]:
    """Decode: ``x (B, 1, d)`` with O(1) state."""
    cfg1 = dataclasses.replace(cfg, impl="scan")
    return rwkv_block_apply(params, cfg1, x, state)
