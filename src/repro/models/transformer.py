"""Unified decoder-only transformer stack covering the assigned families:
dense (GQA), MoE, SSM (RWKV6), hybrid (RG-LRU + local attention), and
early-fusion VLM.  Layers are grouped by the config's ``block_pattern`` and
scanned (compile-time O(1) in depth); heterogeneous patterns scan one
pattern-repetition per step; remainder layers are unrolled.

API (all pure functions over an ``ArchConfig``):
  init(cfg, rng)                        -> params
  forward(cfg, params, batch)           -> (logits, aux_loss)
  loss_fn(cfg, params, batch)           -> scalar
  init_decode_state(cfg, batch, max_len)-> state
  decode_step(cfg, params, tokens, state)-> (logits, new_state)

``batch`` for training: {"tokens" (B,S), "labels" (B,S)}; VLM fusion adds
{"patch_embeds" (B,P,d), "patch_mask" (B,S) bool} — embeddings at masked
positions are replaced by projected patch embeddings (early fusion).
``shard_fn(x, name)`` optionally applies sharding constraints on
activations (injected by the launcher; identity by default).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as G
from repro.models import rwkv6 as R

PyTree = Any
ShardFn = Callable[[jax.Array, str], jax.Array]

__all__ = ["init", "forward", "loss_fn", "init_decode_state", "decode_step",
           "decode_hidden", "prefill_chunk", "attn_config", "rwkv_config",
           "rglru_config"]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def _id_shard(x, name):
    del name
    return x


# ---------------------------------------------------------------------------
# Per-kind block configs
# ---------------------------------------------------------------------------

def attn_config(cfg: ArchConfig, hybrid_local: bool = False) -> A.AttnConfig:
    window = cfg.local_window if hybrid_local else cfg.attn_window
    return A.AttnConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                        qk_norm=cfg.qk_norm, window=window,
                        rope_theta=cfg.rope_theta, impl=cfg.attn_impl)


def rwkv_config(cfg: ArchConfig) -> R.RWKVConfig:
    impl = cfg.rec_impl or "chunked"
    return R.RWKVConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                        head_dim=cfg.rwkv_head_dim, chunk=cfg.rwkv_chunk,
                        impl=impl)


def rglru_config(cfg: ArchConfig) -> G.RGLRUConfig:
    impl = "pallas" if cfg.rec_impl == "pallas" else "scan"
    return G.RGLRUConfig(d_model=cfg.d_model, d_rnn=cfg.d_rnn, impl=impl)


def moe_config(cfg: ArchConfig) -> M.MoEConfig:
    return M.MoEConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                       n_experts=cfg.n_experts, top_k=cfg.moe_top_k,
                       capacity_factor=cfg.capacity_factor,
                       mlp_variant=cfg.mlp_variant)


# ---------------------------------------------------------------------------
# Block init / apply / decode-step (dispatch on kind)
# ---------------------------------------------------------------------------

def _block_init(cfg: ArchConfig, kind: str, rng, dtype) -> PyTree:
    if kind == "attn":
        hybrid_local = len(cfg.block_pattern) > 1
        ks = jax.random.split(rng, 3)
        p = {"ln1": L.rms_norm_init(cfg.d_model, dtype),
             "attn": A.attn_init(ks[0], attn_config(cfg, hybrid_local), dtype),
             "ln2": L.rms_norm_init(cfg.d_model, dtype)}
        if cfg.n_experts:
            p["ffn"] = M.moe_init(ks[1], moe_config(cfg), dtype)
        else:
            p["ffn"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.mlp_variant, dtype)
        return p
    if kind == "rec":
        ks = jax.random.split(rng, 2)
        return {"ln1": L.rms_norm_init(cfg.d_model, dtype),
                "rec": G.rglru_block_init(ks[0], rglru_config(cfg), dtype),
                "ln2": L.rms_norm_init(cfg.d_model, dtype),
                "ffn": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.mlp_variant, dtype)}
    if kind == "rwkv":
        return R.rwkv_block_init(rng, rwkv_config(cfg), dtype)
    raise ValueError(f"unknown block kind {kind!r}")


def _block_apply(cfg: ArchConfig, kind: str, params: PyTree, h: jax.Array,
                 aux: jax.Array, positions: jax.Array,
                 shard: ShardFn) -> tuple[jax.Array, jax.Array]:
    """Training/prefill block (fresh recurrent state)."""
    if kind == "attn":
        hybrid_local = len(cfg.block_pattern) > 1
        acfg = attn_config(cfg, hybrid_local)
        a = A.attention(params["attn"], acfg,
                        shard(L.rms_norm(h, params["ln1"]), "interior"),
                        positions)
        h = h + shard(a, "residual")
        hn = shard(L.rms_norm(h, params["ln2"]), "interior")
        if cfg.n_experts:
            f, aux_l = M.moe_apply(params["ffn"], moe_config(cfg), hn)
            aux = aux + aux_l
        else:
            f = L.mlp_apply(params["ffn"], hn, cfg.mlp_variant)
        return h + shard(f, "residual"), aux
    if kind == "rec":
        r, _ = G.rglru_block_apply(params["rec"], rglru_config(cfg),
                                   shard(L.rms_norm(h, params["ln1"]),
                                         "interior"))
        h = h + shard(r, "residual")
        f = L.mlp_apply(params["ffn"],
                        shard(L.rms_norm(h, params["ln2"]), "interior"),
                        cfg.mlp_variant)
        return h + shard(f, "residual"), aux
    if kind == "rwkv":
        y, _ = R.rwkv_block_apply(params, rwkv_config(cfg), h)
        return shard(y, "residual"), aux
    raise ValueError(kind)


def _block_state_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      dtype) -> PyTree:
    if kind == "attn":
        hybrid_local = len(cfg.block_pattern) > 1
        return A.init_cache(attn_config(cfg, hybrid_local), batch, max_len,
                            dtype)
    if kind == "rec":
        return G.init_rglru_state(rglru_config(cfg), batch, dtype)
    if kind == "rwkv":
        st = R.init_rwkv_state(rwkv_config(cfg), batch)
        # token-shift carries live in activation dtype; wkv state stays fp32
        st["shift_att"] = st["shift_att"].astype(dtype)
        st["shift_ffn"] = st["shift_ffn"].astype(dtype)
        return st
    raise ValueError(kind)


def _block_step(cfg: ArchConfig, kind: str, params: PyTree, h: jax.Array,
                state: PyTree, length: jax.Array,
                shard: ShardFn = _id_shard) -> tuple[jax.Array, PyTree]:
    """Single-token decode block."""
    if kind == "attn":
        hybrid_local = len(cfg.block_pattern) > 1
        acfg = attn_config(cfg, hybrid_local)
        a, new_cache = A.decode_step(params["attn"], acfg,
                                     L.rms_norm(h, params["ln1"]),
                                     state, length, shard)
        h = h + a
        hn = L.rms_norm(h, params["ln2"])
        if cfg.n_experts:
            f, _ = M.moe_apply(params["ffn"], moe_config(cfg), hn)
        else:
            f = L.mlp_apply(params["ffn"], hn, cfg.mlp_variant)
        return h + f, new_cache
    if kind == "rec":
        r, new_state = G.rglru_block_step(params["rec"], rglru_config(cfg),
                                          L.rms_norm(h, params["ln1"]), state)
        h = h + r
        f = L.mlp_apply(params["ffn"], L.rms_norm(h, params["ln2"]),
                        cfg.mlp_variant)
        return h + f, new_state
    if kind == "rwkv":
        return R.rwkv_block_step(params, rwkv_config(cfg), h, state)
    raise ValueError(kind)


def _block_chunk(cfg: ArchConfig, kind: str, params: PyTree, h: jax.Array,
                 state: PyTree, start: jax.Array, valid: jax.Array,
                 shard: ShardFn = _id_shard) -> tuple[jax.Array, PyTree]:
    """Chunked teacher-forced prefill block: ``h (B, C, d)`` against live
    decode state.  ``start`` = absolute position of the chunk's first
    token (scalar — prefill chunks advance uniformly), ``valid (B, C)``
    masks each row's live positions so recurrent state updates stay exact
    under right padding (attention needs no mask: pad writes land past a
    row's true length and are overwritten before they become visible).
    """
    if kind == "attn":
        hybrid_local = len(cfg.block_pattern) > 1
        acfg = attn_config(cfg, hybrid_local)
        a, new_cache = A.decode_chunk(params["attn"], acfg,
                                      L.rms_norm(h, params["ln1"]),
                                      state, start, shard)
        h = h + a
        hn = L.rms_norm(h, params["ln2"])
        if cfg.n_experts:
            f, _ = M.moe_apply(params["ffn"], moe_config(cfg), hn)
        else:
            f = L.mlp_apply(params["ffn"], hn, cfg.mlp_variant)
        return h + f, new_cache
    if kind == "rec":
        r, new_state = G.rglru_block_apply(params["rec"], rglru_config(cfg),
                                           L.rms_norm(h, params["ln1"]),
                                           state, valid)
        h = h + r
        f = L.mlp_apply(params["ffn"], L.rms_norm(h, params["ln2"]),
                        cfg.mlp_variant)
        return h + f, new_state
    if kind == "rwkv":
        return R.rwkv_block_apply(params, rwkv_config(cfg), h, state, valid)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full-model init
# ---------------------------------------------------------------------------

def _stack_trees(trees: list[PyTree]) -> PyTree:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init(cfg: ArchConfig, rng: jax.Array) -> PyTree:
    dtype = _dt(cfg.param_dtype)
    pat = cfg.block_pattern
    n_groups, rest = cfg.n_groups, cfg.rest_kinds
    keys = jax.random.split(rng, 4 + cfg.n_layers)
    p: dict = {
        "embed": L.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": L.rms_norm_init(cfg.d_model, dtype),
        "head": L.dense_init(keys[1], cfg.d_model, cfg.vocab, dtype),
    }
    if cfg.fuse_patches:
        p["patch_proj"] = L.dense_init(keys[2], cfg.d_model, cfg.d_model,
                                       dtype)
    lk = iter(keys[4:])
    if cfg.scan_layers and n_groups > 0:
        groups = []
        for _ in range(n_groups):
            groups.append({str(j): _block_init(cfg, kind, next(lk), dtype)
                           for j, kind in enumerate(pat)})
        p["groups"] = _stack_trees(groups)
    else:
        p["groups_unrolled"] = [
            {str(j): _block_init(cfg, kind, next(lk), dtype)
             for j, kind in enumerate(pat)}
            for _ in range(n_groups)]
    p["rest"] = {str(j): _block_init(cfg, kind, next(lk), dtype)
                 for j, kind in enumerate(rest)}
    return p


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(cfg: ArchConfig, params: PyTree, batch: dict, shard: ShardFn
           ) -> jax.Array:
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    h = h.astype(_dt(cfg.act_dtype))
    if cfg.fuse_patches and "patch_embeds" in batch:
        # Early fusion: positions flagged by patch_mask get (projected)
        # patch embeddings scattered over the token stream, in order.
        pe = batch["patch_embeds"].astype(h.dtype) @ params["patch_proj"]
        mask = batch["patch_mask"]                       # (B, S) bool
        idx = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1
        idx = jnp.clip(idx, 0, pe.shape[1] - 1)
        gathered = jnp.take_along_axis(pe, idx[..., None], axis=1)
        h = jnp.where(mask[..., None], gathered, h)
    return shard(h, "activation")


def forward(cfg: ArchConfig, params: PyTree, batch: dict,
            shard: ShardFn = _id_shard, last_only: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """``last_only=True`` computes logits for the FINAL position only —
    the serving-prefill path (full-seq logits at 32k x 256k vocab is a
    0.5 TB tensor; EXPERIMENTS.md §Perf it-3)."""
    h = _embed(cfg, params, batch, shard)
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    aux = jnp.zeros((), jnp.float32)
    pat = cfg.block_pattern

    def group_body(carry, gp):
        h, aux = carry
        for j, kind in enumerate(pat):
            h, aux = _block_apply(cfg, kind, gp[str(j)], h, aux, positions,
                                  shard)
        return (h, aux), None

    if cfg.scan_layers and cfg.n_groups > 0:
        body = jax.checkpoint(group_body) if cfg.remat else group_body
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["groups"])
    elif "groups_unrolled" in params:
        body = jax.checkpoint(group_body) if cfg.remat else group_body
        for gp in params["groups_unrolled"]:
            (h, aux), _ = body((h, aux), gp)
    for j, kind in enumerate(cfg.rest_kinds):
        h, aux = _block_apply(cfg, kind, params["rest"][str(j)], h, aux,
                              positions, shard)
    if last_only:
        h = h[:, -1:, :]
    h = L.rms_norm(h, params["final_norm"])
    logits = shard(h @ params["head"], "logits")
    return logits, aux


def loss_fn(cfg: ArchConfig, params: PyTree, batch: dict,
            shard: ShardFn = _id_shard, aux_weight: float = 0.01
            ) -> jax.Array:
    logits, aux = forward(cfg, params, batch, shard)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll[..., 0] * mask
        loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      per_slot: bool = False) -> PyTree:
    """``per_slot=True`` keeps a PER-ROW ``length (batch,)`` so every row
    (serving slot) decodes at its own depth — the slot-scheduler layout.
    The default scalar length is the uniform-batch decode path."""
    dtype = _dt(cfg.act_dtype)
    pat = cfg.block_pattern
    state: dict = {"length": jnp.zeros((batch,) if per_slot else (),
                                       jnp.int32)}
    if cfg.scan_layers and cfg.n_groups > 0:
        groups = [
            {str(j): _block_state_init(cfg, kind, batch, max_len, dtype)
             for j, kind in enumerate(pat)}
            for _ in range(cfg.n_groups)]
        state["groups"] = _stack_trees(groups)
    elif cfg.n_groups > 0:
        state["groups_unrolled"] = [
            {str(j): _block_state_init(cfg, kind, batch, max_len, dtype)
             for j, kind in enumerate(pat)}
            for _ in range(cfg.n_groups)]
    state["rest"] = {str(j): _block_state_init(cfg, kind, batch, max_len,
                                               dtype)
                     for j, kind in enumerate(cfg.rest_kinds)}
    return state


def decode_hidden(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
                  state: PyTree, shard: ShardFn = _id_shard
                  ) -> tuple[jax.Array, PyTree]:
    """One decode step up to the FINAL NORM: ``tokens (B, 1)`` ->
    (normed hidden (B, 1, d), new state) — the head is left to the
    caller so serving can swap per-cluster heads/adapters over the
    shared trunk.  ``state["length"]`` may be scalar or per-row ``(B,)``
    (the slot-scheduler layout)."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg.act_dtype))
    h = shard(h, "activation")
    length = state["length"]
    pat = cfg.block_pattern
    new_state: dict = {"length": length + 1}

    def group_body(h, inp):
        gp, gs = inp
        new_gs = {}
        for j, kind in enumerate(pat):
            h, s_new = _block_step(cfg, kind, gp[str(j)], h, gs[str(j)],
                                   length, shard)
            new_gs[str(j)] = s_new
        return h, new_gs

    if cfg.scan_layers and cfg.n_groups > 0:
        h, gs = jax.lax.scan(group_body, h,
                             (params["groups"], state["groups"]))
        new_state["groups"] = gs
    elif "groups_unrolled" in state:
        new_unrolled = []
        for gp, gs in zip(params["groups_unrolled"],
                          state["groups_unrolled"]):
            h, gs_new = group_body(h, (gp, gs))
            new_unrolled.append(gs_new)
        new_state["groups_unrolled"] = new_unrolled
    new_rest = {}
    for j, kind in enumerate(cfg.rest_kinds):
        h, s_new = _block_step(cfg, kind, params["rest"][str(j)], h,
                               state["rest"][str(j)], length, shard)
        new_rest[str(j)] = s_new
    new_state["rest"] = new_rest
    return L.rms_norm(h, params["final_norm"]), new_state


def decode_step(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
                state: PyTree, shard: ShardFn = _id_shard
                ) -> tuple[jax.Array, PyTree]:
    """One decode step: ``tokens (B, 1)`` -> (logits (B, 1, V), new state)."""
    h, new_state = decode_hidden(cfg, params, tokens, state, shard)
    logits = shard(h @ params["head"], "logits")
    return logits, new_state


def prefill_chunk(cfg: ArchConfig, params: PyTree, tokens: jax.Array,
                  state: PyTree, start: jax.Array, valid: jax.Array,
                  shard: ShardFn = _id_shard) -> tuple[jax.Array, PyTree]:
    """Teacher-forced prefill of a C-token chunk in ONE dispatchable step:
    ``tokens (B, C)`` right-padded, ``start`` = the chunk's absolute base
    position (scalar), ``valid (B, C)`` = per-row liveness.  Returns the
    PRE-NORM hidden ``(B, C, d)`` (the caller gathers each row's last
    valid position and applies final_norm + head once) and the advanced
    state (``length`` grows by each row's valid count, so it lands on the
    true prompt length after the last chunk).

    Scanning this over ``prompt_len / C`` chunks replaces the old
    per-token prefill loop: dispatches drop O(prompt_len) ->
    O(prompt_len / C).  Requires a per-slot state (vector ``length``).
    """
    h = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg.act_dtype))
    h = shard(h, "activation")
    pat = cfg.block_pattern
    start = jnp.asarray(start, jnp.int32)
    counts = jnp.sum(valid.astype(jnp.int32), axis=1)
    new_state: dict = {"length": state["length"] + counts}

    def group_body(h, inp):
        gp, gs = inp
        new_gs = {}
        for j, kind in enumerate(pat):
            h, s_new = _block_chunk(cfg, kind, gp[str(j)], h, gs[str(j)],
                                    start, valid, shard)
            new_gs[str(j)] = s_new
        return h, new_gs

    if cfg.scan_layers and cfg.n_groups > 0:
        h, gs = jax.lax.scan(group_body, h,
                             (params["groups"], state["groups"]))
        new_state["groups"] = gs
    elif "groups_unrolled" in state:
        new_unrolled = []
        for gp, gs in zip(params["groups_unrolled"],
                          state["groups_unrolled"]):
            h, gs_new = group_body(h, (gp, gs))
            new_unrolled.append(gs_new)
        new_state["groups_unrolled"] = new_unrolled
    new_rest = {}
    for j, kind in enumerate(cfg.rest_kinds):
        h, s_new = _block_chunk(cfg, kind, params["rest"][str(j)], h,
                                state["rest"][str(j)], start, valid, shard)
        new_rest[str(j)] = s_new
    new_state["rest"] = new_rest
    return h, new_state
