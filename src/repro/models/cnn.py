"""The paper's CIFAR-10 CNN (§III "Datasets and Models").

Two 5x5 conv layers, two 2x2 max-pools, FC(120), FC(84), softmax head,
cross-entropy loss.  The two conv layers are the *common representation*
shared through the GPS (paper Fig. 2 setup); the FC stack + head are
task-specific.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["PaperCNNConfig", "init", "apply", "loss_fn", "accuracy",
           "COMMON_PREFIXES"]

COMMON_PREFIXES = ("conv1", "conv2")


@dataclasses.dataclass(frozen=True)
class PaperCNNConfig:
    image_hw: tuple[int, int, int] = (32, 32, 3)
    c1: int = 6
    c2: int = 16
    fc1: int = 120
    fc2: int = 84
    n_classes: int = 10


def _he(rng, shape, fan_in):
    return jax.random.normal(rng, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def init(cfg: PaperCNNConfig, rng: jax.Array) -> PyTree:
    h, w, c = cfg.image_hw
    k = jax.random.split(rng, 5)
    # Spatial size after two valid 5x5 convs + 2x2 pools.
    s1 = ((h - 4) // 2, (w - 4) // 2)
    s2 = ((s1[0] - 4) // 2, (s1[1] - 4) // 2)
    flat = s2[0] * s2[1] * cfg.c2
    return {
        "conv1": {"w": _he(k[0], (5, 5, c, cfg.c1), 25 * c),
                  "b": jnp.zeros((cfg.c1,))},
        "conv2": {"w": _he(k[1], (5, 5, cfg.c1, cfg.c2), 25 * cfg.c1),
                  "b": jnp.zeros((cfg.c2,))},
        "fc1": {"w": _he(k[2], (flat, cfg.fc1), flat),
                "b": jnp.zeros((cfg.fc1,))},
        "fc2": {"w": _he(k[3], (cfg.fc1, cfg.fc2), cfg.fc1),
                "b": jnp.zeros((cfg.fc2,))},
        "head": {"w": _he(k[4], (cfg.fc2, cfg.n_classes), cfg.fc2),
                 "b": jnp.zeros((cfg.n_classes,))},
    }


def _conv(x, w, b):
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                        ("NHWC", "HWIO", "NHWC"))
    return jax.lax.conv_general_dilated(x, w, (1, 1), "VALID",
                                        dimension_numbers=dn) + b


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply(cfg: PaperCNNConfig, params: PyTree, x_flat: jax.Array) -> jax.Array:
    """``x_flat (B, m)`` -> logits ``(B, n_classes)``."""
    h, w, c = cfg.image_hw
    x = x_flat.reshape((-1, h, w, c))
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv1"]["w"],
                                    params["conv1"]["b"])))
    x = _maxpool2(jax.nn.relu(_conv(x, params["conv2"]["w"],
                                    params["conv2"]["b"])))
    x = x.reshape((x.shape[0], -1))
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["head"]["w"] + params["head"]["b"]


def loss_fn(cfg: PaperCNNConfig):
    def f(params: PyTree, batch: dict) -> jax.Array:
        logits = apply(cfg, params, batch["x"])
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1)
        return jnp.mean(nll)
    return f


def accuracy(cfg: PaperCNNConfig, params: PyTree, x, y) -> float:
    logits = apply(cfg, params, x)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))
