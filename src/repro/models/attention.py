"""GQA attention: training (full-causal / sliding-window / bidirectional /
cross) and single-token decode against a KV cache (full or rolling-window).

Layouts:
  q (B, S, H, hd)   k/v (B, S, K, hd)   K = n_kv_heads, G = H // K groups.
  full cache:    {k, v: (B, S_max, K, hd)}  + scalar ``length``
  rolling cache: {k, v: (B, W, K, hd)}      + scalar ``length`` (absolute)

RoPE is applied at *write* time (keys stored rotated), so decode never
re-rotates the cache.  Softmax in fp32.  The Pallas flash kernel
(`repro.kernels.flash_attention`) implements the same contract for the
training path; `impl="pallas"` routes to it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

PyTree = Any

__all__ = ["AttnConfig", "attn_init", "attention", "decode_step",
           "decode_chunk", "init_cache", "multi_query_attention"]

NEG_INF = -2.0 ** 30  # large-negative for masking (bf16-safe)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    window: int = 0            # 0 = full attention; >0 = sliding window
    causal: bool = True
    rope_theta: float = 10000.0
    impl: str = "jnp"          # jnp | pallas


def attn_init(rng, cfg: AttnConfig, dtype=jnp.float32,
              kv_dim: int | None = None) -> PyTree:
    """kv_dim: source dim for cross-attention K/V (defaults to d_model)."""
    kv_dim = kv_dim or cfg.d_model
    ks = jax.random.split(rng, 6)
    p = {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim,
                           dtype),
        "wk": L.dense_init(ks[1], kv_dim, cfg.n_kv_heads * cfg.head_dim,
                           dtype),
        "wv": L.dense_init(ks[2], kv_dim, cfg.n_kv_heads * cfg.head_dim,
                           dtype),
        "wo": L.dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model,
                           dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rms_norm_init(cfg.head_dim, dtype)
        p["k_norm"] = L.rms_norm_init(cfg.head_dim, dtype)
    return p


def _project_qkv(params, cfg: AttnConfig, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    b, s = x.shape[:2]
    sk = kv_x.shape[1]
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (kv_x @ params["wk"]).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    v = (kv_x @ params["wv"]).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"])
        k = L.rms_norm(k, params["k_norm"])
    return q, k, v


def _expand_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, K*G, hd) by repeat (GQA group expansion)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool, window: int = 0,
                      chunk: int = 512) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (lax.scan over KV
    chunks).  Peak memory is O(S * chunk) instead of O(S^2) — the memory
    lever for the 32k prefill / 4k x 95-layer train shapes
    (EXPERIMENTS.md §Perf it-2).  Same contract as the einsum path.
    """
    b, s, h, hd = q.shape
    skv = k.shape[1]
    c = min(chunk, skv)
    if skv % c:
        return multi_query_attention(
            q, k, v, _structural_mask(s, skv, causal, window), "jnp")
    n = skv // c
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, start = inp
        sij = jnp.einsum("bshd,bthd->bhst", qf, kc.astype(jnp.float32))
        kpos = start + jnp.arange(c)[None, :]
        mask = jnp.ones((s, c), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        sij = jnp.where(mask[None, None], sij, NEG_INF)
        m_cur = jnp.max(sij, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(sij - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    ks = k.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n, c, h, hd).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(n) * c
    m0 = jnp.full((b, h, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, starts))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def _structural_mask(s: int, skv: int, causal: bool, window: int):
    i = jnp.arange(s)[:, None]
    j = jnp.arange(skv)[None, :]
    m = jnp.ones((s, skv), bool)
    if causal:
        m &= j <= i
    if window:
        m &= (i - j) < window
    return m[None, None]


def multi_query_attention(q, k, v, mask, impl: str = "jnp") -> jax.Array:
    """Core attention.  q (B,S,H,hd), k/v (B,Sk,H,hd), mask (B|1,1|H,S,Sk)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _train_mask(cfg: AttnConfig, s: int, sk: int) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(sk)[None, :]
    if not cfg.causal:
        return jnp.ones((1, 1, s, sk), bool)
    m = j <= i
    if cfg.window:
        m &= (i - j) < cfg.window
    return m[None, None]


def attention(params: PyTree, cfg: AttnConfig, x: jax.Array,
              positions: jax.Array | None = None,
              kv_x: jax.Array | None = None,
              mask: jax.Array | None = None) -> jax.Array:
    """Training/prefill path.  ``x (B, S, d)`` -> ``(B, S, d)``.

    ``kv_x`` switches to cross-attention (no causal mask, no rope on kv by
    default — enc-dec style).
    """
    b, s = x.shape[:2]
    q, k, v = _project_qkv(params, cfg, x, kv_x)
    is_cross = kv_x is not None
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if not is_cross:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    groups = cfg.n_heads // cfg.n_kv_heads
    k = _expand_kv(k, groups)
    v = _expand_kv(v, groups)
    if cfg.impl == "pallas" and mask is None:
        # Structural (causal/window) masks route to the flash kernel.
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v,
                                     causal=cfg.causal and not is_cross,
                                     window=cfg.window)
    elif cfg.impl == "chunked" and mask is None:
        out = chunked_attention(q, k, v, causal=cfg.causal and not is_cross,
                                window=cfg.window)
    else:
        if mask is None:
            mcfg = dataclasses.replace(cfg,
                                       causal=cfg.causal and not is_cross)
            mask = _train_mask(mcfg, s, k.shape[1])
        out = multi_query_attention(q, k, v, mask, cfg.impl)
    return out.reshape(b, s, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16
               ) -> PyTree:
    """Cache pytree.  For SWA (cfg.window>0) the cache is the rolling window."""
    size = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params: PyTree, cfg: AttnConfig, x: jax.Array,
                cache: PyTree, length: jax.Array,
                shard=None) -> tuple[jax.Array, PyTree]:
    """One decode step.  ``x (B, 1, d)``, ``length`` = #tokens already cached.

    Returns (out (B, 1, d), new_cache).  Keys are stored pre-rotated.
    ``shard(x, name)`` hints keep the cache and the attention logits
    sharded along the cache's partitioned axis — without them XLA SPMD
    falls back to all-gathering the full cache per layer per step
    (measured: 2 x 1 GB f32 gathers per layer, §Perf it-4).

    ``length`` may also be a per-row vector ``(B,)`` (the slot-scheduler
    serving path, where every row sits at its own depth); that delegates
    to ``decode_chunk`` with a one-token chunk (full caches only).
    """
    shard = shard or (lambda t, name: t)
    if getattr(length, "ndim", 0) == 1:
        return decode_chunk(params, cfg, x, cache, length, shard)
    b = x.shape[0]
    q, k, v = _project_qkv(params, cfg, x)
    pos = jnp.full((b, 1), length, dtype=jnp.int32)
    q = L.rope(q, pos, cfg.rope_theta)
    k = L.rope(k, pos, cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = (length % size) if cfg.window else length
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    ck = shard(ck, "kv_cache")
    cv = shard(cv, "kv_cache")

    idx = jnp.arange(size)
    if cfg.window:
        valid = (idx <= slot) | (length >= size)   # rolling window occupancy
    else:
        valid = idx <= length
    mask = valid[None, None, None, :]              # (1, 1, 1, size)

    # Grouped-head attention WITHOUT materializing the G-expanded KV
    # (repeat would read/write 4x the cache bytes at GQA G=4; §Perf it-5).
    groups = cfg.n_heads // cfg.n_kv_heads
    scale = q.shape[-1] ** -0.5
    qg = q.reshape(b, 1, cfg.n_kv_heads, groups, cfg.head_dim)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    # The :K suffix tells the shard-rule whether kv-head sharding is in
    # play (K divides the model axis) or the cache is seq-sharded.
    logits = shard(jnp.where(mask[:, None], logits, NEG_INF),
                   f"attn_logits:{cfg.n_kv_heads}")
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, cv)
    out = out.reshape(b, 1, -1) @ params["wo"]
    return out, {"k": ck, "v": cv}


def decode_chunk(params: PyTree, cfg: AttnConfig, x: jax.Array,
                 cache: PyTree, lengths: jax.Array,
                 shard=None) -> tuple[jax.Array, PyTree]:
    """Multi-token decode/prefill against a full KV cache with PER-ROW
    write positions: ``x (B, C, d)``, ``lengths (B,)`` (or scalar) =
    #tokens already cached per row.  Token ``t`` of row ``b`` lands at
    absolute position ``lengths[b] + t``; the causal mask admits exactly
    the cache prefix up to that position, so right-padded rows are exact
    without an explicit validity mask — garbage written past a row's true
    length is never attended before being overwritten.

    This is the single-dispatch chunked-prefill / slot-scheduler core.
    Rolling (sliding-window) caches are not supported here — the slot
    engine serves full caches only.
    """
    shard = shard or (lambda t, name: t)
    if cfg.window:
        raise ValueError("decode_chunk serves full caches only "
                         "(cfg.window > 0 uses a rolling cache)")
    b, c = x.shape[:2]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
    positions = lengths[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(params, cfg, x)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)

    size = cache["k"].shape[1]
    rows = jnp.arange(b)[:, None]
    slots = jnp.clip(positions, 0, size - 1)
    ck = cache["k"].at[rows, slots].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[rows, slots].set(v.astype(cache["v"].dtype))
    ck = shard(ck, "kv_cache")
    cv = shard(cv, "kv_cache")

    idx = jnp.arange(size)
    mask = idx[None, None, :] <= positions[:, :, None]   # (B, C, size)

    groups = cfg.n_heads // cfg.n_kv_heads
    scale = q.shape[-1] ** -0.5
    qg = q.reshape(b, c, cfg.n_kv_heads, groups, cfg.head_dim)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    logits = shard(jnp.where(mask[:, None, None], logits, NEG_INF),
                   f"attn_logits:{cfg.n_kv_heads}")
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, cv)
    out = out.reshape(b, c, -1) @ params["wo"]
    return out, {"k": ck, "v": cv}


def cross_decode(params: PyTree, cfg: AttnConfig, x: jax.Array,
                 memory_k: jax.Array, memory_v: jax.Array) -> jax.Array:
    """Cross-attention decode against precomputed encoder memory K/V.

    ``memory_k/v (B, S_src, K, hd)`` are computed once at prefill from the
    encoder output and reused every step.
    """
    b = x.shape[0]
    q = (x @ params["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"])
    groups = cfg.n_heads // cfg.n_kv_heads
    kk = _expand_kv(memory_k, groups)
    vv = _expand_kv(memory_v, groups)
    mask = jnp.ones((1, 1, 1, kk.shape[1]), bool)
    out = multi_query_attention(q, kk, vv, mask, cfg.impl)
    return out.reshape(b, 1, -1) @ params["wo"]


def memory_kv(params: PyTree, cfg: AttnConfig, memory: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output ``memory (B,S,d)``."""
    b, s = memory.shape[:2]
    k = (memory @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (memory @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = L.rms_norm(k, params["k_norm"])
    return k, v
