"""Client-side local update (the inner loop of FedAvg).

``local_update`` runs ``steps`` optimizer steps over pre-batched data with
``jax.lax.scan`` so one client round is a single jit-compiled call.
``fused_lps_round`` vmaps that scan over a stacked client axis and folds
the FedAvg aggregation in, so one jit call performs a cluster's ENTIRE
local round — the vectorized hot path of the MT-HFL trainer.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro import optim

PyTree = Any
LossFn = Callable[[PyTree, dict], jax.Array]

__all__ = ["ClientConfig", "local_update", "fused_lps_round",
           "make_batches", "make_batch_stack"]


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    lr: float = 0.05
    optimizer: str = "sgd"          # sgd | momentum | adamw
    clip_norm: float = 0.0          # 0 disables
    weight_decay: float = 0.0


def _make_opt(cfg: ClientConfig) -> optim.Optimizer:
    if cfg.optimizer == "sgd":
        return optim.sgd(cfg.lr)
    if cfg.optimizer == "momentum":
        return optim.momentum(cfg.lr)
    if cfg.optimizer == "adamw":
        return optim.adamw(cfg.lr, weight_decay=cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")


def _scan_steps(params: PyTree, batches: dict, loss_fn: LossFn,
                optimizer: optim.Optimizer, clip_norm: float
                ) -> tuple[PyTree, jax.Array]:
    """``steps`` optimizer steps via lax.scan (one client, traceable)."""
    opt_state = optimizer.init(params)

    def step(carry, batch):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        if clip_norm:
            grads = optim.clip_by_global_norm(grads, clip_norm)
        updates, s = optimizer.update(grads, s, p)
        p = optim.apply_updates(p, updates)
        return (p, s), loss

    (params, _), losses = jax.lax.scan(step, (params, opt_state), batches)
    return params, losses


_run = jax.jit(_scan_steps,
               static_argnames=("loss_fn", "optimizer", "clip_norm"))


@partial(jax.jit, static_argnames=("loss_fn", "optimizer", "clip_norm"))
def _run_lps(params: PyTree, batches: dict, weights: jax.Array,
             loss_fn: LossFn, optimizer: optim.Optimizer,
             clip_norm: float) -> tuple[PyTree, jax.Array]:
    new_params, losses = jax.vmap(
        lambda b: _scan_steps(params, b, loss_fn, optimizer, clip_norm)
    )(batches)
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    avg = jax.tree.map(
        lambda x: jnp.tensordot(w, x.astype(jnp.float32),
                                axes=1).astype(x.dtype), new_params)
    return avg, losses


def local_update(params: PyTree, batches: dict, loss_fn: LossFn,
                 cfg: ClientConfig) -> tuple[PyTree, jax.Array]:
    """Run one client's local round.

    ``batches``: pytree of arrays with a leading ``steps`` axis (stacked
    mini-batches).  Returns (new_params, per-step losses).
    """
    return _run(params, batches, loss_fn, _make_opt(cfg), cfg.clip_norm)


def fused_lps_round(params: PyTree, batches: dict, weights: jax.Array,
                    loss_fn: LossFn, cfg: ClientConfig
                    ) -> tuple[PyTree, jax.Array]:
    """One LPS round — every client's local scan AND the FedAvg — in one jit.

    ``batches``: pytree with leading ``(clients, steps, batch, ...)`` axes
    (from ``make_batch_stack``); every client starts from the same
    ``params`` (the LPS broadcast) and the sample-count-``weights``ed
    average comes back, plus per-client per-step ``losses``.
    """
    return _run_lps(params, batches, jnp.asarray(weights), loss_fn,
                    _make_opt(cfg), cfg.clip_norm)


def make_batches(x, y, batch_size: int, steps: int, rng) -> dict:
    """Stack ``steps`` random mini-batches from (x, y) -> scan-ready pytree."""
    import numpy as np

    n = len(y)
    idx = rng.integers(0, n, size=(steps, min(batch_size, n)))
    return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}


def make_batch_stack(datasets: Sequence[tuple], batch_size: int,
                     steps: int, rng) -> dict:
    """Batches for a whole cluster -> ``(clients, steps, batch)`` pytree.

    ``datasets``: per-client ``(x, y)`` pairs.  Sampling is uniform WITH
    replacement so every client yields the same batch shape even when some
    hold fewer than ``batch_size`` samples (ragged clusters stay stackable).
    """
    import numpy as np

    xs, ys = [], []
    for x, y in datasets:
        idx = rng.integers(0, len(y), size=(steps, batch_size))
        xs.append(x[idx])
        ys.append(y[idx])
    return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}
