"""Client-side local update (the inner loop of FedAvg).

``local_update`` runs ``steps`` optimizer steps over pre-batched data with
``jax.lax.scan`` so one client round is a single jit-compiled call.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import optim

PyTree = Any
LossFn = Callable[[PyTree, dict], jax.Array]

__all__ = ["ClientConfig", "local_update", "make_batches"]


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    lr: float = 0.05
    optimizer: str = "sgd"          # sgd | momentum | adamw
    clip_norm: float = 0.0          # 0 disables
    weight_decay: float = 0.0


def _make_opt(cfg: ClientConfig) -> optim.Optimizer:
    if cfg.optimizer == "sgd":
        return optim.sgd(cfg.lr)
    if cfg.optimizer == "momentum":
        return optim.momentum(cfg.lr)
    if cfg.optimizer == "adamw":
        return optim.adamw(cfg.lr, weight_decay=cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")


@partial(jax.jit, static_argnames=("loss_fn", "optimizer", "clip_norm"))
def _run(params: PyTree, batches: dict, loss_fn: LossFn,
         optimizer: optim.Optimizer, clip_norm: float) -> tuple[PyTree, jax.Array]:
    opt_state = optimizer.init(params)

    def step(carry, batch):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        if clip_norm:
            grads = optim.clip_by_global_norm(grads, clip_norm)
        updates, s = optimizer.update(grads, s, p)
        p = optim.apply_updates(p, updates)
        return (p, s), loss

    (params, _), losses = jax.lax.scan(step, (params, opt_state), batches)
    return params, losses


def local_update(params: PyTree, batches: dict, loss_fn: LossFn,
                 cfg: ClientConfig) -> tuple[PyTree, jax.Array]:
    """Run one client's local round.

    ``batches``: pytree of arrays with a leading ``steps`` axis (stacked
    mini-batches).  Returns (new_params, per-step losses).
    """
    return _run(params, batches, loss_fn, _make_opt(cfg), cfg.clip_norm)


def make_batches(x, y, batch_size: int, steps: int, rng) -> dict:
    """Stack ``steps`` random mini-batches from (x, y) -> scan-ready pytree."""
    import numpy as np

    n = len(y)
    idx = rng.integers(0, n, size=(steps, min(batch_size, n)))
    return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
