"""Client-side local update (the inner loop of FedAvg).

``local_update`` runs ``steps`` optimizer steps over pre-batched data with
``jax.lax.scan`` so one client round is a single jit-compiled call.
``fused_lps_round`` vmaps that scan over a stacked client axis and folds
the FedAvg aggregation in, so one jit call performs a cluster's ENTIRE
local round.  ``masked_lps_round`` is the fully traceable variant the
fused MT-HFL trainer vmaps over a padded cluster axis: batch sampling
happens in-jit from per-client fold_in keys and the FedAvg is weighted by
a membership mask, so ragged and empty clusters need no Python branches.

Batch sampling is keyed, not stateful: ``sample_batch_indices`` derives
every mini-batch from ``(round_key, user_id)``, so the reference loop and
the fused trainer draw bit-identical batches regardless of cluster
iteration order (the parity contract of ``tests/test_trainer_parity.py``).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro import optim

PyTree = Any
LossFn = Callable[[PyTree, dict], jax.Array]

__all__ = ["ClientConfig", "local_update", "fused_lps_round",
           "masked_lps_round", "sample_batch_indices",
           "participation_mask",
           "make_keyed_batch_stack", "make_batches", "make_batch_stack"]

# fold_in tag separating the participation stream from the batch stream
# (both derive from the same per-cluster round key)
_PARTICIPATION_FOLD = 7451


@dataclasses.dataclass(frozen=True)
class ClientConfig:
    lr: float = 0.05
    optimizer: str = "sgd"          # sgd | momentum | adamw
    clip_norm: float = 0.0          # 0 disables
    weight_decay: float = 0.0


@functools.lru_cache(maxsize=None)
def _make_opt(cfg: ClientConfig) -> optim.Optimizer:
    # Cached so repeated rounds with the same ClientConfig reuse ONE
    # Optimizer object: the jits below take it as a static argument, and a
    # fresh (init, update) closure pair per call would be a cache miss —
    # i.e. a recompile on every round.
    if cfg.optimizer == "sgd":
        return optim.sgd(cfg.lr)
    if cfg.optimizer == "momentum":
        return optim.momentum(cfg.lr)
    if cfg.optimizer == "adamw":
        return optim.adamw(cfg.lr, weight_decay=cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")


def _scan_steps(params: PyTree, batches: dict, loss_fn: LossFn,
                optimizer: optim.Optimizer, clip_norm: float
                ) -> tuple[PyTree, jax.Array]:
    """``steps`` optimizer steps via lax.scan (one client, traceable)."""
    opt_state = optimizer.init(params)

    def step(carry, batch):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        if clip_norm:
            grads = optim.clip_by_global_norm(grads, clip_norm)
        updates, s = optimizer.update(grads, s, p)
        p = optim.apply_updates(p, updates)
        return (p, s), loss

    (params, _), losses = jax.lax.scan(step, (params, opt_state), batches)
    return params, losses


_run = jax.jit(_scan_steps,
               static_argnames=("loss_fn", "optimizer", "clip_norm"))


@partial(jax.jit, static_argnames=("loss_fn", "optimizer", "clip_norm"))
def _run_lps(params: PyTree, batches: dict, weights: jax.Array,
             loss_fn: LossFn, optimizer: optim.Optimizer,
             clip_norm: float) -> tuple[PyTree, jax.Array]:
    new_params, losses = jax.vmap(
        lambda b: _scan_steps(params, b, loss_fn, optimizer, clip_norm)
    )(batches)
    w = weights.astype(jnp.float32)
    w = w / jnp.sum(w)
    avg = jax.tree.map(
        lambda x: jnp.tensordot(w, x.astype(jnp.float32),
                                axes=1).astype(x.dtype), new_params)
    return avg, losses


def local_update(params: PyTree, batches: dict, loss_fn: LossFn,
                 cfg: ClientConfig) -> tuple[PyTree, jax.Array]:
    """Run one client's local round.

    ``batches``: pytree of arrays with a leading ``steps`` axis (stacked
    mini-batches).  Returns (new_params, per-step losses).
    """
    return _run(params, batches, loss_fn, _make_opt(cfg), cfg.clip_norm)


def fused_lps_round(params: PyTree, batches: dict, weights: jax.Array,
                    loss_fn: LossFn, cfg: ClientConfig
                    ) -> tuple[PyTree, jax.Array]:
    """One LPS round — every client's local scan AND the FedAvg — in one jit.

    ``batches``: pytree with leading ``(clients, steps, batch, ...)`` axes
    (from ``make_batch_stack``); every client starts from the same
    ``params`` (the LPS broadcast) and the sample-count-``weights``ed
    average comes back, plus per-client per-step ``losses``.
    """
    return _run_lps(params, batches, jnp.asarray(weights), loss_fn,
                    _make_opt(cfg), cfg.clip_norm)


def sample_batch_indices(key: jax.Array, steps: int, batch_size: int,
                         n: jax.Array | int) -> jax.Array:
    """``(steps, batch)`` uniform-with-replacement indices in ``[0, n)``.

    Traceable in ``n`` (padded batches carry per-client sample counts), so
    the same draw works host-side in the reference loop and in-jit under
    the fused trainer's vmap — the two paths see identical batches.
    """
    r = jax.random.randint(key, (steps, batch_size), 0, jnp.int32(2**31 - 1),
                           dtype=jnp.int32)
    return r % jnp.maximum(jnp.asarray(n, jnp.int32), 1)


def participation_mask(round_key: jax.Array, uids, rate) -> jax.Array:
    """Per-round straggler/dropout mask: client ``uid`` participates iff
    its keyed uniform draw clears ``rate`` (the expected dropout
    fraction).  Keyed off ``(round_key, uid)`` through a dedicated
    fold-in tag, so the draw is independent of the batch stream,
    invariant to cluster numbering, and IDENTICAL whether evaluated
    host-side (reference loop) or in-jit under the fused trainer's vmap
    — the same contract as ``sample_batch_indices``.  ``rate`` may be a
    traced scalar: ``rate == 0.0`` reproduces full participation
    exactly (uniform draws live in [0, 1)), so threading it through the
    fused super-stack costs no retrace.

    Returns a float32 ``(C,)`` mask, 1.0 = participating.
    """
    pk = jax.random.fold_in(round_key, _PARTICIPATION_FOLD)
    uids = jnp.asarray(uids, jnp.int32)
    draws = jax.vmap(
        lambda u: jax.random.uniform(jax.random.fold_in(pk, u)))(uids)
    return (draws >= rate).astype(jnp.float32)


def make_keyed_batch_stack(datasets: Sequence[tuple], uids: Sequence[int],
                           round_key: jax.Array, batch_size: int,
                           steps: int) -> dict:
    """Key-derived batches for a whole cluster -> ``(clients, steps, batch)``.

    The per-client key is ``fold_in(round_key, user_id)`` — exactly the
    derivation ``masked_lps_round`` performs in-jit, so the reference loop
    trains on the same samples as the fused trainer.
    """
    import numpy as np

    xs, ys = [], []
    for (x, y), uid in zip(datasets, uids):
        ck = jax.random.fold_in(round_key, int(uid))
        idx = np.asarray(sample_batch_indices(ck, steps, batch_size, len(y)))
        xs.append(np.asarray(x)[idx])
        ys.append(np.asarray(y)[idx])
    return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}


def masked_lps_round(params: PyTree, x: jax.Array, y: jax.Array,
                     n_per: jax.Array, uids: jax.Array, mask: jax.Array,
                     round_key: jax.Array, loss_fn: LossFn,
                     optimizer: optim.Optimizer, clip_norm: float,
                     steps: int, batch_size: int
                     ) -> tuple[PyTree, jax.Array]:
    """One cluster's LPS round over PADDED client slots — fully traceable.

    ``x (C_max, n_max, ...)`` / ``y (C_max, n_max)``: zero-padded client
    data; ``n_per (C_max,)`` true sample counts (>= 1 even on padding
    slots, they are weighted out); ``uids (C_max,)`` user ids keying the
    batch streams; ``mask (C_max,)`` 1.0 on real clients.  Batches are
    sampled in-jit from ``fold_in(round_key, uid)``, every slot runs the
    ``lax.scan`` local update, and the FedAvg weights are ``n_per * mask``
    so padding slots contribute exactly zero.  An all-masked (empty)
    cluster returns ``params`` unchanged and a NaN loss.

    Designed to be ``vmap``-ed over a leading cluster axis by the fused
    MT-HFL trainer; see ``repro.fed.trainer``.
    """

    def one_client(x_c, y_c, n_c, uid):
        ck = jax.random.fold_in(round_key, uid)
        idx = sample_batch_indices(ck, steps, batch_size, n_c)
        batches = {"x": x_c[idx], "y": y_c[idx]}
        return _scan_steps(params, batches, loss_fn, optimizer, clip_norm)

    new_params, losses = jax.vmap(one_client)(x, y, n_per, uids)

    w = n_per.astype(jnp.float32) * mask.astype(jnp.float32)
    total = jnp.sum(w)
    nonempty = total > 0
    wn = w / jnp.maximum(total, 1e-8)

    def fedavg_leaf(l, p0):
        # Padding slots trained on zero data; where() them out BEFORE the
        # contraction so a non-finite padded result cannot poison the
        # average (NaN * 0 == NaN).
        m = mask.reshape((-1,) + (1,) * (l.ndim - 1))
        lf = jnp.where(m > 0, l.astype(jnp.float32), 0.0)
        return jnp.where(nonempty, jnp.tensordot(wn, lf, axes=1),
                         p0.astype(jnp.float32)).astype(p0.dtype)

    avg = jax.tree.map(fedavg_leaf, new_params, params)
    loss_sum = jnp.sum(jnp.where(mask[:, None] > 0, losses, 0.0))
    loss_cnt = jnp.sum(mask) * losses.shape[1]
    mean_loss = jnp.where(nonempty, loss_sum / jnp.maximum(loss_cnt, 1.0),
                          jnp.nan)
    return avg, mean_loss


def make_batches(x, y, batch_size: int, steps: int, rng) -> dict:
    """Stack ``steps`` random mini-batches from (x, y) -> scan-ready pytree."""
    import numpy as np

    n = len(y)
    idx = rng.integers(0, n, size=(steps, min(batch_size, n)))
    return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}


def make_batch_stack(datasets: Sequence[tuple], batch_size: int,
                     steps: int, rng) -> dict:
    """Batches for a whole cluster -> ``(clients, steps, batch)`` pytree.

    ``datasets``: per-client ``(x, y)`` pairs.  Sampling is uniform WITH
    replacement so every client yields the same batch shape even when some
    hold fewer than ``batch_size`` samples (ragged clusters stay stackable).
    """
    import numpy as np

    xs, ys = [], []
    for x, y in datasets:
        idx = rng.integers(0, len(y), size=(steps, batch_size))
        xs.append(x[idx])
        ys.append(y[idx])
    return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}
