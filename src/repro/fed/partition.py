"""Common/task-specific parameter partition (paper §II-D).

The paper's MT-HFL shares only the *common representation layers* (the two
conv layers for its CNN; embedding + first K blocks for transformer archs)
with the GPS.  Parameters live in nested-dict pytrees; a partition is a
predicate over key paths.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Mapping

import jax
import jax.numpy as jnp

PyTree = Any
PathPred = Callable[[tuple[str, ...]], bool]

__all__ = ["tree_paths", "prefix_predicate", "split_params", "merge_params",
           "tree_path_map", "stack_layout", "admit_layout",
           "group_stack_layout"]


def tree_paths(tree: Mapping, prefix: tuple[str, ...] = ()) -> list[tuple[str, ...]]:
    """All leaf key-paths of a nested dict."""
    out = []
    for k, v in tree.items():
        p = prefix + (str(k),)
        if isinstance(v, Mapping):
            out.extend(tree_paths(v, p))
        else:
            out.append(p)
    return out


def prefix_predicate(prefixes: Iterable[str | tuple[str, ...]]) -> PathPred:
    """Predicate matching any path whose joined form starts with a prefix.

    ``prefix_predicate(["conv1", "conv2"])`` marks the paper-CNN common
    layers; ``prefix_predicate(["embed", "blocks/0", "blocks/1"])`` marks a
    transformer split.
    """
    norm = []
    for p in prefixes:
        if isinstance(p, tuple):
            p = "/".join(p)
        norm.append(p)

    def pred(path: tuple[str, ...]) -> bool:
        joined = "/".join(path)
        return any(joined == p or joined.startswith(p + "/") for p in norm)

    return pred


def tree_path_map(fn: Callable[[tuple[str, ...], Any], Any],
                  tree: Mapping, prefix: tuple[str, ...] = ()) -> dict:
    """Map ``fn(path, leaf)`` over a nested-dict pytree, keeping structure.

    Unlike ``split_params`` this never changes the tree shape, which makes
    it the right tool for in-jit transforms that must stay structurally
    stable (e.g. averaging only the common leaves of a cluster-stacked
    parameter tree inside ``shard_map``).
    """
    out = {}
    for k, v in tree.items():
        p = prefix + (str(k),)
        out[k] = (tree_path_map(fn, v, p) if isinstance(v, Mapping)
                  else fn(p, v))
    return out


def stack_layout(labels, n_clusters: int, c_max: int | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Membership layout of the ``(T, C_max, ...)`` super-stack from a
    cluster assignment — computed with jnp so device labels straight from
    the ``ClusterEngine`` cut never round-trip through host python loops.

    ``labels (N,)`` ints -> ``(rows (N,) i32, slot (N,) i32, mask
    (T, C_max) f32)``: ``slot[u]`` is user ``u``'s column inside its
    cluster's row, preserving original user order (stable within each
    cluster), and ``mask`` marks occupied slots.  Per-user payloads must
    scatter through the SANITIZED row index, ``stack.at[rows, slot]
    .set(values)``: out-of-range labels (including the ``-1`` unassigned
    convention, which raw jnp indexing would wrap into cluster T-1) get
    ``rows == n_clusters`` / ``slot == c_max``, which the scatter drops —
    the same behaviour as the host loop's ``l == t`` membership test.
    """
    labels = jnp.asarray(labels, jnp.int32)
    valid = (labels >= 0) & (labels < n_clusters)
    onehot = labels[:, None] == jnp.arange(n_clusters, dtype=jnp.int32)[None]
    slot = (jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1)[
        jnp.arange(labels.shape[0]), jnp.clip(labels, 0, n_clusters - 1)]
    largest = max(int(onehot.sum(axis=0).max()), 1)
    if c_max is None:
        c_max = largest
    elif c_max < largest:
        # an undersized stack would silently drop VALID users through the
        # same out-of-bounds scatter that drops invalid labels
        raise ValueError(f"c_max={c_max} < largest cluster size {largest}")
    rows = jnp.where(valid, labels, n_clusters).astype(jnp.int32)
    slot = jnp.where(valid, slot, c_max).astype(jnp.int32)
    mask = jnp.zeros((n_clusters, c_max), jnp.float32)
    mask = mask.at[rows, slot].set(1.0)
    return rows, slot, mask


def group_stack_layout(labels, group_ids, n_groups: int, n_clusters: int,
                       c_max: int | None = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array]:
    """Edge-grouped ``(G, T, C_max)`` super-stack layout for the
    hierarchical protocol (``core.hierarchy``): each edge server holds
    only ITS members of each global cluster, so the per-server trainer
    stack is the ``(T, C_max)`` slice ``mask[g]``.

    ``labels (N,)`` global cluster ids + ``group_ids (N,)`` edge groups
    -> ``(grows (N,), rows (N,), slot (N,), mask (G, T, C_max))`` with
    the same scatter contract as ``stack_layout``: per-user payloads go
    through ``stack.at[grows, rows, slot].set(values)`` and any invalid
    label or group id gets the out-of-range ``(G, T, C_max)`` sentinel
    triple, which the scatter drops.  ``c_max`` bounds the LARGEST
    per-group cluster (not the global cluster size — grouping is exactly
    what shrinks the rows), and an undersized value raises just like
    ``stack_layout``.
    """
    labels = jnp.asarray(labels, jnp.int32)
    gids = jnp.asarray(group_ids, jnp.int32)
    if labels.shape != gids.shape:
        raise ValueError(f"labels {labels.shape} and group_ids "
                         f"{gids.shape} must align")
    valid = ((labels >= 0) & (labels < n_clusters)
             & (gids >= 0) & (gids < n_groups))
    # One flat (group, cluster) index reuses stack_layout's stable-rank
    # and sentinel machinery wholesale.
    combined = jnp.where(valid, gids * n_clusters + labels, -1)
    _, slot, mask = stack_layout(combined, n_groups * n_clusters,
                                 c_max=c_max)
    grows = jnp.where(valid, gids, n_groups).astype(jnp.int32)
    rows = jnp.where(valid, labels, n_clusters).astype(jnp.int32)
    return grows, rows, slot, mask.reshape(n_groups, n_clusters, -1)


def admit_layout(mask, new_labels, n_clusters: int | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Warm-start hook: place newly admitted users into an EXISTING
    ``(T, C_max)`` super-stack layout WITHOUT changing its shape.

    ``_train_fused``'s compiled program is specialized on the static
    ``(T, C_max)`` stack shape, so arrivals admitted by the
    ``MembershipEngine`` must slot into the current mask rather than
    rebuild the layout — ``stack_layout`` on the grown population would
    generally grow ``C_max`` and force a retrace.  Each new user with
    label ``l`` takes row ``l``'s rank-th FREE column (stable rank among
    the wave's same-label users) — holes left by departed users are
    refilled, so churn does not leak stack columns.  Invalid labels
    (including the ``-1`` unassigned convention) get the same
    out-of-range ``(rows == T, slot == C_max)`` sentinel as
    ``stack_layout``, which per-user scatters drop.  A wave that
    overflows any row raises — growing the stack is a retrace the caller
    must opt into explicitly.

    Returns ``(rows (M,), slot (M,), mask (T, C_max))`` — the new users'
    scatter coordinates plus the updated occupancy mask.
    """
    mask = jnp.asarray(mask, jnp.float32)
    t, c_max = mask.shape
    if n_clusters is not None and n_clusters != t:
        raise ValueError(f"n_clusters={n_clusters} != mask rows {t}")
    labels = jnp.asarray(new_labels, jnp.int32)
    valid = (labels >= 0) & (labels < t)
    occ = mask.sum(axis=1).astype(jnp.int32)                   # (T,)
    onehot = labels[:, None] == jnp.arange(t, dtype=jnp.int32)[None]
    rank = (jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1)[
        jnp.arange(labels.shape[0]), jnp.clip(labels, 0, t - 1)]
    need = int((occ + onehot.sum(axis=0)).max()) if labels.size else 0
    if need > c_max:
        raise ValueError(
            f"admitting this wave needs {need} slots in a row but "
            f"C_max={c_max} — re-run stack_layout (retrace) to grow")
    # Stable argsort of each 0/1 row lists its FREE columns first, in
    # ascending order — free_cols[l, r] is row l's rank-r free column.
    free_cols = jnp.argsort(mask, axis=1, stable=True).astype(jnp.int32)
    slot = free_cols[jnp.clip(labels, 0, t - 1),
                     jnp.clip(rank, 0, c_max - 1)]
    rows = jnp.where(valid, labels, t).astype(jnp.int32)
    slot = jnp.where(valid, slot, c_max).astype(jnp.int32)
    return rows, slot, mask.at[rows, slot].set(1.0)


def split_params(params: Mapping, is_common: PathPred
                 ) -> tuple[dict, dict]:
    """Split a nested-dict pytree into (common, specific) sub-dicts.

    Every leaf goes to exactly one side; empty sub-dicts are pruned.
    """

    def go(node: Mapping, prefix: tuple[str, ...]) -> tuple[dict, dict]:
        com, spec = {}, {}
        for k, v in node.items():
            p = prefix + (str(k),)
            if isinstance(v, Mapping):
                c, s = go(v, p)
                if c:
                    com[k] = c
                if s:
                    spec[k] = s
            else:
                (com if is_common(p) else spec)[k] = v
        return com, spec

    return go(params, ())


def merge_params(common: Mapping, specific: Mapping) -> dict:
    """Inverse of ``split_params`` (disjoint deep merge)."""

    def go(a: Mapping, b: Mapping) -> dict:
        out = dict(a)
        for k, v in b.items():
            if k in out:
                if not (isinstance(out[k], Mapping) and isinstance(v, Mapping)):
                    raise ValueError(f"overlapping leaf at key {k!r}")
                out[k] = go(out[k], v)
            else:
                out[k] = v
        return out

    return go(common, specific)
