"""Common/task-specific parameter partition (paper §II-D).

The paper's MT-HFL shares only the *common representation layers* (the two
conv layers for its CNN; embedding + first K blocks for transformer archs)
with the GPS.  Parameters live in nested-dict pytrees; a partition is a
predicate over key paths.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Mapping

PyTree = Any
PathPred = Callable[[tuple[str, ...]], bool]

__all__ = ["tree_paths", "prefix_predicate", "split_params", "merge_params",
           "tree_path_map"]


def tree_paths(tree: Mapping, prefix: tuple[str, ...] = ()) -> list[tuple[str, ...]]:
    """All leaf key-paths of a nested dict."""
    out = []
    for k, v in tree.items():
        p = prefix + (str(k),)
        if isinstance(v, Mapping):
            out.extend(tree_paths(v, p))
        else:
            out.append(p)
    return out


def prefix_predicate(prefixes: Iterable[str | tuple[str, ...]]) -> PathPred:
    """Predicate matching any path whose joined form starts with a prefix.

    ``prefix_predicate(["conv1", "conv2"])`` marks the paper-CNN common
    layers; ``prefix_predicate(["embed", "blocks/0", "blocks/1"])`` marks a
    transformer split.
    """
    norm = []
    for p in prefixes:
        if isinstance(p, tuple):
            p = "/".join(p)
        norm.append(p)

    def pred(path: tuple[str, ...]) -> bool:
        joined = "/".join(path)
        return any(joined == p or joined.startswith(p + "/") for p in norm)

    return pred


def tree_path_map(fn: Callable[[tuple[str, ...], Any], Any],
                  tree: Mapping, prefix: tuple[str, ...] = ()) -> dict:
    """Map ``fn(path, leaf)`` over a nested-dict pytree, keeping structure.

    Unlike ``split_params`` this never changes the tree shape, which makes
    it the right tool for in-jit transforms that must stay structurally
    stable (e.g. averaging only the common leaves of a cluster-stacked
    parameter tree inside ``shard_map``).
    """
    out = {}
    for k, v in tree.items():
        p = prefix + (str(k),)
        out[k] = (tree_path_map(fn, v, p) if isinstance(v, Mapping)
                  else fn(p, v))
    return out


def split_params(params: Mapping, is_common: PathPred
                 ) -> tuple[dict, dict]:
    """Split a nested-dict pytree into (common, specific) sub-dicts.

    Every leaf goes to exactly one side; empty sub-dicts are pruned.
    """

    def go(node: Mapping, prefix: tuple[str, ...]) -> tuple[dict, dict]:
        com, spec = {}, {}
        for k, v in node.items():
            p = prefix + (str(k),)
            if isinstance(v, Mapping):
                c, s = go(v, p)
                if c:
                    com[k] = c
                if s:
                    spec[k] = s
            else:
                (com if is_common(p) else spec)[k] = v
        return com, spec

    return go(params, ())


def merge_params(common: Mapping, specific: Mapping) -> dict:
    """Inverse of ``split_params`` (disjoint deep merge)."""

    def go(a: Mapping, b: Mapping) -> dict:
        out = dict(a)
        for k, v in b.items():
            if k in out:
                if not (isinstance(out[k], Mapping) and isinstance(v, Mapping)):
                    raise ValueError(f"overlapping leaf at key {k!r}")
                out[k] = go(out[k], v)
            else:
                out[k] = v
        return out

    return go(common, specific)
