"""IFCA-style iterative clustered FL (Ghosh et al. [5]) — the literature
baseline the paper's one-shot algorithm is positioned against.

Protocol per round: the server broadcasts ALL T cluster models; every user
evaluates its local loss under each, joins the argmin cluster, runs local
steps from that model, and the server FedAvg-aggregates per cluster.
Cluster identities are re-estimated EVERY round (the paper's §I criticism:
early-round weights are uninformative and each round costs a full
model-parameter exchange per user — T models down, one up).

``run_ifca`` returns per-round cluster assignments + comm accounting, so
benchmarks can compare rounds-to-correct-clustering and bytes against the
one-shot ledger.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import client as fclient
from repro.fed.fedavg import fedavg

PyTree = Any

__all__ = ["IFCAConfig", "IFCAResult", "run_ifca"]


@dataclasses.dataclass(frozen=True)
class IFCAConfig:
    n_clusters: int
    rounds: int = 5
    local_steps: int = 10
    batch_size: int = 32
    client: fclient.ClientConfig = fclient.ClientConfig(lr=0.05)
    seed: int = 0


@dataclasses.dataclass
class IFCAResult:
    assignments: np.ndarray        # (rounds, N)
    per_user_bytes_per_round: int  # T models down + 1 up (fp32)
    final_params: list


def _n_params(tree: PyTree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def run_ifca(users: Sequence, init_fn: Callable[[jax.Array], PyTree],
             loss_fn: Callable[[PyTree, dict], jax.Array],
             label_fn: Callable, cfg: IFCAConfig) -> IFCAResult:
    """``users[i]`` needs ``.x``/``.n``; ``label_fn(user) -> y`` gives the
    training labels (global task labels; IFCA has no per-cluster heads
    until identities stabilize, so a shared label space is used)."""
    rng = np.random.default_rng(cfg.seed)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), cfg.n_clusters)
    models = [init_fn(k) for k in keys]
    eval_loss = jax.jit(loss_fn)

    history = []
    for _ in range(cfg.rounds):
        # --- assignment step: argmin local loss over the T models -------
        assign = []
        for u in users:
            y = label_fn(u)
            bx = jnp.asarray(u.x[: cfg.batch_size * 4])
            by = jnp.asarray(y[: cfg.batch_size * 4])
            losses = [float(eval_loss(m, {"x": bx, "y": by}))
                      for m in models]
            assign.append(int(np.argmin(losses)))
        assign = np.asarray(assign)
        history.append(assign)

        # --- local training + per-cluster aggregation -------------------
        new_models = []
        for t in range(cfg.n_clusters):
            members = [u for u, a in zip(users, assign) if a == t]
            if not members:
                new_models.append(models[t])
                continue
            updated, ns = [], []
            for u in members:
                batches = fclient.make_batches(
                    u.x, label_fn(u), cfg.batch_size, cfg.local_steps, rng)
                p, _ = fclient.local_update(models[t], batches, loss_fn,
                                            cfg.client)
                updated.append(p)
                ns.append(u.n)
            new_models.append(fedavg(updated, ns))
        models = new_models

    bytes_per_round = 4 * _n_params(models[0]) * (cfg.n_clusters + 1)
    return IFCAResult(assignments=np.stack(history),
                      per_user_bytes_per_round=bytes_per_round,
                      final_params=models)
