"""MT-HFL training loop (paper Algorithm 1).

Given per-user datasets and a cluster assignment (from the one-shot
algorithm, the random baseline, or the oracle), run:

  for each global round r in [G]:
    for each LPS t in [T]:                 # clusters
      for each local round:
        every client runs `local_steps` optimizer steps from the LPS model
        LPS FedAvg-aggregates its clients
    GPS averages the COMMON layers across LPSs, broadcasts back

The model is pluggable via a ``TaskModel`` bundle (init/loss/accuracy +
common-layer predicate), so the same trainer drives the paper's CNN/MLP and
the transformer zoo.

The per-cluster inner loop is fully vectorized: one
``fed_client.fused_lps_round`` call (vmap over stacked clients, lax.scan
over local steps, FedAvg folded in) performs a whole LPS round per jit
dispatch — see ``benchmarks/bench_kernels.py`` for the speedup vs the
per-client Python loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import client as fed_client
import repro.fed.fedavg as favg
from repro.fed import hierarchy as hier
from repro.fed import partition as part

PyTree = Any

__all__ = ["TaskModel", "MTHFLConfig", "MTHFLHistory", "train_mthfl"]


@dataclasses.dataclass(frozen=True)
class TaskModel:
    """Everything the trainer needs to know about one task's model."""

    init: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, dict], jax.Array]
    accuracy: Callable[[PyTree, np.ndarray, np.ndarray], float]
    is_common: part.PathPred


@dataclasses.dataclass(frozen=True)
class MTHFLConfig:
    global_rounds: int = 10
    local_rounds: int = 2          # LPS-level FedAvg rounds per global round
    local_steps: int = 10          # client optimizer steps per local round
    batch_size: int = 32
    client: fed_client.ClientConfig = fed_client.ClientConfig()
    seed: int = 0


@dataclasses.dataclass
class MTHFLHistory:
    """Per-global-round, per-cluster test accuracy + mean train loss."""

    accuracy: np.ndarray           # (G, T)
    train_loss: np.ndarray         # (G, T)
    labels: np.ndarray             # (N,) cluster assignment used


def train_mthfl(users: Sequence,                      # list[UserData-like]
                labels: Sequence[int],
                models: Sequence[TaskModel],
                eval_sets: Sequence[tuple[np.ndarray, np.ndarray]],
                cfg: MTHFLConfig,
                cluster_classes: Sequence[Sequence[int]] | None = None
                ) -> MTHFLHistory:
    """Run Algorithm 1.

    ``users[i]`` needs ``.x (n_i, m)``, ``.n`` and a training label vector
    via ``.local_label()`` remapped to the cluster's head — here we use the
    label map of the cluster the user is ASSIGNED to (misassigned users
    under random clustering train with the wrong head, which is exactly the
    degradation the paper measures).
    ``models[t]`` / ``eval_sets[t]``: per-cluster model bundle and held-out
    (x, y_local) test set.
    """
    labels = np.asarray(labels)
    n_clusters = len(models)
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    keys = jax.random.split(key, n_clusters)
    lps_params = [models[t].init(keys[t]) for t in range(n_clusters)]

    # Pre-compute per-user training labels remapped to the assigned
    # cluster's class list.  Each LPS t is dedicated to one task; under
    # random clustering misplaced users train against the wrong head,
    # which is the degradation the paper's baseline exhibits.  If the
    # caller does not pin ``cluster_classes``, infer them from the
    # majority task of each cluster's members.
    if cluster_classes is None:
        inferred: list[list[int] | None] = [None] * n_clusters
        for t in range(n_clusters):
            members = [u for u, l in zip(users, labels) if l == t]
            if members:
                counts: dict[tuple, int] = {}
                for u in members:
                    key_t = tuple(u.task_classes)
                    counts[key_t] = counts.get(key_t, 0) + 1
                inferred[t] = list(max(counts, key=counts.get))
            else:
                inferred[t] = list(range(10))
        cluster_classes = inferred
    else:
        cluster_classes = [list(c) for c in cluster_classes]

    def local_y(u, t):
        lut = {c: i for i, c in enumerate(cluster_classes[t])}
        return np.asarray([lut.get(int(c), 0) for c in u.y], dtype=np.int32)

    user_y = {u.user_id: local_y(u, int(t)) for u, t in zip(users, labels)}

    acc_hist = np.zeros((cfg.global_rounds, n_clusters))
    loss_hist = np.zeros((cfg.global_rounds, n_clusters))
    cluster_weights = [float(sum(u.n for u, l in zip(users, labels)
                                 if l == t)) or 1.0
                       for t in range(n_clusters)]

    # Per-cluster member datasets, gathered once: the hot loop below feeds
    # them to ``fused_lps_round`` — every client's lax.scan vmapped over a
    # stacked client axis plus the FedAvg, one jit call per LPS round
    # (instead of the seed's per-client Python loop).
    cluster_data = []
    for t in range(n_clusters):
        members = [u for u, l in zip(users, labels) if l == t]
        cluster_data.append((
            [(u.x, user_y[u.user_id]) for u in members],
            jnp.asarray([u.n for u in members], jnp.float32)
            if members else None))

    for g in range(cfg.global_rounds):
        for t in range(n_clusters):
            datasets, ns = cluster_data[t]
            if not datasets:
                continue
            p = lps_params[t]
            round_losses = []
            for _ in range(cfg.local_rounds):
                batches = fed_client.make_batch_stack(
                    datasets, cfg.batch_size, cfg.local_steps, rng)
                p, losses = fed_client.fused_lps_round(
                    p, batches, ns, models[t].loss_fn, cfg.client)
                round_losses.append(float(jnp.mean(losses)))
            lps_params[t] = p
            loss_hist[g, t] = float(np.mean(round_losses)) if round_losses else 0.0
        # GPS round: average common layers, broadcast.
        lps_params = hier.gps_aggregate(
            lps_params, cluster_weights, models[0].is_common)
        for t in range(n_clusters):
            ex, ey = eval_sets[t]
            acc_hist[g, t] = models[t].accuracy(lps_params[t], ex, ey)

    return MTHFLHistory(accuracy=acc_hist, train_loss=loss_hist,
                        labels=labels)
