"""MT-HFL training loop (paper Algorithm 1) — fused and reference paths.

Given per-user datasets and a cluster assignment (from the one-shot
algorithm, the random baseline, or the oracle), run:

  for each global round r in [G]:
    for each LPS t in [T]:                 # clusters
      for each local round:
        every client runs ``local_steps`` optimizer steps from the LPS model
        LPS FedAvg-aggregates its clients
    GPS averages the COMMON layers across LPSs, broadcasts back

The model is pluggable via a ``TaskModel`` bundle (init/loss/accuracy +
common-layer predicate), so the same trainer drives the paper's CNN/MLP and
the transformer zoo.

Two executions of the same semantics:

* **Fused** (default when the per-cluster models stack): all clusters are
  padded into one ``(T, C_max, ...)`` super-stack with a membership mask,
  ``masked_lps_round`` is vmapped over the cluster axis, local rounds run
  under ``lax.scan``, and the GPS common-layer average folds into the same
  program — ONE jit dispatch per global round (``cfg.scan_rounds`` makes it
  one for the whole run).  ``cfg.backend = "shard_map"`` shards the cluster
  axis over a device mesh (empty padding clusters square off the axis), the
  same backend-selection idiom as ``core/engine.py``.
* **Reference** (``fused=False``, or automatic fallback when cluster models
  do not stack): the retained host loop over clusters — the parity oracle
  for ``tests/test_trainer_parity.py`` and the baseline for
  ``benchmarks/bench_trainer.py``.

Both paths draw batches from the SAME per-cluster key streams, derived from
``cfg.seed`` and the cluster's (sorted) member user ids — never from a
shared mutable RNG — so results are independent of cluster iteration order
and the two paths train on bit-identical batches.

Masking rules (identical in both paths): an empty cluster never trains, has
weight 0 in the GPS average (it still receives the common broadcast), and
reports NaN accuracy / train loss; a misassigned user still trains against
the wrong cluster head (exactly the degradation the paper measures).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.fed import client as fed_client
from repro.fed import hierarchy as hier
from repro.fed import partition as part

PyTree = Any

__all__ = ["TaskModel", "MTHFLConfig", "MTHFLHistory", "train_mthfl",
           "TRAINER_BACKENDS"]

TRAINER_BACKENDS = ("jnp", "shard_map")


@dataclasses.dataclass(frozen=True)
class TaskModel:
    """Everything the trainer needs to know about one task's model."""

    init: Callable[[jax.Array], PyTree]
    loss_fn: Callable[[PyTree, dict], jax.Array]
    accuracy: Callable[[PyTree, np.ndarray, np.ndarray], float]
    is_common: part.PathPred


@dataclasses.dataclass(frozen=True)
class MTHFLConfig:
    global_rounds: int = 10
    local_rounds: int = 2          # LPS-level FedAvg rounds per global round
    local_steps: int = 10          # client optimizer steps per local round
    batch_size: int = 32
    client: fed_client.ClientConfig = fed_client.ClientConfig()
    seed: int = 0
    backend: str = "jnp"           # fused execution: jnp | shard_map
    mesh_axis: str = "clusters"    # mesh axis the cluster dim shards over
    scan_rounds: bool = False      # fused: lax.scan the GLOBAL rounds too
    dropout_frac: float = 0.0      # per-global-round straggler/dropout rate


@dataclasses.dataclass
class MTHFLHistory:
    """Per-global-round, per-cluster test accuracy + mean train loss.

    Empty (memberless) clusters are NaN in both columns.  ``fused`` records
    which execution path produced the history.
    """

    accuracy: np.ndarray           # (G, T)
    train_loss: np.ndarray         # (G, T)
    labels: np.ndarray             # (N,) cluster assignment used
    fused: bool = False


# ---------------------------------------------------------------------------
# Shared setup: cluster membership, label remapping, per-cluster key streams
# ---------------------------------------------------------------------------

def _cluster_base_key(seed: int, member_uids: Sequence[int],
                      t: int) -> jax.Array:
    """Per-cluster PRNG stream root.

    Derived from ``seed`` and the SORTED member user ids, so the stream a
    group of users trains under is invariant to how clusters happen to be
    numbered (determinism under cluster relabeling); an empty cluster falls
    back to its index, which only seeds its unused init params.
    """
    key = jax.random.PRNGKey(seed)
    if len(member_uids):
        for uid in sorted(int(u) for u in member_uids):
            key = jax.random.fold_in(key, uid + 1)
    else:
        key = jax.random.fold_in(key, 0)
        key = jax.random.fold_in(key, t)
    return key


@dataclasses.dataclass
class _ClusterSetup:
    members: list[list]            # per-cluster member UserData lists
    datasets: list[list[tuple]]    # per-cluster [(x, y_local)] pairs
    uids: list[list[int]]
    n_samples: list[list[int]]
    cluster_weights: list[float]   # total samples; 0.0 for empty clusters
    init_keys: list[jax.Array]
    data_keys: list[jax.Array]
    cluster_classes: list[list[int]]


def _setup_clusters(users, labels: np.ndarray, n_clusters: int, seed: int,
                    cluster_classes) -> _ClusterSetup:
    # Per-user training labels remapped to the assigned cluster's class
    # list.  Each LPS t is dedicated to one task; under random clustering
    # misplaced users train against the wrong head, which is the
    # degradation the paper's baseline exhibits.  If the caller does not
    # pin ``cluster_classes``, infer them from the majority task of each
    # cluster's members.
    members = [[u for u, l in zip(users, labels) if l == t]
               for t in range(n_clusters)]
    if cluster_classes is None:
        inferred: list[list[int]] = []
        for t in range(n_clusters):
            counts: dict[tuple, int] = {}
            for u in members[t]:
                key_t = tuple(u.task_classes)
                counts[key_t] = counts.get(key_t, 0) + 1
            inferred.append(list(max(counts, key=counts.get)) if counts
                            else list(range(10)))
        cluster_classes = inferred
    else:
        cluster_classes = [list(c) for c in cluster_classes]

    def local_y(u, t):
        lut = {c: i for i, c in enumerate(cluster_classes[t])}
        return np.asarray([lut.get(int(c), 0) for c in u.y], dtype=np.int32)

    datasets = [[(u.x, local_y(u, t)) for u in members[t]]
                for t in range(n_clusters)]
    base = [_cluster_base_key(seed, [u.user_id for u in members[t]], t)
            for t in range(n_clusters)]
    return _ClusterSetup(
        members=members,
        datasets=datasets,
        uids=[[int(u.user_id) for u in members[t]]
              for t in range(n_clusters)],
        n_samples=[[int(u.n) for u in members[t]] for t in range(n_clusters)],
        cluster_weights=[float(sum(u.n for u in members[t]))
                         for t in range(n_clusters)],
        init_keys=[jax.random.fold_in(k, 0) for k in base],
        data_keys=[jax.random.fold_in(k, 1) for k in base],
        cluster_classes=cluster_classes,
    )


def _stackable(params_list: Sequence[PyTree]) -> bool:
    """True iff every cluster's params share structure, shapes and dtypes —
    the precondition for the ``(T, ...)`` super-stack."""
    ref = jax.tree.structure(params_list[0])
    ref_leaves = [(l.shape, l.dtype) for l in jax.tree.leaves(params_list[0])]
    for p in params_list[1:]:
        if jax.tree.structure(p) != ref:
            return False
        if [(l.shape, l.dtype) for l in jax.tree.leaves(p)] != ref_leaves:
            return False
    return True


# ---------------------------------------------------------------------------
# Fused path: one device-resident program per global round (or per run)
# ---------------------------------------------------------------------------

def _round_body(p_stack, g, x, y, n_per, uids, mask, dkeys, cluster_w,
                part_rate, *,
                loss_fn, optimizer, clip_norm, steps, batch_size,
                local_rounds, is_common, axis):
    """One GLOBAL round, traceable: scan local rounds (each local round =
    masked LPS round vmapped over the cluster axis), then the in-jit GPS
    common-layer average.  ``axis`` names the mesh axis when the cluster
    dim is sharded under shard_map.

    ``part_rate`` is a TRACED dropout scalar: a per-global-round keyed
    participation draw (``fed_client.participation_mask``) folds into
    the existing membership-mask weighting, so stragglers/dropouts cost
    no retrace — at rate 0.0 the mask is untouched and the program is
    bit-identical to the no-dropout one.  A fully-dropped cluster keeps
    its params (``masked_lps_round``'s empty-mask path) and reports a
    NaN round loss, exactly like an empty cluster."""

    def local_round(p, l):
        def per_cluster(p_t, dk, x_t, y_t, n_t, uid_t, m_t):
            rk_g = jax.random.fold_in(dk, g)
            m_eff = m_t * fed_client.participation_mask(rk_g, uid_t,
                                                        part_rate)
            rk = jax.random.fold_in(rk_g, l)
            return fed_client.masked_lps_round(
                p_t, x_t, y_t, n_t, uid_t, m_eff, rk, loss_fn, optimizer,
                clip_norm, steps, batch_size)

        return jax.vmap(per_cluster)(p, dkeys, x, y, n_per, uids, mask)

    p_stack, losses = jax.lax.scan(local_round, p_stack,
                                   jnp.arange(local_rounds))
    mean_loss = jnp.mean(losses, axis=0)                     # (T,)
    p_stack = hier.gps_aggregate_stacked(p_stack, cluster_w, is_common,
                                         axis=axis)
    return p_stack, mean_loss


def _run_scanned(p_stack, x, y, n_per, uids, mask, dkeys, cluster_w,
                 part_rate, *, global_rounds, **kw):
    """The whole run in one program: scan ``_round_body`` over the global
    rounds, emitting each round's params for host-side evaluation."""

    def body(p, g):
        p, loss = _round_body(p, g, x, y, n_per, uids, mask, dkeys,
                              cluster_w, part_rate, **kw)
        return p, (loss, p)

    _, (losses, stacks) = jax.lax.scan(body, p_stack,
                                       jnp.arange(global_rounds))
    return losses, stacks                                    # (G, T), (G,T,…)


_STATICS = ("loss_fn", "optimizer", "clip_norm", "steps", "batch_size",
            "local_rounds", "is_common")

_fused_global_round = partial(jax.jit, static_argnames=_STATICS)(
    partial(_round_body, axis=None))
_fused_run = partial(jax.jit, static_argnames=_STATICS + ("global_rounds",))(
    partial(_run_scanned, axis=None))


@functools.lru_cache(maxsize=64)
def _sharded_round_fn(mesh: Mesh, axis: str, statics_vals: tuple):
    """shard_map + jit of one global round, cached so repeated train calls
    with the same mesh/model bundle reuse the compiled program (Mesh and
    the static values hash by value / identity)."""
    statics = dict(zip(_STATICS, statics_vals))
    spec_c = P(axis)
    return jax.jit(shard_map(
        partial(_round_body, **statics, axis=axis), mesh=mesh,
        in_specs=(spec_c, P()) + (spec_c,) * 7 + (P(),),
        out_specs=(spec_c, spec_c), check_rep=False))


@functools.lru_cache(maxsize=64)
def _sharded_run_fn(mesh: Mesh, axis: str, statics_vals: tuple,
                    global_rounds: int):
    statics = dict(zip(_STATICS, statics_vals))
    spec_c = P(axis)
    return jax.jit(shard_map(
        partial(_run_scanned, **statics, axis=axis,
                global_rounds=global_rounds),
        mesh=mesh, in_specs=(spec_c,) * 8 + (P(),),
        out_specs=(P(None, axis), P(None, axis)), check_rep=False))


def _pad_clusters(stacks: PyTree, n_pad: int) -> PyTree:
    """Append ``n_pad`` dummy clusters (first cluster repeated) so the
    cluster axis divides the mesh; their mask/weights are zeroed by the
    caller so they never train and never contribute to the GPS average."""
    if n_pad == 0:
        return stacks
    return jax.tree.map(
        lambda l: jnp.concatenate(
            [l, jnp.repeat(l[:1], n_pad, axis=0)], axis=0), stacks)


def _train_fused(users, labels, models, eval_sets, cfg: MTHFLConfig,
                 setup: _ClusterSetup, lps_params: list[PyTree],
                 mesh: Mesh | None) -> MTHFLHistory:
    n_clusters = len(models)
    c_max = max(1, max(len(m) for m in setup.members))
    all_members = [u for ms in setup.members for u in ms]
    n_max = max(1, max((int(u.n) for u in all_members), default=1))
    sample_shape = (all_members[0].x.shape[1:] if all_members else (1,))

    # Membership layout of the super-stack comes from the label vector via
    # jnp ops instead of host python loops (train_mthfl's entry asarray is
    # the one remaining host sync — member bookkeeping needs it).  The
    # slot order matches _setup_clusters' member lists (stable original
    # user order), so the ragged x/y copies below land in the same cells.
    labels_dev = jnp.asarray(labels, jnp.int32)
    rows, slot, mask = part.stack_layout(labels_dev, n_clusters, c_max)
    uid_all = jnp.asarray([int(u.user_id) for u in users], jnp.int32)
    n_all = jnp.asarray([float(u.n) for u in users], jnp.float32)
    uid_stack = jnp.zeros((n_clusters, c_max), jnp.int32
                          ).at[rows, slot].set(uid_all)
    n_stack = jnp.ones((n_clusters, c_max), jnp.float32  # pads: n=1, masked
                       ).at[rows, slot].set(n_all)

    x_np = np.zeros((n_clusters, c_max, n_max) + tuple(sample_shape),
                    np.float32)
    y_np = np.zeros((n_clusters, c_max, n_max), np.int32)
    for t in range(n_clusters):
        for c, ((x, y), n) in enumerate(zip(setup.datasets[t],
                                            setup.n_samples[t])):
            x_np[t, c, :n] = x
            y_np[t, c, :n] = y

    p_stack = jax.tree.map(lambda *ls: jnp.stack(ls), *lps_params)
    data = dict(x=jnp.asarray(x_np), y=jnp.asarray(y_np),
                n_per=n_stack, uids=uid_stack,
                mask=mask,
                dkeys=jnp.stack(setup.data_keys),
                cluster_w=jnp.asarray(setup.cluster_weights, jnp.float32))
    statics = dict(loss_fn=models[0].loss_fn,
                   optimizer=fed_client._make_opt(cfg.client),
                   clip_norm=cfg.client.clip_norm, steps=cfg.local_steps,
                   batch_size=cfg.batch_size, local_rounds=cfg.local_rounds,
                   is_common=models[0].is_common)

    n_pad = 0
    if cfg.backend == "shard_map":
        axis = cfg.mesh_axis
        mesh = mesh or Mesh(np.asarray(jax.devices()), (axis,))
        n_dev = mesh.shape[axis]
        n_pad = (-n_clusters) % n_dev
        p_stack = _pad_clusters(p_stack, n_pad)
        data = {k: _pad_clusters(v, n_pad) for k, v in data.items()}
        # Padding clusters must be inert: no members, no GPS weight.
        data["mask"] = data["mask"].at[n_clusters:].set(0.0)
        data["cluster_w"] = data["cluster_w"].at[n_clusters:].set(0.0)
        # Shard the cluster axis NOW: round outputs come back with this
        # sharding, so placing the inputs up front keeps every round on one
        # compiled signature (no host->device reshard between rounds).
        shard_c = NamedSharding(mesh, P(axis))
        p_stack = jax.device_put(p_stack, shard_c)
        data = {k: jax.device_put(v, shard_c) for k, v in data.items()}
        statics_vals = tuple(statics[k] for k in _STATICS)
        round_fn = _sharded_round_fn(mesh, axis, statics_vals)
        run_fn = _sharded_run_fn(mesh, axis, statics_vals,
                                 cfg.global_rounds)
    else:
        body_statics = {k: statics[k] for k in _STATICS}
        round_fn = partial(_fused_global_round, **body_statics)
        run_fn = partial(_fused_run, **body_statics,
                         global_rounds=cfg.global_rounds)

    # Dropout rate rides as a TRACED scalar (replicated under shard_map):
    # changing it between runs re-dispatches, never retraces.
    part_rate = jnp.asarray(cfg.dropout_frac, jnp.float32)
    args = (data["x"], data["y"], data["n_per"], data["uids"], data["mask"],
            data["dkeys"], data["cluster_w"], part_rate)

    acc_hist = np.zeros((cfg.global_rounds, n_clusters))
    loss_hist = np.zeros((cfg.global_rounds, n_clusters))
    empty = [not setup.members[t] for t in range(n_clusters)]

    def eval_round(g, stack):
        for t in range(n_clusters):
            if empty[t]:
                acc_hist[g, t] = np.nan
                continue
            p_t = jax.tree.map(lambda l: l[t], stack)
            ex, ey = eval_sets[t]
            acc_hist[g, t] = models[t].accuracy(p_t, ex, ey)

    if cfg.scan_rounds:
        with obs.span("trainer.scan_rounds",
                      rounds=cfg.global_rounds) as sp:
            losses, stacks = run_fn(p_stack, *args)
            sp.sync((losses, stacks))
        loss_hist[:] = np.asarray(losses)[:, :n_clusters]
        for g in range(cfg.global_rounds):
            eval_round(g, jax.tree.map(lambda l: l[g], stacks))
    else:
        with obs.span("trainer.rounds", rounds=cfg.global_rounds) as sp:
            for g in range(cfg.global_rounds):
                p_stack, loss = round_fn(p_stack, jnp.asarray(g, jnp.int32),
                                         *args)
                loss_hist[g] = np.asarray(loss)[:n_clusters]
                eval_round(g, p_stack)
            sp.sync(p_stack)

    return MTHFLHistory(accuracy=acc_hist, train_loss=loss_hist,
                        labels=labels, fused=True)


# ---------------------------------------------------------------------------
# Reference path: the retained host loop (parity oracle + bench baseline)
# ---------------------------------------------------------------------------

def _train_reference(users, labels, models, eval_sets, cfg: MTHFLConfig,
                     setup: _ClusterSetup, lps_params: list[PyTree]
                     ) -> MTHFLHistory:
    n_clusters = len(models)
    acc_hist = np.zeros((cfg.global_rounds, n_clusters))
    loss_hist = np.zeros((cfg.global_rounds, n_clusters))
    any_weight = sum(setup.cluster_weights) > 0

    for g in range(cfg.global_rounds):
        for t in range(n_clusters):
            if not setup.datasets[t]:
                loss_hist[g, t] = np.nan
                continue
            p = lps_params[t]
            rk_g = jax.random.fold_in(setup.data_keys[t], g)
            # Same keyed per-round participation draw as the fused path;
            # dropped clients keep weight 0 in the FedAvg and are
            # excluded from the round loss.
            pmask = np.asarray(fed_client.participation_mask(
                rk_g, setup.uids[t], cfg.dropout_frac))
            if pmask.sum() == 0:               # whole cluster dropped
                loss_hist[g, t] = np.nan
                continue
            ns = jnp.asarray(setup.n_samples[t], jnp.float32) \
                * jnp.asarray(pmask)
            round_losses = []
            for l in range(cfg.local_rounds):
                rk = jax.random.fold_in(rk_g, l)
                batches = fed_client.make_keyed_batch_stack(
                    setup.datasets[t], setup.uids[t], rk, cfg.batch_size,
                    cfg.local_steps)
                p, losses = fed_client.fused_lps_round(
                    p, batches, ns, models[t].loss_fn, cfg.client)
                round_losses.append(
                    float(np.mean(np.asarray(losses)[pmask > 0])))
            lps_params[t] = p
            loss_hist[g, t] = float(np.mean(round_losses))
        # GPS round: average common layers, broadcast (empty clusters carry
        # weight 0; skipped entirely in the degenerate all-empty case).
        if any_weight:
            lps_params = hier.gps_aggregate(
                lps_params, setup.cluster_weights, models[0].is_common)
        for t in range(n_clusters):
            if not setup.datasets[t]:
                acc_hist[g, t] = np.nan
                continue
            ex, ey = eval_sets[t]
            acc_hist[g, t] = models[t].accuracy(lps_params[t], ex, ey)

    return MTHFLHistory(accuracy=acc_hist, train_loss=loss_hist,
                        labels=labels, fused=False)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def train_mthfl(users: Sequence,                      # list[UserData-like]
                labels: Sequence[int],
                models: Sequence[TaskModel],
                eval_sets: Sequence[tuple[np.ndarray, np.ndarray]],
                cfg: MTHFLConfig,
                cluster_classes: Sequence[Sequence[int]] | None = None,
                *,
                fused: bool | str = "auto",
                mesh: Mesh | None = None) -> MTHFLHistory:
    """Run Algorithm 1.

    ``users[i]`` needs ``.x (n_i, m)``, ``.n``, ``.user_id``, ``.y`` and
    ``.task_classes``; training labels are remapped to the head of the
    cluster the user is ASSIGNED to (misassigned users under random
    clustering train with the wrong head, which is exactly the degradation
    the paper measures).
    ``labels`` may be a host sequence or a device ``jax.Array`` straight
    from the ``ClusterEngine`` cut — the fused path derives the
    super-stack membership layout from it via ``partition.stack_layout``
    (one host sync remains for the ragged per-user data copies).
    ``models[t]`` / ``eval_sets[t]``: per-cluster model bundle and held-out
    (x, y_local) test set.

    ``fused``: ``"auto"`` (default) runs the fused super-stack program when
    every cluster's params stack (same structure/shapes/dtypes) and falls
    back to the reference loop otherwise; ``True`` requires stackability
    (raises if violated — the fused path also assumes the per-cluster
    ``loss_fn``/``is_common`` are replicas, and uses ``models[0]``'s);
    ``False`` forces the reference loop.  ``cfg.backend`` picks the fused
    execution (``"jnp"`` single jit, ``"shard_map"`` cluster axis sharded
    over ``mesh`` — defaults to a 1-D mesh over all local devices).
    """
    labels = np.asarray(labels)
    n_clusters = len(models)
    if cfg.backend not in TRAINER_BACKENDS:
        raise ValueError(f"cfg.backend must be one of {TRAINER_BACKENDS}, "
                         f"got {cfg.backend!r}")
    if not 0.0 <= cfg.dropout_frac < 1.0:
        raise ValueError("cfg.dropout_frac must be in [0, 1), got "
                         f"{cfg.dropout_frac!r}")
    setup = _setup_clusters(users, labels, n_clusters, cfg.seed,
                            cluster_classes)
    lps_params = [models[t].init(setup.init_keys[t])
                  for t in range(n_clusters)]

    can_fuse = _stackable(lps_params)
    if fused == "auto":
        use_fused = can_fuse
    elif fused:
        if not can_fuse:
            raise ValueError(
                "fused=True requires every cluster's params to stack — "
                "same structure, shapes and dtypes (got heterogeneous "
                "models); use fused='auto' to fall back to the reference "
                "loop")
        use_fused = True
    else:
        use_fused = False

    with obs.span("trainer.train_mthfl", fused=use_fused,
                  backend=cfg.backend, rounds=cfg.global_rounds):
        if use_fused:
            hist = _train_fused(users, labels, models, eval_sets, cfg,
                                setup, lps_params, mesh)
        else:
            hist = _train_reference(users, labels, models, eval_sets, cfg,
                                    setup, lps_params)
    if obs.enabled():
        obs.count("trainer.runs")
        obs.count("trainer.global_rounds", cfg.global_rounds)
    return hist
