"""FedAvg aggregation primitives (McMahan et al., AISTATS'17)."""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["weighted_mean", "fedavg"]


def weighted_mean(trees: Sequence[PyTree], weights: Sequence[float]) -> PyTree:
    """Sample-count-weighted average of parameter pytrees."""
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)

    def avg(*leaves):
        acc = sum(wi * l.astype(jnp.float32) for wi, l in zip(w, leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)


def fedavg(client_params: Sequence[PyTree],
           n_samples: Sequence[int]) -> PyTree:
    """Standard FedAvg: average client models weighted by local sample count."""
    return weighted_mean(client_params, [float(n) for n in n_samples])
