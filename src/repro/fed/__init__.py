"""Federated substrate: clients, FedAvg, LPS/GPS hierarchy, MT-HFL trainer."""
from repro.fed.partition import (split_params, merge_params, prefix_predicate,
                                 tree_paths)
from repro.fed.fedavg import weighted_mean  # (fedavg stays module-scoped:
# re-exporting the function here would shadow the submodule binding)
from repro.fed.client import local_update, ClientConfig
from repro.fed.hierarchy import (lps_round, gps_aggregate, masked_cluster_mean)
from repro.fed.trainer import MTHFLConfig, train_mthfl, MTHFLHistory
