"""LPS/GPS hierarchical aggregation (paper §II-D, Algorithm 1).

Two modes:

* **Simulation** (host loop over users): ``lps_round`` aggregates each
  cluster's clients with FedAvg; ``gps_aggregate`` averages the *common*
  sub-tree across LPSs (weighted by cluster sample counts) and grafts it
  back into every LPS model — exactly the paper's "share the weights of the
  first common layers with the GPS ... aggregate ... broadcast back".

* **Distributed** (shard_map): cluster membership is data-dependent, so LPS
  groups cannot be static mesh axes.  ``masked_cluster_mean`` computes all
  per-cluster means in ONE batched collective: a one-hot membership matrix
  turns per-cluster FedAvg into ``einsum('u...,ut->t...') / counts`` followed
  by a single ``psum`` over the user axis — the TPU-idiomatic form of the
  paper's LPS message exchange (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.fed.fedavg import fedavg as _fedavg, weighted_mean as _wmean
from repro.fed import partition as part

PyTree = Any

__all__ = ["lps_round", "gps_aggregate", "gps_aggregate_stacked",
           "masked_cluster_mean"]


def lps_round(cluster_client_params: Sequence[PyTree],
              n_samples: Sequence[int]) -> PyTree:
    """One LPS aggregation: FedAvg over the cluster's clients."""
    return _fedavg(cluster_client_params, n_samples)


def gps_aggregate(lps_params: Sequence[PyTree],
                  cluster_weights: Sequence[float],
                  is_common: part.PathPred) -> list[PyTree]:
    """GPS round: average common layers across LPSs, broadcast back.

    Returns the new per-LPS parameter pytrees (common part replaced by the
    global average, task-specific part untouched).
    """
    splits = [part.split_params(p, is_common) for p in lps_params]
    commons = [c for c, _ in splits]
    specifics = [s for _, s in splits]
    avg_common = _wmean(commons, list(cluster_weights))
    return [part.merge_params(avg_common, s) for s in specifics]


def gps_aggregate_stacked(stack: PyTree, cluster_weights: jax.Array,
                          is_common: part.PathPred,
                          axis: str | None = None) -> PyTree:
    """In-jit GPS round over CLUSTER-STACKED params (leaves ``(T, ...)``).

    The traceable counterpart of ``gps_aggregate`` used by the fused
    MT-HFL trainer: common leaves are replaced by their
    ``cluster_weights``-weighted mean over the leading cluster axis and
    broadcast back; task-specific leaves pass through untouched.  Empty
    clusters carry weight 0 and so are excluded from the average (they
    still RECEIVE the broadcast common part, like any LPS).

    ``axis``: mesh axis to psum over when the cluster axis is sharded
    under ``shard_map`` (same idiom as ``masked_cluster_mean``); ``None``
    for single-host.  If every weight is zero the stack is returned
    unchanged.
    """
    w = jnp.asarray(cluster_weights, jnp.float32)
    total = jnp.sum(w)
    if axis is not None:
        total = jax.lax.psum(total, axis)
    wn = w / jnp.maximum(total, 1e-8)

    def leaf(path, v):
        if not is_common(path):
            return v
        num = jnp.tensordot(wn, v.astype(jnp.float32), axes=1)
        if axis is not None:
            num = jax.lax.psum(num, axis)
        avg = jnp.broadcast_to(num[None], v.shape)
        return jnp.where(total > 0, avg, v.astype(jnp.float32)).astype(v.dtype)

    return part.tree_path_map(leaf, stack)


def masked_cluster_mean(values: PyTree, onehot: jax.Array,
                        weights: jax.Array, axis: str | None = None) -> PyTree:
    """Batched per-cluster weighted mean (all LPS FedAvgs in one shot).

    ``values``: pytree of arrays with leading user axis ``(U, ...)`` (the
    local shard when used inside shard_map).
    ``onehot (U, T)``: cluster membership; ``weights (U,)``: sample counts.
    ``axis``: mesh axis name to psum over (inside shard_map), or None for
    single-host.

    Returns a pytree with leading cluster axis ``(T, ...)``.
    """
    w = onehot * weights[:, None]                       # (U, T)
    denom = jnp.sum(w, axis=0)                          # (T,)
    if axis is not None:
        denom = jax.lax.psum(denom, axis)
    denom = jnp.maximum(denom, 1e-8)

    def reduce_leaf(v):
        vf = v.astype(jnp.float32)
        num = jnp.einsum("u...,ut->t...", vf, w)
        if axis is not None:
            num = jax.lax.psum(num, axis)
        out = num / denom.reshape((-1,) + (1,) * (num.ndim - 1))
        return out.astype(v.dtype)

    return jax.tree.map(reduce_leaf, values)
