"""SeamlessM4T-large-v2 backbone — encoder-decoder, multimodal
[arXiv:2308.11596].

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16, MHA),
d_ff=8192, vocab=256206, GELU FFN.  The speech frontend (mel +
conv feature extractor) is a STUB: ``frames (B, S_src, d_model)`` are
precomputed frame embeddings (assignment carve-out).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", arch_type="audio",
    n_layers=24, encoder_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, mlp_variant="gelu",
    source="arXiv:2308.11596",
)

REDUCED = ArchConfig(
    name="seamless-m4t-reduced", arch_type="audio",
    n_layers=2, encoder_layers=2,
    d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab=512, mlp_variant="gelu",
    param_dtype="float32", act_dtype="float32", remat=False,
    source="arXiv:2308.11596",
)
