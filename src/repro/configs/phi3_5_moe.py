"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts, top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), per-expert d_ff=6400, vocab=32064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab=32064, mlp_variant="swiglu",
    n_experts=16, moe_top_k=2,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

REDUCED = ArchConfig(
    name="phi3.5-moe-reduced", arch_type="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=256, vocab=512, mlp_variant="swiglu",
    n_experts=4, moe_top_k=2,
    param_dtype="float32", act_dtype="float32", remat=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
