"""RecurrentGemma-9B — hybrid RG-LRU + local attention, 1 attn : 2 rec
[arXiv:2402.19427].

38L, d_model=4096, 16 heads (MQA: kv=1), d_ff=12288, vocab=256000,
local attention window 2048, RG-LRU width = d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000, mlp_variant="swiglu",
    block_pattern=("rec", "rec", "attn"), local_window=2048, d_rnn=4096,
    source="arXiv:2402.19427",
)

REDUCED = ArchConfig(
    name="recurrentgemma-9b-reduced", arch_type="hybrid",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, head_dim=64,
    d_ff=512, vocab=512, mlp_variant="swiglu",
    block_pattern=("rec", "attn"), local_window=64, d_rnn=256,
    param_dtype="float32", act_dtype="float32", remat=False,
    source="arXiv:2402.19427",
)
