"""Architecture config schema + input-shape table + registry.

Every assigned architecture has a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact assigned sizes, source cited) and ``REDUCED`` (<=2 layers,
d_model<=512, <=4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "get_arch",
           "list_archs", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    mlp_variant: str = "swiglu"      # swiglu | gelu
    rope_theta: float = 10000.0
    # --- attention variant ---
    attn_window: int = 0             # 0 = full causal; >0 = sliding window
    long_context_window: int = 8192  # SWA window used for long_500k decode
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # --- hybrid layer pattern (cycled); remainder layers use pattern[0] ---
    block_pattern: tuple[str, ...] = ("attn",)   # attn | rec | rwkv
    local_window: int = 0            # window for attn blocks inside hybrid
    d_rnn: int = 0                   # RG-LRU width (0 -> d_model)
    # --- rwkv ---
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64
    # recurrent-core impl for rwkv/rec blocks:
    #   "" = default (chunked jnp rwkv, associative-scan rglru),
    #   "scan" = sequential oracle, "chunked" = jnp chunked,
    #   "pallas" = kernels/recurrent_scan fused path
    rec_impl: str = ""
    # --- enc-dec (audio) ---
    encoder_layers: int = 0          # >0 => encoder-decoder
    # --- vlm early fusion ---
    fuse_patches: bool = False       # input carries patch_embeds + mask
    patch_frac: float = 0.25         # fraction of seq positions that are image
    # --- numerics / compilation ---
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "jnp"           # jnp | pallas
    source: str = ""

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        pat = self.block_pattern
        full = (pat * (self.n_layers // len(pat) + 1))[: self.n_layers]
        return tuple(full)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def rest_kinds(self) -> tuple[str, ...]:
        rem = self.n_layers - self.n_groups * len(self.block_pattern)
        return tuple(self.block_pattern[0] for _ in range(rem))

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_layer = 0
        for kind in self.layer_kinds:
            if kind == "attn":
                attn = (self.n_heads + 2 * self.n_kv_heads) \
                    * self.head_dim * d + self.n_heads * self.head_dim * d
                if self.n_experts:
                    ff = self.n_experts * (3 if self.mlp_variant == "swiglu"
                                           else 2) * d * f + d * self.n_experts
                else:
                    ff = (3 if self.mlp_variant == "swiglu" else 2) * d * f
                per_layer += attn + ff
            elif kind == "rec":
                dr = self.d_rnn or d
                per_layer += 2 * d * dr + 2 * dr * dr + dr * d
            elif kind == "rwkv":
                per_layer += 5 * d * d + 2 * d * f + d * d
        emb = v * d * (2 if self.encoder_layers == 0 else 2)
        if self.encoder_layers:
            # encoder blocks: attn + mlp, plus decoder cross-attn
            enc = self.encoder_layers * (
                4 * self.n_heads * self.head_dim * d
                + (3 if self.mlp_variant == "swiglu" else 2) * d * f)
            cross = self.n_layers * 4 * self.n_heads * self.head_dim * d
            per_layer = per_layer  # decoder layers already counted
            return emb + per_layer + enc + cross
        return emb + per_layer

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        n_ff_all = len([k for k in self.layer_kinds if k == "attn"]) \
            * self.n_experts * (3 if self.mlp_variant == "swiglu" else 2) * d * f
        n_ff_active = n_ff_all // self.n_experts * self.moe_top_k
        return self.n_params() - n_ff_all + n_ff_active


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "codeqwen1_5_7b", "recurrentgemma_9b", "granite_8b", "rwkv6_1_6b",
    "phi3_5_moe", "qwen3_1_7b", "chameleon_34b", "deepseek_67b",
    "seamless_m4t_v2", "llama4_scout",
    # the paper's own models
    "paper_cnn", "paper_mlp",
]

_ALIASES = {
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "granite-8b": "granite_8b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "qwen3-1.7b": "qwen3_1_7b",
    "chameleon-34b": "chameleon_34b",
    "deepseek-67b": "deepseek_67b",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
    "llama4-scout-17b-a16e": "llama4_scout",
}


def get_arch(arch_id: str, reduced: bool = False):
    """Load CONFIG (or REDUCED) from ``repro.configs.<id>``."""
    arch_id = _ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return getattr(mod, "REDUCED" if reduced else "CONFIG")


def list_archs() -> list[str]:
    return list(ARCH_IDS)
