"""DeepSeek-67B — dense llama-arch [arXiv:2401.02954].

95L, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=102400.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", arch_type="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=102400, mlp_variant="swiglu",
    source="arXiv:2401.02954",
)

REDUCED = ArchConfig(
    name="deepseek-67b-reduced", arch_type="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab=512, mlp_variant="swiglu",
    param_dtype="float32", act_dtype="float32", remat=False,
    source="arXiv:2401.02954",
)
