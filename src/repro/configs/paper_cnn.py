"""The paper's own CIFAR-10 CNN (§III) — config handle for the FL substrate."""
from repro.models.cnn import PaperCNNConfig

CONFIG = PaperCNNConfig()
REDUCED = PaperCNNConfig(c1=4, c2=8, fc1=32, fc2=16)
