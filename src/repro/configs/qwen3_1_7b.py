"""Qwen3-1.7B — dense decoder with qk-norm, GQA [hf:Qwen/Qwen3-8B family].

28L, d_model=2048, 16 heads (GQA kv=8), d_ff=6144, vocab=151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", arch_type="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab=151936, qk_norm=True, mlp_variant="swiglu",
    source="hf:Qwen/Qwen3-8B",
)

REDUCED = ArchConfig(
    name="qwen3-1.7b-reduced", arch_type="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
    d_ff=512, vocab=512, qk_norm=True, mlp_variant="swiglu",
    param_dtype="float32", act_dtype="float32", remat=False,
    source="hf:Qwen/Qwen3-8B",
)
