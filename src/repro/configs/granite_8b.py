"""Granite-8B-Code — dense llama-arch code model [arXiv:2405.04324].

36L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=49152.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", arch_type="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=49152, mlp_variant="swiglu",
    source="arXiv:2405.04324",
)

REDUCED = ArchConfig(
    name="granite-8b-reduced", arch_type="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab=512, mlp_variant="swiglu",
    param_dtype="float32", act_dtype="float32", remat=False,
    source="arXiv:2405.04324",
)
