"""RWKV-6 "Finch" 1.6B — attention-free SSM with data-dependent decay
[arXiv:2404.05892].

24L, d_model=2048, d_ff=7168, vocab=65536, head_size 64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", arch_type="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=7168, vocab=65536,
    block_pattern=("rwkv",), rwkv_head_dim=64, rwkv_chunk=64,
    source="arXiv:2404.05892",
)

REDUCED = ArchConfig(
    name="rwkv6-1.6b-reduced", arch_type="ssm",
    n_layers=2, d_model=256, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=512, vocab=512,
    block_pattern=("rwkv",), rwkv_head_dim=32, rwkv_chunk=16,
    param_dtype="float32", act_dtype="float32", remat=False,
    source="arXiv:2404.05892",
)
