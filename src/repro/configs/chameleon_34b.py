"""Chameleon-34B — early-fusion VLM over VQ image tokens [arXiv:2405.09818].

48L, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=65536 (shared
text + VQ image codes), qk-norm.  Early fusion is at the TOKEN level: the
VQ image tokenizer (the stubbed frontend) maps images into the same vocab,
so the backbone consumes one mixed token stream — no separate patch
projector (contrast llama4_scout).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", arch_type="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536, qk_norm=True, mlp_variant="swiglu",
    source="arXiv:2405.09818",
)

REDUCED = ArchConfig(
    name="chameleon-34b-reduced", arch_type="vlm",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab=512, qk_norm=True, mlp_variant="swiglu",
    param_dtype="float32", act_dtype="float32", remat=False,
    source="arXiv:2405.09818",
)
