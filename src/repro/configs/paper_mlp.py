"""The paper's own Fashion-MNIST MLP (§III) — config handle for the FL
substrate."""
from repro.models.mlp import PaperMLPConfig

CONFIG = PaperMLPConfig()
REDUCED = PaperMLPConfig(hidden=16)
