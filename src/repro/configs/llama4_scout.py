"""Llama-4-Scout-17B-16E — MoE (16 experts, top-1), early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model=5120, 40 heads (GQA kv=8), per-expert d_ff=8192,
vocab=202048.  Early fusion via projected patch embeddings scattered into
the token stream (the vision encoder is the stubbed frontend:
``patch_embeds (B, P, d_model)``).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, mlp_variant="swiglu",
    n_experts=16, moe_top_k=1,
    fuse_patches=True, patch_frac=0.25,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

REDUCED = ArchConfig(
    name="llama4-scout-reduced", arch_type="moe",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, mlp_variant="swiglu",
    n_experts=4, moe_top_k=1,
    fuse_patches=True, patch_frac=0.25,
    param_dtype="float32", act_dtype="float32", remat=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
