"""Architecture + input-shape configs."""
from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape, get_arch, list_archs
