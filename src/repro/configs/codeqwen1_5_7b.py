"""CodeQwen1.5-7B — dense decoder, qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B].

32L, d_model=4096, 32 heads (MHA: kv=32), d_ff=13440, vocab=92416.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab=92416, mlp_variant="swiglu",
    source="hf:Qwen/CodeQwen1.5-7B",
)

REDUCED = ArchConfig(
    name="codeqwen1.5-7b-reduced", arch_type="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
    d_ff=512, vocab=512, mlp_variant="swiglu",
    param_dtype="float32", act_dtype="float32", remat=False,
    source="hf:Qwen/CodeQwen1.5-7B",
)
