"""Observability switchboard: the module-level enable flag and the
monotonic timer every other pillar (trace / metrics / events) builds on.

The flag is deliberately a plain module global read through
``enabled()``: every instrumentation call site in the engines does one
function call + one attribute read when telemetry is off, and nothing
else — no registry lookups, no allocations, and (critically) no work
inside jit boundaries, so toggling the flag can never retrace a compiled
program.  ``benchmarks/bench_obs.py`` holds that contract to numbers:
<=5% hot-path overhead enabled, <=0.5% disabled.

``now()`` is ``time.perf_counter`` — the monotonic clock all spans,
events and launch scripts time with (``time.time()`` is wall clock and
can step backwards under NTP; PR 9 purged it from the serving loop, this
module is where the fix lives so it cannot regress).
"""
from __future__ import annotations

import contextlib
import time

__all__ = ["enabled", "enable", "disable", "scope", "now", "configure",
           "sync_default", "profiler_annotations", "epoch"]

#: Process epoch for relative timestamps (spans + events share it so the
#: two streams line up on one timeline).
_EPOCH = time.perf_counter()

_enabled = False
_sync_default = True
_profiler_annotations = False

#: The obs timer: monotonic, sub-microsecond, never steps backwards.
now = time.perf_counter


def epoch() -> float:
    """The perf_counter value all relative ``*_us`` timestamps key off."""
    return _EPOCH


def enabled() -> bool:
    """Is telemetry recording?  The one check every call site makes."""
    return _enabled


def enable() -> None:
    """Turn telemetry on (and lazily install the jit-retrace hook)."""
    global _enabled
    from repro.obs import metrics

    metrics.install_retrace_hook()
    _enabled = True


def disable() -> None:
    """Turn telemetry off: every obs call becomes a near-free no-op."""
    global _enabled
    _enabled = False


@contextlib.contextmanager
def scope(on: bool = True):
    """Temporarily enable (or disable) telemetry, restoring on exit."""
    global _enabled
    prev = _enabled
    if on:
        enable()
    else:
        disable()
    try:
        yield
    finally:
        _enabled = prev


def configure(*, sync: bool | None = None,
              profiler: bool | None = None) -> None:
    """Global span behaviour knobs.

    ``sync``      — default for ``span(..., sync=...)``: block_until_ready
                    registered device values at span exit (accurate device
                    timing) vs leave them in flight (async paths).
    ``profiler``  — wrap every span in ``jax.profiler.TraceAnnotation`` so
                    spans line up with XLA ops in Perfetto traces.
    """
    global _sync_default, _profiler_annotations
    if sync is not None:
        _sync_default = bool(sync)
    if profiler is not None:
        _profiler_annotations = bool(profiler)


def sync_default() -> bool:
    return _sync_default


def profiler_annotations() -> bool:
    return _profiler_annotations
