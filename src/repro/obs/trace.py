"""Tracing pillar: nested, thread-safe spans with device-sync-aware
timing.

    with obs.span("protocol.run", impl="dense") as sp:
        out = sp.sync(engine_dispatch(...))   # registered for sync

At span exit the registered values are ``jax.block_until_ready``-ed
before the clock is read, so ``dur_us`` measures device work, not just
async dispatch latency.  Pass ``sync=False`` (or register nothing) for
async paths where blocking would serialize a pipeline.

Spans nest per-thread via a thread-local stack; completed spans append
to one process-global record list exported as JSONL (``save_trace``) or
rendered as an indented tree (``format_tree``).  With
``obs.configure(profiler=True)`` each span also enters a
``jax.profiler.TraceAnnotation`` so it lines up with XLA ops in
Perfetto; ``profile_trace(logdir)`` wraps a block in
``jax.profiler.start_trace``/``stop_trace``.

When telemetry is disabled ``span()`` returns one shared no-op object —
no allocation, no clock read, no lock.
"""
from __future__ import annotations

import itertools
import json
import threading
from pathlib import Path

import jax

from repro.obs import core

__all__ = ["span", "Span", "trace_records", "clear_trace", "save_trace",
           "load_trace", "format_tree", "profile_trace"]

_records: list[dict] = []
_lock = threading.Lock()
_tls = threading.local()
_ids = itertools.count(1)


class _NoopSpan:
    """Shared disabled-mode span: every method is a constant no-op."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def sync(self, value):
        return value

    def note(self, **fields) -> None:
        return None


_NOOP = _NoopSpan()


class Span:
    __slots__ = ("name", "meta", "_sync", "_vals", "id", "parent", "depth",
                 "t0", "_annot")

    def __init__(self, name: str, sync: bool | None, meta: dict):
        self.name = name
        self.meta = meta
        self._sync = core.sync_default() if sync is None else sync
        self._vals: list = []
        self._annot = None

    def sync(self, value):
        """Register ``value`` (any pytree of arrays) to be blocked on at
        span exit; returns it unchanged so call sites stay one-liners."""
        if self._sync:
            self._vals.append(value)
        return value

    def note(self, **fields) -> None:
        """Attach metadata to the span record."""
        self.meta.update(fields)

    def __enter__(self):
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        self.id = next(_ids)
        self.parent = stack[-1].id if stack else 0
        self.depth = len(stack)
        stack.append(self)
        if core.profiler_annotations():
            self._annot = jax.profiler.TraceAnnotation(self.name)
            self._annot.__enter__()
        self.t0 = core.now()
        return self

    def __exit__(self, *exc):
        if self._vals:
            jax.block_until_ready(self._vals)
        t1 = core.now()
        if self._annot is not None:
            self._annot.__exit__(*exc)
        _tls.stack.pop()
        rec = {
            "name": self.name,
            "id": self.id,
            "parent": self.parent,
            "depth": self.depth,
            "thread": threading.current_thread().name,
            "ts_us": round((self.t0 - core.epoch()) * 1e6, 3),
            "dur_us": round((t1 - self.t0) * 1e6, 3),
        }
        if self.meta:
            rec["meta"] = {k: _jsonable(v) for k, v in self.meta.items()}
        with _lock:
            _records.append(rec)
        return False


def span(name: str, *, sync: bool | None = None, **meta):
    """A timed span context manager (the shared no-op when disabled)."""
    if not core.enabled():
        return _NOOP
    return Span(name, sync, meta)


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if hasattr(v, "item"):
        try:
            return v.item()
        except Exception:
            pass
    return str(v)


def trace_records() -> list[dict]:
    """Snapshot of completed span records (copy; safe to mutate)."""
    with _lock:
        return [dict(r) for r in _records]


def clear_trace() -> None:
    with _lock:
        _records.clear()


def save_trace(path) -> Path:
    """Write completed spans as JSONL (one record per line)."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    recs = trace_records()
    with p.open("w") as f:
        for r in recs:
            f.write(json.dumps(r, sort_keys=True) + "\n")
    return p


def load_trace(path) -> list[dict]:
    recs = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            recs.append(json.loads(line))
    return recs


def format_tree(records: list[dict] | None = None) -> str:
    """Render spans as an indented tree, one line per span:

        protocol.run                         1234.5us  impl=dense
          signature.accumulate_grams          987.6us
    """
    recs = trace_records() if records is None else list(records)
    if not recs:
        return "(no spans recorded)"
    recs.sort(key=lambda r: (r.get("ts_us", 0.0), r.get("id", 0)))
    by_parent: dict[int, list[dict]] = {}
    ids = {r.get("id") for r in recs}
    for r in recs:
        parent = r.get("parent", 0)
        if parent not in ids:
            parent = 0
        by_parent.setdefault(parent, []).append(r)
    lines: list[str] = []

    def walk(parent: int, indent: int) -> None:
        for r in by_parent.get(parent, []):
            meta = r.get("meta") or {}
            extra = "  " + " ".join(f"{k}={v}" for k, v in meta.items()) \
                if meta else ""
            pad = "  " * indent
            label = f"{pad}{r['name']}"
            lines.append(f"{label:<44s} {r['dur_us']:>12.1f}us{extra}")
            walk(r.get("id", -1), indent + 1)

    walk(0, 0)
    return "\n".join(lines)


class profile_trace:
    """Context manager pass-through to ``jax.profiler.start_trace`` —
    wraps a block so spans and XLA ops land in one Perfetto trace."""

    def __init__(self, logdir: str):
        self.logdir = str(logdir)

    def __enter__(self):
        jax.profiler.start_trace(self.logdir)
        return self

    def __exit__(self, *exc):
        jax.profiler.stop_trace()
        return False
