"""Metrics pillar: a process-global registry of counters, gauges and
histograms, snapshotable to JSON and diffable between snapshots.

Naming: flat metric names with optional labels folded into the key —
``count("kernel_calls", kernel="assign")`` lands under
``kernel_calls{kernel=assign}``.  The registry is guarded by one lock;
every mutator is a no-op (zero registry mutation) while telemetry is
disabled.

Stack-wide metrics fed from the instrumented hot paths:

  ``dispatch_count`` / ``kernel_calls{kernel=..}`` / ``kernel_blocks``
      from ``kernels/dispatch.record_dispatch`` (called at tile
      resolution, host-side, never inside jit).
  ``retrace_count``
      via the jit-cache-miss hook: a ``jax.monitoring`` duration
      listener on ``/jax/core/compile/jaxpr_trace_duration``, which
      fires exactly once per jit trace (= compilation-cache miss).
  ``assign_latency_us`` / ``directory_bytes`` / ``unassigned_frac`` /
  ``recluster_events``
      from ``MembershipEngine``.
  ``comm_upload_bytes`` + the full ``comm.*`` mirror
      fed straight from ``CommLedger.summary()`` via ``record_ledger``.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path

from repro.obs import core

__all__ = ["count", "gauge", "observe", "counter_value", "counter_total",
           "gauge_value", "snapshot", "diff", "clear_metrics",
           "save_snapshot", "load_snapshot", "record_ledger", "stamp",
           "install_retrace_hook"]

_lock = threading.RLock()
_counters: dict[str, float] = {}
_gauges: dict[str, float | str] = {}
_hists: dict[str, dict] = {}

#: The jax.monitoring key emitted once per jit trace (cache miss).
_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
_hook_installed = False


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def count(name: str, n: float = 1, **labels) -> None:
    """Increment a monotonic counter (no-op while disabled)."""
    if not core.enabled():
        return
    k = _key(name, labels)
    with _lock:
        _counters[k] = _counters.get(k, 0) + n


def gauge(name: str, value, **labels) -> None:
    """Set a last-value-wins gauge (numbers or short strings)."""
    if not core.enabled():
        return
    k = _key(name, labels)
    if hasattr(value, "item"):
        value = value.item()
    with _lock:
        _gauges[k] = value


def observe(name: str, value: float, **labels) -> None:
    """Record one histogram observation (pow-2 buckets)."""
    if not core.enabled():
        return
    value = float(value)
    k = _key(name, labels)
    le = 1 << max(0, int(value) - 1).bit_length() if value > 1 else 1
    with _lock:
        h = _hists.get(k)
        if h is None:
            h = _hists[k] = {"count": 0, "total": 0.0,
                             "min": value, "max": value, "buckets": {}}
        h["count"] += 1
        h["total"] += value
        h["min"] = min(h["min"], value)
        h["max"] = max(h["max"], value)
        b = str(le)
        h["buckets"][b] = h["buckets"].get(b, 0) + 1


def counter_value(name: str, default: float = 0, **labels) -> float:
    with _lock:
        return _counters.get(_key(name, labels), default)


def counter_total(name: str) -> float:
    """Sum of a counter over all its label sets."""
    prefix = name + "{"
    with _lock:
        return sum(v for k, v in _counters.items()
                   if k == name or k.startswith(prefix))


def gauge_value(name: str, default=None, **labels):
    with _lock:
        return _gauges.get(_key(name, labels), default)


def snapshot() -> dict:
    """JSON-able snapshot of the whole registry."""
    with _lock:
        return {
            "counters": dict(_counters),
            "gauges": dict(_gauges),
            "histograms": {
                k: {**{kk: vv for kk, vv in h.items() if kk != "buckets"},
                    "mean": (h["total"] / h["count"] if h["count"] else 0.0),
                    "buckets": dict(h["buckets"])}
                for k, h in _hists.items()},
        }


def diff(before: dict, after: dict) -> dict:
    """Delta between two ``snapshot()`` dicts: counter increments, gauge
    transitions and histogram count/total growth (zero deltas elided)."""
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    keys = set(before.get("counters", {})) | set(after.get("counters", {}))
    for k in sorted(keys):
        d = (after.get("counters", {}).get(k, 0)
             - before.get("counters", {}).get(k, 0))
        if d:
            out["counters"][k] = d
    bg, ag = before.get("gauges", {}), after.get("gauges", {})
    for k in sorted(set(bg) | set(ag)):
        if bg.get(k) != ag.get(k):
            out["gauges"][k] = [bg.get(k), ag.get(k)]
    bh, ah = before.get("histograms", {}), after.get("histograms", {})
    for k in sorted(set(bh) | set(ah)):
        b = bh.get(k, {"count": 0, "total": 0.0})
        a = ah.get(k, {"count": 0, "total": 0.0})
        dc = a["count"] - b["count"]
        if dc:
            out["histograms"][k] = {"count": dc,
                                    "total": a["total"] - b["total"]}
    return out


def clear_metrics() -> None:
    with _lock:
        _counters.clear()
        _gauges.clear()
        _hists.clear()


def save_snapshot(path) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(snapshot(), indent=2, sort_keys=True) + "\n")
    return p


def load_snapshot(path) -> dict:
    return json.loads(Path(path).read_text())


def record_ledger(ledger) -> None:
    """Mirror a ``CommLedger.summary()`` into ``comm.*`` gauges, plus the
    headline ``comm_upload_bytes`` total (all users' protocol uploads)."""
    if not core.enabled():
        return
    s = ledger.summary()
    for k, v in s.items():
        if v is None:
            continue
        gauge(f"comm.{k}", v)
    gauge("comm_upload_bytes", s["per_user_upload_bytes"] * s["n_users"])


def stamp() -> dict:
    """The small metrics stamp benchmarks attach next to
    ``environment_stamp``: dispatch/retrace counters + enablement."""
    return {
        "obs_enabled": core.enabled(),
        "dispatch_count": counter_total("dispatch_count"),
        "retrace_count": counter_total("retrace_count"),
    }


def install_retrace_hook() -> None:
    """Count jit cache misses via ``jax.monitoring``.

    Idempotent; jax offers no per-listener removal, so the listener is
    registered once and gates on ``core.enabled()`` at fire time.
    """
    global _hook_installed
    if _hook_installed:
        return
    from jax import monitoring

    def _on_duration(key: str, _dur: float, **_kw) -> None:
        if key == _TRACE_EVENT and core.enabled():
            count("retrace_count")

    monitoring.register_event_duration_secs_listener(_on_duration)
    _hook_installed = True
