"""Events pillar: a structured, append-only event log.

Where spans answer "how long" and metrics answer "how much", events
answer "what happened, in what order": membership lifecycle
(``admit`` / ``evict`` / ``assign_wave`` / ``drift_trip`` /
``recluster`` with before/after label agreement) and ServeEngine
scheduling (``wave_admitted`` / ``slot_freed`` / ``request_done`` with
per-request TTFT).

Each record carries a process-wide sequence number and a ``t_us``
timestamp relative to the same epoch the trace spans use, so the two
streams interleave on one timeline.  Values are coerced to JSON-able
scalars at emit time (device scalars via ``.item()``), and the log
round-trips through JSONL (``save_events`` / ``load_events``).
"""
from __future__ import annotations

import itertools
import json
import threading
from pathlib import Path

from repro.obs import core

__all__ = ["event", "events", "clear_events", "save_events", "load_events"]

_events: list[dict] = []
_lock = threading.Lock()
_seq = itertools.count()


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if hasattr(v, "item"):
        try:
            return v.item()
        except Exception:
            pass
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def event(kind: str, **fields) -> None:
    """Append one structured event (no-op while disabled)."""
    if not core.enabled():
        return
    rec = {"seq": next(_seq),
           "t_us": round((core.now() - core.epoch()) * 1e6, 3),
           "kind": kind}
    for k, v in fields.items():
        rec[k] = _jsonable(v)
    with _lock:
        _events.append(rec)


def events(kind: str | None = None) -> list[dict]:
    """Snapshot of the event log (optionally filtered by kind)."""
    with _lock:
        recs = [dict(r) for r in _events]
    if kind is not None:
        recs = [r for r in recs if r["kind"] == kind]
    return recs


def clear_events() -> None:
    with _lock:
        _events.clear()


def save_events(path) -> Path:
    """Write the event log as JSONL (one event per line)."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    recs = events()
    with p.open("w") as f:
        for r in recs:
            f.write(json.dumps(r, sort_keys=True) + "\n")
    return p


def load_events(path) -> list[dict]:
    recs = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            recs.append(json.loads(line))
    return recs
