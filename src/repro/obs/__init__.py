"""``repro.obs`` — zero-dependency observability: spans, metrics, events.

Three pillars behind one module-level enable flag (off by default):

  * **tracing** — nested ``span()`` context managers with
    device-sync-aware timing, JSONL export, pretty trees, and optional
    ``jax.profiler`` pass-through (``repro.obs.trace``);
  * **metrics** — a process-global counter/gauge/histogram registry,
    snapshotable and diffable, with a jit-cache-miss ``retrace_count``
    hook and a ``CommLedger`` feed (``repro.obs.metrics``);
  * **events** — a structured log of membership lifecycle and serving
    scheduling events (``repro.obs.events``).

Disabled-path contract: every instrumentation call is a function call +
one flag check — no allocation, no locking, no registry mutation, and
never any work inside a jit boundary (so the flag cannot retrace).

    from repro import obs
    obs.enable()
    with obs.span("protocol.run") as sp:
        labels = sp.sync(one_shot_clustering(...).labels)
    print(obs.format_tree())
    obs.save_trace("trace.jsonl"); obs.save_events("events.jsonl")
"""
from repro.obs.core import (configure, disable, enable, enabled, epoch,
                            now, scope)
from repro.obs.events import (clear_events, event, events, load_events,
                              save_events)
from repro.obs.metrics import (clear_metrics, count, counter_total,
                               counter_value, diff, gauge, gauge_value,
                               install_retrace_hook, load_snapshot, observe,
                               record_ledger, save_snapshot, snapshot, stamp)
from repro.obs.trace import (Span, clear_trace, format_tree, load_trace,
                             profile_trace, save_trace, span, trace_records)

__all__ = [
    "enabled", "enable", "disable", "scope", "now", "epoch", "configure",
    "span", "Span", "trace_records", "clear_trace", "save_trace",
    "load_trace", "format_tree", "profile_trace",
    "count", "gauge", "observe", "counter_value", "counter_total",
    "gauge_value", "snapshot", "diff", "clear_metrics", "save_snapshot",
    "load_snapshot", "record_ledger", "stamp", "install_retrace_hook",
    "event", "events", "clear_events", "save_events", "load_events",
    "reset",
]


def reset() -> None:
    """Clear all three pillars (trace records, metrics, events)."""
    clear_trace()
    clear_metrics()
    clear_events()
