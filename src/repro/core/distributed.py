"""Distributed one-shot clustering protocol (shard_map backend).

Compatibility surface over ``repro.core.engine``: the shard_map body now
lives in ``engine._sharded_protocol`` and is selected with
``SimilarityConfig(backend="shard_map")`` — this module keeps the original
``distributed_similarity(features, mesh, ...)`` call signature for
existing callers and tests.

Users are sharded over one mesh axis (default ``"data"``).  Per-device
communication is exactly the paper's accounting: upload O(k*d), download
O(N*k*d) for the signature exchange, plus the O(N^2) relevance gather —
independent of model size, which is the paper's point.  The heavy compute
(Gram, eigh, cross-projection) runs fully sharded; only eigenvector blocks
cross the interconnect.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh

from repro.core import similarity as sim
from repro.core.engine import ProtocolEngine, make_user_mesh

__all__ = ["distributed_similarity", "make_user_mesh"]


def distributed_similarity(features: jax.Array, mesh: Mesh,
                           cfg: sim.SimilarityConfig | None = None,
                           axis: str = "data",
                           n_valid: jax.Array | None = None) -> jax.Array:
    """Run the one-shot similarity protocol sharded over ``mesh[axis]``.

    ``features (N, n, d)`` with ``N`` divisible by the axis size.  Returns
    the replicated ``R (N, N)``.
    """
    cfg = dataclasses.replace(cfg or sim.SimilarityConfig(),
                              backend="shard_map", block_users=0,
                              mesh_axis=axis)
    return ProtocolEngine(cfg, mesh=mesh).similarity(features,
                                                     n_valid=n_valid)
