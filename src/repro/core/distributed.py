"""Distributed one-shot clustering protocol (shard_map version).

Maps the paper's star-topology message pattern onto TPU collectives:

  paper                               | here
  ------------------------------------|---------------------------------
  user i broadcasts V_i to all users  | all_gather of (k, d) blocks over
                                      | the user-sharded mesh axis
  user i uploads row r(i, .) to GPS   | all_gather of relevance rows
  GPS symmetrizes R, runs HAC         | every device holds R; HAC runs
                                      | host-side on the (tiny) N x N R

Users are sharded over one mesh axis (default ``"data"``).  Per-device
communication is exactly the paper's accounting: upload O(k*d), download
O(N*k*d) for the signature exchange, plus the O(N^2) relevance gather —
independent of model size, which is the paper's point.

The heavy compute (Gram, eigh, cross-projection) runs fully sharded; only
eigenvector blocks cross the interconnect.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import similarity as sim

__all__ = ["distributed_similarity", "make_user_mesh"]


def make_user_mesh(axis_name: str = "data") -> Mesh:
    """A 1-D mesh over all local devices for user sharding (tests/demos)."""
    import numpy as np

    devs = np.asarray(jax.devices())
    return Mesh(devs, (axis_name,))


def _protocol(features, n_valid, *, axis: str, top_k: int, eig_floor: float,
              impl: str):
    """shard_map body.  ``features (N_local, n, d)`` per device."""
    # --- Phase 1: local spectral signatures (no communication). ---------
    grams = sim.batched_gram(features, n_valid, impl=impl)        # (Nl,d,d)
    lam, v = jax.vmap(lambda g: sim.spectrum(g, top_k))(grams)    # (Nl,k),(Nl,d,k)

    # --- Phase 2: signature exchange == paper's "share V_i". ------------
    # all_gather over the user axis; tiled=True concatenates shards so the
    # result is the full (N, ...) signature table on every device.
    lam_all = jax.lax.all_gather(lam, axis, tiled=True)           # (N, k)
    v_all = jax.lax.all_gather(v, axis, tiled=True)               # (N, d, k)

    # --- Phase 3: local relevance rows (no communication). --------------
    r_rows = sim.relevance_matrix(grams, lam, v_all, eig_floor,
                                  impl=impl)                      # (Nl, N)
    # relevance_matrix pairs grams[i] with lams[i]; here lam is local and
    # v_all is global, which is what we want: row i uses MY gram+spectrum
    # against EVERY user's eigenvectors.

    # --- Phase 4: GPS assembly == all_gather of rows + symmetrize. ------
    r_full = jax.lax.all_gather(r_rows, axis, tiled=True)         # (N, N)
    return sim.symmetrize(r_full)


def distributed_similarity(features: jax.Array, mesh: Mesh,
                           cfg: sim.SimilarityConfig | None = None,
                           axis: str = "data",
                           n_valid: jax.Array | None = None) -> jax.Array:
    """Run the one-shot similarity protocol sharded over ``mesh[axis]``.

    ``features (N, n, d)`` with ``N`` divisible by the axis size.  Returns
    the replicated ``R (N, N)``.
    """
    cfg = cfg or sim.SimilarityConfig()
    n_users = features.shape[0]
    axis_size = mesh.shape[axis]
    if n_users % axis_size:
        raise ValueError(
            f"n_users={n_users} not divisible by mesh axis {axis!r}"
            f" of size {axis_size}")
    if n_valid is None:
        n_valid = jnp.full((n_users,), features.shape[1], dtype=jnp.float32)
    top_k = cfg.top_k or features.shape[-1]

    body = partial(_protocol, axis=axis, top_k=top_k,
                   eig_floor=cfg.eig_floor, impl=cfg.impl)
    other_axes = tuple(n for n in mesh.axis_names if n != axis)
    spec_in = P(axis)
    spec_out = P()  # replicated R
    fn = shard_map(body, mesh=mesh,
                   in_specs=(spec_in, spec_in),
                   out_specs=spec_out,
                   check_rep=False)
    with mesh:
        feats = jax.device_put(features,
                               NamedSharding(mesh, P(axis)))
        nv = jax.device_put(n_valid, NamedSharding(mesh, P(axis)))
        return jax.jit(fn)(feats, nv)
