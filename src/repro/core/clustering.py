"""Clustering decisions on the similarity matrix (paper §II-C).

The GPS feeds ``R`` to Hierarchical Agglomerative Clustering and cuts the
dendrogram at ``T`` clusters.  We implement HAC from scratch (no scipy in
this container) over a *similarity* matrix (merge the most-similar pair),
with single / complete / average linkage.  Baselines used by the paper and
by the literature it contrasts against:

  * ``random_clusters``  - the paper's baseline (ignores similarity).
  * ``oracle_clusters``  - ground-truth task partition (upper bound).
  * ``spectral_clusters``- beyond-paper alternative on the same R.
  * ``ifca_assign``      - one step of IFCA-style loss-based assignment
                           (the iterative family of [5]) for comparison.

Metrics: ``clustering_accuracy`` (best-permutation match) and
``adjusted_rand_index`` — both pure numpy, used in tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "Dendrogram",
    "hac",
    "cut",
    "hac_clusters",
    "random_clusters",
    "oracle_clusters",
    "spectral_clusters",
    "clustering_accuracy",
    "adjusted_rand_index",
]


@dataclasses.dataclass(frozen=True)
class Dendrogram:
    """Merge history of HAC.

    ``merges[t] = (a, b, sim)``: at step ``t`` clusters ``a`` and ``b``
    (ids; leaves are ``0..N-1``, internal nodes ``N+t``) merged at
    similarity ``sim``.  ``sizes[c]`` is the leaf count of node ``c``.
    """

    n_leaves: int
    merges: tuple[tuple[int, int, float], ...]

    def heights(self) -> np.ndarray:
        return np.asarray([m[2] for m in self.merges])


_LINKAGES = ("average", "single", "complete")


def _validate_similarity(similarity: np.ndarray) -> np.ndarray:
    """Shared input validation -> float64 copy.

    Garbage in (NaN from an upstream 0/0, a non-square or asymmetric
    matrix) used to be silently merged into a nonsense dendrogram; now it
    raises at the door.  Tiny float asymmetry from accumulation order is
    tolerated (the protocol's ``symmetrize`` output is exactly symmetric,
    but callers may hand-build matrices in float32).
    """
    s = np.array(similarity, dtype=np.float64, copy=True)
    if s.ndim != 2 or s.shape[0] != s.shape[1]:
        raise ValueError(f"similarity must be square, got {s.shape}")
    if not np.isfinite(s).all():
        raise ValueError("similarity contains NaN/Inf entries")
    if not np.allclose(s, s.T, rtol=1e-5, atol=1e-6):
        raise ValueError("similarity must be symmetric "
                         "(max |R - R^T| = "
                         f"{np.abs(s - s.T).max():.3g})")
    return s


def hac(similarity: np.ndarray, linkage: str = "average") -> Dendrogram:
    """Agglomerative clustering over a symmetric similarity matrix.

    Similarity semantics (higher = closer): each step merges the pair of
    active clusters with *maximum* linkage similarity.

    Linkage between clusters A, B:
      average : mean_{i in A, j in B} R[i, j]   (UPGMA)
      single  : max  (closest members — "single link" in similarity space)
      complete: min  (farthest members)
    """
    if linkage not in _LINKAGES:
        raise ValueError(f"linkage must be one of {_LINKAGES}, got {linkage!r}")
    s = _validate_similarity(similarity)
    n = s.shape[0]
    # Active cluster bookkeeping. ``sim`` holds pairwise cluster linkage.
    sim = s.copy()
    np.fill_diagonal(sim, -np.inf)
    active = list(range(n))                 # index into sim rows -> node id
    node_of = {i: i for i in range(n)}      # row index -> dendrogram node id
    sizes = {i: 1 for i in range(n)}
    merges: list[tuple[int, int, float]] = []
    alive = np.ones(n, dtype=bool)

    for step in range(n - 1):
        # Find the max-similarity active pair.
        masked = np.where(np.outer(alive, alive), sim, -np.inf)
        np.fill_diagonal(masked, -np.inf)
        flat = int(np.argmax(masked))
        i, j = divmod(flat, n)
        if i > j:
            i, j = j, i
        h = float(masked[i, j])
        a, b = node_of[i], node_of[j]
        new_id = n + step
        merges.append((a, b, h))
        na, nb = sizes[a], sizes[b]
        # Lance-Williams update of row i (the merged cluster); kill row j.
        if linkage == "average":
            upd = (na * sim[i] + nb * sim[j]) / (na + nb)
        elif linkage == "single":
            upd = np.maximum(sim[i], sim[j])
        else:  # complete
            upd = np.minimum(sim[i], sim[j])
        sim[i] = upd
        sim[:, i] = upd
        sim[i, i] = -np.inf
        alive[j] = False
        node_of[i] = new_id
        sizes[new_id] = na + nb
    return Dendrogram(n_leaves=n, merges=tuple(merges))


def cut(dend: Dendrogram, n_clusters: int) -> np.ndarray:
    """Cut the dendrogram into ``n_clusters`` groups -> labels ``(N,)``.

    Replays merges until ``n_clusters`` components remain (the last
    ``n_clusters - 1`` merges are skipped).
    """
    n = dend.n_leaves
    if not 1 <= n_clusters <= n:
        raise ValueError(f"n_clusters must be in [1, {n}], got {n_clusters}")
    parent = list(range(n + len(dend.merges)))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    keep = n - n_clusters                   # how many merges to apply
    for t, (a, b, _) in enumerate(dend.merges[:keep]):
        new_id = n + t
        parent[find(a)] = new_id
        parent[find(b)] = new_id
    roots = {}
    labels = np.empty(n, dtype=np.int32)
    for leaf in range(n):
        r = find(leaf)
        labels[leaf] = roots.setdefault(r, len(roots))
    return labels


def hac_clusters(similarity: np.ndarray, n_clusters: int,
                 linkage: str = "average") -> np.ndarray:
    """Convenience: HAC + cut -> labels."""
    return cut(hac(similarity, linkage), n_clusters)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def random_clusters(n_users: int, n_clusters: int,
                    rng: np.random.Generator | int = 0,
                    cluster_sizes: Sequence[int] | None = None) -> np.ndarray:
    """The paper's baseline: a uniformly random partition.

    If ``cluster_sizes`` is given the partition respects those sizes (the
    paper's random baseline keeps the LPS capacities fixed and shuffles
    users); otherwise each user picks a cluster uniformly, re-drawn until
    every cluster is non-empty.
    """
    rng = np.random.default_rng(rng) if isinstance(rng, int) else rng
    if cluster_sizes is not None:
        if sum(cluster_sizes) != n_users:
            raise ValueError("cluster_sizes must sum to n_users")
        labels = np.repeat(np.arange(len(cluster_sizes)), cluster_sizes)
        rng.shuffle(labels)
        return labels.astype(np.int32)
    if not 1 <= n_clusters <= n_users:
        # every cluster must be non-empty, so n_clusters > n_users would
        # spin the redraw loop forever
        raise ValueError(f"n_clusters must be in [1, {n_users}], "
                         f"got {n_clusters}")
    while True:
        labels = rng.integers(0, n_clusters, size=n_users).astype(np.int32)
        if len(np.unique(labels)) == n_clusters:
            return labels


def oracle_clusters(task_ids: Sequence[int]) -> np.ndarray:
    """Ground-truth partition (relabelled to 0..T-1)."""
    _, labels = np.unique(np.asarray(task_ids), return_inverse=True)
    return labels.astype(np.int32)


def spectral_clusters(similarity: np.ndarray, n_clusters: int,
                      rng: np.random.Generator | int = 0) -> np.ndarray:
    """Beyond-paper: normalized spectral clustering on the affinity R.

    Ng-Jordan-Weiss: normalized Laplacian, bottom-T eigenvectors, row
    normalisation, k-means (Lloyd, 50 iters, best of 8 inits).
    """
    rng = np.random.default_rng(rng) if isinstance(rng, int) else rng
    a = _validate_similarity(similarity)
    if not 1 <= n_clusters <= a.shape[0]:
        # otherwise this crashes opaquely inside rng.choice (or silently
        # k-means-es more centers than points)
        raise ValueError(f"n_clusters must be in [1, {a.shape[0]}], "
                         f"got {n_clusters}")
    np.fill_diagonal(a, 0.0)
    deg = a.sum(axis=1)
    d_inv_sqrt = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
    lap = np.eye(len(a)) - d_inv_sqrt[:, None] * a * d_inv_sqrt[None, :]
    w, v = np.linalg.eigh(lap)
    emb = v[:, :n_clusters]
    norms = np.linalg.norm(emb, axis=1, keepdims=True)
    emb = emb / np.maximum(norms, 1e-12)
    best_labels, best_obj = None, np.inf
    for _ in range(8):
        centers = emb[rng.choice(len(emb), n_clusters, replace=False)]
        for _ in range(50):
            dists = ((emb[:, None, :] - centers[None]) ** 2).sum(-1)
            labels = dists.argmin(1)
            for c in range(n_clusters):
                pts = emb[labels == c]
                if len(pts):
                    centers[c] = pts.mean(0)
        obj = float(dists.min(1).sum())
        if obj < best_obj:
            best_obj, best_labels = obj, labels
    return best_labels.astype(np.int32)


def ifca_assign(losses: np.ndarray) -> np.ndarray:
    """One IFCA-style assignment step: ``losses (N, T)`` per-user per-cluster
    model loss -> each user joins its argmin cluster.  Used as the iterative
    literature baseline ([5]) in benchmarks."""
    return np.asarray(losses).argmin(axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def clustering_accuracy(pred: Sequence[int], true: Sequence[int]) -> float:
    """Fraction of users correctly grouped under the best label permutation."""
    pred = np.asarray(pred)
    true = oracle_clusters(true)
    k = max(pred.max(), true.max()) + 1
    if k <= 8:  # exact over permutations
        best = 0
        for perm in itertools.permutations(range(k)):
            mapped = np.asarray(perm)[pred]
            best = max(best, int((mapped == true).sum()))
        return best / len(pred)
    # Greedy fallback for many clusters.
    conf = np.zeros((k, k), dtype=int)
    for p, t in zip(pred, true):
        conf[p, t] += 1
    total, used = 0, set()
    for p in np.argsort(-conf.max(axis=1)):
        order = np.argsort(-conf[p])
        for t in order:
            if t not in used:
                used.add(t)
                total += conf[p, t]
                break
    return total / len(pred)


def adjusted_rand_index(pred: Sequence[int], true: Sequence[int]) -> float:
    pred, true = np.asarray(pred), np.asarray(true)
    n = len(pred)
    classes, class_idx = np.unique(true, return_inverse=True)
    clusters, cluster_idx = np.unique(pred, return_inverse=True)
    table = np.zeros((len(classes), len(clusters)), dtype=np.int64)
    for c, k in zip(class_idx, cluster_idx):
        table[c, k] += 1

    def comb2(x):
        return x * (x - 1) / 2.0

    sum_comb = comb2(table).sum()
    sum_a = comb2(table.sum(axis=1)).sum()
    sum_b = comb2(table.sum(axis=0)).sum()
    expected = sum_a * sum_b / comb2(n)
    max_index = (sum_a + sum_b) / 2.0
    if max_index == expected:
        return 1.0
    return float((sum_comb - expected) / (max_index - expected))
