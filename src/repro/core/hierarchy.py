"""Two-level hierarchical one-shot clustering — the O(N^2) wall breaker.

Every flat path through the ``ProtocolEngine`` scores (or Nystrom-
completes) an N x N relevance matrix before HAC runs, which caps the
one-shot protocol at ~10^4 users on one host.  This module is the
edge-server decomposition of the same Algorithm-2 maths:

  1. **Shard** the N users into G edge groups of N_g = N / G.
  2. **Group protocol + HAC**, all groups in ONE dispatch: the dense
     protocol (``engine._dense_protocol``) and the device NN-chain HAC
     (``cluster_engine._nn_chain`` / ``_cut_device``) are both single
     jitted programs, so ``jax.vmap`` over the group axis clusters every
     group at once — O(G * N_g^2) relevance entries instead of O(N^2).
  3. **Compress** each group's T_g clusters into a directory entry, the
     same representation the ``MembershipEngine`` serves from: the
     cluster-mean rank-k Gram ``Ghat_t = mean_i V_i diag(lam_i) V_i^T``
     re-eigendecomposed to an entry signature ``(lam_e, V_e)``, plus the
     mean projector ``P_t = mean_i V_i V_i^T`` and the member count.
  4. **Global stage**: the E = G * T_g entries are clustered into the
     final T by HAC over ``similarity.signature_relevance`` — the same
     signature-only relevance the drift re-cluster path already trusts —
     at O(E^2) cost, E << N.
  5. **Stitch**: user i's global label is the global label of its
     group-local cluster's entry.  ``greedy_match_labels`` (the
     canonical id matcher, shared with the ``MembershipEngine``
     re-cluster path) aligns label ids across independent runs for
     agreement measurement.

Communication: a user talks only to its edge server — one ``(k x d)``
signature upload plus an N_g-length relevance row (vs N-length flat);
each edge server forwards T_g entry signatures to the GPS.  The ledger
on the result accounts the per-user view with ``n_users = N_g``.

The result duck-types ``OneShotResult`` where it matters:
``MembershipEngine.from_oneshot`` consumes ``labels`` / ``lam`` / ``v``
unchanged, so online serving works identically at hierarchical scale.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import similarity as sim
from repro.core.cluster_engine import (ClusterConfig, ClusterEngine,
                                       _cut_device, _nn_chain)
from repro.core.engine import _dense_protocol
from repro.core.oneshot import CommLedger

__all__ = ["HierarchyConfig", "HierarchicalResult", "hierarchical_one_shot",
           "greedy_match_labels", "group_permutation"]

_ASSIGNMENTS = ("contiguous", "strided")
_NEG = -jnp.inf


@dataclasses.dataclass(frozen=True)
class HierarchyConfig:
    """Configuration of the two-level protocol.

    Attributes:
      n_groups: G edge groups.  ``n_users % n_groups == 0`` is required —
        phantom-user padding would distort the group HAC heights.
      group_clusters: T_g clusters cut per group; ``0`` means the final
        ``n_clusters`` (safe default: a group that happens to contain
        every task can still separate them).  Must end up <= N / G.
      group_batch: groups vmapped per dispatch; ``0`` = all G at once.
        Bounds peak memory at O(group_batch * (N/G)^2 + N * d * k).
      assignment: how user ids map to groups — "contiguous" (group g =
        ids [g*N_g, (g+1)*N_g)) or "strided" (group g = ids g, g+G, ...;
        mixes rosters that arrive sorted by task).
    """

    n_groups: int
    group_clusters: int = 0
    group_batch: int = 0
    assignment: str = "contiguous"

    def __post_init__(self):
        if self.n_groups < 2:
            raise ValueError(f"n_groups must be >= 2 (use the flat path "
                             f"for one group), got {self.n_groups}")
        if self.group_clusters < 0:
            raise ValueError(f"group_clusters must be >= 0, "
                             f"got {self.group_clusters}")
        if self.group_batch < 0:
            raise ValueError(f"group_batch must be >= 0, "
                             f"got {self.group_batch}")
        if self.assignment not in _ASSIGNMENTS:
            raise ValueError(f"assignment must be one of {_ASSIGNMENTS}, "
                             f"got {self.assignment!r}")


@dataclasses.dataclass(frozen=True)
class HierarchicalResult:
    """Global labels + the directory the global stage clustered.

    ``labels`` / ``lam`` / ``v`` follow the ``OneShotResult`` contract
    (``MembershipEngine.from_oneshot`` consumes them unchanged).  The
    entry arrays expose the compressed level: ``entry_labels[e]`` is the
    global cluster of directory entry ``e = g * T_g + t_local``, and a
    user's global label is ``entry_labels[group_ids * T_g +
    local_labels]`` by construction.
    """

    labels: jax.Array               # (N,) global cluster ids 0..T-1
    lam: jax.Array                  # (N, k) shared per-user spectra
    v: jax.Array                    # (N, d, k) shared eigenvectors
    group_ids: jax.Array            # (N,) edge group of each user
    local_labels: jax.Array         # (N,) group-local cluster ids
    entry_labels: jax.Array         # (E,) global label per entry
    entry_lam: jax.Array            # (E, k) entry spectra
    entry_v: jax.Array              # (E, d, k) entry eigenvectors
    entry_protos: jax.Array         # (E, d, d) mean projectors
    entry_counts: jax.Array         # (E,) members per entry
    global_similarity: jax.Array    # (E, E) signature-only relevance
    ledger: CommLedger              # per-user view: n_users = N / G


def greedy_match_labels(new_labels: np.ndarray, old_labels: np.ndarray,
                        n_clusters: int) -> np.ndarray:
    """Greedy-overlap relabeling of ``new_labels`` onto ``old_labels``'
    ids (both length-N, values in [0, n_clusters) or -1 = unassigned).

    HAC cut ids are arbitrary, so any two runs — or the two levels of
    the hierarchy vs a flat run — need id alignment before exact-match
    agreement means anything.  Host-side: matching is a rare, tiny
    (T x T) event.  Shared by the ``MembershipEngine`` re-cluster path
    (serving continuity) and the scale benchmarks (agreement metric).
    """
    new_labels = np.asarray(new_labels)
    old_labels = np.asarray(old_labels)
    overlap = np.zeros((n_clusters, n_clusters), np.int64)
    for new, old in zip(new_labels, old_labels):
        if new >= 0 and old >= 0:
            overlap[new, old] += 1
    perm = np.full(n_clusters, -1, np.int64)
    used = np.zeros(n_clusters, bool)
    for new, old in zip(*np.unravel_index(np.argsort(-overlap, axis=None),
                                          overlap.shape)):
        if perm[new] < 0 and not used[old]:
            perm[new] = old
            used[old] = True
    for t in range(n_clusters):                 # clusters with no overlap
        if perm[t] < 0:
            perm[t] = int(np.flatnonzero(~used)[0])
            used[perm[t]] = True
    return np.where(new_labels >= 0, perm[np.clip(new_labels, 0, None)],
                    -1).astype(np.int32)


def group_permutation(n_users: int, cfg: HierarchyConfig) -> np.ndarray:
    """User-id order such that ``perm.reshape(G, N_g)`` rows are the
    edge groups.  A pure host-side index computation."""
    if n_users % cfg.n_groups:
        raise ValueError(
            f"n_users={n_users} not divisible by n_groups="
            f"{cfg.n_groups}: phantom-user padding would distort the "
            "group HAC — resize the groups instead")
    perm = np.arange(n_users)
    if cfg.assignment == "strided":
        perm = perm.reshape(-1, cfg.n_groups).T.ravel()
    return perm


# ---------------------------------------------------------------------------
# Batched group stage: protocol + NN-chain HAC, vmapped over groups
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("top_k", "impl"))
def _batched_protocol(feats, nv, top_k, eig_floor, impl):
    """``feats (B, N_g, n, d)`` -> per-group ``(R (B, N_g, N_g),
    lam (B, N_g, k), v (B, N_g, d, k))`` — B groups, one dispatch."""
    _, big_r, lam, v = jax.vmap(
        lambda f, m: _dense_protocol(f, m, top_k, eig_floor, impl))(feats, nv)
    return big_r, lam, v


@partial(jax.jit, static_argnames=("n", "linkage", "impl", "interpret",
                                   "n_clusters"))
def _batched_hac_cut(big_r, *, n: int, linkage: str, impl: str,
                     interpret: bool | None, n_clusters: int):
    """Batched device HAC: prepare (diag -inf) + NN-chain + cut, vmapped
    over the leading group axis -> ``(labels (B, n), steps (B,))``."""
    idx = jnp.arange(n)
    alive = jnp.ones((n,), bool)

    def one(r):
        s = r.astype(jnp.float32).at[idx, idx].set(_NEG)
        merge_rows, heights, steps = _nn_chain(
            s, alive, n=n, linkage=linkage, impl=impl, interpret=interpret)
        labels = _cut_device(merge_rows, heights, n_leaves=n,
                             n_clusters=n_clusters)
        return labels, steps

    return jax.vmap(one)(big_r)


# ---------------------------------------------------------------------------
# Directory compression: per-entry mean rank-k Gram -> entry signature
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_entries", "top_k"))
def _compress_entries(lam, v, entry_id, *, n_entries: int, top_k: int):
    """``(lam (N, k), v (N, d, k), entry_id (N,))`` -> directory arrays.

    The entry's rank-k Gram reconstruction ``Ghat = mean_i V_i
    diag(lam_i) V_i^T`` is re-eigendecomposed so the entry signature has
    the exact ``(lam_e, V_e)`` shape ``signature_relevance`` expects;
    the unweighted mean projector rides along for serving-directory
    parity with ``MembershipEngine``.  Segment sums keep this one pass
    over the users, O(N * d^2) flops.
    """
    w = jnp.einsum("ndk,nk,nek->nde", v, lam, v)      # V diag(lam) V^T
    p = jnp.einsum("ndk,nek->nde", v, v)              # V V^T
    seg_w = jax.ops.segment_sum(w, entry_id, num_segments=n_entries)
    seg_p = jax.ops.segment_sum(p, entry_id, num_segments=n_entries)
    counts = jax.ops.segment_sum(jnp.ones_like(entry_id, jnp.float32),
                                 entry_id, num_segments=n_entries)
    denom = jnp.maximum(counts, 1.0)[:, None, None]
    ghat = seg_w / denom
    protos = seg_p / denom
    lam_e, v_e = jax.vmap(lambda g: sim.spectrum(g, top_k))(ghat)
    return lam_e, v_e, protos, counts


# ---------------------------------------------------------------------------
# The two-level protocol
# ---------------------------------------------------------------------------

def hierarchical_one_shot(features, n_clusters: int,
                          cfg: sim.SimilarityConfig | None = None,
                          hierarchy_cfg: HierarchyConfig | None = None,
                          cluster_cfg: ClusterConfig | None = None,
                          n_valid=None, model_params: int = 0
                          ) -> HierarchicalResult:
    """Two-level one-shot clustering of ``features`` into ``n_clusters``.

    ``cfg`` supplies the protocol maths knobs (``top_k``, ``eig_floor``,
    ``impl``); its *routing* fields must be off — groups ARE the scaling
    mechanism here, so ``backend`` must be single-host ("jnp"/"pallas")
    and ``block_users`` / ``landmarks`` zero.  ``cluster_cfg`` drives
    BOTH HAC stages and must be a device backend ("jnp"/"pallas",
    default "jnp"): the group stage is a vmapped NN-chain, which the
    host-numpy reference cannot batch.
    """
    cfg = cfg or sim.SimilarityConfig()
    hcfg = hierarchy_cfg or HierarchyConfig(n_groups=2)
    ccfg = cluster_cfg or ClusterConfig(backend="jnp")
    if cfg.backend == "shard_map":
        raise ValueError("hierarchical_one_shot shards users into groups "
                         "itself; use a single-host backend "
                         "('jnp'/'pallas') for the group protocol")
    if cfg.block_users or cfg.landmarks:
        raise ValueError(
            "hierarchical_one_shot runs the DENSE protocol per edge "
            "group (each group is already small); block_users="
            f"{cfg.block_users} / landmarks={cfg.landmarks} must be 0")
    if ccfg.backend == "numpy":
        raise ValueError("the group HAC stage is a batched (vmapped) "
                         "device NN-chain; use cluster backend 'jnp' or "
                         "'pallas'")

    feats, nv = sim.prepare_user_batch(features, n_valid, device=True)
    n_users, n_samples, d = feats.shape
    g = hcfg.n_groups
    perm = group_permutation(n_users, hcfg)
    inv_perm = np.argsort(perm)
    ng = n_users // g
    t_g = hcfg.group_clusters or n_clusters
    if not 1 <= t_g <= ng:
        raise ValueError(f"group_clusters={t_g} must be in [1, N/G={ng}]")
    n_entries = g * t_g
    if not 1 <= n_clusters <= n_entries:
        raise ValueError(
            f"n_clusters={n_clusters} must be in [1, G*T_g={n_entries}] — "
            "raise group_clusters or n_groups")

    top_k = min(cfg.top_k or d, d)
    impl = "pallas" if cfg.backend == "pallas" else cfg.impl
    hac_impl = "pallas" if ccfg.backend == "pallas" else "jnp"
    feats_g = feats[perm].reshape(g, ng, n_samples, d)
    nv_g = nv[perm].reshape(g, ng)

    # -- level 1: per-group protocol + HAC, batches of groups ---------------
    batch = hcfg.group_batch or g
    lam_parts, v_parts, local_parts = [], [], []
    for s in range(0, g, batch):
        big_r, lam_b, v_b = _batched_protocol(
            feats_g[s:s + batch], nv_g[s:s + batch], top_k,
            cfg.eig_floor, impl)
        labels_b, steps = _batched_hac_cut(
            big_r, n=ng, linkage=ccfg.linkage, impl=hac_impl,
            interpret=ccfg.interpret, n_clusters=t_g)
        bad = np.flatnonzero(np.asarray(steps) != ng - 1)
        if bad.size:                            # same witness as ClusterEngine
            raise ValueError(
                f"group HAC stopped early in group(s) {s + bad} — the "
                "group similarity likely contains NaN/Inf")
        lam_parts.append(lam_b.reshape(-1, top_k))
        v_parts.append(v_b.reshape(-1, d, top_k))
        local_parts.append(labels_b.reshape(-1))
    lam_g = jnp.concatenate(lam_parts)          # (N, k), group order
    v_g = jnp.concatenate(v_parts)              # (N, d, k), group order
    local_g = jnp.concatenate(local_parts)      # (N,), group order
    group_of = jnp.repeat(jnp.arange(g, dtype=jnp.int32), ng)

    # -- level 2: compress clusters -> directory entries --------------------
    entry_id = group_of * t_g + local_g         # (N,) in [0, E)
    lam_e, v_e, protos_e, counts_e = _compress_entries(
        lam_g, v_g, entry_id, n_entries=n_entries, top_k=top_k)

    # -- level 2: global clustering on signature-only relevance -------------
    r_global = sim.signature_relevance(lam_e, v_e, eig_floor=cfg.eig_floor)
    entry_labels = ClusterEngine(ccfg).labels(r_global, n_clusters)

    # -- stitch back to user order ------------------------------------------
    labels_g = entry_labels[entry_id]           # (N,), group order
    inv = jnp.asarray(inv_perm)
    ledger = CommLedger(n_users=ng, d=d, top_k=top_k,
                        model_params=model_params, mode="broadcast")
    return HierarchicalResult(
        labels=labels_g[inv], lam=lam_g[inv], v=v_g[inv],
        group_ids=group_of[inv], local_labels=local_g[inv],
        entry_labels=entry_labels, entry_lam=lam_e, entry_v=v_e,
        entry_protos=protos_e, entry_counts=counts_e,
        global_similarity=r_global, ledger=ledger)
