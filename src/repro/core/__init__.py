"""Paper core: one-shot data-similarity clustering for MT-HFL."""
from repro.core.similarity import (SimilarityConfig, pad_ragged, gram,
                                   spectrum, cross_project, relevance,
                                   relevance_matrix, symmetrize,
                                   similarity_matrix)
from repro.core.engine import (ProtocolEngine, ProtocolResult, BACKENDS,
                               make_user_mesh)
from repro.core.signature_engine import (SignatureConfig, SignatureEngine,
                                         SIGNATURE_BACKENDS, topk_spectrum,
                                         subspace_residual)
from repro.core.clustering import (hac, cut, hac_clusters, random_clusters,
                                   oracle_clusters, spectral_clusters,
                                   clustering_accuracy, adjusted_rand_index,
                                   Dendrogram)
from repro.core.cluster_engine import (ClusterConfig, ClusterEngine,
                                       DeviceDendrogram, CLUSTER_BACKENDS)
from repro.core.oneshot import one_shot_clustering, OneShotResult, CommLedger
from repro.core.membership_engine import (MembershipConfig, MembershipEngine,
                                          MembershipState, AssignResult,
                                          MEMBERSHIP_BACKENDS,
                                          signature_relevance)
