"""Device-resident signature ingest: raw data -> Gram -> top-k spectrum.

PRs 1-3 made the protocol, trainer and HAC cut device-resident, but the
pipeline still *started* on the host: per-user numpy ``feature_map``, a
materialized ``(N, n, d)`` feature stack, and a full ``jnp.linalg.eigh``
(O(d^3) per user) for signatures that only need ``top_k ~ 8`` eigenpairs.
The ``SignatureEngine`` moves the whole ingest onto the device:

  * **Fused featurize -> Gram.**  All four Phi maps
    (``repro.data.features``) run as jit-able jnp, vmapped over users.
  * **Row-chunk streaming.**  ``chunk_rows > 0`` accumulates
    ``G_i += Phi(X_chunk)^T Phi(X_chunk)`` online, so the peak working
    set is O(N * chunk * m) raw rows + the O(N * d'^2) Gram stack — the
    ``(N, n, d')`` feature stack never exists, making peak memory
    independent of n.  The ``pallas`` backend fuses project + accumulate
    into one ``kernels/featurize_gram`` pass (bf16 compute / fp32
    accumulate via ``compute_dtype="bf16"``).
  * **Batched top-k subspace iteration.**  ``topk_spectrum`` replaces the
    full ``eigh`` with orthogonal iteration + Rayleigh-Ritz on the PSD
    Gram stack: O(d^2 (k+oversample) iters) per user instead of O(d^3),
    batched over users as pure matmul/QR work.  ``eig="eigh"`` is the
    exact fallback switch, and ``subspace_residual`` detects
    non-convergence via the relative eigen-residual norm.

Backend selection mirrors the ``ProtocolEngine``/``ClusterEngine`` idiom:
``SignatureConfig.backend`` is ``"jnp"`` (reference jnp maths),
``"pallas"`` (fused kernel chunks) or ``"shard_map"`` (the user axis is
sharded — the engine's chunk step is reused inside
``ProtocolEngine.run_raw``'s sharded body, which owns the collectives).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import similarity as sim
from repro.data import features as feat

__all__ = ["SignatureConfig", "SignatureEngine", "SIGNATURE_BACKENDS",
           "EIG_METHODS", "topk_spectrum", "subspace_residual"]

SIGNATURE_BACKENDS = ("jnp", "pallas", "shard_map")
EIG_METHODS = ("subspace", "eigh")
_COMPUTE_DTYPES = ("fp32", "bf16")


@dataclasses.dataclass(frozen=True)
class SignatureConfig:
    """How raw user shards become ``(lam, V, G)`` signatures.

    Attributes:
      backend: ``"jnp"`` | ``"pallas"`` | ``"shard_map"`` — same idiom as
        ``SimilarityConfig.backend``.  ``pallas`` runs the fused
        ``kernels/featurize_gram`` project+accumulate kernel per chunk;
        ``shard_map`` marks the config for the sharded raw protocol
        (``ProtocolEngine.run_raw`` owns the mesh and collectives).
      chunk_rows: ``0`` ingests each user's rows in one pass; ``> 0``
        streams row-chunks of this size with online Gram accumulation —
        peak working set independent of n.
      eig: ``"subspace"`` (batched top-k orthogonal iteration,
        O(d^2 k iters)) or ``"eigh"`` (exact full decomposition, O(d^3)).
      subspace_iters: orthogonal-iteration G-applications, QR-ed every
        second one (error contracts like (lam_{p+1}/lam_k)^iters; Ritz
        values converge at the square).
      oversample: extra iterated columns beyond ``top_k`` — sharpens
        convergence on tight spectra for the cost of O(d * oversample).
      check: verify subspace convergence on every ingest —
        ``signatures()`` AND the ``ProtocolEngine.run_raw`` paths
        (including shard_map) raise ``RuntimeError`` when the relative
        eigen-residual exceeds ``resid_tol``.
      resid_tol: max relative eigen-residual the convergence check
        accepts before declaring non-convergence.
      compute_dtype: ``"fp32"`` exact path, or ``"bf16"`` matmul inputs
        with fp32 accumulation (kernel and jnp paths alike).
      mesh_axis: mesh axis users are sharded over (shard_map backend).
    """

    backend: str = "jnp"
    chunk_rows: int = 0
    eig: str = "subspace"
    subspace_iters: int = 20
    oversample: int = 8
    check: bool = False
    resid_tol: float = 1e-3
    compute_dtype: str = "fp32"
    mesh_axis: str = "data"

    def __post_init__(self):
        if self.backend not in SIGNATURE_BACKENDS:
            raise ValueError(f"backend must be one of {SIGNATURE_BACKENDS}, "
                             f"got {self.backend!r}")
        if self.chunk_rows < 0:
            raise ValueError(f"chunk_rows must be >= 0, "
                             f"got {self.chunk_rows}")
        if self.eig not in EIG_METHODS:
            raise ValueError(f"eig must be one of {EIG_METHODS}, "
                             f"got {self.eig!r}")
        if self.subspace_iters < 0:
            raise ValueError(f"subspace_iters must be >= 0, "
                             f"got {self.subspace_iters}")
        if self.oversample < 0:
            raise ValueError(f"oversample must be >= 0, "
                             f"got {self.oversample}")
        if self.resid_tol <= 0:
            raise ValueError(f"resid_tol must be positive, "
                             f"got {self.resid_tol}")
        if self.compute_dtype not in _COMPUTE_DTYPES:
            raise ValueError(f"compute_dtype must be one of "
                             f"{_COMPUTE_DTYPES}, got {self.compute_dtype!r}")


# ---------------------------------------------------------------------------
# Batched top-k spectrum: subspace (orthogonal) iteration vs eigh
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k",))
def _eigh_topk(grams: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Exact fallback: the SAME ``sim.spectrum`` primitive the
    pre-featurized engine uses, vmapped over the stack."""
    return jax.vmap(lambda g: sim.spectrum(g, k))(grams)


@partial(jax.jit, static_argnames=("k", "p", "iters", "seed"))
def _subspace_topk(grams: jax.Array, k: int, p: int, iters: int, seed: int
                   ) -> tuple[jax.Array, jax.Array]:
    n, d, _ = grams.shape
    q0 = jax.random.normal(jax.random.PRNGKey(seed), (d, p), jnp.float32)
    q0, _ = jnp.linalg.qr(q0)
    q = jnp.broadcast_to(q0, (n, d, p))

    # ``iters`` counts G-applications; re-orthogonalize every SECOND one
    # (G is PSD: two multiplies between QRs square the per-step column
    # growth, which fp32 absorbs easily, while halving the batched-QR
    # cost — the dominant non-matmul term on CPU).
    def body(_, q):
        z = grams @ (grams @ q)                     # (N, d, p) batched
        q, _ = jnp.linalg.qr(z)
        return q

    q = jax.lax.fori_loop(0, iters // 2, body, q)
    if iters % 2:
        q, _ = jnp.linalg.qr(grams @ q)
    # Rayleigh-Ritz on the iterated subspace: the (p, p) projected problem
    # costs O(p^3) << O(d^3) and upgrades eigenvalue accuracy to the
    # square of the subspace angle.
    gq = grams @ q
    b = jnp.einsum("ndp,ndq->npq", q, gq)
    b = (b + jnp.swapaxes(b, -1, -2)) / 2.0
    lam_b, w_b = jnp.linalg.eigh(b)                 # ascending
    lam = jnp.maximum(lam_b[..., ::-1], 0.0)[..., :k]
    v = (q @ w_b[..., ::-1])[..., :k]
    return lam, v


def topk_spectrum(grams: jax.Array, top_k: int, *, method: str = "subspace",
                  iters: int = 20, oversample: int = 8, seed: int = 0
                  ) -> tuple[jax.Array, jax.Array]:
    """Top-k eigenpairs of a PSD Gram stack ``(N, d, d)``, descending.

    Returns ``(lam (N, k), V (N, d, k))``.  ``method="subspace"`` runs
    batched orthogonal iteration on ``k + oversample`` columns and falls
    through to the exact ``eigh`` whenever the iterated subspace would
    cover (nearly) the whole space anyway — including ``top_k = d``.
    """
    if method not in EIG_METHODS:
        raise ValueError(f"method must be one of {EIG_METHODS}, "
                         f"got {method!r}")
    d = grams.shape[-1]
    k = min(top_k or d, d)
    p = min(k + oversample, d)
    if method == "eigh" or p >= d:
        return _eigh_topk(grams, k)
    return _subspace_topk(grams, k, p, iters, seed)


@jax.jit
def subspace_residual(grams: jax.Array, lam: jax.Array, v: jax.Array
                      ) -> jax.Array:
    """Relative eigen-residual ``max_k ||G v_k - lam_k v_k|| / lam_1``
    per user — the non-convergence detector for the subspace iteration
    (exact eigenpairs score ~float-eps; a stalled iteration does not).
    """
    r = grams @ v - v * lam[..., None, :]           # (N, d, k)
    num = jnp.linalg.norm(r, axis=-2)               # (N, k)
    scale = jnp.maximum(lam[..., :1], 1e-12)
    return jnp.max(num / scale, axis=-1)


# ---------------------------------------------------------------------------
# Chunked featurize -> Gram accumulation (the streaming step)
# ---------------------------------------------------------------------------

def _project_inputs(x_chunk: jax.Array, mask: jax.Array | None,
                    params: dict, fcfg: feat.FeatureConfig
                    ) -> tuple[jax.Array, jax.Array | None]:
    """Reduce any Phi kind to ``(z, w)`` with chunk Gram ``(z w)^T (z w)``
    (``w=None`` means identity) — the form the fused kernel consumes.
    The nonlinear conv front-end runs here in jnp; masking commutes with
    the trailing linear projection, so invalid rows contribute zero.
    ``mask=None`` means every row is valid (no masking pass)."""

    def masked(z):
        return z if mask is None else z * mask

    if fcfg.kind == "identity":
        return masked(x_chunk), None
    if fcfg.kind == "random_projection":
        return masked(x_chunk), params["w"]
    if fcfg.kind == "pca":
        return masked(x_chunk - params["mu"]), params["basis"]
    z = jax.vmap(
        lambda xc: feat._random_conv_features(xc, params["w1"],
                                              params["w2"], fcfg.image_hw)
    )(x_chunk)
    return masked(z), params.get("w_rp")


@partial(jax.jit,
         static_argnames=("fcfg", "backend", "compute_dtype",
                          "apply_mask"))
def _chunk_gram_accum(acc: jax.Array, x_chunk: jax.Array,
                      n_valid: jax.Array, start: jax.Array, params: dict,
                      fcfg: feat.FeatureConfig, backend: str,
                      compute_dtype: str, apply_mask: bool = True
                      ) -> jax.Array:
    """One streaming step: ``acc (N, d', d') += Phi(chunk)^T Phi(chunk)``.

    ``x_chunk (N, c, m)`` raw rows starting at global row ``start``; rows
    at or beyond each user's ``n_valid`` are masked to zero AFTER Phi
    (identical to zero-padding the featurized stack, for every kind
    including the affine ``pca``).  ``apply_mask=False`` skips the
    O(N*c*m) mask pass — only valid when the caller KNOWS every chunk
    row is a true data row.  Shared by all three backends — the
    shard_map raw protocol calls it per local shard.
    """
    x_chunk = x_chunk.astype(jnp.float32)
    if apply_mask:
        rows = start + jnp.arange(x_chunk.shape[1])
        mask = (rows[None, :] < n_valid[:, None]
                ).astype(jnp.float32)[..., None]
    else:
        mask = None
    z, w = _project_inputs(x_chunk, mask, params, fcfg)
    if backend == "pallas":
        from repro.kernels.featurize_gram import ops as fg_ops
        from repro.kernels.gram import ops as gram_ops

        if w is None:
            zc = z.astype(jnp.bfloat16) if compute_dtype == "bf16" else z
            g = jax.lax.map(lambda zi: gram_ops.gram_matrix(zi), zc)
        else:
            g = jax.lax.map(
                lambda zi: fg_ops.featurize_gram(
                    zi, w, compute_dtype=compute_dtype), z)
        return acc + g
    # Mirror the kernel's mixed precision exactly: bf16 matmul INPUTS
    # (projection and Gram alike), fp32 accumulation via
    # preferred_element_type.  The fp32 path uses the plain batched
    # matmul (fastest XLA:CPU lowering — one flattened GEMM).
    if compute_dtype == "bf16":
        z = z.astype(jnp.bfloat16)
        if w is not None:
            f = jnp.einsum("ncm,md->ncd", z, w.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
            f = f.astype(jnp.bfloat16)
        else:
            f = z
    else:
        f = z @ w if w is not None else z
    return acc + jnp.einsum("ncd,nce->nde", f, f,
                            preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class SignatureEngine:
    """One object that owns raw-data ingest: Phi, Gram streaming, top-k.

    ``feature_cfg`` fixes the shared Phi (pass the ``pca`` probe set via
    ``probe=`` — the config only pins its digest); ``cfg`` picks the
    execution strategy.  ``grams``/``signatures`` are the single-host
    entry points; the shard_map backend defers to
    ``ProtocolEngine.run_raw``, which reuses this engine's chunk step
    inside its sharded body.
    """

    def __init__(self, feature_cfg: feat.FeatureConfig,
                 cfg: SignatureConfig | None = None,
                 probe: np.ndarray | None = None):
        if not isinstance(feature_cfg, feat.FeatureConfig):
            raise TypeError("feature_cfg must be a FeatureConfig, got "
                            f"{type(feature_cfg).__name__}")
        self.feature_cfg = feature_cfg
        self.cfg = cfg or SignatureConfig()
        self._probe = probe
        self._params: dict[int, dict] = {}

    def params_for(self, m: int) -> dict:
        """Phi parameters for input dim ``m``, cached per engine AS
        DEVICE ARRAYS — so the per-chunk jit never re-uploads the
        projection matrices."""
        if m not in self._params:
            self._params[m] = {
                k: jnp.asarray(v)
                for k, v in feat.phi_params(self.feature_cfg, m,
                                            probe=self._probe).items()}
        return self._params[m]

    def out_dim(self, m: int) -> int:
        return feat.phi_out_dim(self.feature_cfg, m, probe=self._probe)

    def prepare(self, raw, n_valid=None) -> tuple[np.ndarray, jax.Array]:
        """Normalize raw input to ``(padded (N, n, m), n_valid (N,))``.

        Ragged lists of per-user ``(n_i, m)`` arrays are zero-padded ON
        THE HOST (``sim.prepare_user_batch(device=False)``) so the
        streaming path device-puts one row-chunk at a time.
        """
        return sim.prepare_user_batch(raw, n_valid, device=False)

    # -- ingest stages ------------------------------------------------------

    def accumulate_grams(self, raw, nv: jax.Array,
                         assume_full: bool = False) -> jax.Array:
        """The streaming core: ``raw (N, n, m)`` -> Grams ``(N, d', d')``.

        Streams ``chunk_rows`` rows at a time: each chunk is featurized
        and folded into the fp32 accumulator, then dies — the
        ``(N, n, d')`` feature stack never exists.  Works on host numpy
        (one row-chunk is device-put per step), on device arrays, and on
        traced values (``ProtocolEngine.run_raw`` calls this inside its
        shard_map body with the local user shard).

        ``assume_full=True`` declares every user's count equal to n, so
        the O(N*c*m) ragged mask pass is elided for chunks that lie
        entirely inside the data (the zero-padded tail chunk, if any, is
        still masked — ``pca``'s affine Phi needs it).
        """
        if isinstance(raw, jax.core.Tracer) or isinstance(
                nv, jax.core.Tracer):
            # inside a shard_map/jit trace: spans are host-side and would
            # record trace time, not run time — instrument nothing here
            return self._accumulate_grams(raw, nv, assume_full)
        with obs.span("signature.accumulate_grams",
                      n_users=raw.shape[0],
                      backend=self.cfg.backend) as sp:
            return sp.sync(self._accumulate_grams(raw, nv, assume_full))

    def _accumulate_grams(self, raw, nv: jax.Array,
                          assume_full: bool = False) -> jax.Array:
        n_users, n, m = raw.shape
        d_out = self.out_dim(m)
        params = self.params_for(m)
        chunk_backend = "pallas" if self.cfg.backend == "pallas" else "jnp"
        chunk = min(self.cfg.chunk_rows or n, n)
        acc = jnp.zeros((n_users, d_out, d_out), jnp.float32)
        prev = None
        for s in range(0, n, chunk):
            x_c = jnp.asarray(raw[:, s:s + chunk])
            padded_tail = x_c.shape[1] < chunk
            if padded_tail:                # square off the last chunk so
                x_c = jnp.pad(               # one compiled step serves all
                    x_c, ((0, 0), (0, chunk - x_c.shape[1]), (0, 0)))
            acc = _chunk_gram_accum(acc, x_c, nv,
                                    jnp.asarray(s, jnp.float32), params,
                                    self.feature_cfg, chunk_backend,
                                    self.cfg.compute_dtype,
                                    apply_mask=(not assume_full
                                                or padded_tail))
            # Bound the async dispatch queue to a 2-chunk window
            # (double-buffering): without this, jax enqueues EVERY chunk
            # transfer before the first step runs and the whole raw
            # array is simultaneously live — peak memory silently scales
            # with n, which is exactly what streaming must prevent.
            # (No-op under tracing: the shard_map body has no queue.)
            if prev is not None and not isinstance(prev, jax.core.Tracer):
                prev.block_until_ready()
            prev = acc
        return acc / jnp.maximum(nv, 1.0)[:, None, None]

    def grams(self, raw, n_valid=None) -> jax.Array:
        """Per-user Grams ``(N, d', d')`` straight from raw shards."""
        if self.cfg.backend == "shard_map":
            raise ValueError(
                "the shard_map signature backend runs inside "
                "ProtocolEngine.run_raw (it owns the mesh); use backend "
                "'jnp'/'pallas' for direct grams()")
        full = (n_valid is None
                and isinstance(raw, (jax.Array, np.ndarray)))
        raw, nv = self.prepare(raw, n_valid)
        return self.accumulate_grams(raw, nv, assume_full=full)

    def verify_convergence(self, resid: jax.Array) -> None:
        """Raise ``RuntimeError`` if any user's relative eigen-residual
        exceeds ``cfg.resid_tol`` (host sync — call outside jit)."""
        worst = float(jnp.max(resid))
        if not worst < self.cfg.resid_tol:
            raise RuntimeError(
                f"top-k subspace iteration did not converge: max "
                f"relative residual {worst:.2e} > tol "
                f"{self.cfg.resid_tol:.2e} — raise subspace_iters/"
                f"oversample or set eig='eigh'")

    def signatures(self, raw, n_valid=None, top_k: int = 8,
                   check: bool | None = None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Raw shards -> ``(lam (N, k), V (N, d', k), G (N, d', d'))``.

        ``lam``/``V`` are what users share (upload unchanged at O(k*d));
        ``G`` stays device-resident for cross-projection.  ``check``
        (default ``cfg.check``) verifies subspace convergence via the
        relative residual norm and raises ``RuntimeError`` above
        ``cfg.resid_tol``.
        """
        with obs.span("signature.signatures", top_k=top_k,
                      backend=self.cfg.backend) as sp:
            g = self.grams(raw, n_valid)
            lam, v = topk_spectrum(g, top_k, method=self.cfg.eig,
                                   iters=self.cfg.subspace_iters,
                                   oversample=self.cfg.oversample)
            sp.sync((lam, v))
        if self.cfg.check if check is None else check:
            self.verify_convergence(subspace_residual(g, lam, v))
        return lam, v, g
