"""Online cluster-identity serving — the MembershipEngine.

The paper's protocol estimates every cluster identity once, with all N
users present; a single newcomer would force a full O(N^2) protocol
re-run.  This module is the serving-side answer: after
``one_shot_clustering`` the GPS keeps a compact device-resident **cluster
directory** — per-cluster signature prototypes ``P_t = mean_{i in t}
V_i V_i^T`` plus the member spectra table — and decides a newcomer's
cluster identity from its existing ``(k x d)`` signature upload alone, in
O(T * k * d^2) per arrival, with zero training rounds.  IFCA-style
frameworks need a per-round loss probe against every cluster model; here
the signature the user already shared IS the probe.

Engine idiom mirrors ``ProtocolEngine``/``ClusterEngine``/
``SignatureEngine`` — one object, a config-selected backend:

  backend   | execution
  ----------|------------------------------------------------------------
  "numpy"   | host reference: np.einsum affinities, host lifecycle
  "jnp"     | jitted directory ops; one dispatch per arrival wave
  "pallas"  | the same program with the fused ``kernels/assign``
            | project + trace + argmax kernel (bf16 / fp32 accumulate)

Lifecycle on top of assignment:

  * ``assign``   — batched wave: affinities vs prototypes, labels +
                   confidence margins; low-margin / low-affinity arrivals
                   land in the ``unassigned`` bucket (label -1).
  * ``admit``    — append signatures to the table, update prototypes by
                   streaming mean.
  * ``evict``    — churn: masked removal + prototype down-date.
  * ``recluster``— drift trigger: when the unassigned fraction or the
                   prototype-shift norm trips the configured threshold,
                   re-run HAC over the CURRENT table via the
                   ``ClusterEngine`` (reused verbatim) on a
                   signature-only relevance matrix, then relabel to
                   maximize continuity with the previous directory.

The signature-only relevance uses the rank-k reconstruction
``G_i ~ V_i diag(lam_i) V_i^T`` — exactly the data users shared — so
``lamhat = ||diag(lam_i) (V_i^T v_j)||`` needs no private Grams and the
GPS can re-cluster without another protocol round.

**Robust prototypes (dirty-data serving).**  The plain mean projector has
breakdown point 0: one Byzantine signature upload (no norm check is
possible on an adversarial client) steers a whole cluster's directory
entry arbitrarily far.  ``MembershipConfig.aggregator`` selects a
resistant statistic over the member projectors ``V_i V_i^T``:

  aggregator | statistic                         | breakdown point
  -----------|-----------------------------------|----------------------
  "mean"     | streaming mean (the paper's)      | 0
  "trimmed"  | coordinate-wise trimmed mean,     | ``trim_frac``
             | ``trim_frac`` cut from each end   |
  "medians"  | coordinate-wise median-of-means   | ~``n_clean_groups/2``
             | over ``mom_groups`` member groups |

The resistant modes cannot be maintained by the O(1) streaming
admit/evict down-date (order statistics do not decompose), so those
paths fall back to a windowed recompute over the live table — the clean
"mean" path keeps its streaming update and its latency.  The drift
statistic has a matching robust variant: ``drift_stat="median"`` trips
the re-cluster trigger on the *median* per-cluster prototype shift
instead of the max, so one poisoned prototype cannot force re-cluster
thrash.  Corruption generators for exercising all of this live in
``repro.data.synthetic`` (``CorruptionSpec``) and the scenario matrix in
``repro.launch.membership``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core import similarity as sim
from repro.core.cluster_engine import ClusterConfig, ClusterEngine
from repro.core.engine import make_user_mesh
from repro.kernels import quant
from repro.kernels.assign.ref import assign_ref

__all__ = ["MembershipConfig", "MembershipEngine", "MembershipState",
           "AssignResult", "MEMBERSHIP_BACKENDS", "signature_relevance"]

MEMBERSHIP_BACKENDS = ("numpy", "jnp", "pallas")
AGGREGATORS = ("mean", "trimmed", "medians")
DRIFT_STATS = ("max", "median")
UNASSIGNED = -1


@dataclasses.dataclass(frozen=True)
class MembershipConfig:
    """Configuration of the online membership layer.

    Attributes:
      backend: "numpy" (host reference), "jnp" (jitted device directory)
        or "pallas" (fused ``kernels/assign`` arrival kernel).
      capacity: signature-table slots; ``0`` sizes the directory at
        2x the seed population on ``from_oneshot``/``seed``.
      affinity_floor: arrivals whose best affinity falls below this land
        in the unassigned bucket (label -1).  Affinities live in [0, 1].
      margin_floor: arrivals whose best-minus-second margin falls below
        this are unassigned — the outlier/drift statistic.
      recluster_unassigned_frac: drift trigger — re-cluster when the
        unassigned fraction of the table exceeds this.
      recluster_proto_shift: drift trigger — re-cluster when any
        prototype's relative Frobenius shift since the last (re)cluster
        exceeds this.
      eig_floor: relevance eigenvalue floor for the signature-only
        re-cluster similarity (same semantics as ``SimilarityConfig``).
      aggregator: prototype statistic over member projectors — "mean"
        (streaming, breakdown point 0), "trimmed" (coordinate-wise
        trimmed mean, resists up to a ``trim_frac`` fraction of
        Byzantine members per cluster) or "medians" (coordinate-wise
        median-of-means over ``mom_groups`` member groups).  The
        resistant modes recompute prototypes from the live table on
        admit/evict (windowed recompute) instead of the streaming
        update.
      trim_frac: per-end trim fraction for ``aggregator="trimmed"``,
        in [0, 0.5).
      mom_groups: member-group count for ``aggregator="medians"``; the
        statistic resists corruption while fewer than half the occupied
        groups contain a poisoned member.
      drift_stat: "max" trips ``recluster_proto_shift`` on the worst
        per-cluster prototype shift (the PR-5 statistic); "median" on
        the median shift — robust to a single poisoned prototype.
      linkage: HAC linkage handed to the ``ClusterEngine`` on re-cluster.
      compute_dtype: pallas assign kernel precision — "bf16" matmul
        inputs with fp32 accumulation (default) or exact "fp32".
      directory_dtype: storage dtype of the prototype table — "f32"
        (exact), "bf16" (2x memory cut) or "int8" (4x, symmetric
        per-prototype scales from ``kernels.quant``).  The pallas
        backend dequantizes inside the assign kernel's epilogue; the
        jnp/numpy paths dequantize before scoring.  Streaming
        admit/evict updates dequant -> update -> requant, so the table
        never needs a resident f32 copy.
      interpret: Pallas interpret-mode override (default: lowered on
        TPU/GPU, interpret on CPU via ``kernels.dispatch``), consulted
        by the pallas backend only.
    """

    backend: str = "numpy"
    capacity: int = 0
    affinity_floor: float = 0.0
    margin_floor: float = 0.0
    recluster_unassigned_frac: float = 0.25
    recluster_proto_shift: float = 0.75
    eig_floor: float = 1e-6
    aggregator: str = "mean"
    trim_frac: float = 0.1
    mom_groups: int = 5
    drift_stat: str = "max"
    linkage: str = "average"
    compute_dtype: str = "bf16"
    directory_dtype: str = "f32"
    interpret: bool | None = None

    def __post_init__(self):
        if self.backend not in MEMBERSHIP_BACKENDS:
            raise ValueError(f"backend must be one of "
                             f"{MEMBERSHIP_BACKENDS}, got {self.backend!r}")
        if self.capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {self.capacity}")
        if not 0.0 < self.recluster_unassigned_frac <= 1.0:
            raise ValueError(f"recluster_unassigned_frac must be in "
                             f"(0, 1], got {self.recluster_unassigned_frac}")
        if self.recluster_proto_shift <= 0:
            raise ValueError(f"recluster_proto_shift must be positive, "
                             f"got {self.recluster_proto_shift}")
        if self.eig_floor <= 0:
            raise ValueError(f"eig_floor must be positive, "
                             f"got {self.eig_floor}")
        if self.aggregator not in AGGREGATORS:
            raise ValueError(f"aggregator must be one of {AGGREGATORS}, "
                             f"got {self.aggregator!r}")
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError(f"trim_frac must be in [0, 0.5), "
                             f"got {self.trim_frac}")
        if self.mom_groups < 1:
            raise ValueError(f"mom_groups must be >= 1, "
                             f"got {self.mom_groups}")
        if self.drift_stat not in DRIFT_STATS:
            raise ValueError(f"drift_stat must be one of {DRIFT_STATS}, "
                             f"got {self.drift_stat!r}")
        if self.compute_dtype not in ("fp32", "bf16"):
            raise ValueError(f"compute_dtype must be 'fp32' or 'bf16', "
                             f"got {self.compute_dtype!r}")
        if self.directory_dtype not in quant.DIRECTORY_DTYPES:
            raise ValueError(f"directory_dtype must be one of "
                             f"{quant.DIRECTORY_DTYPES}, "
                             f"got {self.directory_dtype!r}")


@dataclasses.dataclass(frozen=True)
class MembershipState:
    """The cluster directory: signature table + prototypes.

    Slots are fixed at ``capacity``; ``valid`` marks occupied ones and
    ``labels`` holds cluster ids (``-1`` = unassigned bucket / empty
    slot).  ``protos0``/``counts`` snapshot the prototypes at the last
    (re)cluster — the reference the drift statistic measures against.
    Arrays are jnp on the device backends, numpy on the reference.

    ``protos``/``protos0`` live in ``MembershipConfig.directory_dtype``
    (f32 exact, bf16 or int8 quantized); ``proto_scales`` /
    ``proto0_scales`` carry the per-prototype symmetric int8 scales
    (``None`` for f32/bf16).  ``directory_bytes`` is the resident
    serving-directory footprint the quantized dtypes shrink.
    """

    lam: jax.Array | np.ndarray        # (cap, k) member spectra
    v: jax.Array | np.ndarray          # (cap, d, k) member eigenvectors
    labels: jax.Array | np.ndarray     # (cap,) i32, -1 = unassigned/empty
    valid: jax.Array | np.ndarray      # (cap,) bool
    protos: jax.Array | np.ndarray     # (T, d, d) directory-dtype table
    counts: jax.Array | np.ndarray     # (T,) members per cluster
    protos0: jax.Array | np.ndarray    # (T, d, d) snapshot at last cluster
    n_clusters: int
    n_reclusters: int = 0
    proto_scales: jax.Array | np.ndarray | None = None   # (T,) int8 scales
    proto0_scales: jax.Array | np.ndarray | None = None

    @property
    def capacity(self) -> int:
        return int(self.lam.shape[0])

    @property
    def directory_bytes(self) -> int:
        """Resident bytes of the serving directory (table + scales)."""
        return quant.directory_nbytes(self.protos, self.proto_scales)

    @property
    def protos_f32(self) -> jax.Array | np.ndarray:
        """The dequantized ``(T, d, d)`` prototype view (f32)."""
        return quant.dequantize_directory(self.protos, self.proto_scales)

    @property
    def n_members(self) -> int:
        return int(np.asarray(self.valid).sum())

    @property
    def n_unassigned(self) -> int:
        va, lb = np.asarray(self.valid), np.asarray(self.labels)
        return int((va & (lb < 0)).sum())


@dataclasses.dataclass(frozen=True)
class AssignResult:
    """One arrival wave's verdict: labels (-1 = unassigned), the full
    affinity rows, and the confidence margins."""

    labels: jax.Array | np.ndarray     # (B,) i32
    affinity: jax.Array | np.ndarray   # (B, T)
    margin: jax.Array | np.ndarray     # (B,)


# ---------------------------------------------------------------------------
# Device directory primitives (shared by the jnp and pallas backends)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_clusters",))
def _protos_from_table(v, labels, valid, *, n_clusters: int):
    """Per-cluster mean projector from the live table rows."""
    member = ((labels[:, None] == jnp.arange(n_clusters)[None])
              & valid[:, None]).astype(jnp.float32)          # (cap, T)
    counts = member.sum(axis=0)
    outer = jnp.einsum("cdk,cek->cde", v, v)                 # (cap, d, d)
    protos = jnp.einsum("ct,cde->tde", member, outer)
    return protos / jnp.maximum(counts, 1.0)[:, None, None], counts


@partial(jax.jit, static_argnames=("n_clusters", "aggregator", "trim_frac",
                                   "mom_groups"))
def _protos_from_table_robust(v, labels, valid, *, n_clusters: int,
                              aggregator: str, trim_frac: float,
                              mom_groups: int):
    """Resistant per-cluster prototype statistics over member projectors.

    "trimmed": per coordinate of the flattened ``V_i V_i^T``, drop the
    ``floor(m * trim_frac)`` smallest and largest member values and
    average the rest — bounded influence for up to a ``trim_frac``
    fraction of Byzantine members per cluster.

    "medians": members are split round-robin (by live-slot rank) into
    ``mom_groups`` groups; the prototype is the coordinate-wise median
    of the group means — resists corruption while fewer than half the
    occupied groups are poisoned.

    Order statistics do not stream, so this is the *windowed recompute*
    the resistant admit/evict paths pay; one ``lax.map`` over clusters
    keeps peak memory at one (cap, d*d) sort per cluster.
    """
    cap, d, _k = v.shape
    member = (labels[:, None] == jnp.arange(n_clusters)[None]) \
        & valid[:, None]                                     # (cap, T)
    counts = member.sum(axis=0).astype(jnp.float32)
    outer = jnp.einsum("cdk,cek->cde", v, v).reshape(cap, d * d)

    def trimmed(mem_t):
        m = mem_t.sum().astype(jnp.int32)
        g = jnp.floor(m.astype(jnp.float32) * trim_frac).astype(jnp.int32)
        # non-members sort to the top end; kept ranks stay below m - g
        s = jnp.sort(jnp.where(mem_t[:, None], outer, jnp.inf), axis=0)
        rank = jnp.arange(cap, dtype=jnp.int32)[:, None]
        keep = (rank >= g) & (rank < m - g)
        kept = jnp.where(keep, s, 0.0)                       # inf never kept
        return kept.sum(axis=0) / jnp.maximum(m - 2 * g, 1)

    def medians(mem_t):
        rank = jnp.cumsum(mem_t) - 1                         # rank among live
        gid = jnp.where(mem_t, rank % mom_groups, mom_groups)
        onehot = (gid[:, None] == jnp.arange(mom_groups)[None]
                  ).astype(jnp.float32)                      # (cap, G)
        gcnt = onehot.sum(axis=0)                            # (G,)
        gsum = onehot.T @ jnp.where(mem_t[:, None], outer, 0.0)
        gmean = gsum / jnp.maximum(gcnt, 1.0)[:, None]
        nv = (gcnt > 0).sum().astype(jnp.int32)
        s = jnp.sort(jnp.where((gcnt > 0)[:, None], gmean, jnp.inf), axis=0)
        lo = jnp.clip((nv - 1) // 2, 0, mom_groups - 1)
        hi = jnp.clip(nv // 2, 0, mom_groups - 1)
        med = (jnp.take(s, lo, axis=0) + jnp.take(s, hi, axis=0)) / 2.0
        return jnp.where(nv > 0, med, 0.0)

    one = trimmed if aggregator == "trimmed" else medians
    protos = jax.lax.map(one, member.T)                      # (T, d*d)
    return protos.reshape(n_clusters, d, d).astype(jnp.float32), counts


def _apply_floors(labels, best, margin, affinity_floor, margin_floor):
    """The unassigned-bucket rule, shared by every device verdict path
    (the numpy backend keeps an independent host reference on purpose —
    backend agreement is parity-TESTED, not shared-by-construction)."""
    out = (best < affinity_floor) | (margin < margin_floor)
    return jnp.where(out, UNASSIGNED, labels).astype(jnp.int32)


def _verdict_from_affinity(aff, affinity_floor, margin_floor):
    """``(B, T)`` affinity rows -> ``(labels, margin)`` with floor
    bucketing — same argmax/margin semantics as ``assign_ref`` and the
    fused kernel, for callers that already hold the affinity rows (the
    sharded directory path)."""
    labels = jnp.argmax(aff, axis=1).astype(jnp.int32)
    best = jnp.max(aff, axis=1)
    if aff.shape[1] == 1:
        margin = best
    else:
        cols = jnp.arange(aff.shape[1], dtype=jnp.int32)
        margin = best - jnp.max(
            jnp.where(cols[None] == labels[:, None], -jnp.inf, aff),
            axis=1)
    return _apply_floors(labels, best, margin, affinity_floor,
                         margin_floor), margin


def _assign_device(v_wave, protos, counts, affinity_floor, margin_floor,
                   *, scales=None, impl: str, compute_dtype: str,
                   interpret: bool | None):
    # NOT jitted at this level: the pallas path resolves tile sizes
    # through the tuning cache (a host-side lookup) before its own jit.
    if impl == "pallas":
        from repro.kernels.assign import ops as assign_ops

        aff, labels, margin = assign_ops.assign(
            v_wave, protos, counts > 0, compute_dtype=compute_dtype,
            interpret=interpret, scales=scales)
        return _finish_assign_device(labels, aff, margin, affinity_floor,
                                     margin_floor)
    return _assign_device_ref(v_wave, protos, counts, scales,
                              affinity_floor, margin_floor)


@jax.jit
def _finish_assign_device(labels, aff, margin, affinity_floor, margin_floor):
    labels = _apply_floors(labels, jnp.max(aff, axis=1), margin,
                           affinity_floor, margin_floor)
    return labels, aff, margin


@jax.jit
def _assign_device_ref(v_wave, protos, counts, scales, affinity_floor,
                       margin_floor):
    protos = quant.dequantize_directory(protos, scales)
    aff, labels, margin = assign_ref(v_wave, protos, counts > 0)
    labels = _apply_floors(labels, jnp.max(aff, axis=1), margin,
                           affinity_floor, margin_floor)
    return labels, aff, margin


@jax.jit
def _wave_outer_sums(v_wave, labels, n_clusters_arr):
    """Per-cluster sums of admitted ``V V^T`` (unassigned rows drop out
    through the one-hot, exactly like the ``stack_layout`` scatter)."""
    t = n_clusters_arr.shape[0]
    onehot = (labels[:, None] == jnp.arange(t)[None]).astype(jnp.float32)
    outer = jnp.einsum("bdk,bek->bde", v_wave, v_wave)
    return jnp.einsum("bt,bde->tde", onehot, outer), onehot.sum(axis=0)


@partial(jax.jit, static_argnames=("sign",))
def _proto_update(protos, counts, delta, m, *, sign: float):
    """Streaming-mean prototype update: admit (+1) or evict (-1)."""
    new_counts = jnp.maximum(counts + sign * m, 0.0)
    num = protos * counts[:, None, None] + sign * delta
    upd = num / jnp.maximum(new_counts, 1.0)[:, None, None]
    return jnp.where((new_counts > 0)[:, None, None], upd,
                     jnp.zeros_like(upd)), new_counts


# Canonical home is ``core.similarity`` (the hierarchy global stage uses
# it too); re-exported here because it is directory-serving API surface.
signature_relevance = sim.signature_relevance


def _match_labels(new_labels: np.ndarray, old_labels: np.ndarray,
                  n_clusters: int) -> np.ndarray:
    """Greedy-overlap relabeling of a fresh cut onto the previous
    directory ids, so serving continuity survives a re-cluster (HAC cut
    ids are arbitrary).  Host-side — re-clusters are rare events.
    Canonical implementation: ``core.hierarchy.greedy_match_labels``."""
    from repro.core.hierarchy import greedy_match_labels

    return greedy_match_labels(new_labels, old_labels, n_clusters)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class MembershipEngine:
    """One object that owns online cluster-identity serving.

    Functional core, stateful shell: every lifecycle operation is a pure
    transition on a ``MembershipState``; the engine holds the current
    directory in ``self.state`` and replaces it in place, so a serving
    loop is ``engine.assign(...) -> engine.admit(...) ->
    engine.maybe_recluster()``.
    """

    def __init__(self, cfg: MembershipConfig | None = None):
        self.cfg = cfg or MembershipConfig()
        self.state: MembershipState | None = None

    # -- construction -------------------------------------------------------

    @classmethod
    def from_oneshot(cls, result, cfg: MembershipConfig | None = None,
                     capacity: int | None = None) -> "MembershipEngine":
        """Build the cluster directory from a ``OneShotResult``.

        The one-shot protocol already produced everything the directory
        needs: the per-user signatures (``result.lam``, ``result.v`` —
        the same ``(k x d)`` blocks users uploaded) and the GPS labels.
        """
        if getattr(result, "lam", None) is None or result.v is None:
            raise ValueError(
                "OneShotResult carries no signatures (lam/v) — run "
                "one_shot_clustering from this repo version, which "
                "returns them on every backend")
        eng = cls(cfg)
        labels = np.asarray(result.labels)
        eng.seed(result.lam, result.v, labels,
                 n_clusters=int(labels.max()) + 1, capacity=capacity)
        return eng

    def seed(self, lam, v, labels, n_clusters: int,
             capacity: int | None = None) -> MembershipState:
        """Initialize the directory from seed signatures + labels."""
        lam = np.asarray(lam, np.float32)
        v = np.asarray(v, np.float32)
        labels = np.asarray(labels, np.int32)
        n, k = lam.shape
        d = v.shape[1]
        cap = capacity or self.cfg.capacity or 2 * n
        if cap < n:
            raise ValueError(f"capacity {cap} < seed population {n}")
        if not 1 <= n_clusters:
            raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
        lam_t = np.zeros((cap, k), np.float32)
        v_t = np.zeros((cap, d, k), np.float32)
        lab_t = np.full((cap,), UNASSIGNED, np.int32)
        valid = np.zeros((cap,), bool)
        lam_t[:n], v_t[:n], lab_t[:n], valid[:n] = lam, v, labels, True
        if self.on_device:
            lam_t, v_t = jnp.asarray(lam_t), jnp.asarray(v_t)
            lab_t, valid = jnp.asarray(lab_t), jnp.asarray(valid)
        protos, counts = self._rebuild_protos(v_t, lab_t, valid, n_clusters)
        table, scales = self._quantize(protos)
        self.state = MembershipState(
            lam=lam_t, v=v_t, labels=lab_t, valid=valid, protos=table,
            counts=counts, protos0=table, n_clusters=n_clusters,
            proto_scales=scales, proto0_scales=scales)
        if obs.enabled():
            obs.gauge("directory_bytes", self.state.directory_bytes)
            obs.event("seed", n_members=n, n_clusters=n_clusters,
                      capacity=cap, backend=self.cfg.backend)
        return self.state

    @property
    def on_device(self) -> bool:
        return self.cfg.backend != "numpy"

    def _require_state(self) -> MembershipState:
        if self.state is None:
            raise ValueError("directory is empty — seed() or "
                             "from_oneshot() first")
        return self.state

    def _quantize(self, protos):
        """f32 prototypes -> (directory-dtype table, scales | None)."""
        return quant.quantize_directory(protos, self.cfg.directory_dtype)

    @staticmethod
    def _dequantize(st: MembershipState):
        return quant.dequantize_directory(st.protos, st.proto_scales)

    def _rebuild_protos(self, v, labels, valid, n_clusters: int):
        agg = self.cfg.aggregator
        if self.on_device:
            if agg == "mean":
                return _protos_from_table(v, labels, valid,
                                          n_clusters=n_clusters)
            return _protos_from_table_robust(
                v, labels, valid, n_clusters=n_clusters, aggregator=agg,
                trim_frac=self.cfg.trim_frac,
                mom_groups=self.cfg.mom_groups)
        if agg != "mean":
            return self._np_robust_protos(v, labels, valid, n_clusters)
        member = ((np.asarray(labels)[:, None] == np.arange(n_clusters))
                  & np.asarray(valid)[:, None]).astype(np.float32)
        counts = member.sum(axis=0)
        outer = np.einsum("cdk,cek->cde", v, v)
        protos = (np.einsum("ct,cde->tde", member, outer)
                  / np.maximum(counts, 1.0)[:, None, None])
        return protos.astype(np.float32), counts.astype(np.float32)

    def _np_robust_protos(self, v, labels, valid, n_clusters: int):
        """Host reference of the resistant aggregators — an independent
        implementation on purpose (backend agreement is parity-TESTED,
        not shared-by-construction, same contract as ``assign``)."""
        v = np.asarray(v, np.float32)
        labels, valid = np.asarray(labels), np.asarray(valid)
        d = v.shape[1]
        protos = np.zeros((n_clusters, d, d), np.float32)
        counts = np.zeros((n_clusters,), np.float32)
        for t in range(n_clusters):
            mem = np.flatnonzero((labels == t) & valid)
            counts[t] = len(mem)
            if not len(mem):
                continue
            outers = np.einsum("cdk,cek->cde", v[mem], v[mem]
                               ).reshape(len(mem), d * d)
            m = len(mem)
            if self.cfg.aggregator == "trimmed":
                g = int(np.floor(m * self.cfg.trim_frac))
                flat = np.sort(outers, axis=0)[g:m - g].mean(axis=0)
            else:                                            # medians
                gid = np.arange(m) % self.cfg.mom_groups
                gmeans = np.stack(
                    [outers[gid == j].mean(axis=0)
                     for j in range(self.cfg.mom_groups)
                     if (gid == j).any()])
                flat = np.median(gmeans, axis=0)
            protos[t] = flat.reshape(d, d)
        return protos, counts

    # -- assignment ---------------------------------------------------------

    def assign(self, lam, v) -> AssignResult:
        """Batched arrival wave -> labels + affinities + margins.

        ``lam (B, k)`` rides along for the subsequent ``admit`` (it is
        what the newcomer uploaded); the affinity itself needs only
        ``v (B, d, k)``.  One dispatch per wave on the device backends.
        """
        st = self._require_state()
        t0 = obs.now()
        with obs.span("membership.assign", backend=self.cfg.backend) as sp:
            res = self._assign(st, v)
            # labels alone gate the whole one-dispatch wave program, so
            # blocking on them times the full device computation without
            # paying three separate readiness walks
            sp.sync(res.labels)
        if obs.enabled():
            obs.observe("assign_latency_us", (obs.now() - t0) * 1e6)
            obs.count("membership.assign_waves")
            # compare on the host: a jnp == here would be a full jax
            # dispatch per wave, dwarfing the rest of the telemetry
            labels_np = np.asarray(res.labels)
            obs.event("assign_wave", n=int(labels_np.shape[0]),
                      n_unassigned=int((labels_np == UNASSIGNED).sum()),
                      backend=self.cfg.backend)
        return res

    def _assign(self, st: MembershipState, v) -> AssignResult:
        if self.on_device:
            labels, aff, margin = _assign_device(
                jnp.asarray(v, jnp.float32), st.protos, st.counts,
                self.cfg.affinity_floor, self.cfg.margin_floor,
                scales=st.proto_scales,
                impl=("pallas" if self.cfg.backend == "pallas" else "jnp"),
                compute_dtype=self.cfg.compute_dtype,
                interpret=self.cfg.interpret)
            return AssignResult(labels=labels, affinity=aff, margin=margin)
        v = np.asarray(v, np.float32)
        k = v.shape[-1]
        protos = self._dequantize(st)
        aff = np.einsum("bdk,tde,bek->bt", v, protos, v) / k
        aff = np.where(st.counts > 0, aff, -np.inf)
        labels = aff.argmax(axis=1).astype(np.int32)
        best = aff.max(axis=1)
        if st.n_clusters == 1:
            margin = best.copy()
        else:
            cols = np.arange(st.n_clusters)
            margin = best - np.where(cols[None] == labels[:, None],
                                     -np.inf, aff).max(axis=1)
        out = (best < self.cfg.affinity_floor) | \
              (margin < self.cfg.margin_floor)
        labels = np.where(out, UNASSIGNED, labels).astype(np.int32)
        return AssignResult(labels=labels, affinity=aff, margin=margin)

    def assign_sharded(self, lam, v, mesh=None,
                       axis: str = "data") -> AssignResult:
        """``assign`` with the DIRECTORY sharded over a mesh axis: each
        device scores the wave against its local prototype shard, one
        all_gather assembles the ``(B, T)`` affinity rows, and the
        argmax/margin/floor logic runs replicated — bitwise the same
        verdict as the single-device path.  ``T`` must divide the axis.
        """
        st = self._require_state()
        if not self.on_device:
            raise ValueError("assign_sharded needs a device backend "
                             "('jnp'/'pallas'); numpy is host-only")
        mesh = mesh or make_user_mesh(axis)
        n_dev = mesh.shape[axis]
        if st.n_clusters % n_dev:
            raise ValueError(f"n_clusters={st.n_clusters} not divisible "
                             f"by mesh axis {axis!r} of size {n_dev}")
        floors = (self.cfg.affinity_floor, self.cfg.margin_floor)

        def body(v_wave, protos, counts):
            k = v_wave.shape[-1]
            aff_l = jnp.einsum("bdk,tde,bek->bt", v_wave, protos,
                               v_wave) / k                  # (B, T_local)
            aff_l = jnp.where((counts > 0)[None, :], aff_l, -jnp.inf)
            aff = jnp.moveaxis(
                jax.lax.all_gather(aff_l.T, axis, tiled=True), 0, 1)
            labels, margin = _verdict_from_affinity(aff, *floors)
            return labels, aff, margin

        fn = shard_map(body, mesh=mesh, in_specs=(P(), P(axis), P(axis)),
                       out_specs=(P(), P(), P()), check_rep=False)
        with mesh:
            v_w = jax.device_put(jnp.asarray(v, jnp.float32),
                                 NamedSharding(mesh, P()))
            # dequantize before sharding: the per-shard einsum path has no
            # in-kernel dequant epilogue, and scales would need their own
            # matching shard layout
            protos = jax.device_put(jnp.asarray(self._dequantize(st)),
                                    NamedSharding(mesh, P(axis)))
            counts = jax.device_put(st.counts, NamedSharding(mesh, P(axis)))
            labels, aff, margin = jax.jit(fn)(v_w, protos, counts)
        return AssignResult(labels=labels, affinity=aff, margin=margin)

    # -- lifecycle ----------------------------------------------------------

    def _free_slots(self, n: int) -> np.ndarray:
        st = self._require_state()
        free = np.flatnonzero(~np.asarray(st.valid))
        if len(free) < n:
            raise ValueError(
                f"directory full: {n} arrivals but only {len(free)} free "
                f"slots of {st.capacity} — grow MembershipConfig.capacity")
        return free[:n].astype(np.int32)

    def admit(self, lam, v, labels) -> np.ndarray:
        """Append an assigned wave to the table (streaming-mean prototype
        update; unassigned rows join the table but no prototype).
        Resistant aggregators cannot down-/up-date order statistics in
        O(1), so they pay a windowed recompute over the live table
        instead.  Returns the occupied slot indices (for ``evict``)."""
        with obs.span("membership.admit") as sp:
            slots = self._admit(lam, v, labels)
            sp.sync(self.state.protos)
        if obs.enabled():
            st = self.state
            obs.count("membership.admits", len(slots))
            obs.gauge("directory_bytes", st.directory_bytes)
            obs.event("admit", n=len(slots), slots=slots,
                      n_members=int(st.n_members))
        return slots

    def _admit(self, lam, v, labels) -> np.ndarray:
        st = self._require_state()
        lam = np.asarray(lam, np.float32)
        slots = self._free_slots(lam.shape[0])
        labels = np.asarray(labels, np.int32)
        streaming = self.cfg.aggregator == "mean"
        if self.on_device:
            v_w = jnp.asarray(v, jnp.float32)
            lab_w = jnp.asarray(labels)
            sl = jnp.asarray(slots)
            lam_t = st.lam.at[sl].set(jnp.asarray(lam))
            v_t = st.v.at[sl].set(v_w)
            lab_t = st.labels.at[sl].set(lab_w)
            valid = st.valid.at[sl].set(True)
            if streaming:
                delta, m = _wave_outer_sums(v_w, lab_w, st.counts)
                protos, counts = _proto_update(self._dequantize(st),
                                               st.counts, delta, m,
                                               sign=1.0)
            else:
                protos, counts = self._rebuild_protos(v_t, lab_t, valid,
                                                      st.n_clusters)
            table, scales = self._quantize(protos)
            self.state = dataclasses.replace(
                st, lam=lam_t, v=v_t, labels=lab_t, valid=valid,
                protos=table, counts=counts, proto_scales=scales)
            return slots
        v = np.asarray(v, np.float32)
        lam_t, v_t = st.lam.copy(), st.v.copy()
        lab_t, valid = st.labels.copy(), st.valid.copy()
        lam_t[slots], v_t[slots], lab_t[slots], valid[slots] = \
            lam, v, labels, True
        if streaming:
            protos, counts = self._np_proto_shift(st, v, labels, +1.0)
        else:
            protos, counts = self._rebuild_protos(v_t, lab_t, valid,
                                                  st.n_clusters)
        table, scales = self._quantize(protos)
        self.state = dataclasses.replace(
            st, lam=lam_t, v=v_t, labels=lab_t, valid=valid,
            protos=table, counts=counts, proto_scales=scales)
        return slots

    def evict(self, slots) -> None:
        """Masked removal of table slots (churn): free the rows and
        down-date the prototypes by the departing members' projectors."""
        with obs.span("membership.evict") as sp:
            self._evict(slots)
            sp.sync(self.state.protos)
        if obs.enabled():
            st = self.state
            obs.count("membership.evicts", len(np.asarray(slots)))
            obs.gauge("directory_bytes", st.directory_bytes)
            obs.event("evict", n=len(np.asarray(slots)),
                      slots=np.asarray(slots),
                      n_members=int(st.n_members))

    def _evict(self, slots) -> None:
        st = self._require_state()
        slots = np.asarray(slots, np.int32)
        if len(np.unique(slots)) != len(slots):
            # a repeated slot would down-date the prototype twice for one
            # departure, silently corrupting the streaming mean
            raise ValueError(f"duplicate slots in evict: {slots.tolist()}")
        occupied = np.asarray(st.valid)[slots]
        if not occupied.all():
            raise ValueError(f"evicting empty slots "
                             f"{slots[~occupied].tolist()}")
        labels_out = np.asarray(st.labels)[slots]
        streaming = self.cfg.aggregator == "mean"
        if self.on_device:
            sl = jnp.asarray(slots)
            lab_t = st.labels.at[sl].set(UNASSIGNED)
            valid = st.valid.at[sl].set(False)
            if streaming:
                delta, m = _wave_outer_sums(st.v[sl],
                                            jnp.asarray(labels_out),
                                            st.counts)
                protos, counts = _proto_update(self._dequantize(st),
                                               st.counts, delta, m,
                                               sign=-1.0)
            else:
                protos, counts = self._rebuild_protos(st.v, lab_t, valid,
                                                      st.n_clusters)
            table, scales = self._quantize(protos)
            self.state = dataclasses.replace(
                st, labels=lab_t, valid=valid,
                protos=table, counts=counts, proto_scales=scales)
            return
        lab_t, valid = st.labels.copy(), st.valid.copy()
        lab_t[slots], valid[slots] = UNASSIGNED, False
        if streaming:
            protos, counts = self._np_proto_shift(
                st, np.asarray(st.v)[slots], labels_out, -1.0)
        else:
            protos, counts = self._rebuild_protos(st.v, lab_t, valid,
                                                  st.n_clusters)
        table, scales = self._quantize(protos)
        self.state = dataclasses.replace(st, labels=lab_t, valid=valid,
                                         protos=table, counts=counts,
                                         proto_scales=scales)

    def _np_proto_shift(self, st: MembershipState, v: np.ndarray,
                        labels: np.ndarray, sign: float):
        onehot = (labels[:, None] == np.arange(st.n_clusters)
                  ).astype(np.float32)
        outer = np.einsum("bdk,bek->bde", v, v)
        delta = np.einsum("bt,bde->tde", onehot, outer)
        m = onehot.sum(axis=0)
        counts = np.maximum(st.counts + sign * m, 0.0)
        num = self._dequantize(st) * st.counts[:, None, None] + sign * delta
        protos = np.where((counts > 0)[:, None, None],
                          num / np.maximum(counts, 1.0)[:, None, None],
                          0.0).astype(np.float32)
        return protos, counts.astype(np.float32)

    # -- drift statistics + re-cluster --------------------------------------

    def drift_stats(self) -> dict:
        """The two trigger statistics: unassigned fraction of the live
        table and the relative prototype Frobenius shift since the last
        (re)cluster — the worst per-cluster shift by default, the median
        under ``drift_stat="median"`` (one poisoned prototype then
        cannot trip re-cluster thrash on its own)."""
        st = self._require_state()
        n = max(st.n_members, 1)
        p = np.asarray(quant.dequantize_directory(st.protos,
                                                  st.proto_scales))
        p0 = np.asarray(quant.dequantize_directory(st.protos0,
                                                   st.proto0_scales))
        shift = np.linalg.norm((p - p0).reshape(st.n_clusters, -1), axis=1)
        base = np.maximum(
            np.linalg.norm(p0.reshape(st.n_clusters, -1), axis=1), 1e-6)
        rel = shift / base
        stat = (np.median(rel) if self.cfg.drift_stat == "median"
                else rel.max())
        stats = {
            "unassigned_frac": st.n_unassigned / n,
            "proto_shift": float(stat),
            "proto_shift_max": float(rel.max()),
            "n_members": st.n_members,
            "n_reclusters": st.n_reclusters,
        }
        if obs.enabled():
            obs.gauge("unassigned_frac", stats["unassigned_frac"])
            obs.gauge("proto_shift", stats["proto_shift"])
        return stats

    def should_recluster(self) -> bool:
        s = self.drift_stats()
        return (s["unassigned_frac"] > self.cfg.recluster_unassigned_frac
                or s["proto_shift"] > self.cfg.recluster_proto_shift)

    def recluster(self, force: bool = False) -> bool:
        """Drift-triggered incremental re-cluster: HAC over the CURRENT
        table (unassigned bucket included) on the signature-only
        relevance matrix, via the ``ClusterEngine`` — numpy reference on
        the numpy backend, device NN-chain otherwise.  New cut ids are
        greedily matched onto the previous labels for serving
        continuity.  Returns whether a re-cluster ran."""
        if not force:
            stats = self.drift_stats()
            tripped = (
                stats["unassigned_frac"] > self.cfg.recluster_unassigned_frac
                or stats["proto_shift"] > self.cfg.recluster_proto_shift)
            if not tripped:
                return False
            obs.event("drift_trip", **stats)
        st = self._require_state()
        live = np.flatnonzero(np.asarray(st.valid))
        if len(live) < st.n_clusters:
            raise ValueError(f"cannot cut {st.n_clusters} clusters from "
                             f"{len(live)} members")
        lam_m = jnp.asarray(np.asarray(st.lam)[live])
        v_m = jnp.asarray(np.asarray(st.v)[live])
        big_r = signature_relevance(lam_m, v_m, self.cfg.eig_floor)
        cengine = ClusterEngine(ClusterConfig(
            backend="numpy" if self.cfg.backend == "numpy" else "jnp",
            linkage=self.cfg.linkage))
        with obs.span("membership.recluster", n_members=len(live)) as sp:
            fresh = np.asarray(cengine.labels(big_r, st.n_clusters))
            matched = _match_labels(fresh, np.asarray(st.labels)[live],
                                    st.n_clusters)
            lab_t = np.asarray(st.labels).copy()
            lab_t[live] = matched
            labels = jnp.asarray(lab_t) if self.on_device else lab_t
            protos, counts = self._rebuild_protos(st.v, labels, st.valid,
                                                  st.n_clusters)
            table, scales = self._quantize(protos)
            sp.sync((labels, table, counts))
        self.state = dataclasses.replace(
            st, labels=labels, protos=table, counts=counts,
            protos0=table, n_reclusters=st.n_reclusters + 1,
            proto_scales=scales, proto0_scales=scales)
        if obs.enabled():
            before = np.asarray(st.labels)[live]
            obs.count("recluster_events")
            obs.event("recluster", n_members=len(live), forced=bool(force),
                      label_agreement=float((matched == before).mean()),
                      n_reclusters=int(self.state.n_reclusters))
        return True

    def maybe_recluster(self) -> bool:
        """The serve-loop hook: re-cluster iff a drift trigger tripped."""
        return self.recluster(force=False)
