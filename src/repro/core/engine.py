"""Backend-pluggable one-shot protocol engine (paper Algorithm 2).

The ``ProtocolEngine`` is the single entry point for the similarity
protocol: signature computation (Eq. 1-2), exchange, relevance (Eq. 3-4)
and symmetrization (Eq. 5).  ``oneshot.one_shot_clustering``,
``similarity.similarity_matrix``, ``distributed.distributed_similarity``,
the benchmarks and ``repro.launch.protocol`` all route through it; the
backend is picked by ``SimilarityConfig``, not by call-site forking:

  backend      | execution
  -------------|----------------------------------------------------------
  "jnp"        | single host, reference jnp maths
  "pallas"     | single host, Pallas kernels for Gram / cross-projection
  "shard_map"  | users sharded over a mesh axis; the paper's star-topology
               | message pattern becomes two all_gathers (signatures, rows)

Orthogonally, ``block_users > 0`` turns on **blockwise streaming** for the
single-host backends: users are processed in tiles, per-tile Grams are
eigendecomposed and discarded, and cross-projection against the running
signature table is Gram-free (``||G_i v|| = ||F_i^T (F_i v)|| / n_i``,
fused in ``repro.kernels.gram_project`` on the Pallas path).  Peak memory
drops from O(N * d^2) to O(block_users * d^2) + the O(N * d * k) signature
table — exactly what each user receives over the air anyway — so
multi-thousand-user similarity fits on one host.

``landmarks = m > 0`` instead turns on the **Nystrom-sketched** flat
path: every user is scored only against m << N landmark signatures via
the ``kernels/assign`` projector-affinity scorer (``C (N, m)``), and the
full similarity is completed from the landmark block, ``R ~= C W^+ C^T``
with ``W = C[landmark_rows]`` — O(N * m) scored entries instead of
O(N^2).  The sketched similarity approximates the (PSD, unit-diagonal)
projector-affinity kernel ``A[i, j] = ||V_j^T V_i||_F^2 / k`` rather
than the eigenvalue-ratio relevance of Eq. 3-4; both order same-task
pairs above cross-task pairs, and the Nystrom completion is exact at
m = N.  Landmark sets are nested (prefixes of one fixed seeded
permutation), so the approximation error is monotone non-increasing
in m.

``run_raw`` is the RAW-DATA entry point: callers hand per-user raw shards
plus a ``FeatureConfig`` instead of pre-featurized arrays, and the
``SignatureEngine`` (``core/signature_engine.py``) runs featurize -> Gram
-> top-k spectrum on-device (row-chunk streaming, fused Pallas kernel,
batched subspace iteration instead of the O(d^3) ``eigh``) before the
relevance stage — raw data to R without the host Phi loop or the
``(N, n, d)`` feature stack.  Under the shard_map backend the user axis
of the raw shards is itself sharded over the mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.core import similarity as sim
from repro.core import signature_engine as sig

__all__ = ["ProtocolEngine", "ProtocolResult", "BACKENDS", "make_user_mesh",
           "landmark_indices"]

BACKENDS = ("jnp", "pallas", "shard_map")


def make_user_mesh(axis_name: str = "data") -> Mesh:
    """A 1-D mesh over all local devices for user sharding (tests/demos)."""
    devs = np.asarray(jax.devices())
    return Mesh(devs, (axis_name,))


@dataclasses.dataclass(frozen=True)
class ProtocolResult:
    """Everything the protocol produces before clustering.

    ``lam``/``v`` are the shared per-user signatures (what each user
    uploaded) — every backend returns them so the serving layer
    (``core.membership_engine``) can build its cluster directory without
    re-running any protocol stage.
    """

    relevance: jax.Array          # (N, N) directed r(i, j)
    similarity: jax.Array         # (N, N) symmetrized R
    n_users: int
    d: int
    top_k: int
    lam: jax.Array | None = None  # (N, k) shared spectra
    v: jax.Array | None = None    # (N, d, k) shared eigenvectors


# ---------------------------------------------------------------------------
# Dense path: one jit, full (N, d, d) Gram stack (fast for modest N)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("top_k", "impl"))
def _dense_protocol(features, n_valid, top_k, eig_floor, impl):
    grams = sim.batched_gram(features, n_valid, impl=impl)
    lam, v = jax.vmap(lambda g: sim.spectrum(g, top_k))(grams)
    r = sim.relevance_matrix(grams, lam, v, eig_floor, impl=impl)
    return r, sim.symmetrize(r), lam, v


# ---------------------------------------------------------------------------
# Blockwise streaming path: tiles of users, Gram-free cross-projection
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("top_k", "impl"))
def _tile_signatures(features, n_valid, top_k, impl):
    """One tile's shared signatures; the (block, d, d) Grams die here."""
    grams = sim.batched_gram(features, n_valid, impl=impl)
    return jax.vmap(lambda g: sim.spectrum(g, top_k))(grams)


@partial(jax.jit, static_argnames=("top_k", "impl"))
def _tile_rows(features, n_valid, lam_tile, v_flat, eig_floor, top_k, impl):
    """Relevance rows for one user tile against the full signature table.

    ``v_flat (d, N_pad * k)`` stacks every user's eigenvectors column-wise,
    so one matmul pair per user projects ALL signatures at once —
    ``||G_i v|| = ||F_i^T (F_i v)|| / n_i`` (no (d, d) Gram).
    """

    def one(args):
        f, nv, lam_i = args
        if impl == "pallas":
            from repro.kernels.gram_project import ops as gp_ops

            lam_hat = gp_ops.gram_project(f, v_flat, n_valid=nv)
        else:
            from repro.kernels.gram_project.ref import gram_project_ref

            lam_hat = gram_project_ref(f, v_flat, n_valid=nv)
        lam_hat = lam_hat.reshape(-1, top_k)                 # (N_pad, k)
        return jax.vmap(
            lambda lh: sim.relevance(lam_i, lh, eig_floor))(lam_hat)

    return jax.lax.map(one, (features, n_valid, lam_tile))


# ---------------------------------------------------------------------------
# Landmark/Nystrom-sketched path: O(N * m) scored entries, m << N
# ---------------------------------------------------------------------------

def landmark_indices(n: int, m: int) -> np.ndarray:
    """``m`` deterministic landmark user ids out of ``n``, NESTED: every
    set is a prefix of one fixed seeded permutation, so the set for any
    ``m' > m`` contains the set for ``m`` and Nystrom error can only
    shrink as landmarks are added.  A uniform permutation rather than an
    index-stride scheme: federated rosters commonly interleave tasks
    over user id (round-robin), where any stride-aligned pick collapses
    onto a single task and the sketch misses whole clusters."""
    if not 0 < m <= n:
        raise ValueError(f"need 0 < m <= n, got m={m}, n={n}")
    return np.random.default_rng(0x5EED).permutation(n)[:m].astype(np.int32)


@jax.jit
def _nystroem_complete(c: jax.Array, w: jax.Array) -> jax.Array:
    """``R ~= C W^+ C^T`` from the scored columns ``C (N, m)`` and the
    landmark-landmark block ``W (m, m)``, symmetrized + clipped to the
    affinity range (pinv noise can leave tiny negatives / > 1 spill)."""
    r = c @ jnp.linalg.pinv(w, rtol=1e-6) @ c.T
    return jnp.clip(sim.symmetrize(r), 0.0, 1.0)


# ---------------------------------------------------------------------------
# shard_map path: the paper's message pattern on TPU collectives
# ---------------------------------------------------------------------------

def _sharded_protocol(features, n_valid, *, axis: str, top_k: int,
                      eig_floor: float, impl: str):
    """shard_map body.  ``features (N_local, n, d)`` per device.

      paper                               | here
      ------------------------------------|-------------------------------
      user i broadcasts V_i to all users  | all_gather of (k, d) blocks
      user i uploads row r(i, .) to GPS   | all_gather of relevance rows
      GPS symmetrizes R, runs HAC         | every device holds R; HAC runs
                                          | host-side on the tiny N x N R
    """
    # Phase 1: local spectral signatures (no communication).
    grams = sim.batched_gram(features, n_valid, impl=impl)        # (Nl,d,d)
    lam, v = jax.vmap(lambda g: sim.spectrum(g, top_k))(grams)

    # Phase 2: signature exchange == paper's "share V_i".  The spectra
    # ride along (tiny (Nl, k) blocks) so the GPS-side serving directory
    # can be built straight from the gathered signatures.
    v_all = jax.lax.all_gather(v, axis, tiled=True)               # (N, d, k)
    lam_all = jax.lax.all_gather(lam, axis, tiled=True)           # (N, k)

    # Phase 3: local relevance rows — row i uses MY gram + spectrum
    # against EVERY user's eigenvectors (Algorithm 2 lines 7-12).
    r_rows = sim.relevance_matrix(grams, lam, v_all, eig_floor,
                                  impl=impl)                      # (Nl, N)

    # Phase 4: GPS assembly == all_gather of rows + symmetrize.
    r_full = jax.lax.all_gather(r_rows, axis, tiled=True)         # (N, N)
    return r_full, sim.symmetrize(r_full), lam_all, v_all


# ---------------------------------------------------------------------------
# Raw-data path: SignatureEngine ingest -> relevance (no host Phi stage)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("top_k", "impl", "eig", "iters",
                                   "oversample", "check"))
def _raw_finish(grams, top_k, eig_floor, impl, eig, iters, oversample,
                check):
    """Gram stack -> (r, R, resid, lam, v) in one jit: top-k spectrum
    (subspace iteration by default — no O(d^3) eigh) + relevance +
    symmetrize.  The per-user eigen-residual is only computed when the
    caller will ``check`` it (``resid`` is ``None`` otherwise)."""
    lam, v = sig.topk_spectrum(grams, top_k, method=eig, iters=iters,
                               oversample=oversample)
    resid = sig.subspace_residual(grams, lam, v) if check else None
    r = sim.relevance_matrix(grams, lam, v, eig_floor, impl=impl)
    return r, sim.symmetrize(r), resid, lam, v


def _sharded_raw_protocol(x, nv, *, axis: str, engine, top_k: int,
                          eig_floor: float, impl: str,
                          assume_full: bool = False):
    """shard_map body for the raw entry point: each device featurizes its
    own user shard (the SAME ``SignatureEngine.accumulate_grams`` row-chunk
    streaming the single-host path runs), extracts top-k signatures
    locally, then the same two all_gathers as the pre-featurized path
    (signatures, rows)."""
    grams = engine.accumulate_grams(x, nv, assume_full=assume_full)
    lam, v = sig.topk_spectrum(grams, top_k, method=engine.cfg.eig,
                               iters=engine.cfg.subspace_iters,
                               oversample=engine.cfg.oversample)
    v_all = jax.lax.all_gather(v, axis, tiled=True)               # (N, d, k)
    lam_all = jax.lax.all_gather(lam, axis, tiled=True)           # (N, k)
    r_rows = sim.relevance_matrix(grams, lam, v_all, eig_floor,
                                  impl=impl)                      # (Nl, N)
    r_full = jax.lax.all_gather(r_rows, axis, tiled=True)         # (N, N)
    if engine.cfg.check:
        resid = sig.subspace_residual(grams, lam, v)              # (Nl,)
        return (r_full, sim.symmetrize(r_full),
                jax.lax.all_gather(resid, axis, tiled=True),
                lam_all, v_all)
    return (r_full, sim.symmetrize(r_full), jnp.zeros((0,), jnp.float32),
            lam_all, v_all)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class ProtocolEngine:
    """One object that owns the whole one-shot protocol.

    ``cfg.backend`` selects the execution strategy; ``cfg.block_users``
    selects dense vs streaming on the single-host backends.  A ``mesh`` is
    only consulted by the shard_map backend (defaults to a 1-D mesh over
    all local devices).
    """

    def __init__(self, cfg: sim.SimilarityConfig | None = None,
                 mesh: Mesh | None = None):
        cfg = cfg or sim.SimilarityConfig()
        if cfg.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {cfg.backend!r}")
        if cfg.block_users < 0:
            raise ValueError(f"block_users must be >= 0, got "
                             f"{cfg.block_users}")
        if cfg.block_users and cfg.backend == "shard_map":
            raise ValueError("blockwise streaming (block_users > 0) is a "
                             "single-host mode; the shard_map backend "
                             "already tiles users over devices")
        if cfg.landmarks and cfg.backend == "shard_map":
            raise ValueError("the landmark-sketched path (landmarks > 0) "
                             "is a single-host mode; shard_map computes "
                             "exact relevance rows per device")
        self.cfg = cfg
        self.mesh = mesh

    @property
    def impl(self) -> str:
        """Kernel implementation: the pallas backend forces Pallas kernels."""
        return "pallas" if self.cfg.backend == "pallas" else self.cfg.impl

    def _top_k(self, d: int) -> int:
        """Effective signature width: ``0`` means all d, and a Gram only has
        d eigenpairs however large ``cfg.top_k`` is."""
        return min(self.cfg.top_k or d, d)

    def prepare(self, features, n_valid=None
                ) -> tuple[jax.Array, jax.Array]:
        """Normalize any accepted input form to ``(padded, n_valid)``.

        Ragged lists of ``(n_i, d)`` arrays are zero-padded via
        ``sim.pad_ragged``; padded arrays get a full-length ``n_valid``
        unless the true counts are supplied.
        """
        return sim.prepare_user_batch(features, n_valid, device=True)

    # -- protocol stages ----------------------------------------------------

    def signatures(self, features, n_valid=None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Per-user ``(lam (N, k), V (N, d, k), G (N, d, d))`` — dense only.

        ``lam``/``V`` are what users share; ``G`` stays on-device and is
        exposed for robustness studies (e.g. perturbed-eigenvector sweeps).
        Materializing every Gram is inherently dense, so non-dense configs
        are rejected rather than silently run dense.
        """
        if self.cfg.backend == "shard_map" or self.cfg.block_users:
            raise ValueError(
                "signatures() materializes the full (N, d, d) Gram stack "
                "and is only available on the dense single-host config "
                f"(got backend={self.cfg.backend!r}, "
                f"block_users={self.cfg.block_users})")
        feats, nv = self.prepare(features, n_valid)
        grams = sim.batched_gram(feats, nv, impl=self.impl)
        lam, v = jax.vmap(
            lambda g: sim.spectrum(g, self._top_k(feats.shape[-1])))(grams)
        return lam, v, grams

    def relevance_and_similarity(self, features, n_valid=None
                                 ) -> tuple[jax.Array, jax.Array]:
        """Run the full protocol -> ``(r (N, N) directed, R symmetrized)``."""
        feats, nv = self.prepare(features, n_valid)
        return self._dispatch(feats, nv)[:2]

    def similarity(self, features, n_valid=None) -> jax.Array:
        """``R (N, N)`` — the matrix the GPS feeds to HAC."""
        return self.relevance_and_similarity(features, n_valid)[1]

    def run(self, features, n_valid=None) -> ProtocolResult:
        with obs.span("protocol.run", backend=self.cfg.backend):
            feats, nv = self.prepare(features, n_valid)
            r, big_r, lam, v = self._dispatch(feats, nv)
        n_users, _, d = feats.shape
        return ProtocolResult(relevance=r, similarity=big_r,
                              n_users=n_users, d=d, top_k=self._top_k(d),
                              lam=lam, v=v)

    # -- raw-data entry point ----------------------------------------------

    def _signature_engine(self, feature_cfg, signature_cfg, probe
                          ) -> "sig.SignatureEngine":
        """Build the ingest engine, deriving its backend from the protocol
        backend when not given and rejecting conflicting combinations."""
        if signature_cfg is None:
            signature_cfg = sig.SignatureConfig(backend=self.cfg.backend,
                                                mesh_axis=self.cfg.mesh_axis)
        if ((signature_cfg.backend == "shard_map")
                != (self.cfg.backend == "shard_map")):
            raise ValueError(
                f"signature backend {signature_cfg.backend!r} conflicts "
                f"with protocol backend {self.cfg.backend!r}: shard_map "
                "ingest runs inside the sharded protocol — use both or "
                "neither")
        if (signature_cfg.backend == "shard_map"
                and signature_cfg.mesh_axis != self.cfg.mesh_axis):
            raise ValueError(
                f"signature mesh_axis {signature_cfg.mesh_axis!r} "
                f"conflicts with protocol mesh_axis "
                f"{self.cfg.mesh_axis!r}: the raw shard_map pipeline "
                "shards users over ONE axis")
        return sig.SignatureEngine(feature_cfg, signature_cfg, probe=probe)

    def run_raw(self, raw, feature_cfg, n_valid=None, probe=None,
                signature_cfg: "sig.SignatureConfig | None" = None
                ) -> ProtocolResult:
        """Full protocol from RAW user shards: ``raw (N, n, m)`` (or a
        ragged list of ``(n_i, m)``) + a ``FeatureConfig`` -> ``(r, R)``.

        The ``SignatureEngine`` ingests on-device (streamed featurize ->
        Gram, batched top-k subspace iteration); the relevance stage then
        runs on the resulting ``(N, d', d')`` Gram stack in the same jit.
        Pass the ``pca`` probe set via ``probe=``.  ``block_users``
        streaming belongs to the pre-featurized path (it never holds the
        Gram stack, which raw relevance needs) and is rejected here.
        """
        if self.cfg.block_users:
            raise ValueError(
                "run_raw computes relevance on the (N, d', d') Gram stack "
                "and does not support block_users streaming; stream the "
                "ROW axis instead via SignatureConfig.chunk_rows")
        if self.cfg.landmarks:
            raise ValueError(
                "run_raw computes exact relevance on the Gram stack and "
                "does not support the landmark sketch; featurize first "
                "and use run() with landmarks > 0")
        engine = self._signature_engine(feature_cfg, signature_cfg, probe)
        full = (n_valid is None
                and isinstance(raw, (jax.Array, np.ndarray)))
        raw, nv = engine.prepare(raw, n_valid)
        n_users, _, m = raw.shape
        d_out = engine.out_dim(m)
        top_k = self._top_k(d_out)
        with obs.span("protocol.run_raw", backend=self.cfg.backend,
                      n_users=n_users) as sp:
            if self.cfg.backend == "shard_map":
                r, big_r, resid, lam, v = self._run_raw_shard_map(
                    engine, raw, nv, top_k, full)
            else:
                grams = engine.accumulate_grams(raw, nv, assume_full=full)
                r, big_r, resid, lam, v = _raw_finish(
                    grams, top_k, self.cfg.eig_floor, self.impl,
                    engine.cfg.eig, engine.cfg.subspace_iters,
                    engine.cfg.oversample, engine.cfg.check)
            sp.sync((r, big_r, lam, v))
        if engine.cfg.check:
            engine.verify_convergence(resid)
        return ProtocolResult(relevance=r, similarity=big_r,
                              n_users=n_users, d=d_out, top_k=top_k,
                              lam=lam, v=v)

    def similarity_from_raw(self, raw, feature_cfg, n_valid=None,
                            probe=None, signature_cfg=None) -> jax.Array:
        """``R (N, N)`` straight from raw shards — see ``run_raw``."""
        return self.run_raw(raw, feature_cfg, n_valid=n_valid, probe=probe,
                            signature_cfg=signature_cfg).similarity

    def _run_raw_shard_map(self, engine, raw, nv, top_k: int,
                           assume_full: bool = False):
        axis = self.cfg.mesh_axis
        mesh = self.mesh or make_user_mesh(axis)
        n_users = raw.shape[0]
        axis_size = mesh.shape[axis]
        if n_users % axis_size:
            raise ValueError(
                f"n_users={n_users} not divisible by mesh axis {axis!r}"
                f" of size {axis_size}")
        engine.params_for(raw.shape[-1])      # fit Phi OUTSIDE the trace
        body = partial(_sharded_raw_protocol, axis=axis, engine=engine,
                       top_k=top_k, eig_floor=self.cfg.eig_floor,
                       impl=self.impl, assume_full=assume_full)
        spec_in = P(axis)
        fn = shard_map(body, mesh=mesh,
                       in_specs=(spec_in, spec_in),
                       out_specs=(P(), P(), P(), P(), P()),
                       check_rep=False)
        with mesh:
            raw = jax.device_put(jnp.asarray(raw),
                                 NamedSharding(mesh, P(axis)))
            nv = jax.device_put(nv, NamedSharding(mesh, P(axis)))
            return jax.jit(fn)(raw, nv)

    def _dispatch(self, feats: jax.Array, nv: jax.Array):
        """Backend dispatch on already-``prepare``d inputs ->
        ``(r, R, lam, v)``."""
        mode = ("shard_map" if self.cfg.backend == "shard_map"
                else "landmarks" if self.cfg.landmarks
                else "blockwise" if self.cfg.block_users else "dense")
        with obs.span("protocol.dispatch", mode=mode,
                      backend=self.cfg.backend, impl=self.impl,
                      n_users=feats.shape[0]) as sp:
            if self.cfg.backend == "shard_map":
                out = self._run_shard_map(feats, nv)
            elif self.cfg.landmarks:
                out = self._run_landmarks(feats, nv)
            elif self.cfg.block_users:
                out = self._run_blockwise(feats, nv)
            else:
                out = _dense_protocol(feats, nv,
                                      self._top_k(feats.shape[-1]),
                                      self.cfg.eig_floor, self.impl)
            sp.sync(out)
        if obs.enabled():
            obs.count("protocol.dispatches", mode=mode)
        return out

    # -- backends -----------------------------------------------------------

    def _run_blockwise(self, feats: jax.Array, nv: jax.Array):
        n_users, n, d = feats.shape
        block = min(self.cfg.block_users, n_users)
        top_k = self._top_k(d)
        pad = (-n_users) % block
        if pad:
            # Phantom users (zero features, n_valid 1) square off the last
            # tile so every tile jit-compiles once; their rows/cols are
            # sliced away below.
            feats = jnp.concatenate(
                [feats, jnp.zeros((pad, n, d), feats.dtype)])
            nv = jnp.concatenate([nv, jnp.ones((pad,), nv.dtype)])
        n_total = n_users + pad

        # Pass 1 — signature table, one tile at a time.  O(block * d^2)
        # live Grams; the table itself is O(N * d * k), the same payload
        # every user downloads in the paper's exchange.
        lam_tiles, v_tiles = [], []
        for s in range(0, n_total, block):
            lam_t, v_t = _tile_signatures(feats[s:s + block],
                                          nv[s:s + block], top_k, self.impl)
            lam_tiles.append(lam_t)
            v_tiles.append(v_t)
        lam_all = jnp.concatenate(lam_tiles)                  # (N_tot, k)
        v_all = jnp.concatenate(v_tiles)                      # (N_tot, d, k)
        v_flat = jnp.transpose(v_all, (1, 0, 2)).reshape(d, -1)

        # Pass 2 — relevance rows, tile by tile, Gram-free.
        rows = []
        for s in range(0, n_total, block):
            rows.append(_tile_rows(feats[s:s + block], nv[s:s + block],
                                   lam_all[s:s + block], v_flat,
                                   self.cfg.eig_floor, top_k, self.impl))
        r = jnp.concatenate(rows)[:n_users, :n_users]
        return (r, sim.symmetrize(r), lam_all[:n_users], v_all[:n_users])

    def _run_landmarks(self, feats: jax.Array, nv: jax.Array):
        """Nystrom-sketched flat path -> ``(R, R, lam, v)``.

        Pass 1 streams the signature table exactly like the blockwise
        path (per-tile Grams die young).  Pass 2 scores every user
        against the m landmark PROJECTORS ``V_j V_j^T`` through the
        ``kernels/assign`` scorer — ``C[i, j] = ||V_j^T V_i||_F^2 / k``,
        O(N * m) entries — and ``_nystroem_complete`` fills in the rest.
        The sketched similarity is already symmetric, so the directed
        ``r`` slot returns the same matrix.
        """
        n_users, _, d = feats.shape
        m = self.cfg.landmarks
        if m >= n_users:
            raise ValueError(
                f"landmarks={m} must be < n_users={n_users}: the sketch "
                "only pays when m << N — drop landmarks to 0 and run the "
                "exact dense path instead")
        top_k = self._top_k(d)
        tile = min(2048, n_users)
        lam_tiles, v_tiles = [], []
        for s in range(0, n_users, tile):
            lam_t, v_t = _tile_signatures(feats[s:s + tile],
                                          nv[s:s + tile], top_k, self.impl)
            lam_tiles.append(lam_t)
            v_tiles.append(v_t)
        lam_all = jnp.concatenate(lam_tiles)              # (N, k)
        v_all = jnp.concatenate(v_tiles)                  # (N, d, k)

        idx = landmark_indices(n_users, m)
        v_land = v_all[idx]
        protos = jnp.einsum("mdk,mek->mde", v_land, v_land)   # (m, d, d)
        if self.impl == "pallas":
            from repro.kernels.assign import ops as assign_ops

            score = partial(assign_ops.assign, protos=protos)
        else:
            from repro.kernels.assign.ref import assign_ref

            score = jax.jit(partial(assign_ref, protos=protos))
        cols = [score(v_all[s:s + tile])[0]
                for s in range(0, n_users, tile)]
        c = jnp.concatenate(cols)                         # (N, m)
        big_r = _nystroem_complete(c, c[idx])
        return big_r, big_r, lam_all, v_all

    def _run_shard_map(self, feats: jax.Array, nv: jax.Array):
        axis = self.cfg.mesh_axis
        mesh = self.mesh or make_user_mesh(axis)
        n_users = feats.shape[0]
        axis_size = mesh.shape[axis]
        if n_users % axis_size:
            raise ValueError(
                f"n_users={n_users} not divisible by mesh axis {axis!r}"
                f" of size {axis_size}")
        top_k = self._top_k(feats.shape[-1])
        body = partial(_sharded_protocol, axis=axis, top_k=top_k,
                       eig_floor=self.cfg.eig_floor, impl=self.impl)
        spec_in = P(axis)
        fn = shard_map(body, mesh=mesh,
                       in_specs=(spec_in, spec_in),
                       out_specs=(P(), P(), P(), P()),  # replicated
                       check_rep=False)
        with mesh:
            feats = jax.device_put(feats, NamedSharding(mesh, P(axis)))
            nv = jax.device_put(nv, NamedSharding(mesh, P(axis)))
            return jax.jit(fn)(feats, nv)
