"""Backend-pluggable GPS decision layer (paper §II-C) — the ClusterEngine.

``core/clustering.py`` keeps the pure-numpy reference HAC: a greedy
full-matrix argmax per merge, O(N^3) work, host-resident.  This module is
its device-side counterpart, mirroring ``core/engine.py``'s
``ProtocolEngine`` idiom — one object, a config-selected backend:

  backend   | execution
  ----------|------------------------------------------------------------
  "numpy"   | the reference: ``clustering.hac`` / ``clustering.cut`` /
            | ``clustering.spectral_clusters`` on the host
  "jnp"     | nearest-neighbor-chain HAC as ONE jitted ``lax.while_loop``
            | over an on-device linkage matrix — O(N^2) work and memory
  "pallas"  | the same program with the fused ``kernels/linkage``
            | row-update + argmax kernel as the inner step

The NN-chain algorithm (Benzecri / Murtagh): walk nearest-neighbour
links until a *reciprocal* pair is found, merge it, continue from the
remaining chain.  For the reducible linkages (single / complete /
average all satisfy ``s(x, a∪b) <= max(s(x, a), s(x, b))`` in similarity
space) the set of reciprocal-NN merges is exactly the greedy dendrogram,
so sorting the chain-order merges by height recovers the reference
merge sequence up to tie order.  Each loop step is O(N) — a row argmax,
plus a Lance-Williams row update on merges — for O(N^2) total instead of
the reference's O(N^2) argmax per merge.

``R`` produced by the ``ProtocolEngine`` therefore never leaves the
device between protocol and trainer: ``hac`` ingests the device array,
``cut`` extracts labels with a top-(N-T)-by-height union forest plus
log(N) pointer-jumping rounds, and the labels feed
``fed.partition.stack_layout`` directly.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import clustering as clu
from repro.kernels.linkage.ref import LINKAGES, linkage_step_ref

__all__ = ["ClusterConfig", "ClusterEngine", "DeviceDendrogram",
           "CLUSTER_BACKENDS"]

CLUSTER_BACKENDS = ("numpy", "jnp", "pallas")

_NEG = -jnp.inf


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Configuration of the GPS decision layer.

    Attributes:
      backend: "numpy" (host reference), "jnp" (device NN-chain HAC) or
        "pallas" (NN-chain with the fused ``kernels/linkage`` inner step).
      linkage: "average" | "single" | "complete" (similarity semantics).
      interpret: Pallas interpret-mode override (default: interpret off
        TPU), consulted by the pallas backend only.
    """

    backend: str = "numpy"
    linkage: str = "average"
    interpret: bool | None = None


@dataclasses.dataclass(frozen=True)
class DeviceDendrogram:
    """Merge history of the device NN-chain HAC, in CHAIN order.

    ``merge_rows[t] = (i, j)``: at chain step ``t`` the cluster living at
    row ``j`` merged into row ``i`` (``i < j``; rows are matrix indices,
    not dendrogram node ids) at similarity ``heights[t]``.  Chain order
    is NOT height order — ``to_host()`` sorts into the greedy sequence.
    """

    n_leaves: int
    merge_rows: jax.Array          # (N-1, 2) int32, (surviving, dying)
    heights: jax.Array             # (N-1,) float32

    def to_host(self) -> clu.Dendrogram:
        """Greedy-order ``clustering.Dendrogram`` (sort by height desc,
        replay to assign node ids) — the bridge to host-side ``cut`` and
        the dendrogram-invariant tests."""
        rows = np.asarray(self.merge_rows)
        h = np.asarray(self.heights, dtype=np.float64)
        order = np.argsort(-h, kind="stable")
        node_of = {int(i): int(i) for i in range(self.n_leaves)}
        merges = []
        for t, m in enumerate(order):
            i, j = int(rows[m, 0]), int(rows[m, 1])
            merges.append((node_of[i], node_of[j], float(h[m])))
            node_of[i] = self.n_leaves + t
        return clu.Dendrogram(n_leaves=self.n_leaves, merges=tuple(merges))


# ---------------------------------------------------------------------------
# Device NN-chain HAC
# ---------------------------------------------------------------------------

def _step_fn(impl: str, linkage: str, interpret: bool | None):
    """The fused inner step: Lance-Williams row update + masked argmax."""
    if impl == "pallas":
        from repro.kernels.linkage import ops as lk_ops

        return partial(lk_ops.linkage_step, linkage=linkage,
                       interpret=interpret)
    return partial(linkage_step_ref, linkage=linkage)


@partial(jax.jit, static_argnames=("n", "linkage", "impl", "interpret"))
def _nn_chain(s, alive0, *, n: int, linkage: str, impl: str,
              interpret: bool | None):
    """NN-chain HAC over a prepared linkage matrix.

    ``s (Np, Np)`` f32 with dead rows/cols (padding) and the diagonal at
    ``-inf``; ``alive0 (Np,)`` bool marks the ``n`` real leaves.  Returns
    ``(merge_rows (n-1, 2) i32, heights (n-1,) f32)`` in chain order.

    Every iteration either extends the chain (one fused argmax) or pops a
    reciprocal pair and merges it (one fused row-update + argmax).  Chain
    similarities strictly increase, so iterations are bounded by ~4n; the
    cap is a safety net, not a tuning knob.
    """
    np_pad = s.shape[0]
    step = _step_fn(impl, linkage, interpret)
    one = jnp.float32(1.0)
    cols = jnp.arange(np_pad, dtype=jnp.int32)

    def cond(st):
        s_, size, alive, chain, clen, mi, mj, hh, t, it = st
        return (t < n - 1) & (it < 4 * n + 8)

    def body(st):
        s_, size, alive, chain, clen, mi, mj, hh, t, it = st
        # Re-seed an empty chain with the smallest alive row.
        seed = jnp.argmax(alive).astype(jnp.int32)
        chain = jnp.where(clen == 0, chain.at[0].set(seed), chain)
        clen = jnp.maximum(clen, 1)
        top = chain[clen - 1]
        prev = chain[jnp.maximum(clen - 2, 0)]

        row_top = jax.lax.dynamic_slice(s_, (top, 0), (1, np_pad))[0]
        mask_top = alive & (cols != top)
        _, nn, best = step(row_top, row_top, one, one, mask_top)
        prev_sim = jnp.where(clen >= 2, row_top[prev], _NEG)
        # prev is on the chain as top's predecessor, so ``prev_sim >=
        # best`` means prev attains top's row max: a reciprocal pair.
        do_merge = (clen >= 2) & (prev_sim >= best)

        def merge(_):
            i = jnp.minimum(top, prev)
            j = jnp.maximum(top, prev)
            na = size[i]
            nb = size[j]
            alive2 = alive.at[j].set(False)
            mask_m = alive2 & (cols != i)
            row_i = jax.lax.dynamic_slice(s_, (i, 0), (1, np_pad))[0]
            row_j = jax.lax.dynamic_slice(s_, (j, 0), (1, np_pad))[0]
            new_row, _, _ = step(row_i, row_j, na, nb, mask_m)
            dead = jnp.full((np_pad,), _NEG, jnp.float32)
            s2 = jax.lax.dynamic_update_slice(s_, new_row[None, :], (i, 0))
            s2 = jax.lax.dynamic_update_slice(s2, new_row[:, None], (0, i))
            s2 = jax.lax.dynamic_update_slice(s2, dead[None, :], (j, 0))
            s2 = jax.lax.dynamic_update_slice(s2, dead[:, None], (0, j))
            return (s2, size.at[i].set(na + nb).at[j].set(0.0), alive2,
                    chain, clen - 2, mi.at[t].set(i), mj.at[t].set(j),
                    hh.at[t].set(prev_sim), t + 1, it + 1)

        def extend(_):
            return (s_, size, alive, chain.at[clen].set(nn), clen + 1,
                    mi, mj, hh, t, it + 1)

        return jax.lax.cond(do_merge, merge, extend, None)

    init = (s,
            jnp.where(alive0, 1.0, 0.0).astype(jnp.float32),
            alive0,
            jnp.zeros((np_pad + 1,), jnp.int32),
            jnp.int32(0),
            jnp.zeros((max(n - 1, 0),), jnp.int32),
            jnp.zeros((max(n - 1, 0),), jnp.int32),
            jnp.zeros((max(n - 1, 0),), jnp.float32),
            jnp.int32(0), jnp.int32(0))
    out = jax.lax.while_loop(cond, body, init)
    _, _, _, _, _, mi, mj, hh, t, _ = out
    return jnp.stack([mi, mj], axis=1), hh, t


@partial(jax.jit, static_argnames=("n_leaves", "n_clusters"))
def _cut_device(merge_rows, heights, *, n_leaves: int, n_clusters: int):
    """Labels from chain-order merges: apply the ``N - T`` highest merges
    as a union forest (dying row -> surviving row), resolve roots by
    pointer jumping, and canonicalize labels by sorted root."""
    keep = n_leaves - n_clusters
    order = jnp.argsort(-heights, stable=True)
    sel = order[:keep]
    parent = jnp.arange(n_leaves, dtype=jnp.int32)
    parent = parent.at[merge_rows[sel, 1]].set(merge_rows[sel, 0])
    rounds = max(1, int(np.ceil(np.log2(max(n_leaves, 2)))))
    parent = jax.lax.fori_loop(0, rounds, lambda _, p: p[p], parent)
    _, labels = jnp.unique(parent, return_inverse=True, size=n_leaves,
                           fill_value=n_leaves)
    return labels.reshape(n_leaves).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Device spectral clustering (Ng-Jordan-Weiss on the affinity R)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_clusters", "n_init", "n_iter"))
def _spectral_device(r, key, *, n_clusters: int, n_init: int = 8,
                     n_iter: int = 50):
    n = r.shape[0]
    eye = jnp.eye(n, dtype=r.dtype)
    a = r * (1.0 - eye)
    deg = a.sum(axis=1)
    d_inv_sqrt = 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12))
    lap = eye - d_inv_sqrt[:, None] * a * d_inv_sqrt[None, :]
    _, v = jnp.linalg.eigh(lap)
    emb = v[:, :n_clusters]
    emb = emb / jnp.maximum(jnp.linalg.norm(emb, axis=1, keepdims=True),
                            1e-12)

    def one_init(k):
        idx = jax.random.choice(k, n, (n_clusters,), replace=False)
        centers = emb[idx]

        def lloyd(_, c):
            d = ((emb[:, None, :] - c[None]) ** 2).sum(-1)
            lab = d.argmin(1)
            onehot = (lab[:, None] ==
                      jnp.arange(n_clusters)[None]).astype(emb.dtype)
            cnt = onehot.sum(0)
            new_c = (onehot.T @ emb) / jnp.maximum(cnt, 1.0)[:, None]
            return jnp.where(cnt[:, None] > 0, new_c, c)

        centers = jax.lax.fori_loop(0, n_iter, lloyd, centers)
        d = ((emb[:, None, :] - centers[None]) ** 2).sum(-1)
        return d.argmin(1).astype(jnp.int32), d.min(1).sum()

    labs, objs = jax.vmap(one_init)(jax.random.split(key, n_init))
    return labs[jnp.argmin(objs)]


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class ClusterEngine:
    """One object that owns the GPS clustering decision (HAC + spectral).

    ``cfg.backend`` selects host-numpy reference vs device NN-chain
    (jnp / pallas inner step).  The device backends keep similarity,
    dendrogram and labels on-device; value-level input validation (NaN,
    asymmetry) lives on the numpy reference path where it is free —
    device inputs get static shape checks only.
    """

    def __init__(self, cfg: ClusterConfig | None = None):
        cfg = cfg or ClusterConfig()
        if cfg.backend not in CLUSTER_BACKENDS:
            raise ValueError(f"backend must be one of {CLUSTER_BACKENDS}, "
                             f"got {cfg.backend!r}")
        if cfg.linkage not in LINKAGES:
            raise ValueError(f"linkage must be one of {LINKAGES}, "
                             f"got {cfg.linkage!r}")
        self.cfg = cfg

    @property
    def on_device(self) -> bool:
        return self.cfg.backend != "numpy"

    @staticmethod
    def _check_square(s: jax.Array) -> int:
        if s.ndim != 2 or s.shape[0] != s.shape[1]:
            raise ValueError(f"similarity must be square, got {s.shape}")
        return s.shape[0]

    @staticmethod
    def _check_n_clusters(n_clusters: int, n: int) -> None:
        if not 1 <= n_clusters <= n:
            raise ValueError(f"n_clusters must be in [1, {n}], "
                             f"got {n_clusters}")

    def _prepare(self, similarity) -> tuple[jax.Array, jax.Array, int]:
        """Device linkage matrix: f32, diag ``-inf``, padded to a lane
        multiple of 128 for the pallas inner step (dead rows/cols)."""
        s = jnp.asarray(similarity, jnp.float32)
        n = self._check_square(s)
        pad = (-n) % 128 if self.cfg.backend == "pallas" else 0
        full = (jnp.pad(s, ((0, pad), (0, pad)), constant_values=_NEG)
                if pad else s)
        idx = jnp.arange(n + pad)
        full = full.at[idx, idx].set(_NEG)
        alive = idx < n
        return full, alive, n

    # -- HAC ----------------------------------------------------------------

    def hac(self, similarity) -> clu.Dendrogram | DeviceDendrogram:
        """Agglomerative clustering -> dendrogram (host or device form)."""
        with obs.span("cluster.hac", backend=self.cfg.backend,
                      linkage=self.cfg.linkage):
            dend = self._hac(similarity)
        if obs.enabled():
            obs.count("cluster.hac_runs")
        return dend

    def _hac(self, similarity) -> clu.Dendrogram | DeviceDendrogram:
        if self.cfg.backend == "numpy":
            return clu.hac(np.asarray(similarity), linkage=self.cfg.linkage)
        s, alive, n = self._prepare(similarity)
        merge_rows, heights, steps = _nn_chain(
            s, alive, n=n, linkage=self.cfg.linkage,
            impl="pallas" if self.cfg.backend == "pallas" else "jnp",
            interpret=self.cfg.interpret)
        # NaN/Inf in R breaks the chain's comparisons and the loop stops
        # at the iteration cap with the merge buffers part-filled; the
        # step count is the cheap completion witness (one scalar sync, no
        # extra device work) so garbage never reaches the cut silently.
        if int(steps) != n - 1:
            raise ValueError(
                f"device HAC stopped after {int(steps)}/{n - 1} merges — "
                "the similarity matrix likely contains NaN/Inf (the numpy "
                "backend validates values; device inputs are only "
                "shape-checked)")
        return DeviceDendrogram(n_leaves=n, merge_rows=merge_rows,
                                heights=heights)

    def cut(self, dend, n_clusters: int):
        """Dendrogram -> labels; device dendrograms cut on-device."""
        with obs.span("cluster.cut", n_clusters=n_clusters) as sp:
            if isinstance(dend, clu.Dendrogram):
                return clu.cut(dend, n_clusters)
            self._check_n_clusters(n_clusters, dend.n_leaves)
            return sp.sync(_cut_device(dend.merge_rows, dend.heights,
                                       n_leaves=dend.n_leaves,
                                       n_clusters=n_clusters))

    def labels(self, similarity, n_clusters: int):
        """HAC + cut.  numpy backend -> ``np.ndarray``; device backends ->
        a ``jax.Array`` that never left the accelerator."""
        return self.cut(self.hac(similarity), n_clusters)

    # -- Spectral -----------------------------------------------------------

    def spectral(self, similarity, n_clusters: int, rng=0):
        """Normalized spectral clustering on the affinity ``R``.

        numpy backend delegates to ``clustering.spectral_clusters``;
        device backends run the same NJW pipeline (eigh + 8-init Lloyd)
        jitted on-device (the pallas backend shares the jnp maths — the
        hot spot here is ``eigh``, not a row kernel).  ``rng`` is a numpy
        seed / Generator on the host path, an int seed or PRNG key on the
        device path.
        """
        with obs.span("cluster.spectral", backend=self.cfg.backend,
                      n_clusters=n_clusters) as sp:
            if self.cfg.backend == "numpy":
                return clu.spectral_clusters(np.asarray(similarity),
                                             n_clusters, rng=rng)
            s = jnp.asarray(similarity, jnp.float32)
            self._check_n_clusters(n_clusters, self._check_square(s))
            key = rng if isinstance(rng, jax.Array) else jax.random.PRNGKey(
                int(rng))
            return sp.sync(_spectral_device(s, key, n_clusters=n_clusters))
