"""One-shot clustering protocol (paper Algorithm 2).

Ties together the ``ProtocolEngine`` (Eqs. 1-5, any backend) and
``repro.core.clustering`` (HAC + cut) and tracks the communication ledger —
the paper's headline claim is that the whole clustering costs each user one
``(k x d)`` eigenvector upload + one ``(N,)`` relevance upload, before any
training happens.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np

from repro.core import clustering as clu
from repro.core import similarity as sim
from repro.core.engine import ProtocolEngine

__all__ = ["CommLedger", "OneShotResult", "one_shot_clustering"]


@dataclasses.dataclass(frozen=True)
class CommLedger:
    """Bytes moved by the clustering protocol (fp32 accounting).

    ``per_user_upload``: what one user sends (V_i broadcast + r row to GPS).
    ``per_user_download``: what one user receives (all other users' V_j).
    ``gps_total``: what the GPS receives (N relevance rows).
    ``iterative_equiv``: what ONE ROUND of weight-based iterative clustering
    would upload per user, given a model of ``model_params`` weights — the
    literature baseline the paper contrasts against (its Fig. 4 point).
    """

    n_users: int
    d: int
    top_k: int
    model_params: int = 0

    @property
    def per_user_upload(self) -> int:
        return 4 * (self.top_k * self.d + self.n_users)

    @property
    def per_user_download(self) -> int:
        return 4 * (self.n_users - 1) * self.top_k * self.d

    @property
    def gps_total(self) -> int:
        return 4 * self.n_users * self.n_users

    @property
    def iterative_equiv(self) -> int:
        return 4 * self.model_params

    def summary(self) -> dict:
        return {
            "n_users": self.n_users,
            "d": self.d,
            "top_k": self.top_k,
            "per_user_upload_bytes": self.per_user_upload,
            "per_user_download_bytes": self.per_user_download,
            "gps_total_bytes": self.gps_total,
            "iterative_per_round_upload_bytes": self.iterative_equiv,
            "oneshot_vs_iterative_ratio": (
                self.per_user_upload / self.iterative_equiv
                if self.model_params else None),
        }


@dataclasses.dataclass(frozen=True)
class OneShotResult:
    labels: np.ndarray            # (N,) cluster assignment in 0..T-1
    similarity: np.ndarray        # (N, N) symmetrized R
    relevance: np.ndarray         # (N, N) directed r(i, j)
    dendrogram: clu.Dendrogram
    ledger: CommLedger


def one_shot_clustering(features: Sequence[np.ndarray] | jax.Array,
                        n_clusters: int,
                        cfg: sim.SimilarityConfig | None = None,
                        linkage: str = "average",
                        model_params: int = 0,
                        n_valid: jax.Array | None = None,
                        mesh=None) -> OneShotResult:
    """Run paper Algorithm 2 end-to-end on per-user feature matrices.

    ``features``: list of ``(n_i, d)`` arrays (or a padded ``(N, n, d)``
    array, with the true per-user counts in ``n_valid``).  The similarity
    backend — dense / blockwise-streaming / shard_map — is chosen by
    ``cfg``; ``mesh`` is only consulted by the shard_map backend.  Returns
    labels, the similarity matrix, and the comm ledger.
    """
    engine = ProtocolEngine(cfg, mesh=mesh)
    res = engine.run(features, n_valid)

    big_r_np = np.asarray(res.similarity)
    dend = clu.hac(big_r_np, linkage=linkage)
    labels = clu.cut(dend, n_clusters)
    ledger = CommLedger(n_users=res.n_users, d=res.d, top_k=res.top_k,
                        model_params=model_params)
    return OneShotResult(labels=labels, similarity=big_r_np,
                         relevance=np.asarray(res.relevance), dendrogram=dend,
                         ledger=ledger)
