"""One-shot clustering protocol (paper Algorithm 2), single-host.

Ties together ``repro.core.similarity`` (Eqs. 1-5) and
``repro.core.clustering`` (HAC + cut) and tracks the communication ledger —
the paper's headline claim is that the whole clustering costs each user one
``(k x d)`` eigenvector upload + one ``(N,)`` relevance upload, before any
training happens.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering as clu
from repro.core import similarity as sim

__all__ = ["CommLedger", "OneShotResult", "one_shot_clustering"]


@dataclasses.dataclass(frozen=True)
class CommLedger:
    """Bytes moved by the clustering protocol (fp32 accounting).

    ``per_user_upload``: what one user sends (V_i broadcast + r row to GPS).
    ``per_user_download``: what one user receives (all other users' V_j).
    ``gps_total``: what the GPS receives (N relevance rows).
    ``iterative_equiv``: what ONE ROUND of weight-based iterative clustering
    would upload per user, given a model of ``model_params`` weights — the
    literature baseline the paper contrasts against (its Fig. 4 point).
    """

    n_users: int
    d: int
    top_k: int
    model_params: int = 0

    @property
    def per_user_upload(self) -> int:
        return 4 * (self.top_k * self.d + self.n_users)

    @property
    def per_user_download(self) -> int:
        return 4 * (self.n_users - 1) * self.top_k * self.d

    @property
    def gps_total(self) -> int:
        return 4 * self.n_users * self.n_users

    @property
    def iterative_equiv(self) -> int:
        return 4 * self.model_params

    def summary(self) -> dict:
        return {
            "n_users": self.n_users,
            "d": self.d,
            "top_k": self.top_k,
            "per_user_upload_bytes": self.per_user_upload,
            "per_user_download_bytes": self.per_user_download,
            "gps_total_bytes": self.gps_total,
            "iterative_per_round_upload_bytes": self.iterative_equiv,
            "oneshot_vs_iterative_ratio": (
                self.per_user_upload / self.iterative_equiv
                if self.model_params else None),
        }


@dataclasses.dataclass(frozen=True)
class OneShotResult:
    labels: np.ndarray            # (N,) cluster assignment in 0..T-1
    similarity: np.ndarray        # (N, N) symmetrized R
    relevance: np.ndarray         # (N, N) directed r(i, j)
    dendrogram: clu.Dendrogram
    ledger: CommLedger


def one_shot_clustering(features: Sequence[np.ndarray] | jax.Array,
                        n_clusters: int,
                        cfg: sim.SimilarityConfig | None = None,
                        linkage: str = "average",
                        model_params: int = 0) -> OneShotResult:
    """Run paper Algorithm 2 end-to-end on per-user feature matrices.

    ``features``: list of ``(n_i, d)`` arrays (or a padded ``(N, n, d)``
    array).  Returns labels, the similarity matrix, and the comm ledger.
    """
    cfg = cfg or sim.SimilarityConfig()
    if isinstance(features, (jax.Array, np.ndarray)):
        n_users, _, d = features.shape
        feats = features
        n_valid = None
    else:
        n_users, d = len(features), features[0].shape[1]
        feats = features
        n_valid = None
    top_k = cfg.top_k or d

    # Directed relevance r and symmetrized R (Eqs. 1-5).
    if isinstance(feats, (jax.Array, np.ndarray)):
        grams = sim.batched_gram(jnp.asarray(feats), impl=cfg.impl)
    else:
        counts = [f.shape[0] for f in feats]
        n_max = max(counts)
        padded = np.zeros((n_users, n_max, d), dtype=np.float32)
        for i, f in enumerate(feats):
            padded[i, : f.shape[0]] = f
        grams = sim.batched_gram(jnp.asarray(padded),
                                 jnp.asarray(counts, dtype=jnp.float32),
                                 impl=cfg.impl)
    lam, v = jax.vmap(lambda g: sim.spectrum(g, top_k))(grams)
    r = sim.relevance_matrix(grams, lam, v, cfg.eig_floor, impl=cfg.impl)
    big_r = sim.symmetrize(r)

    big_r_np = np.asarray(big_r)
    dend = clu.hac(big_r_np, linkage=linkage)
    labels = clu.cut(dend, n_clusters)
    ledger = CommLedger(n_users=n_users, d=d, top_k=top_k,
                        model_params=model_params)
    return OneShotResult(labels=labels, similarity=big_r_np,
                         relevance=np.asarray(r), dendrogram=dend,
                         ledger=ledger)
