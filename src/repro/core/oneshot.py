"""One-shot clustering protocol (paper Algorithm 2).

Ties together the ``ProtocolEngine`` (Eqs. 1-5, any backend), the
``ClusterEngine`` (HAC + cut, host reference or device NN-chain) and the
communication ledger — the paper's headline claim is that the whole
clustering costs each user one ``(k x d)`` eigenvector upload + one
``(N,)`` relevance upload, before any training happens.

With a device cluster backend (``ClusterConfig.backend`` "jnp"/"pallas")
the similarity matrix ``R`` never leaves the accelerator: the protocol
produces it on-device, the NN-chain HAC consumes it on-device, and the
returned labels are a ``jax.Array`` ready for
``fed.partition.stack_layout`` / ``fed.trainer.train_mthfl``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np

from repro import obs
from repro.core import clustering as clu
from repro.core import similarity as sim
from repro.core.cluster_engine import (ClusterConfig, ClusterEngine,
                                       DeviceDendrogram)
from repro.core.engine import ProtocolEngine

__all__ = ["CommLedger", "OneShotResult", "one_shot_clustering"]

_LEDGER_MODES = ("broadcast", "streaming")


@dataclasses.dataclass(frozen=True)
class CommLedger:
    """Bytes moved by the clustering protocol.

    ``dtype_bytes`` parameterizes the wire precision (4 = fp32 default;
    2 models an fp16/bf16 signature exchange).  ``mode`` selects the
    exchange pattern the engine actually ran:

    * ``"broadcast"`` — the paper's star topology: every user receives
      each other user's ``V_j`` as a separate per-peer transfer, so the
      per-user download is ``(N - 1) * k * d`` duplicated broadcasts.
    * ``"streaming"`` — the blockwise engine mode: the GPS assembles the
      signature table once and each user fetches the whole
      ``O(N * d * k)`` table in one download (its own row rides along for
      table alignment) instead of N - 1 per-peer duplicates.

    The ledger is INGEST-INVARIANT: whether signatures come from the
    host-numpy Phi stage, the streaming ``SignatureEngine`` (raw-data
    entry point) or the subspace-iteration eigensolver, what each user
    uploads is the same ``(k x d)`` eigenvector block + relevance row —
    the per-user upload stays O(k * d) regardless of how it was computed.

    ``per_user_upload``: what one user sends (V_i + its relevance row).
    ``gps_total``: what the GPS receives (N relevance rows).
    ``iterative_equiv``: what ONE ROUND of weight-based iterative
    clustering would upload per user for a ``model_params``-weight model —
    the literature baseline the paper contrasts against (its Fig. 4
    point).

    ARRIVAL ACCOUNTING (``core.membership_engine`` serving): a newcomer
    joining AFTER the one-shot round uploads exactly one ``(k x d)``
    signature block (``assign_upload`` — no relevance row: the GPS scores
    it against its cluster directory) and downloads one ``int32`` label
    (``assign_download`` — no signature-table broadcast).  Arrival cost
    is independent of the population N, unlike ``per_user_upload``, which
    carries the O(N) relevance row.
    """

    n_users: int
    d: int
    top_k: int
    model_params: int = 0
    dtype_bytes: int = 4
    mode: str = "broadcast"

    def __post_init__(self):
        if self.mode not in _LEDGER_MODES:
            raise ValueError(f"mode must be one of {_LEDGER_MODES}, "
                             f"got {self.mode!r}")
        if self.dtype_bytes <= 0:
            raise ValueError(f"dtype_bytes must be positive, "
                             f"got {self.dtype_bytes}")

    @property
    def signature_table_bytes(self) -> int:
        """The assembled ``(N, d, k)`` signature table the GPS hosts."""
        return self.dtype_bytes * self.n_users * self.top_k * self.d

    @property
    def per_user_upload(self) -> int:
        return self.dtype_bytes * (self.top_k * self.d + self.n_users)

    @property
    def per_user_download(self) -> int:
        if self.mode == "streaming":
            return self.signature_table_bytes
        return self.dtype_bytes * (self.n_users - 1) * self.top_k * self.d

    @property
    def assign_upload(self) -> int:
        """One newcomer's arrival upload: its ``(k x d)`` signature."""
        return self.dtype_bytes * self.top_k * self.d

    @property
    def assign_download(self) -> int:
        """One newcomer's arrival download: a single ``int32`` cluster
        label — no signature-table or model download."""
        return 4

    @property
    def gps_total(self) -> int:
        return self.dtype_bytes * self.n_users * self.n_users

    @property
    def iterative_equiv(self) -> int:
        return self.dtype_bytes * self.model_params

    def summary(self) -> dict:
        return {
            "n_users": self.n_users,
            "d": self.d,
            "top_k": self.top_k,
            "dtype_bytes": self.dtype_bytes,
            "mode": self.mode,
            "per_user_upload_bytes": self.per_user_upload,
            "per_user_download_bytes": self.per_user_download,
            "assign_upload_bytes": self.assign_upload,
            "assign_download_bytes": self.assign_download,
            "assign_vs_protocol_upload_ratio": (
                self.assign_upload / self.per_user_upload),
            "signature_table_bytes": self.signature_table_bytes,
            "gps_total_bytes": self.gps_total,
            "iterative_per_round_upload_bytes": self.iterative_equiv,
            "oneshot_vs_iterative_ratio": (
                self.per_user_upload / self.iterative_equiv
                if self.model_params else None),
        }


@dataclasses.dataclass(frozen=True)
class OneShotResult:
    """Labels + intermediates.  With a device cluster backend, ``labels``,
    ``similarity`` and ``relevance`` are ``jax.Array``s that never left
    the accelerator; the numpy backend returns host arrays.

    ``lam``/``v`` are the shared per-user signatures — exactly what each
    user uploaded — kept so the online serving layer
    (``repro.core.membership_engine.MembershipEngine.from_oneshot``) can
    seed its cluster directory without re-running the protocol.
    """

    labels: np.ndarray | jax.Array          # (N,) cluster assignment 0..T-1
    similarity: np.ndarray | jax.Array      # (N, N) symmetrized R
    relevance: np.ndarray | jax.Array       # (N, N) directed r(i, j)
    dendrogram: clu.Dendrogram | DeviceDendrogram
    ledger: CommLedger
    lam: jax.Array | None = None            # (N, k) shared spectra
    v: jax.Array | None = None              # (N, d, k) shared eigenvectors


def one_shot_clustering(features: Sequence[np.ndarray] | jax.Array,
                        n_clusters: int,
                        cfg: sim.SimilarityConfig | None = None,
                        linkage: str = "average",
                        model_params: int = 0,
                        n_valid: jax.Array | None = None,
                        mesh=None,
                        cluster_cfg: ClusterConfig | None = None,
                        feature_cfg=None,
                        probe: np.ndarray | None = None,
                        signature_cfg=None,
                        hierarchy_cfg=None):
    """Run paper Algorithm 2 end-to-end on per-user feature matrices.

    ``features``: list of ``(n_i, d)`` arrays (or a padded ``(N, n, d)``
    array, with the true per-user counts in ``n_valid``).  The similarity
    backend — dense / blockwise-streaming / shard_map — is chosen by
    ``cfg``; ``mesh`` is only consulted by the shard_map backend.

    RAW-DATA ENTRY POINT: passing ``feature_cfg`` (a
    ``repro.data.features.FeatureConfig``) declares ``features`` to be
    raw user shards ``(n_i, m)`` instead — the device-resident
    ``SignatureEngine`` then runs featurize -> Gram -> top-k signatures
    (row-chunk streaming / fused Pallas kernel / sharded users, chosen by
    ``signature_cfg``) with no host Phi stage and no ``(N, n, d)``
    feature stack.  ``probe`` carries the public ``pca`` probe set.

    ``cluster_cfg`` chooses the GPS decision layer: the default numpy
    reference HAC, or the device NN-chain ("jnp" / "pallas") which keeps
    ``R`` and the labels on-device.  ``linkage`` is honoured when
    ``cluster_cfg`` is not given (back-compat); passing both with
    conflicting linkages raises rather than silently preferring one.

    The result carries the shared signatures (``lam``, ``v``) — feed it
    to ``repro.core.membership_engine.MembershipEngine.from_oneshot`` to
    serve STREAMING arrivals afterwards: a newcomer's cluster identity
    costs one O(T * k * d^2) directory lookup instead of re-running this
    O(N^2) protocol.

    HIERARCHICAL ENTRY POINT: passing ``hierarchy_cfg`` (a
    ``repro.core.hierarchy.HierarchyConfig``) routes to the two-level
    edge-group protocol — O(G * (N/G)^2 + (G * T_g)^2) instead of O(N^2)
    — and returns a ``HierarchicalResult`` instead: same ``labels`` /
    ``lam`` / ``v`` / ``ledger`` contract (``from_oneshot`` compatible),
    no N x N ``similarity``/``dendrogram`` (that matrix is exactly what
    the hierarchy never builds).  Pre-featurized single-host configs
    only.
    """
    if (cluster_cfg is not None and linkage != "average"
            and linkage != cluster_cfg.linkage):
        raise ValueError(
            f"conflicting linkages: linkage={linkage!r} vs "
            f"cluster_cfg.linkage={cluster_cfg.linkage!r} — set it on "
            "cluster_cfg only")
    if feature_cfg is None and (probe is not None
                                or signature_cfg is not None):
        raise ValueError("probe/signature_cfg configure the raw-data "
                         "entry point; pass feature_cfg to enable it")
    if hierarchy_cfg is not None:
        if feature_cfg is not None:
            raise ValueError("the hierarchical path consumes pre-"
                             "featurized users; run the SignatureEngine "
                             "separately before hierarchy_cfg")
        from repro.core.hierarchy import hierarchical_one_shot

        return hierarchical_one_shot(
            features, n_clusters, cfg=cfg, hierarchy_cfg=hierarchy_cfg,
            cluster_cfg=(cluster_cfg if cluster_cfg is not None
                         else ClusterConfig(backend="jnp", linkage=linkage)),
            n_valid=n_valid, model_params=model_params)
    with obs.span("oneshot.run", n_clusters=n_clusters):
        engine = ProtocolEngine(cfg, mesh=mesh)
        if feature_cfg is not None:
            res = engine.run_raw(features, feature_cfg, n_valid=n_valid,
                                 probe=probe, signature_cfg=signature_cfg)
        else:
            res = engine.run(features, n_valid)

        ccfg = cluster_cfg or ClusterConfig(linkage=linkage)
        cengine = ClusterEngine(ccfg)
        if cengine.on_device:
            big_r, relevance = res.similarity, res.relevance
        else:
            big_r, relevance = (np.asarray(res.similarity),
                                np.asarray(res.relevance))
        dend = cengine.hac(big_r)
        labels = cengine.cut(dend, n_clusters)
        ledger = CommLedger(
            n_users=res.n_users, d=res.d, top_k=res.top_k,
            model_params=model_params,
            mode="streaming" if engine.cfg.block_users else "broadcast")
    obs.record_ledger(ledger)
    return OneShotResult(labels=labels, similarity=big_r,
                         relevance=relevance, dendrogram=dend,
                         ledger=ledger, lam=res.lam, v=res.v)
