"""Data-similarity estimation (paper §II-B, Eqs. 1-5).

Each user i holds features ``F_i = Phi(X_i) in R^{n_i x d}``.  The protocol:

  1. ``gram(F_i)``            -> ``G_i = (1/n_i) F_i^T F_i``            (Eq. 1)
  2. ``spectrum(G_i)``        -> top-k eigenpairs ``(lam_i, V_i)``
  3. ``cross_project(G_i, V_j)`` -> ``lamhat_k = ||G_i v_k^{(j)}||``    (Eq. 2)
  4. ``relevance(lam_i, lamhat)`` -> ``r(i,j)`` geometric-mean ratio    (Eqs. 3-4)
  5. ``symmetrize(r)``        -> ``R(i,j) = (r(i,j)+r(j,i))/2``         (Eq. 5)

Everything is jit-able and batched over users where noted.  The Gram matrix
and the cross-projection are the compute hot spots; ``repro.kernels.gram``
and ``repro.kernels.eigproject`` provide Pallas TPU kernels for them, and
these functions accept an ``impl`` switch (``"jnp"`` default, ``"pallas"``
on TPU / interpret mode).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SimilarityConfig",
    "pad_ragged",
    "prepare_user_batch",
    "gram",
    "spectrum",
    "user_signature",
    "cross_project",
    "relevance",
    "relevance_matrix",
    "signature_relevance",
    "symmetrize",
    "similarity_matrix",
    "perturb_eigenvectors",
    "subsample_rows",
]

EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class SimilarityConfig:
    """Configuration of the one-shot similarity protocol.

    Attributes:
      top_k: number of eigenvectors each user shares (paper Fig. 4: 5 suffice;
        we default to 8 for margin).  ``0`` means "all d".
      eig_floor: eigenvalues below this are clamped before the min/max ratio
        (paper §III: tiny eigenvalues drift the geometric mean).
      impl: kernel implementation inside the protocol, "jnp" reference maths
        or "pallas" TPU kernels.
      backend: which ``ProtocolEngine`` backend runs the protocol —
        "jnp" (single host), "pallas" (single host, forces ``impl="pallas"``)
        or "shard_map" (users sharded over a mesh axis, paper star topology
        mapped onto collectives).
      block_users: ``0`` runs the dense path (full ``(N, d, d)`` Gram stack
        in one jit).  ``> 0`` enables blockwise streaming: users are
        processed in tiles of this size, Grams live only per tile, and
        cross-projection is Gram-free — peak memory O(block_users * d^2).
        Single-host backends only.
      landmarks: ``0`` scores every user pair (O(N^2) relevance entries).
        ``> 0`` enables the Nystrom-SKETCHED flat path: all N users are
        scored against ``landmarks`` landmark signatures only (the
        ``kernels/assign`` projector-affinity scorer) and R is completed
        from the m x m landmark block — O(N * m) scored entries instead of
        O(N^2).  Mutually exclusive with ``block_users`` (the sketched
        path never materializes the N x N cross-projection the streaming
        tiles exist to bound; combining them has no meaning and is
        rejected).  Single-host backends only; must be < N at run time.
      mesh_axis: mesh axis users are sharded over (shard_map backend).
    """

    top_k: int = 8
    eig_floor: float = 1e-6
    impl: str = "jnp"
    backend: str = "jnp"
    block_users: int = 0
    landmarks: int = 0
    mesh_axis: str = "data"

    def __post_init__(self):
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = all d eigenpairs), "
                             f"got {self.top_k}")
        if self.eig_floor <= 0:
            raise ValueError(f"eig_floor must be positive (it clamps the "
                             f"min/max ratio), got {self.eig_floor}")
        if self.impl not in ("jnp", "pallas"):
            raise ValueError(f"impl must be 'jnp' or 'pallas', "
                             f"got {self.impl!r}")
        if self.block_users < 0:
            raise ValueError(f"block_users must be >= 0, "
                             f"got {self.block_users}")
        if self.landmarks < 0:
            raise ValueError(f"landmarks must be >= 0 (0 = exact, no "
                             f"sketch), got {self.landmarks}")
        if self.landmarks and self.block_users:
            raise ValueError(
                "landmarks and block_users are mutually exclusive: the "
                "sketched path scores O(N * m) entries and never builds "
                "the N x N matrix blockwise streaming tiles — pick one")


def pad_ragged(features: Sequence[np.ndarray], device: bool = True
               ) -> tuple[jax.Array, jax.Array]:
    """Zero-pad a ragged list of per-user ``(n_i, d)`` feature matrices.

    Returns ``(padded (N, n_max, d) float32, n_valid (N,) float32)`` — the
    single conversion point used by ``similarity_matrix``,
    ``one_shot_clustering``, the ``ProtocolEngine`` and the
    ``SignatureEngine``.  ``device=False`` keeps the padded stack as host
    numpy (the raw-ingest streaming path device-puts one row-chunk at a
    time instead of the whole stack).
    """
    counts = [f.shape[0] for f in features]
    n_max = max(counts)
    d = features[0].shape[1]
    padded = np.zeros((len(features), n_max, d), dtype=np.float32)
    for i, f in enumerate(features):
        padded[i, : f.shape[0]] = f
    counts = np.asarray(counts, dtype=np.float32)
    if device:
        return jnp.asarray(padded), jnp.asarray(counts)
    return padded, counts


def prepare_user_batch(data, n_valid=None, device: bool = True):
    """Normalize either accepted user-batch form to ``(padded, n_valid)``.

    Ragged lists of per-user ``(n_i, d)`` arrays are zero-padded via
    ``pad_ragged``; stacked ``(N, n, d)`` arrays pass through (host numpy
    when ``device=False`` — the streaming ingest path — device arrays
    otherwise) with full-length counts unless the true ones are supplied.
    The single input-normalization point shared by ``ProtocolEngine`` and
    ``SignatureEngine``.
    """
    if not isinstance(data, (jax.Array, np.ndarray)):
        if n_valid is not None:
            raise ValueError("n_valid is derived from ragged input; "
                             "pass one or the other")
        padded, counts = pad_ragged(data, device=device)
        return padded, jnp.asarray(counts)
    if data.ndim != 3:
        raise ValueError(f"user batch must be (N, n, m)-shaped "
                         f"(users, rows, dim), got shape {data.shape}")
    if device:
        data = jnp.asarray(data)
    if n_valid is None:
        n_valid = jnp.full((data.shape[0],), data.shape[1], jnp.float32)
    return data, jnp.asarray(n_valid, jnp.float32)


# ---------------------------------------------------------------------------
# Step 1: Gram matrix (Eq. 1)
# ---------------------------------------------------------------------------

def gram(features: jax.Array, *, n_valid: jax.Array | int | None = None,
         impl: str = "jnp") -> jax.Array:
    """``(1/n) F^T F`` for one user's feature matrix ``F (n, d)``.

    ``n_valid`` supports ragged per-user sample counts under a padded batch:
    rows ``>= n_valid`` must already be zero, and the normalisation uses
    ``n_valid`` instead of the padded length.
    """
    n = features.shape[0] if n_valid is None else n_valid
    n = jnp.maximum(jnp.asarray(n, features.dtype), 1.0)
    if impl == "pallas":
        from repro.kernels.gram import ops as gram_ops

        g = gram_ops.gram_matrix(features)
    else:
        g = features.T @ features
    return g / n


def batched_gram(features: jax.Array, n_valid: jax.Array | None = None,
                 *, impl: str = "jnp") -> jax.Array:
    """Vectorised Gram over a user axis: ``features (N, n, d) -> (N, d, d)``."""
    if n_valid is None:
        n_valid = jnp.full((features.shape[0],), features.shape[1],
                           dtype=features.dtype)
    return jax.vmap(lambda f, nv: gram(f, n_valid=nv, impl=impl))(
        features, n_valid)


# ---------------------------------------------------------------------------
# Step 2: eigen-decomposition -> user signature
# ---------------------------------------------------------------------------

def spectrum(g: jax.Array, top_k: int = 0) -> tuple[jax.Array, jax.Array]:
    """Eigen-decomposition of a PSD Gram matrix, descending order.

    Returns ``(lam (k,), V (d, k))`` with ``k = top_k or d``.  ``jnp.linalg
    .eigh`` returns ascending order, so we flip.  The Gram matrix is PSD by
    construction; numerical negatives are clamped at 0.
    """
    lam, v = jnp.linalg.eigh(g)
    lam = jnp.maximum(lam[::-1], 0.0)
    v = v[:, ::-1]
    if top_k and top_k < lam.shape[0]:
        lam = lam[:top_k]
        v = v[:, :top_k]
    return lam, v


def user_signature(features: jax.Array, cfg: SimilarityConfig,
                   *, n_valid: jax.Array | int | None = None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One user's public signature: ``(lam (k,), V (d,k), G (d,d))``.

    ``lam`` and ``V`` are what the user shares; ``G`` stays private and is
    used locally for cross-projection.
    """
    g = gram(features, n_valid=n_valid, impl=cfg.impl)
    lam, v = spectrum(g, cfg.top_k)
    return lam, v, g


# ---------------------------------------------------------------------------
# Step 3: cross-projection (Eq. 2)
# ---------------------------------------------------------------------------

def cross_project(g_own: jax.Array, v_other: jax.Array,
                  *, impl: str = "jnp") -> jax.Array:
    """``lamhat_k = || G_i v_k^{(j)} ||_2`` for each eigenvector column.

    ``g_own (d, d)``, ``v_other (d, k)`` -> ``(k,)``.
    """
    if impl == "pallas":
        from repro.kernels.eigproject import ops as proj_ops

        return proj_ops.project_norms(g_own, v_other)
    proj = g_own @ v_other                      # (d, k)
    return jnp.sqrt(jnp.sum(proj * proj, axis=0))


# ---------------------------------------------------------------------------
# Step 4: relevance (Eqs. 3-4)
# ---------------------------------------------------------------------------

def relevance(lam_own: jax.Array, lam_hat: jax.Array,
              eig_floor: float = 1e-6) -> jax.Array:
    """Geometric mean of the min/max eigenvalue ratios.

    Both spectra are floored at ``eig_floor`` first (paper §III
    "Communication Improvement": a single tiny eigenvalue otherwise drives
    the product to ~0 regardless of the rest).  Computed in log space for
    stability: ``exp(mean_k log(min/max))``.
    """
    a = jnp.maximum(lam_own, eig_floor)
    b = jnp.maximum(lam_hat, eig_floor)
    lo = jnp.minimum(a, b)
    hi = jnp.maximum(a, b)
    return jnp.exp(jnp.mean(jnp.log(lo) - jnp.log(hi)))


def relevance_matrix(grams: jax.Array, lams: jax.Array, vs: jax.Array,
                     eig_floor: float = 1e-6, *, impl: str = "jnp"
                     ) -> jax.Array:
    """All-pairs directed relevance ``r (N, N)``.

    ``grams (N, d, d)``: each user's private Gram.
    ``lams (N, k)``, ``vs (N, d, k)``: the shared signatures.
    ``r[i, j]`` is user *i*'s estimate of its relevance to user *j*
    (projects j's eigenvectors through i's Gram, compares against i's own
    spectrum — paper Algorithm 2 lines 7-12).
    """

    def row(g_i, lam_i):
        def one(v_j):
            lam_hat = cross_project(g_i, v_j, impl=impl)
            return relevance(lam_i, lam_hat, eig_floor)

        return jax.vmap(one)(vs)

    return jax.vmap(row)(grams, lams)


@partial(jax.jit, static_argnames=("eig_floor",))
def signature_relevance(lam, v, eig_floor: float = 1e-6):
    """Symmetrized relevance ``R (N, N)`` from SHARED signatures only.

    Rank-k Gram reconstruction: ``G_i v ~ V_i diag(lam_i) (V_i^T v)``, so
    ``lamhat(i, j) = ||diag(lam_i) (V_i^T V_j)||`` column-wise — O(k^2 d)
    per pair instead of O(k d^2), and computable by the GPS without any
    private Gram.  Row-mapped so peak memory stays O(N k^2).

    Shared by the ``MembershipEngine`` drift re-cluster and the
    ``core.hierarchy`` global stage (clustering the per-group directory
    entries): both decide over compressed signatures the GPS already
    holds, with no extra protocol round.
    """

    def row(args):
        lam_i, v_i = args
        c = jnp.einsum("dk,ndl->nkl", v_i, v)            # (N, k, k)
        lam_hat = jnp.sqrt(jnp.sum((lam_i[None, :, None] * c) ** 2,
                                   axis=1))              # (N, k)
        return jax.vmap(lambda lh: relevance(lam_i, lh, eig_floor)
                        )(lam_hat)

    r = jax.lax.map(row, (lam, v))
    return symmetrize(r)


# ---------------------------------------------------------------------------
# Beyond-paper: privacy noise + subsampled Gram (paper §IV future work)
# ---------------------------------------------------------------------------

def perturb_eigenvectors(v: jax.Array, sigma: float, rng: jax.Array,
                         renormalize: bool = True) -> jax.Array:
    """Additive Gaussian noise on the SHARED eigenvectors (the only thing
    that leaves a user) — the extra privacy layer the paper's §IV names as
    future work.  ``v (d, k)`` or ``(N, d, k)``; columns are re-normalized
    so the projection magnitudes stay comparable.

    Robustness is benchmarked in ``benchmarks/bench_robustness.py``:
    clustering survives sigma up to ~0.1 (columns are unit-norm).
    """
    noise = sigma * jax.random.normal(rng, v.shape, dtype=jnp.float32)
    out = v.astype(jnp.float32) + noise
    if renormalize:
        norms = jnp.linalg.norm(out, axis=-2, keepdims=True)
        out = out / jnp.maximum(norms, EPS)
    return out.astype(v.dtype)


def subsample_rows(features: np.ndarray, max_rows: int,
                   seed: int = 0) -> np.ndarray:
    """Nystrom-style row subsampling: the Gram estimate from ``max_rows``
    uniformly-sampled rows is an unbiased second-moment estimator, cutting
    the Eq.-1 cost from O(n d^2) to O(max_rows d^2) for n >> d regimes."""
    n = features.shape[0]
    if n <= max_rows:
        return features
    idx = np.random.default_rng(seed).choice(n, max_rows, replace=False)
    return features[idx]


# ---------------------------------------------------------------------------
# Step 5: symmetrization (Eq. 5)
# ---------------------------------------------------------------------------

def symmetrize(r: jax.Array) -> jax.Array:
    """``R = (r + r^T) / 2`` — the GPS-side average of the two directed views."""
    return (r + r.T) / 2.0


# ---------------------------------------------------------------------------
# End-to-end (any backend)
# ---------------------------------------------------------------------------

def similarity_matrix(features: jax.Array | Sequence[np.ndarray],
                      cfg: SimilarityConfig | None = None,
                      n_valid: jax.Array | None = None) -> jax.Array:
    """Full protocol on a padded user batch ``features (N, n, d)`` -> ``R (N, N)``.

    Accepts a list of per-user ``(n_i, d)`` arrays (ragged); they are
    zero-padded to the max ``n_i`` and the true counts are passed through.
    Thin wrapper over ``repro.core.engine.ProtocolEngine`` — the backend
    (dense / blockwise / shard_map) is chosen by ``cfg``.
    """
    from repro.core.engine import ProtocolEngine

    return ProtocolEngine(cfg).similarity(features, n_valid=n_valid)
