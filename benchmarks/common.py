"""Shared benchmark helpers: timing + the MT-HFL comparison harness used by
the Fig. 2 / Fig. 3 reproductions."""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import partition as dpart
from repro.data import synthetic as syn
from repro.fed import client as fclient
from repro.fed import partition as fpart
from repro.fed import trainer as ftrainer


def time_us(fn: Callable, n_iter: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = obs.now()
    for _ in range(n_iter):
        fn()
    return (obs.now() - t0) / n_iter * 1e6


def row(name: str, us: float, **derived) -> str:
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    return f"{name},{us:.1f},{kv}"


def environment_stamp() -> dict:
    """The reproducibility stamp every recorded payload carries: numbers
    measured under one jax version / device class cannot be compared to
    another's without knowing it."""
    import jax

    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "device_count": jax.device_count(),
    }


def record_result(json_path: str | Path, payload: dict) -> None:
    """Write one benchmark's JSON record under ``benchmarks/results/``.

    The single JSON-writing path shared by every recording benchmark
    (creates parent dirs, pretty-prints, trailing newline, stamps the
    jax/device environment plus the telemetry counters active during
    the run), so recorded artifacts stay diff-friendly and uniform.
    """
    payload = {**payload, "env": environment_stamp(),
               "metrics": obs.stamp()}
    p = Path(json_path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2) + "\n")


def mthfl_compare(users, tasks: dict, model_builder: Callable,
                  eval_spec, n_clusters: int, seeds: Sequence[int],
                  cfg: ftrainer.MTHFLConfig,
                  feature_fn: Callable | None = None,
                  top_k: int = 8,
                  fused: bool | str = "auto"):
    """Run proposed (one-shot similarity) vs random clustering over seeds.

    Returns dict with per-method mean/std of final per-cluster accuracy,
    plus the clustering accuracy of the proposed method.  ``fused`` and
    ``cfg.backend``/``cfg.scan_rounds`` select the trainer execution path
    (the paper layouts have per-task head sizes, so ``"auto"`` falls back
    to the reference loop unless the heads happen to match).
    """
    feats = [feature_fn(u.x) if feature_fn else u.x for u in users]
    res = oneshot.one_shot_clustering(feats, n_clusters,
                                      cfg=SimilarityConfig(top_k=top_k))
    true = [u.task_id for u in users]
    clu_acc = clu.clustering_accuracy(res.labels, true)

    def run(labels, seed):
        cc = []
        for t in range(n_clusters):
            members = [u for u, l in zip(users, labels) if l == t]
            counts = {}
            for u in members:
                key = tuple(u.task_classes)
                counts[key] = counts.get(key, 0) + 1
            cc.append(list(max(counts, key=counts.get)) if counts
                      else list(list(tasks.values())[t]))
        models = [model_builder(c) for c in cc]
        evals = [eval_spec(c, tasks) for c in cc]
        run_cfg = dataclasses.replace(cfg, seed=seed)
        hist = ftrainer.train_mthfl(users, labels, models, evals, run_cfg,
                                    cluster_classes=cc, fused=fused)
        return hist.accuracy[-1]

    proposed, random_base = [], []
    sizes = np.bincount(res.labels, minlength=n_clusters)
    import jax

    for seed in seeds:
        proposed.append(run(res.labels, seed))
        rand = clu.random_clusters(len(users), n_clusters, rng=seed,
                                   cluster_sizes=list(sizes))
        random_base.append(run(rand, seed))
        # Every run creates fresh jit closures (new loss_fn per cluster);
        # XLA's CPU JIT intermittently fails ("Failed to materialize
        # symbols") once too many compiled dylibs accumulate — drop them
        # between seeds.
        jax.clear_caches()
    proposed = np.stack(proposed)
    random_base = np.stack(random_base)
    return {
        "clustering_accuracy": clu_acc,
        "proposed_mean": proposed.mean(),
        "proposed_std": proposed.std(),
        "proposed_per_task": proposed.mean(0),
        "random_mean": random_base.mean(),
        "random_std": random_base.std(),
        "random_per_task": random_base.mean(0),
    }


def make_eval_spec(spec: syn.SyntheticImageSpec, n: int = 60, seed: int = 999):
    def eval_spec(classes, tasks):
        task_id = [k for k, v in tasks.items() if set(v) == set(classes)]
        tid = task_id[0] if task_id else 0
        x, y = syn.make_task_dataset(spec, list(classes), n, seed=seed,
                                     task_of_class={c: tid for c in classes})
        lut = {c: i for i, c in enumerate(classes)}
        return (jnp.asarray(x),
                np.asarray([lut[int(v)] for v in y], np.int32))
    return eval_spec
