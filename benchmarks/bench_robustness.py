"""Robustness suite: dirty-data serving + the paper's noise future-work.

Three sweeps:

* **Corruption x aggregator grid** (the ISSUE 7 acceptance): seed an
  ``N``-user directory, replace ``frac`` of the member signatures with
  the colluding-copy Byzantine attack (``data.synthetic``: attackers in
  cluster t upload a ``scale``-multiplied copy of an honest victim from
  cluster t+1 — the coordinated poison a plain mean cannot shrug off),
  then assign a CLEAN 64-arrival wave and score accuracy vs the task
  oracle.  Every (frac, aggregator) cell runs all three backends and
  asserts they agree on the labels.  At 20% Byzantine members the
  resistant aggregators must recover >= 95% accuracy while ``mean``
  collapses; at 0% the ``mean`` row must match the PR-6
  ``bench_membership.json`` baseline (latency within 10%, accuracy
  within 0.10) — the hardening must not slow the clean path.

* **Eigenvector noise** (paper §IV future work): DP-style perturbation
  of the only shared artifact, FMNIST three-task accuracy.

* **Nystrom row-subsampling**: Gram subsample size vs accuracy, each
  user subsampled under its OWN seed (spawned from one root
  ``SeedSequence`` — a single shared seed would correlate the sampled
  row subsets across clients and bias the sweep).

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_robustness.py``
(``--quick``: N=256 corruption grid only, no legacy sweeps — the CI
smoke).  Full runs record ``benchmarks/results/bench_robustness.json``.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core import similarity as sim
from repro.core.cluster_engine import ClusterConfig
from repro.core.engine import ProtocolEngine
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.core.similarity import SimilarityConfig
from repro.data import partition as dpart
from repro.data import synthetic as syn

# Same grid constants as bench_membership so the clean-path rows are
# directly comparable to its recorded baseline.
WAVE = 64
D = 32
SAMPLES = 16
TASKS = 8
TOP_K = 8
BACKENDS = ("numpy", "jnp", "pallas")
AGGREGATORS = ("mean", "trimmed", "medians")
FRACS = (0.0, 0.1, 0.2, 0.3)
TRIM_FRAC = 0.3                  # breakdown margin above the 0.2 assert
BYZ_SCALE = 8.0

BASELINE_JSON = Path(__file__).parent / "results" / "bench_membership.json"


def _baseline_row(n: int) -> dict | None:
    """PR-6 ``bench_membership`` record for table size ``n`` (if any)."""
    if not BASELINE_JSON.exists():
        return None
    import json

    for rec in json.loads(BASELINE_JSON.read_text()).get("grid", []):
        if rec.get("N") == n:
            return rec
    return None


def _assign_accuracy(labels: np.ndarray, wave_tasks: np.ndarray,
                     task_of_cluster: np.ndarray) -> float:
    """Accuracy vs oracle over the WHOLE wave — an unassigned arrival
    counts as a miss (robustness must not hide behind abstention)."""
    hit = (labels >= 0) & (task_of_cluster[np.maximum(labels, 0)]
                           == wave_tasks)
    return float(hit.mean())


def corruption_grid(n: int, quick: bool) -> tuple[list[str], dict]:
    feats, tids = syn.make_task_feature_mixture(n + WAVE, SAMPLES, D,
                                                TASKS, seed=0)
    block = 256 if n > 512 else 0
    res = oneshot.one_shot_clustering(
        feats[:n], TASKS, cfg=SimilarityConfig(top_k=TOP_K,
                                               block_users=block),
        cluster_cfg=ClusterConfig(backend="jnp"))
    seed_labels = np.asarray(jax.block_until_ready(res.labels))
    lam0 = np.asarray(res.lam, np.float32)
    v0 = np.asarray(res.v, np.float32)

    # cluster id -> oracle task (majority vote over the CLEAN seed; the
    # attack poisons statistics, it never relabels directory members).
    task_of_cluster = np.full(TASKS, -1)
    for t in range(TASKS):
        members = tids[:n][seed_labels == t]
        if len(members):
            task_of_cluster[t] = np.bincount(members).argmax()

    lam_w, v_w, _ = ProtocolEngine(
        SimilarityConfig(top_k=TOP_K)).signatures(feats[n:])
    wave_tasks = tids[n:]

    # median-of-means group count: > 2x the expected per-cluster poison
    # at the largest swept frac, so a majority of groups stays clean.
    mom_groups = int(2 * np.ceil(0.35 * n / TASKS)) + 1

    rows, grid = [], []
    for frac in FRACS:
        lam_c, v_c, byz = syn.byzantine_signatures(
            lam0, v0, frac, mode="colluding_copy",
            seed=17 + int(frac * 100), scale=BYZ_SCALE,
            labels=seed_labels)
        for agg in AGGREGATORS:
            labels_by, assign_s = {}, None
            for backend in BACKENDS:
                eng = MembershipEngine(MembershipConfig(
                    backend=backend, aggregator=agg,
                    trim_frac=TRIM_FRAC, mom_groups=mom_groups))
                eng.seed(lam_c, v_c, seed_labels, n_clusters=TASKS)
                out = eng.assign(lam_w, v_w)
                if backend != "numpy":
                    jax.block_until_ready(out.labels)
                labels_by[backend] = np.asarray(out.labels)
                if backend == "jnp":
                    # min of 3 medians-of-10: the clean-path latency
                    # guard compares this against the PR-6 baseline.
                    meds = []
                    for _ in range(3):
                        t0 = time.perf_counter()
                        for _ in range(10):
                            jax.block_until_ready(
                                eng.assign(lam_w, v_w).labels)
                        meds.append((time.perf_counter() - t0) / 10)
                    assign_s = min(meds)
            for backend in BACKENDS[1:]:
                assert (labels_by[backend] == labels_by["numpy"]).all(), (
                    f"{backend}/numpy labels disagree at frac={frac}, "
                    f"aggregator={agg}")
            acc = _assign_accuracy(labels_by["jnp"], wave_tasks,
                                   task_of_cluster)
            grid.append({
                "N": n, "frac": frac, "aggregator": agg,
                "n_byzantine": int(byz.sum()),
                "accuracy_vs_oracle": round(acc, 4),
                "assign_jnp_s": round(assign_s, 6),
                "backends_agree": True,
            })
            rows.append(common.row(
                f"robust_byz{int(frac * 100)}_{agg}", assign_s * 1e6,
                accuracy_vs_oracle=round(acc, 4),
                n_byzantine=int(byz.sum())))
        jax.clear_caches()

    by = {(g["frac"], g["aggregator"]): g for g in grid}
    # frac=0: robust aggregators must be no worse than mean (clean
    # equality is property-tested exactly; here the served verdicts).
    for agg in AGGREGATORS:
        assert by[(0.0, agg)]["accuracy_vs_oracle"] >= 0.95, (
            f"clean-path accuracy with {agg} aggregator below 95%")
    # frac=0.2 (the acceptance cell): a resistant aggregator recovers
    # while the mean collapses under the colluding poison.
    robust_best = max(by[(0.2, "trimmed")]["accuracy_vs_oracle"],
                      by[(0.2, "medians")]["accuracy_vs_oracle"])
    acc_mean = by[(0.2, "mean")]["accuracy_vs_oracle"]
    assert robust_best >= 0.95, (
        f"no resistant aggregator recovers at 20% Byzantine "
        f"(best {robust_best:.1%})")
    assert acc_mean < robust_best - 0.2, (
        f"mean did not degrade at 20% Byzantine (acc {acc_mean:.1%} vs "
        f"robust {robust_best:.1%}) — the attack is not exercising the "
        f"breakdown point")

    # Clean-path guard vs the PR-6 bench_membership baseline.
    base = _baseline_row(n)
    clean = by[(0.0, "mean")]
    guard = {"baseline_found": base is not None}
    if base is not None:
        ratio = clean["assign_jnp_s"] / base["assign_jnp_s"]
        guard.update(baseline_assign_jnp_s=base["assign_jnp_s"],
                     clean_assign_jnp_s=clean["assign_jnp_s"],
                     latency_ratio=round(ratio, 3),
                     baseline_match=base["match_vs_full_recluster"],
                     clean_accuracy=clean["accuracy_vs_oracle"])
        if not quick:
            assert ratio <= 1.10, (
                f"clean-path mean assignment {ratio:.2f}x slower than "
                f"the PR-6 baseline (> 1.10x)")
            assert clean["accuracy_vs_oracle"] >= \
                base["match_vs_full_recluster"] - 0.10, (
                    "clean-path mean accuracy fell more than 0.10 below "
                    "the PR-6 baseline")
    rec = {"grid": grid, "clean_guard": guard, "trim_frac": TRIM_FRAC,
           "mom_groups": mom_groups, "byzantine_scale": BYZ_SCALE}
    return rows, rec


def _cluster_with_noise(feats, true, sigma: float, top_k: int = 8) -> float:
    engine = ProtocolEngine(sim.SimilarityConfig(top_k=top_k))
    lam, v, grams = engine.signatures(feats)
    if sigma > 0:
        v = sim.perturb_eigenvectors(v, sigma, jax.random.PRNGKey(17))
    r = sim.relevance_matrix(grams, lam, v)
    big_r = np.asarray(sim.symmetrize(r))
    labels = clu.hac_clusters(big_r, len(set(true)))
    return clu.clustering_accuracy(labels, true)


def legacy_sweeps(sigmas=(0.0, 0.01, 0.05, 0.1, 0.3, 1.0),
                  subsamples=(64, 128, 256, 0)
                  ) -> tuple[list[str], list[dict]]:
    """The pre-ISSUE-7 sweeps: eigenvector noise + Nystrom subsampling."""
    users = dpart.paper_fmnist_three_task(seed=0, scale=0.25)
    feats = [u.x for u in users]
    true = [u.task_id for u in users]
    rows, recs = [], []
    for s in sigmas:
        acc = _cluster_with_noise(feats, true, s)
        rows.append(common.row(f"robust_noise_sigma{s}", 0.0,
                               clustering_accuracy=acc))
        recs.append({"sweep": "noise", "sigma": s,
                     "clustering_accuracy": round(acc, 4)})
    # Per-user subsample seeds spawned from one root: a single shared
    # seed would pick the SAME row subset for every user.
    for m in subsamples:
        seeds = np.random.SeedSequence(3).spawn(len(feats))
        sub = [sim.subsample_rows(f, m, seed=s) if m else f
               for f, s in zip(feats, seeds)]
        acc = _cluster_with_noise(sub, true, 0.0)
        cost = round((min(m, feats[0].shape[0]) if m
                      else feats[0].shape[0]) / feats[0].shape[0], 3)
        rows.append(common.row(
            f"robust_subsample_{m or 'full'}", 0.0,
            clustering_accuracy=acc, gram_cost_rel=cost))
        recs.append({"sweep": "subsample", "m": m or "full",
                     "clustering_accuracy": round(acc, 4),
                     "gram_cost_rel": cost})
    return rows, recs


def run(quick: bool = False, json_path: str | None = None) -> list[str]:
    n = 256 if quick else 1024
    rows, rec = corruption_grid(n, quick)
    legacy = []
    if not quick:
        lrows, legacy = legacy_sweeps()
        rows.extend(lrows)
    if json_path:
        common.record_result(json_path, {
            "quick": quick,
            "backend": jax.default_backend(),
            # pallas ran inside every grid cell (the agreement assert);
            # off-TPU it executes in interpret mode.
            "pallas_interpret": jax.default_backend() != "tpu",
            **rec,
            "legacy": legacy,
        })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: N=256 corruption grid only")
    ap.add_argument("--json",
                    default="benchmarks/results/bench_robustness.json",
                    help="where to record the sweep")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(r, flush=True)
