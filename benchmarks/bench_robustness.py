"""Beyond-paper: robustness of the clustering to noisy shared eigenvectors
(the paper's §IV future-work item) and to Nystrom row-subsampling.

Sweeps the eigenvector noise sigma (DP-style perturbation of the ONLY
shared artifact) and the Gram subsample size, reporting clustering
accuracy on the FMNIST three-task layout.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core import similarity as sim
from repro.core.engine import ProtocolEngine
from repro.data import partition as dpart


def _cluster_with_noise(feats, true, sigma: float, top_k: int = 8) -> float:
    engine = ProtocolEngine(sim.SimilarityConfig(top_k=top_k))
    lam, v, grams = engine.signatures(feats)
    if sigma > 0:
        v = sim.perturb_eigenvectors(v, sigma, jax.random.PRNGKey(17))
    r = sim.relevance_matrix(grams, lam, v)
    big_r = np.asarray(sim.symmetrize(r))
    labels = clu.hac_clusters(big_r, len(set(true)))
    return clu.clustering_accuracy(labels, true)


def run(sigmas=(0.0, 0.01, 0.05, 0.1, 0.3, 1.0),
        subsamples=(64, 128, 256, 0)) -> list[str]:
    users = dpart.paper_fmnist_three_task(seed=0, scale=0.25)
    feats = [u.x for u in users]
    true = [u.task_id for u in users]
    rows = []
    for s in sigmas:
        acc = _cluster_with_noise(feats, true, s)
        rows.append(common.row(f"robust_noise_sigma{s}", 0.0,
                               clustering_accuracy=acc))
    for m in subsamples:
        sub = [sim.subsample_rows(f, m, seed=3) if m else f for f in feats]
        acc = _cluster_with_noise(sub, true, 0.0)
        rows.append(common.row(
            f"robust_subsample_{m or 'full'}", 0.0,
            clustering_accuracy=acc,
            gram_cost_rel=round((min(m, feats[0].shape[0]) if m
                                 else feats[0].shape[0])
                                / feats[0].shape[0], 3)))
    return rows
