"""Paper Table II: cross-dataset similarity (CIFAR-10 vehicles vs CIFAR-100
vehicles vs CIFAR-100 other classes).  Paper: r(1,2)=0.62 > r(1,3)=0.39."""
from __future__ import annotations

from benchmarks import common
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import features as feat
from repro.data import synthetic as syn


def run() -> list[str]:
    shared = 777
    x1, _ = syn.make_task_dataset(
        syn.CIFAR_LIKE, [0, 1, 8, 9], 100, seed=1,
        task_of_class={c: 0 for c in (0, 1, 8, 9)}, shared_task_seed=shared)
    x2, _ = syn.make_task_dataset(
        syn.CIFAR100_LIKE, [10, 11, 12], 120, seed=2,
        task_of_class={10: 0, 11: 0, 12: 0}, shared_task_seed=shared)
    x3, _ = syn.make_task_dataset(
        syn.CIFAR100_LIKE, [40, 41, 42], 120, seed=3,
        task_of_class={40: 1, 41: 1, 42: 1}, shared_task_seed=shared)
    fc = feat.FeatureConfig(kind="random_projection", d=128)
    feats = [feat.feature_map(x, fc) for x in (x1, x2, x3)]
    res = oneshot.one_shot_clustering(feats, n_clusters=2,
                                      cfg=SimilarityConfig(top_k=8))
    r12 = float(res.similarity[0, 1])
    r13 = float(res.similarity[0, 2])
    return [common.row(
        "table2_cross_dataset", 0.0,
        sim_vehicles_vehicles=round(r12, 4),
        sim_vehicles_other=round(r13, 4),
        matched_higher=bool(r12 > r13),
        paper_values="0.62_vs_0.39")]
