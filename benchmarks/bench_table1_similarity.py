"""Paper Table I: the similarity matrix R on the CIFAR-10 two-task split.

Reports the in-task / cross-task block means (paper: ~0.97 vs ~0.31), the
block separation margin, clustering accuracy at T=2, and the wall time of
the full one-shot protocol for 10 users.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import features as feat
from repro.data import partition as dpart


def run() -> list[str]:
    users = dpart.paper_cifar_two_task(n_per_user=400, seed=0)
    fc = feat.FeatureConfig(kind="random_projection", d=128)
    feats = [feat.feature_map(u.x, fc) for u in users]

    res = oneshot.one_shot_clustering(feats, n_clusters=2,
                                      cfg=SimilarityConfig(top_k=8))
    us = common.time_us(
        lambda: oneshot.one_shot_clustering(
            feats, 2, cfg=SimilarityConfig(top_k=8)), n_iter=3)

    r = res.similarity
    tid = np.asarray([u.task_id for u in users])
    same_mask = (tid[:, None] == tid[None, :]) & ~np.eye(len(users), dtype=bool)
    in_task = float(r[same_mask].mean())
    cross = float(r[~(tid[:, None] == tid[None, :])].mean())
    acc = clu.clustering_accuracy(res.labels, tid)
    return [common.row(
        "table1_similarity_matrix", us,
        in_task_mean=round(in_task, 4), cross_task_mean=round(cross, 4),
        separation=round(in_task - cross, 4),
        clustering_accuracy=acc,
        paper_in_task=0.97, paper_cross_task=0.31)]
