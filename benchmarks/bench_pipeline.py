"""End-to-end pipeline benchmark: RAW user shards -> cluster labels.

The full Algorithm-2 wall-clock, both ways:

  host_ingest    numpy Phi per user -> padded feature stack ->
                 ProtocolEngine (dense jnp) -> host numpy HAC
  raw_dense      one_shot_clustering raw entry point, one-pass device
                 featurize, subspace top-k, device NN-chain HAC
  raw_stream     same, row-chunk streaming Gram accumulation
  raw_pallas     same, fused kernels/featurize_gram chunks (bf16)

Every device point asserts LABEL PARITY against the host path (ARI == 1
up to relabelling) and perfect task recovery, so the speedup is measured
at equal answer quality.  Wall-clock includes everything from raw numpy
shards to labels on the host.

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_pipeline.py``
(CI smoke: ``--quick``).  Results recorded via ``--json``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.cluster_engine import ClusterConfig
from repro.core.engine import ProtocolEngine
from repro.core.signature_engine import SignatureConfig
from repro.core.similarity import SimilarityConfig
from repro.data import features as feat
from repro.data import synthetic as syn

TOP_K = 8


def host_pipeline(raw: np.ndarray, fc: feat.FeatureConfig, n_tasks: int
                  ) -> np.ndarray:
    """Seed-era path: host featurize loop + dense protocol + host HAC."""
    feats = np.stack([feat.feature_map(raw[i], fc)
                      for i in range(raw.shape[0])])
    cfg = SimilarityConfig(top_k=TOP_K)
    big_r = np.asarray(ProtocolEngine(cfg).similarity(feats))
    return clu.hac_clusters(big_r, n_tasks)


def bench_point(n_users: int, n: int, m: int, d: int, n_tasks: int,
                chunk: int, run_pallas: bool) -> tuple[list[str], dict]:
    raw, task_ids = syn.make_task_feature_mixture(n_users, n, m, n_tasks,
                                                  seed=0)
    fc = feat.FeatureConfig(kind="random_projection", d=d)

    labels_host = host_pipeline(raw, fc, n_tasks)      # warm engine jit
    t0 = time.perf_counter()
    labels_host = host_pipeline(raw, fc, n_tasks)
    t_host = time.perf_counter() - t0
    assert clu.clustering_accuracy(labels_host, task_ids) == 1.0

    modes = [
        ("raw_dense", SignatureConfig()),
        ("raw_stream", SignatureConfig(chunk_rows=chunk)),
    ]
    if run_pallas:
        # Off-TPU the kernel executes in interpret mode, which times the
        # interpreter rather than the kernel — keep it to the small point
        # (parity still asserted), like bench_clustering's pallas cap.
        modes.append(("raw_pallas",
                      SignatureConfig(backend="pallas", chunk_rows=chunk,
                                      compute_dtype="bf16")))
    rows, recs = [], []
    for name, sig_cfg in modes:
        sim_backend = "pallas" if sig_cfg.backend == "pallas" else "jnp"

        def run_once():
            res = oneshot.one_shot_clustering(
                raw, n_clusters=n_tasks,
                cfg=SimilarityConfig(top_k=TOP_K, backend=sim_backend),
                cluster_cfg=ClusterConfig(backend="jnp"),
                feature_cfg=fc, signature_cfg=sig_cfg)
            return np.asarray(res.labels)

        labels = run_once()                                   # compile
        t0 = time.perf_counter()
        labels = run_once()
        dt = time.perf_counter() - t0
        ari = float(clu.adjusted_rand_index(labels, labels_host))
        assert ari == 1.0, (
            f"{name} label parity broken at N={n_users}: ARI={ari}")
        rec = {"mode": name, "seconds": round(dt, 4),
               "speedup_vs_host": round(t_host / dt, 2), "parity": True}
        if sim_backend == "pallas":
            rec["pallas_interpret"] = jax.default_backend() != "tpu"
        recs.append(rec)
        rows.append(common.row(
            f"pipeline_{name}_N{n_users}", dt * 1e6,
            host_us=round(t_host * 1e6, 1),
            speedup_vs_host=rec["speedup_vs_host"], parity=True))
    record = {"N": n_users, "n": n, "m": m, "d": d, "tasks": n_tasks,
              "chunk_rows": chunk, "host_s": round(t_host, 4),
              "modes": recs}
    return rows, record


def run(quick: bool = False, json_path: str | None = None) -> list[str]:
    on_tpu = jax.default_backend() == "tpu"
    if quick:
        points = [(48, 48, 96, 32, 4, 16, True)]
    else:
        points = [(64, 64, 128, 64, 4, 32, True),
                  (256, 128, 512, 128, 8, 64, on_tpu)]
    rows, records = [], []
    for point in points:
        r, rec = bench_point(*point)
        rows.extend(r)
        records.append(rec)
        jax.clear_caches()
    payload = {"quick": quick, "backend": jax.default_backend(),
               "grid": records}
    if json_path:
        common.record_result(json_path, payload)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small point, same code paths")
    ap.add_argument("--json",
                    default="benchmarks/results/bench_pipeline.json",
                    help="where to record the wall-clock grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(r, flush=True)
