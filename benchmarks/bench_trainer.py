"""Fused MT-HFL trainer benchmark: rounds/sec vs the per-cluster loop.

Grid: T in {2, 4, 8} clusters x C in {8, 32} clients per cluster (MLP
clients, synthetic data).  Three execution paths of ``train_mthfl``:

  loop        — the retained reference loop (``fused=False``): Python over
                clusters, one ``fused_lps_round`` dispatch per cluster per
                local round, host-side batch gathering.
  fused       — the cluster-stacked program (vmap clusters + scan local
                rounds + in-jit GPS): ONE dispatch per global round.
  fused_shmap — same program under shard_map (cluster axis over devices;
                1 device on a CPU runner, so this measures overhead).

Methodology: every path warms the jit caches with one ``train_mthfl``
call, then runs at G=1 and at G=1+``--rounds``; per-round time is the
difference divided by ``--rounds``, which subtracts per-call setup (stack
building, eval) identically from all paths.  Both paths
train on bit-identical batches (keyed sampling), so a parity flag rides
along with every row.

Acceptance (ISSUE 2): fused >= 3x loop rounds/sec at T=8, C=32 on CPU,
recorded in the JSON written to ``--json``.

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_trainer.py --quick``
(CI smoke: T=2, C=8 only, same code paths).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data.partition import UserData
from repro.fed import client as fclient
from repro.fed import partition as fpart
from repro.fed import trainer as ftrainer
from repro.models import mlp

M, NCLS, N_PER_CLIENT = 32, 4, 128
MCFG = mlp.PaperMLPConfig(m=M, hidden=16, n_classes=NCLS)


def make_setup(n_clusters: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((NCLS, M)).astype(np.float32)
    users, labels = [], []
    uid = 0
    for t in range(n_clusters):
        for _ in range(n_clients):
            y = rng.integers(0, NCLS, N_PER_CLIENT).astype(np.int32)
            x = (centers[y] + 0.3 * rng.standard_normal(
                (N_PER_CLIENT, M))).astype(np.float32)
            users.append(UserData(user_id=uid, task_id=t, x=x, y=y,
                                  task_classes=tuple(range(NCLS))))
            labels.append(t)
            uid += 1
    models = [ftrainer.TaskModel(
        init=lambda k, c=MCFG: mlp.init(c, k),
        loss_fn=mlp.loss_fn(MCFG),
        accuracy=lambda p, x, y, c=MCFG: mlp.accuracy(c, p, x, y),
        is_common=fpart.prefix_predicate(mlp.COMMON_PREFIXES))
        for _ in range(n_clusters)]
    evals = []
    for _ in range(n_clusters):
        y = rng.integers(0, NCLS, 32).astype(np.int32)
        x = (centers[y] + 0.3 * rng.standard_normal((32, M))).astype(
            np.float32)
        evals.append((jnp.asarray(x), y))
    cc = [list(range(NCLS))] * n_clusters
    return users, np.asarray(labels), models, evals, cc


def _time_rounds(setup, n_rounds: int, **train_kw) -> tuple[float, object]:
    """Seconds per global round (compile subtracted) + the G=1 history."""
    users, labels, models, evals, cc = setup

    def run(g):
        cfg = ftrainer.MTHFLConfig(
            global_rounds=g, local_rounds=1, local_steps=10, batch_size=32,
            client=fclient.ClientConfig(lr=0.05), seed=0,
            **train_kw.get("cfg_kw", {}))
        t0 = time.perf_counter()
        hist = ftrainer.train_mthfl(users, labels, models, evals, cfg,
                                    cluster_classes=cc,
                                    fused=train_kw["fused"])
        return time.perf_counter() - t0, hist

    run(1)                          # warmup: compiles land in the jit cache
    t1, hist1 = run(1)
    t2, _ = run(1 + n_rounds)
    return max((t2 - t1) / n_rounds, 1e-9), hist1


def bench_grid(n_clusters: int, n_clients: int, n_rounds: int
               ) -> tuple[list[str], dict]:
    setup = make_setup(n_clusters, n_clients)
    s_loop, h_loop = _time_rounds(setup, n_rounds, fused=False)
    s_fused, h_fused = _time_rounds(setup, n_rounds, fused=True)
    s_shmap, h_shmap = _time_rounds(
        setup, n_rounds, fused=True, cfg_kw={"backend": "shard_map"})

    def close(a, b):
        return bool(np.allclose(a.accuracy, b.accuracy, atol=1e-5)
                    and np.allclose(a.train_loss, b.train_loss, atol=1e-5))

    rec = {
        "T": n_clusters, "C": n_clients,
        "loop_rounds_per_sec": round(1.0 / s_loop, 2),
        "fused_rounds_per_sec": round(1.0 / s_fused, 2),
        "fused_shard_map_rounds_per_sec": round(1.0 / s_shmap, 2),
        "speedup_fused_vs_loop": round(s_loop / s_fused, 2),
        "speedup_shard_map_vs_loop": round(s_loop / s_shmap, 2),
        "fused_matches_loop": close(h_fused, h_loop),
        "shard_map_matches_loop": close(h_shmap, h_loop),
        "n_devices": len(jax.devices()),
    }
    rows = [common.row(
        f"trainer_T{n_clusters}_C{n_clients}", s_fused * 1e6,
        loop_us=round(s_loop * 1e6, 1),
        shard_map_us=round(s_shmap * 1e6, 1),
        speedup_vs_loop=rec["speedup_fused_vs_loop"],
        matches_loop=rec["fused_matches_loop"])]
    return rows, rec


def run(quick: bool = False, n_rounds: int = 4,
        json_path: str | None = None) -> list[str]:
    grid = [(2, 8)] if quick else [(2, 8), (2, 32), (4, 8), (4, 32),
                                   (8, 8), (8, 32)]
    rows, records = [], []
    for n_clusters, n_clients in grid:
        r, rec = bench_grid(n_clusters, n_clients, n_rounds)
        rows.extend(r)
        records.append(rec)
        jax.clear_caches()
    if json_path:
        common.record_result(json_path, {"quick": quick, "rounds": n_rounds,
                                         "grid": records})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: T=2, C=8 only, same code paths")
    ap.add_argument("--rounds", type=int, default=4,
                    help="timed global rounds per path")
    ap.add_argument("--json", default="benchmarks/results/bench_trainer.json",
                    help="where to record the speedup grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, n_rounds=args.rounds,
                 json_path=args.json):
        print(r, flush=True)
