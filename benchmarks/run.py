"""Benchmark harness — one benchmark per paper table/figure (+ comm, IFCA
baseline, robustness, kernels, and the roofline table from the dry-run
artifacts).

Prints ``name,us_per_call,derived`` CSV.  ``--only <prefix>`` filters;
``--seeds N`` widens the MT-HFL comparisons (paper used 6 runs).

Each suite runs in its OWN subprocess: XLA's CPU JIT intermittently fails
("Failed to materialize symbols") after many compilations accumulate in
one long-lived process, so suite isolation is required for a reliable
full run (suites behave identically run individually).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

from repro import obs

SUITES = ["table1", "table2", "fig2", "fig3", "fig4", "comm", "ifca",
          "robustness", "kernels", "clustering", "signature", "pipeline",
          "membership", "scale", "roofline", "serve", "obs"]


def run_suite(name: str, seeds: int) -> list[str]:
    from benchmarks import (bench_clustering, bench_comm_cost,
                            bench_fig2_cifar, bench_fig3_fmnist,
                            bench_fig4_eigvectors, bench_ifca,
                            bench_kernels, bench_membership, bench_obs,
                            bench_pipeline, bench_robustness,
                            bench_roofline, bench_scale, bench_serve,
                            bench_signature, bench_table1_similarity,
                            bench_table2_crossdataset)

    s = tuple(range(seeds))
    fns = {
        "table1": lambda: bench_table1_similarity.run(),
        "table2": lambda: bench_table2_crossdataset.run(),
        "fig2": lambda: bench_fig2_cifar.run(seeds=s),
        "fig3": lambda: bench_fig3_fmnist.run(seeds=s),
        "fig4": lambda: bench_fig4_eigvectors.run(),
        "comm": lambda: bench_comm_cost.run(),
        "ifca": lambda: bench_ifca.run(),
        "robustness": lambda: bench_robustness.run(quick=True),
        "kernels": lambda: bench_kernels.run(),
        # quick grid inside the harness; the full N=4096 sweep (which
        # times the O(N^3) host reference once) runs standalone
        "clustering": lambda: bench_clustering.run(quick=True),
        # likewise: the full acceptance grids (N=512 ingest, N=256
        # pipeline) run standalone — the harness smokes the code paths
        "signature": lambda: bench_signature.run(quick=True),
        "pipeline": lambda: bench_pipeline.run(quick=True),
        # likewise: the full acceptance grid (N up to 8192 table sizes,
        # re-run baselines) runs standalone
        "membership": lambda: bench_membership.run(quick=True),
        # likewise: the full N=10^3 -> 10^5 trajectory (exact-path
        # baselines + the 10^5 hierarchical point) runs standalone
        "scale": lambda: bench_scale.run(quick=True),
        "roofline": lambda: bench_roofline.run(),
        # likewise: the full acceptance run (batch-8 ragged mix, >= 3x
        # continuous-vs-static assert) runs standalone
        "serve": lambda: bench_serve.run(quick=True),
        # telemetry overhead guard: enabled <= 5%, disabled <= 0.5%
        "obs": lambda: bench_obs.run(quick=True),
    }
    return fns[name]()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--suite-child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.suite_child:                      # child mode: run one suite
        for row in run_suite(args.suite_child, args.seeds):
            print(row, flush=True)
        return

    print("name,us_per_call,derived")
    selected = [s for s in SUITES
                if args.only is None or s.startswith(args.only)]
    for name in selected:
        t0 = obs.now()
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.run",
             "--suite-child", name, "--seeds", str(args.seeds)],
            capture_output=True, text=True,
            env=dict(os.environ), timeout=3600)
        out = res.stdout.strip()
        if res.returncode != 0 or not out:
            tail = (res.stderr or "")[-200:].replace("\n", " ")
            print(f"{name}_ERROR,0.0,error={tail}", flush=True)
        else:
            print(out, flush=True)
        print(f"# suite {name} took {obs.now() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
