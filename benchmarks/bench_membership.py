"""Membership serving benchmark: batched assignment vs protocol re-run.

Without the ``MembershipEngine``, a wave of newcomers forces the GPS to
re-run the whole one-shot protocol (O(N^2) pair work + HAC) over
seed+newcomers.  With it, the wave is one batched directory lookup —
O(T * k * d^2) per arrival, independent of the table size N.

Grid: table sizes N in {1024, 4096, 8192} (``--quick``: 256 only), waves
of 64 newcomers from the same task mixture.  At every point:

  * baseline  — ``one_shot_clustering`` over seed+wave (the blockwise
    streaming engine + device NN-chain HAC: the FASTEST full re-run this
    repo has), timed cold (with its shape-change compiles — what a
    growing population pays every wave) AND warm (pure compute — the
    number the speedup uses);
  * assign    — ``MembershipEngine.assign`` on the wave, ALL THREE
    backends timed at every point (the batched wave kernel made the
    pallas path competitive even in interpret mode, so there is no
    longer a "too slow to time" row: ``pallas_timed`` and
    ``assign_pallas_s`` now always appear together in every record);
  * agreement — all three backends must produce IDENTICAL labels
    (margins are asserted well clear of bf16 tie dither);
  * accuracy  — assignment labels must match a full re-cluster of
    seed+wave on >= 95% of arrivals (cluster ids aligned by seed-user
    majority overlap).

At the largest N the quantized-directory sweep serves the same wave
under ``directory_dtype`` in {f32, bf16, int8}: per-dtype verdict
agreement vs f32 (int8 must be >= 99% at N=4096) and the measured
resident directory bytes (int8 is ~4x smaller than f32 including its
per-prototype scales).

Acceptance (ISSUE 5): >= 20x (floor 5x) assignment speedup vs the re-run
baseline per 64-newcomer wave at N=4096 on CPU, recorded in the JSON
written to ``--json``.

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_membership.py --quick``
(CI smoke: N=256, same code paths, agreement + match still asserted).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import oneshot
from repro.core.cluster_engine import ClusterConfig
from repro.core.engine import ProtocolEngine
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.core.similarity import SimilarityConfig
from repro.data import synthetic as syn

WAVE = 64
D = 32
SAMPLES = 16
TASKS = 8
TOP_K = 8
BACKENDS = ("numpy", "jnp", "pallas")


def _match_vs_full(seed_labels, full_labels, assign_labels, n: int
                   ) -> float:
    """Fraction of the wave where assignment agrees with the full
    re-cluster, after aligning the re-cluster's arbitrary cluster ids to
    the seed directory's by majority overlap on the seed users."""
    mapping = np.full(TASKS, -1)
    for t in range(TASKS):
        members = seed_labels[full_labels[:n] == t]
        if len(members):
            mapping[t] = np.bincount(members).argmax()
    return float((mapping[full_labels[n:]] == assign_labels).mean())


def bench_directory_dtypes(res, lam_w, v_w, n: int,
                           assert_agreement: bool) -> tuple[list[str], dict]:
    """Serve the same wave under f32 / bf16 / int8 directories: verdict
    agreement vs f32 plus the resident directory footprint."""
    rows, recs = [], {}
    f32_labels = None
    f32_bytes = 0
    for dt in ("f32", "bf16", "int8"):
        eng = MembershipEngine.from_oneshot(
            res, MembershipConfig(backend="pallas", directory_dtype=dt))
        out = eng.assign(lam_w, v_w)                        # warm / compile
        jax.block_until_ready(out.labels)
        t0 = time.perf_counter()
        for _ in range(5):
            out = eng.assign(lam_w, v_w)
            jax.block_until_ready(out.labels)
        dt_s = (time.perf_counter() - t0) / 5
        labels = np.asarray(out.labels)
        nbytes = eng.state.directory_bytes
        if dt == "f32":
            f32_labels, f32_bytes = labels, nbytes
        agree = float((labels == f32_labels).mean())
        if assert_agreement and dt == "int8":
            assert agree >= 0.99, (
                f"int8 directory verdict agreement {agree:.1%} < 99% "
                f"at N={n}")
        recs[dt] = {
            "assign_s": round(dt_s, 6),
            "directory_bytes": nbytes,
            "bytes_vs_f32": round(f32_bytes / nbytes, 2),
            "label_agreement_vs_f32": agree,
        }
        rows.append(common.row(
            f"membership_dtype_{dt}_N{n}", dt_s * 1e6,
            directory_kb=round(nbytes / 1024, 1),
            bytes_vs_f32=recs[dt]["bytes_vs_f32"],
            agreement_vs_f32=agree))
    return rows, recs


def bench_point(n: int, dtype_sweep: bool,
                assert_agreement: bool) -> tuple[list[str], dict]:
    feats, _ = syn.make_task_feature_mixture(n + WAVE, SAMPLES, D, TASKS,
                                             seed=0)
    block = 256 if n > 512 else 0
    cfg = SimilarityConfig(top_k=TOP_K, block_users=block)
    ccfg = ClusterConfig(backend="jnp")

    res = oneshot.one_shot_clustering(feats[:n], TASKS, cfg=cfg,
                                      cluster_cfg=ccfg)
    seed_labels = np.asarray(jax.block_until_ready(res.labels))

    # Baseline: the newcomers arrive, the GPS re-runs everything.  Timed
    # twice — the first run pays the N+64-shape jit compiles (what a
    # growing population pays EVERY wave), the second is pure compute;
    # the acceptance speedup uses the warm number so it never conflates
    # compile cost with the O(N^2)-vs-O(T k d^2) claim.
    baseline = []
    for _ in range(2):
        t0 = time.perf_counter()
        res_full = oneshot.one_shot_clustering(feats, TASKS, cfg=cfg,
                                               cluster_cfg=ccfg)
        full_labels = np.asarray(jax.block_until_ready(res_full.labels))
        baseline.append(time.perf_counter() - t0)
    baseline_cold_s, baseline_s = baseline

    # The wave's signatures (what each newcomer uploads anyway).
    lam_w, v_w, _ = ProtocolEngine(
        SimilarityConfig(top_k=TOP_K)).signatures(feats[n:])

    labels_by, times = {}, {}
    for backend in BACKENDS:
        eng = MembershipEngine.from_oneshot(
            res, MembershipConfig(backend=backend))
        out = eng.assign(lam_w, v_w)                        # warm / compile
        if backend != "numpy":
            jax.block_until_ready(out.labels)
        n_iter = 10
        t0 = time.perf_counter()
        for _ in range(n_iter):
            out = eng.assign(lam_w, v_w)
            if backend != "numpy":
                jax.block_until_ready(out.labels)
        times[backend] = (time.perf_counter() - t0) / n_iter
        labels_by[backend] = np.asarray(out.labels)

    for backend in BACKENDS[1:]:
        assert (labels_by[backend] == labels_by["numpy"]).all(), (
            f"{backend}/numpy assignment disagree at N={n}")
    match = _match_vs_full(seed_labels, full_labels, labels_by["jnp"], n)
    assert match >= 0.95, (
        f"assignment vs full re-cluster match {match:.1%} < 95% at N={n}")

    assign_s = times["jnp"]
    rec = {
        "N": n, "wave": WAVE, "d": D, "top_k": TOP_K, "tasks": TASKS,
        "baseline_rerun_s": round(baseline_s, 4),
        "baseline_rerun_cold_s": round(baseline_cold_s, 4),
        "assign_numpy_s": round(times["numpy"], 6),
        "assign_jnp_s": round(assign_s, 6),
        "assignments_per_s": round(WAVE / assign_s, 1),
        "speedup_vs_rerun": round(baseline_s / assign_s, 1),
        "match_vs_full_recluster": match,
        "backends_agree": True,
        # Off-accelerator the pallas backend executes in interpret mode —
        # every record states that fact next to its timing, and the two
        # fields below are now unconditional (the batched wave kernel is
        # fast enough to time everywhere).
        "pallas_interpret": jax.default_backend() != "tpu",
        "pallas_timed": True,
        "assign_pallas_s": round(times["pallas"], 6),
    }
    if dtype_sweep:
        dt_rows, dt_recs = bench_directory_dtypes(res, lam_w, v_w, n,
                                                  assert_agreement)
        rec["directory_dtypes"] = dt_recs
    else:
        dt_rows = []
    rows = [common.row(
        f"membership_assign_N{n}", assign_s * 1e6,
        baseline_us=round(baseline_s * 1e6, 1),
        speedup_vs_rerun=rec["speedup_vs_rerun"],
        assignments_per_s=rec["assignments_per_s"],
        pallas_us=round(times["pallas"] * 1e6, 1),
        match=match)] + dt_rows
    return rows, rec


def run(quick: bool = False, json_path: str | None = None) -> list[str]:
    grid = [256] if quick else [1024, 4096, 8192]
    rows, records = [], []
    for n in grid:
        # The dtype sweep rides on the acceptance point (N=4096; the only
        # point in --quick), where the >= 99% int8 agreement is asserted.
        sweep = n == (256 if quick else 4096)
        r, rec = bench_point(n, dtype_sweep=sweep,
                             assert_agreement=sweep and not quick)
        rows.extend(r)
        records.append(rec)
        jax.clear_caches()
    payload = {"quick": quick, "backend": jax.default_backend(),
               "grid": records}
    if json_path:
        common.record_result(json_path, payload)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: N=256 only, same code paths")
    ap.add_argument("--json",
                    default="benchmarks/results/bench_membership.json",
                    help="where to record the speedup grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(r, flush=True)
