"""Membership serving benchmark: batched assignment vs protocol re-run.

Without the ``MembershipEngine``, a wave of newcomers forces the GPS to
re-run the whole one-shot protocol (O(N^2) pair work + HAC) over
seed+newcomers.  With it, the wave is one batched directory lookup —
O(T * k * d^2) per arrival, independent of the table size N.

Grid: table sizes N in {1024, 4096, 8192} (``--quick``: 256 only), waves
of 64 newcomers from the same task mixture.  At every point:

  * baseline  — ``one_shot_clustering`` over seed+wave (the blockwise
    streaming engine + device NN-chain HAC: the FASTEST full re-run this
    repo has), timed cold (with its shape-change compiles — what a
    growing population pays every wave) AND warm (pure compute — the
    number the speedup uses);
  * assign    — ``MembershipEngine.assign`` on the wave, numpy / jnp
    backends timed (pallas timed at the smallest point only — off-TPU it
    executes in interpret mode, which measures the interpreter);
  * agreement — all three backends must produce IDENTICAL labels
    (margins are asserted well clear of bf16 tie dither);
  * accuracy  — assignment labels must match a full re-cluster of
    seed+wave on >= 95% of arrivals (cluster ids aligned by seed-user
    majority overlap).

Acceptance (ISSUE 5): >= 20x (floor 5x) assignment speedup vs the re-run
baseline per 64-newcomer wave at N=4096 on CPU, recorded in the JSON
written to ``--json``.

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_membership.py --quick``
(CI smoke: N=256, same code paths, agreement + match still asserted).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import oneshot
from repro.core.cluster_engine import ClusterConfig
from repro.core.engine import ProtocolEngine
from repro.core.membership_engine import MembershipConfig, MembershipEngine
from repro.core.similarity import SimilarityConfig
from repro.data import synthetic as syn

WAVE = 64
D = 32
SAMPLES = 16
TASKS = 8
TOP_K = 8
BACKENDS = ("numpy", "jnp", "pallas")


def _match_vs_full(seed_labels, full_labels, assign_labels, n: int
                   ) -> float:
    """Fraction of the wave where assignment agrees with the full
    re-cluster, after aligning the re-cluster's arbitrary cluster ids to
    the seed directory's by majority overlap on the seed users."""
    mapping = np.full(TASKS, -1)
    for t in range(TASKS):
        members = seed_labels[full_labels[:n] == t]
        if len(members):
            mapping[t] = np.bincount(members).argmax()
    return float((mapping[full_labels[n:]] == assign_labels).mean())


def bench_point(n: int, run_pallas: bool) -> tuple[list[str], dict]:
    feats, _ = syn.make_task_feature_mixture(n + WAVE, SAMPLES, D, TASKS,
                                             seed=0)
    block = 256 if n > 512 else 0
    cfg = SimilarityConfig(top_k=TOP_K, block_users=block)
    ccfg = ClusterConfig(backend="jnp")

    res = oneshot.one_shot_clustering(feats[:n], TASKS, cfg=cfg,
                                      cluster_cfg=ccfg)
    seed_labels = np.asarray(jax.block_until_ready(res.labels))

    # Baseline: the newcomers arrive, the GPS re-runs everything.  Timed
    # twice — the first run pays the N+64-shape jit compiles (what a
    # growing population pays EVERY wave), the second is pure compute;
    # the acceptance speedup uses the warm number so it never conflates
    # compile cost with the O(N^2)-vs-O(T k d^2) claim.
    baseline = []
    for _ in range(2):
        t0 = time.perf_counter()
        res_full = oneshot.one_shot_clustering(feats, TASKS, cfg=cfg,
                                               cluster_cfg=ccfg)
        full_labels = np.asarray(jax.block_until_ready(res_full.labels))
        baseline.append(time.perf_counter() - t0)
    baseline_cold_s, baseline_s = baseline

    # The wave's signatures (what each newcomer uploads anyway).
    lam_w, v_w, _ = ProtocolEngine(
        SimilarityConfig(top_k=TOP_K)).signatures(feats[n:])

    labels_by, times = {}, {}
    for backend in BACKENDS:
        if backend == "pallas" and not run_pallas:
            eng = MembershipEngine.from_oneshot(
                res, MembershipConfig(backend=backend))
            labels_by[backend] = np.asarray(eng.assign(lam_w, v_w).labels)
            continue
        eng = MembershipEngine.from_oneshot(
            res, MembershipConfig(backend=backend))
        out = eng.assign(lam_w, v_w)                        # warm / compile
        if backend != "numpy":
            jax.block_until_ready(out.labels)
        n_iter = 1 if backend == "pallas" else 10
        t0 = time.perf_counter()
        for _ in range(n_iter):
            out = eng.assign(lam_w, v_w)
            if backend != "numpy":
                jax.block_until_ready(out.labels)
        times[backend] = (time.perf_counter() - t0) / n_iter
        labels_by[backend] = np.asarray(out.labels)

    for backend in BACKENDS[1:]:
        assert (labels_by[backend] == labels_by["numpy"]).all(), (
            f"{backend}/numpy assignment disagree at N={n}")
    match = _match_vs_full(seed_labels, full_labels, labels_by["jnp"], n)
    assert match >= 0.95, (
        f"assignment vs full re-cluster match {match:.1%} < 95% at N={n}")

    assign_s = times["jnp"]
    rec = {
        "N": n, "wave": WAVE, "d": D, "top_k": TOP_K, "tasks": TASKS,
        "baseline_rerun_s": round(baseline_s, 4),
        "baseline_rerun_cold_s": round(baseline_cold_s, 4),
        "assign_numpy_s": round(times["numpy"], 6),
        "assign_jnp_s": round(assign_s, 6),
        "assignments_per_s": round(WAVE / assign_s, 1),
        "speedup_vs_rerun": round(baseline_s / assign_s, 1),
        "match_vs_full_recluster": match,
        "backends_agree": True,
        # The pallas backend runs on EVERY row (the agreement assert),
        # timed or not — so every record states the interpret-mode fact.
        "pallas_interpret": jax.default_backend() != "tpu",
        "pallas_timed": run_pallas,
    }
    if run_pallas:
        rec["assign_pallas_s"] = round(times["pallas"], 6)
    rows = [common.row(
        f"membership_assign_N{n}", assign_s * 1e6,
        baseline_us=round(baseline_s * 1e6, 1),
        speedup_vs_rerun=rec["speedup_vs_rerun"],
        assignments_per_s=rec["assignments_per_s"],
        match=match)]
    return rows, rec


def run(quick: bool = False, json_path: str | None = None) -> list[str]:
    grid = [256] if quick else [1024, 4096, 8192]
    on_tpu = jax.default_backend() == "tpu"
    rows, records = [], []
    for n in grid:
        r, rec = bench_point(n, run_pallas=(n == grid[0] or on_tpu))
        rows.extend(r)
        records.append(rec)
        jax.clear_caches()
    payload = {"quick": quick, "backend": jax.default_backend(),
               "grid": records}
    if json_path:
        common.record_result(json_path, payload)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: N=256 only, same code paths")
    ap.add_argument("--json",
                    default="benchmarks/results/bench_membership.json",
                    help="where to record the speedup grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(r, flush=True)
