"""Roofline table (deliverable g): reads the dry-run artifacts written by
``repro.launch.dryrun`` and emits one row per (arch x shape x mesh) with
the three roofline terms, the dominant bottleneck, and the useful-FLOPs
ratio.  Rows are omitted (with a notice) if the sweep has not produced the
artifact yet."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks import common

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run() -> list[str]:
    rows = []
    files = sorted(DRYRUN_DIR.glob("*.json")) if DRYRUN_DIR.exists() else []
    if not files:
        return [common.row("roofline_no_artifacts", 0.0,
                           note="run repro.launch.dryrun first")]
    n_ok = n_fail = 0
    for f in files:
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            n_fail += 1
            rows.append(common.row(f"roofline_{f.stem}", 0.0, status="FAIL",
                                   error=r.get("error", "?")[:80]))
            continue
        n_ok += 1
        roof = r["roofline"]
        rows.append(common.row(
            f"roofline_{f.stem}", 0.0,
            compute_s=round(roof["compute_term_s"], 5),
            memory_s=round(roof["memory_term_s"], 5),
            collective_s=round(roof["collective_term_s"], 5),
            bottleneck=roof["bottleneck"],
            useful_flops_ratio=round(roof["useful_flops_ratio"], 3),
            hbm_gb=round(r["memory"].get("total_hbm_bytes", 0) / 2 ** 30, 2),
            compile_s=r.get("compile_s")))
    rows.append(common.row("roofline_summary", 0.0, ok=n_ok, fail=n_fail))
    return rows
