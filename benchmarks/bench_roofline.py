"""Roofline bench: per-kernel achieved-vs-roof measurements plus the
dry-run artifact table.

Part 1 (new, the tile-plan justification loop): for every Pallas kernel
family, resolve the tuned tile plan through ``kernels.tuning``, compute
the analytic roofline floor for that plan on the detected hardware
(``launch.roofline.kernel_roofline`` — bytes depend on how the plan
re-streams operands, so a bad plan shows up as a higher roof BEFORE any
timing), then time the kernel and record achieved vs roof.  On an
accelerator ``roof_frac`` is a utilization number; in interpret mode the
achieved time is dominated by the interpreter so the roof is reported as
the floor the same plan would hit lowered — the ``assign`` family also
gets an int8-directory row (itemsize 1) showing the memory-term drop the
quantized directory buys.

Part 2 (deliverable g, unchanged): reads the dry-run artifacts written by
``repro.launch.dryrun`` and emits one row per (arch x shape x mesh) with
the three roofline terms, the dominant bottleneck, and the useful-FLOPs
ratio.  Rows are omitted (with a notice) if the sweep has not produced
the artifact yet.

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_roofline.py --quick``
(``--peak-flops`` overrides the detected compute roof, e.g. to model a
target part from a host).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import dispatch, quant, tuning
from repro.kernels.assign import ops as assign_ops
from repro.kernels.eigproject import ops as proj_ops
from repro.kernels.featurize_gram import ops as fg_ops
from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram_project import ops as gp_ops
from repro.kernels.linkage import ops as link_ops
from repro.launch import roofline as RL

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _kernel_cases(rng, quick: bool) -> list[dict]:
    """One case per kernel family: inputs, cost dims, and a runner that
    takes the resolved tile plan."""
    n, d, k = (512, 128, 64) if quick else (2048, 256, 128)
    m = 256 if quick else 512
    nl = 1024 if quick else 8192
    b, dd, t = (64, 32, 8) if quick else (256, 32, 16)

    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((d, k)), jnp.float32)
    xm = jnp.asarray(rng.standard_normal((n, m)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((m, d)) / np.sqrt(m), jnp.float32)
    g = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
    ra = jnp.asarray(rng.standard_normal(nl), jnp.float32)
    rb = jnp.asarray(rng.standard_normal(nl), jnp.float32)
    mask = jnp.asarray((rng.random(nl) > 0.2).astype(np.float32))
    vw = jnp.asarray(rng.standard_normal((b, dd, 8)), jnp.float32)
    protos = jnp.asarray(rng.standard_normal((t, dd, dd)), jnp.float32)
    q8, sc8 = quant.quantize_directory(protos, "int8")

    cases = [
        dict(kernel="gram", tune_dims=dict(n=n, d=d),
             cost_dims=dict(n=n, d=d), itemsize=4,
             run=lambda blk: gram_ops.gram_matrix(
                 x, block_n=blk["block_n"], block_d=blk["block_d"])),
        dict(kernel="gram_project", tune_dims=dict(n=n, k=k),
             cost_dims=dict(n=n, d=d, k=k), itemsize=4,
             run=lambda blk: gp_ops.gram_project(
                 x, v, block_n=blk["block_n"], block_k=blk["block_k"],
                 double_buffer=blk.get("double_buffer", False))),
        dict(kernel="featurize_gram", tune_dims=dict(n=n),
             cost_dims=dict(n=n, m=m, d=d), itemsize=4,
             run=lambda blk: fg_ops.featurize_gram(
                 xm, w, block_n=blk["block_n"],
                 double_buffer=blk.get("double_buffer", False))),
        dict(kernel="eigproject", tune_dims=dict(d=d, k=k),
             cost_dims=dict(d=d, k=k), itemsize=4,
             run=lambda blk: proj_ops.project_norms(
                 g, v, block_d=blk["block_d"], block_k=blk["block_k"])),
        dict(kernel="linkage", tune_dims=dict(n=nl),
             cost_dims=dict(n=nl), itemsize=4,
             run=lambda blk: link_ops.linkage_step(
                 ra, rb, 2.0, 3.0, mask, block=blk["block"])[0]),
        dict(kernel="assign", tune_dims=dict(b=b, d2=dd * dd),
             cost_dims=dict(b=b, d2=dd * dd, t=t), itemsize=4,
             run=lambda blk: assign_ops.assign(
                 vw, protos, block_b=blk["block_b"],
                 block_d2=blk["block_d2"])[0]),
        dict(kernel="assign", variant="int8",
             tune_dims=dict(b=b, d2=dd * dd),
             cost_dims=dict(b=b, d2=dd * dd, t=t), itemsize=1,
             run=lambda blk: assign_ops.assign(
                 vw, q8, scales=sc8, block_b=blk["block_b"],
                 block_d2=blk["block_d2"])[0]),
    ]
    return cases


def run_kernels(quick: bool, hw: RL.HardwareSpec,
                records: list[dict]) -> list[str]:
    rng = np.random.default_rng(1)
    rows = []
    interp = not dispatch.supports_lowering()
    for case in _kernel_cases(rng, quick):
        name = case["kernel"]
        tag = name + (f"_{case['variant']}" if "variant" in case else "")
        blocks = tuning.get_blocks(name, **case["tune_dims"])
        roof = RL.kernel_roofline(name, blocks, hw=hw,
                                  itemsize=case["itemsize"],
                                  **case["cost_dims"])
        us = common.time_us(
            lambda: jax.block_until_ready(case["run"](blocks)), n_iter=3)
        achieved_s = us * 1e-6
        records.append({
            "kernel": tag, "dims": case["cost_dims"],
            "blocks": dict(blocks), "hw": hw.name,
            "interpret": interp,
            "flops": roof["flops"], "bytes": roof["bytes"],
            "roof_s": roof["roof_s"], "bound": roof["bound"],
            "arithmetic_intensity": round(
                roof["arithmetic_intensity"], 3),
            "achieved_s": achieved_s,
            "roof_frac": (roof["roof_s"] / achieved_s
                          if achieved_s else 0.0),
        })
        rows.append(common.row(
            f"kernel_roof_{tag}", us,
            roof_us=round(roof["roof_s"] * 1e6, 2),
            bound=roof["bound"],
            intensity=round(roof["arithmetic_intensity"], 1),
            roof_frac=round(roof["roof_s"] / achieved_s, 4),
            interpret=interp))
    return rows


def run_dryrun_table(records: list[dict] | None = None) -> list[str]:
    rows = []
    files = sorted(DRYRUN_DIR.glob("*.json")) if DRYRUN_DIR.exists() else []
    if not files:
        return [common.row("roofline_no_artifacts", 0.0,
                           note="run repro.launch.dryrun first")]
    n_ok = n_fail = 0
    for f in files:
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            n_fail += 1
            rows.append(common.row(f"roofline_{f.stem}", 0.0, status="FAIL",
                                   error=r.get("error", "?")[:80]))
            continue
        n_ok += 1
        roof = r["roofline"]
        if records is not None:
            records.append({"artifact": f.stem, **roof})
        rows.append(common.row(
            f"roofline_{f.stem}", 0.0,
            compute_s=round(roof["compute_term_s"], 5),
            memory_s=round(roof["memory_term_s"], 5),
            collective_s=round(roof["collective_term_s"], 5),
            bottleneck=roof["bottleneck"],
            useful_flops_ratio=round(roof["useful_flops_ratio"], 3),
            hbm_gb=round(r["memory"].get("total_hbm_bytes", 0) / 2 ** 30, 2),
            compile_s=r.get("compile_s")))
    rows.append(common.row("roofline_summary", 0.0, ok=n_ok, fail=n_fail))
    return rows


def run(quick: bool = False, peak_flops: float | None = None,
        json_path: str | None = None) -> list[str]:
    hw = RL.detect_hardware(peak_flops=peak_flops)
    kernel_records: list[dict] = []
    dryrun_records: list[dict] = []
    rows = run_kernels(quick, hw, kernel_records)
    rows += run_dryrun_table(dryrun_records)
    if json_path:
        common.record_result(json_path, {
            "quick": quick, "hw": hw.name,
            "peak_flops": hw.peak_flops, "hbm_bw": hw.hbm_bw,
            "kernels": kernel_records,
            "dryrun_artifacts": dryrun_records,
        })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shrunken shapes, same code paths")
    ap.add_argument("--peak-flops", type=float, default=None,
                    help="override the detected peak FLOP/s (model a "
                         "target part from a host)")
    ap.add_argument("--json", default="benchmarks/results/bench_roofline.json",
                    help="where to record the achieved-vs-roof grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, peak_flops=args.peak_flops,
                 json_path=args.json):
        print(r, flush=True)
