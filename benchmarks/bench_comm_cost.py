"""Paper §III "Communication Improvement": one-shot clustering bytes vs a
weight-exchange iterative clustering round, for both paper models and a
transformer arch — the clustering cost is model-size independent, the
iterative baseline is not."""
from __future__ import annotations

from benchmarks import common
from repro.configs.base import get_arch
from repro.core.oneshot import CommLedger


def run() -> list[str]:
    rows = []
    scenarios = [
        ("paper_mlp_10users", 10, 784, 5, 784 * 32 + 32 + 32 * 10 + 10),
        ("paper_cnn_10users", 10, 256, 8,
         5 * 5 * 3 * 6 + 6 + 5 * 5 * 6 * 16 + 16 + 400 * 120 + 120
         + 120 * 84 + 84 + 84 * 10 + 10),
        ("qwen3_1p7b_64users", 64, 128, 8,
         get_arch("qwen3_1_7b").n_params()),
    ]
    for name, n, d, k, params in scenarios:
        led = CommLedger(n_users=n, d=d, top_k=k, model_params=params)
        s = led.summary()
        rows.append(common.row(
            f"comm_{name}", 0.0,
            oneshot_upload_bytes=s["per_user_upload_bytes"],
            iterative_round_bytes=s["iterative_per_round_upload_bytes"],
            ratio=round(s["oneshot_vs_iterative_ratio"], 6)))
    return rows
