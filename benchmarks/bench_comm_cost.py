"""Paper §III "Communication Improvement": one-shot clustering bytes vs a
weight-exchange iterative clustering round, for both paper models and a
transformer arch — the clustering cost is model-size independent, the
iterative baseline is not.

The ledger is parameterized over wire precision (``dtype_bytes``) and
exchange pattern: ``broadcast`` is the paper's star topology (each user
receives N-1 per-peer V_j transfers), ``streaming`` is the blockwise
engine mode (one O(N*d*k) signature-table fetch from the GPS per user,
no per-peer duplicates) — the mode ``one_shot_clustering`` reports when
``block_users > 0``."""
from __future__ import annotations

from benchmarks import common
from repro.configs.base import get_arch
from repro.core.oneshot import CommLedger


def run() -> list[str]:
    rows = []
    scenarios = [
        ("paper_mlp_10users", 10, 784, 5, 784 * 32 + 32 + 32 * 10 + 10),
        ("paper_cnn_10users", 10, 256, 8,
         5 * 5 * 3 * 6 + 6 + 5 * 5 * 6 * 16 + 16 + 400 * 120 + 120
         + 120 * 84 + 84 + 84 * 10 + 10),
        ("qwen3_1p7b_64users", 64, 128, 8,
         get_arch("qwen3_1_7b").n_params()),
    ]
    for name, n, d, k, params in scenarios:
        led = CommLedger(n_users=n, d=d, top_k=k, model_params=params)
        s = led.summary()
        rows.append(common.row(
            f"comm_{name}", 0.0,
            oneshot_upload_bytes=s["per_user_upload_bytes"],
            iterative_round_bytes=s["iterative_per_round_upload_bytes"],
            ratio=round(s["oneshot_vs_iterative_ratio"], 6)))
    # Streaming (blockwise) accounting at protocol scale, fp32 and bf16
    # wire precision: the per-user download is the one-shot table fetch.
    for dtype_bytes, tag in ((4, "fp32"), (2, "bf16")):
        led = CommLedger(n_users=4096, d=64, top_k=8,
                         dtype_bytes=dtype_bytes, mode="streaming")
        s = led.summary()
        rows.append(common.row(
            f"comm_streaming_4096users_{tag}", 0.0,
            per_user_download_bytes=s["per_user_download_bytes"],
            signature_table_bytes=s["signature_table_bytes"],
            gps_total_bytes=s["gps_total_bytes"]))
    return rows
