"""Scale benchmark: the N=10^3 -> 10^5 one-shot clustering trajectory.

Three routes to labels from the same synthetic multi-task mixture:

  exact        flat ``ProtocolEngine`` (dense or blockwise) + device HAC
               — O(N^2 d k^2) relevance entries, the O(N^2) wall
  hierarchical ``core.hierarchy``: G edge groups, vmapped group protocol
               + HAC, directory compression, signature-only global stage
               — O(G * (N/G)^2 + (G * T_g)^2)
  sketched     ``SimilarityConfig.landmarks``: score m landmarks,
               Nystrom-complete — O(N * m)

Acceptance (ISSUE 6), asserted inline and recorded to ``--json``:
  * hierarchical completes end-to-end at N=10^5 on a single CPU host;
    the exact path is not attempted there (the N x N matrix alone is
    ~40 GB) and is recorded as infeasible with the byte arithmetic.
  * at N=8192 hierarchical is >= 10x faster than the best exact path
    (warm wall-clock, best of dense/blockwise), with >= 0.95 label
    agreement (max of ARI and exact-match after ``greedy_match_labels``
    id alignment) at EVERY grid point where both routes run.
  * the sketched path's completion error vs the exact projector-affinity
    kernel decays monotonically with the landmark count.

Geometry is sized for the trajectory (d=16, k=8, 8 samples/user): small
enough that 10^5 users fit one host, structured enough that every route
recovers the task partition.  Pallas is not timed here — this benchmark
measures protocol SCALING on the jnp route; kernel-level pallas numbers
live in bench_kernels/bench_clustering (interpret-mode caveats and all).

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_scale.py --quick``
(CI smoke: N=512, same code paths, agreement + decay still asserted).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.cluster_engine import ClusterConfig, ClusterEngine
from repro.core.engine import ProtocolEngine
from repro.core.hierarchy import (HierarchyConfig, greedy_match_labels,
                                  hierarchical_one_shot)
from repro.core.similarity import SimilarityConfig

D = 16
TOP_K = 8
SAMPLES = 8
TASKS = 4

# N -> (n_groups, group_batch); N_g stays <= 200 so the vmapped group
# stage never holds more than group_batch * N_g^2 relevance entries.
HIER_PLAN = {512: (16, 0), 1024: (16, 0), 4096: (64, 0), 8192: (128, 0),
             100_000: (500, 100)}
EXACT_MAX_N = 8192            # beyond this the N x N route is not attempted
SPEEDUP_AT = 8192             # the 10x acceptance point
AGREEMENT_FLOOR = 0.95
SPEEDUP_FLOOR = 10.0


def _mixture(n: int, seed: int = 0):
    from repro.data import synthetic as syn

    return syn.make_task_feature_mixture(n, SAMPLES, D, TASKS, seed=seed)


def _agreement(labels_a, labels_b) -> dict:
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    matched = greedy_match_labels(a, b, TASKS)
    return {"ari": round(float(clu.adjusted_rand_index(a, b)), 4),
            "exact_match": round(float((matched == b).mean()), 4)}


def _time_exact(feats, mode: str) -> tuple[float, np.ndarray]:
    """Warm wall-clock of one flat protocol + device-HAC run."""
    cfg = SimilarityConfig(
        top_k=TOP_K,
        block_users=(1024 if mode == "blockwise" else 0))

    def once():
        res = oneshot.one_shot_clustering(
            feats, TASKS, cfg=cfg, cluster_cfg=ClusterConfig(backend="jnp"))
        return jax.block_until_ready(res.labels)

    labels = once()                                          # compile
    t0 = time.perf_counter()
    labels = once()
    return time.perf_counter() - t0, np.asarray(labels)


def _time_hier(feats, n: int, warm: bool) -> tuple[float, float, np.ndarray]:
    """(cold_s, warm_s, labels); cold includes compilation — the honest
    number for the one-off 10^5 run, where nothing is ever warm."""
    groups, batch = HIER_PLAN[n]
    hcfg = HierarchyConfig(n_groups=groups, group_batch=batch)

    def once():
        res = hierarchical_one_shot(
            feats, TASKS, cfg=SimilarityConfig(top_k=TOP_K),
            hierarchy_cfg=hcfg, cluster_cfg=ClusterConfig(backend="jnp"))
        return jax.block_until_ready(res.labels)

    t0 = time.perf_counter()
    labels = once()
    cold = time.perf_counter() - t0
    warm_s = cold
    if warm:
        t0 = time.perf_counter()
        labels = once()
        warm_s = time.perf_counter() - t0
    return cold, warm_s, np.asarray(labels)


def _sketch_sweep(n: int, landmark_grid: tuple[int, ...]) -> list[dict]:
    """Nystrom error vs the exact projector-affinity kernel, per m."""
    feats, tids = _mixture(n)
    feats = jnp.asarray(feats, jnp.float32)
    exact = ProtocolEngine(SimilarityConfig(top_k=TOP_K)).run(feats)
    v = np.asarray(exact.v)
    affinity = np.einsum("idk,jdl->ijkl", v, v)
    affinity = (affinity ** 2).sum((2, 3)) / TOP_K           # (N, N) exact
    out = []
    for m in landmark_grid:
        cfg = SimilarityConfig(top_k=TOP_K, landmarks=m)
        eng = ProtocolEngine(cfg)
        jax.block_until_ready(eng.run(feats).similarity)     # compile
        t0 = time.perf_counter()
        res = eng.run(feats)
        jax.block_until_ready(res.similarity)
        dt = time.perf_counter() - t0
        err = float(np.abs(np.asarray(res.similarity) - affinity).mean())
        labels = ClusterEngine(ClusterConfig(backend="jnp")).labels(
            res.similarity, TASKS)
        out.append({
            "N": n, "landmarks": m, "s": round(dt, 4),
            "mean_abs_err": round(err, 6),
            "ari_vs_tasks": round(
                float(clu.adjusted_rand_index(np.asarray(labels), tids)),
                4)})
    errs = [r["mean_abs_err"] for r in out]
    assert all(b <= a + 1e-6 for a, b in zip(errs, errs[1:])), (
        f"sketched error not monotone in landmarks at N={n}: {errs}")
    return out


def bench_point(n: int, run_exact: bool) -> tuple[list[str], dict]:
    feats_np, tids = _mixture(n)
    feats = jnp.asarray(feats_np, jnp.float32)
    groups, batch = HIER_PLAN[n]
    cold, warm, hier_labels = _time_hier(feats, n, warm=run_exact)
    rec = {
        "N": n, "n_groups": groups, "group_batch": batch,
        "hier_cold_s": round(cold, 3), "hier_warm_s": round(warm, 3),
        "hier_ari_vs_tasks": round(
            float(clu.adjusted_rand_index(hier_labels, tids)), 4),
    }
    if run_exact:
        by_mode = {}
        for mode in ("dense", "blockwise"):
            s, exact_labels = _time_exact(feats, mode)
            by_mode[mode] = (s, exact_labels)
            rec[f"exact_{mode}_s"] = round(s, 3)
        best_mode = min(by_mode, key=lambda m: by_mode[m][0])
        best_s, exact_labels = by_mode[best_mode]
        agree = _agreement(hier_labels, exact_labels)
        speedup = best_s / warm
        rec.update(exact_best=best_mode,
                   speedup_vs_best_exact=round(speedup, 2),
                   agreement=agree)
        best_agree = max(agree["ari"], agree["exact_match"])
        assert best_agree >= AGREEMENT_FLOOR, (
            f"hierarchical/exact agreement {agree} below "
            f"{AGREEMENT_FLOOR} at N={n}")
        if n >= SPEEDUP_AT:
            assert speedup >= SPEEDUP_FLOOR, (
                f"hierarchical only {speedup:.1f}x vs best exact "
                f"({best_mode}) at N={n}; acceptance needs "
                f">= {SPEEDUP_FLOOR}x")
    else:
        nn_bytes = 4 * n * n
        rec.update(exact_attempted=False,
                   exact_nn_matrix_gib=round(nn_bytes / 2**30, 1),
                   reason=(f"N x N similarity alone is "
                           f"{nn_bytes / 2**30:.0f} GiB fp32; the flat "
                           "path is infeasible on one host"))
    rows = [common.row(
        f"scale_N{n}", warm * 1e6,
        groups=groups,
        speedup_vs_exact=rec.get("speedup_vs_best_exact", "n/a"),
        ari_vs_tasks=rec["hier_ari_vs_tasks"])]
    return rows, rec


def run(quick: bool = False, json_path: str | None = None) -> list[str]:
    grid = [512] if quick else [1024, 4096, 8192, 100_000]
    landmark_grid = (16, 64) if quick else (16, 32, 64, 128, 256)
    sketch_n = 512 if quick else 2048
    rows, records = [], []
    for n in grid:
        r, rec = bench_point(n, run_exact=n <= EXACT_MAX_N)
        rows.extend(r)
        records.append(rec)
        jax.clear_caches()
    sketch = _sketch_sweep(sketch_n, landmark_grid)
    rows.extend(common.row(
        f"sketch_N{sketch_n}_m{r['landmarks']}", r["s"] * 1e6,
        mean_abs_err=r["mean_abs_err"], ari=r["ari_vs_tasks"])
        for r in sketch)
    payload = {
        "quick": quick, "backend": jax.default_backend(),
        "d": D, "top_k": TOP_K, "samples": SAMPLES, "tasks": TASKS,
        "timing": ("hier_warm_s vs warm best exact at co-run points; "
                   "hier_cold_s includes compilation and is the honest "
                   "one-off number at N=10^5"),
        "agreement_floor": AGREEMENT_FLOOR,
        "speedup_floor_at_n": {str(SPEEDUP_AT): SPEEDUP_FLOOR},
        "grid": records, "sketch": sketch,
    }
    if json_path:
        common.record_result(json_path, payload)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: N=512 only, same code paths + asserts")
    ap.add_argument("--json", default="benchmarks/results/bench_scale.json",
                    help="where to record the scaling grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, json_path=args.json):
        print(r, flush=True)
