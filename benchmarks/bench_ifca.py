"""One-shot vs IFCA-style iterative clustering (literature baseline [5]):
clustering accuracy per round and communication accounting.

The paper's argument: iterative weight-based clustering needs several
rounds (early weights are uninformative) and each round moves full model
parameters per user; the one-shot protocol decides BEFORE training for a
few kB.  This bench quantifies both on the FMNIST three-task layout.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import partition as dpart
from repro.fed import client as fclient
from repro.fed.ifca import IFCAConfig, run_ifca
from repro.models import mlp


def run(rounds=4) -> list[str]:
    users = dpart.paper_fmnist_three_task(seed=0, scale=0.15)
    true = [u.task_id for u in users]

    res_os = oneshot.one_shot_clustering([u.x for u in users], 3,
                                         cfg=SimilarityConfig(top_k=8))
    acc_os = clu.clustering_accuracy(res_os.labels, true)
    led = res_os.ledger
    oneshot_bytes = led.per_user_upload + led.per_user_download

    mcfg = mlp.PaperMLPConfig(m=784, n_classes=10)
    cfg = IFCAConfig(n_clusters=3, rounds=rounds, local_steps=10,
                     client=fclient.ClientConfig(lr=0.05,
                                                 optimizer="momentum"))
    res_it = run_ifca(users, lambda k: mlp.init(mcfg, k),
                      mlp.loss_fn(mcfg), lambda u: u.y.astype(np.int32),
                      cfg)
    rows = [common.row(
        "ifca_vs_oneshot", 0.0,
        oneshot_accuracy=acc_os,
        oneshot_total_bytes=oneshot_bytes,
        ifca_bytes_per_round=res_it.per_user_bytes_per_round,
        comm_ratio_one_round=round(
            res_it.per_user_bytes_per_round / oneshot_bytes, 1))]
    for r in range(rounds):
        rows.append(common.row(
            f"ifca_round{r}", 0.0,
            clustering_accuracy=clu.clustering_accuracy(
                res_it.assignments[r], true)))
    return rows
