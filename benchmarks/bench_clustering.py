"""GPS decision-layer benchmark: host-numpy HAC vs the device NN-chain.

The reference ``core/clustering.py::hac`` pays a full-matrix argmax per
merge (O(N^3) total) on the host; the ``ClusterEngine`` jnp backend runs
nearest-neighbor-chain HAC as one jitted ``lax.while_loop`` (O(N^2)), and
the pallas backend swaps the inner step for the fused ``kernels/linkage``
row-update + argmax kernel.

Grid: N in {256, 1024, 4096} users (``--quick``: 256 only), 8-block
similarity matrices.  Every timed point asserts LABEL PARITY against the
numpy reference (ARI == 1 up to cluster relabelling).  The pallas point
runs at N=256 only by default — off-TPU it executes in interpret mode,
which measures the interpreter, not the kernel (``--pallas-all`` lifts
the cap on real hardware).

Acceptance (ISSUE 3): jnp >= 5x numpy wall-clock at N=4096 on CPU,
recorded in the JSON written to ``--json``.

Standalone: ``PYTHONPATH=src:. python benchmarks/bench_clustering.py --quick``
(CI smoke: N=256, same code paths, parity still asserted).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core.cluster_engine import ClusterConfig, ClusterEngine

N_BLOCKS = 8
LINKAGES = ("average", "single", "complete")


def block_similarity(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """8-block task structure + noise: the protocol-output regime, and a
    shape whose cut labels are robust to f32-vs-f64 tie dithering."""
    rng = np.random.default_rng(seed)
    sizes = [n // N_BLOCKS] * N_BLOCKS
    sizes[-1] += n - sum(sizes)
    labels = np.repeat(np.arange(N_BLOCKS), sizes)
    r = np.where(labels[:, None] == labels[None, :], 0.9, 0.2)
    r = r + rng.uniform(-0.02, 0.02, size=(n, n))
    r = (r + r.T) / 2
    np.fill_diagonal(r, 1.0)
    return r, labels


def _time_numpy(r: np.ndarray, linkage: str) -> tuple[float, np.ndarray]:
    t0 = time.perf_counter()
    labels = clu.hac_clusters(r, N_BLOCKS, linkage)
    return time.perf_counter() - t0, labels


def _time_engine(r: np.ndarray, backend: str, linkage: str,
                 n_iter: int = 3) -> tuple[float, np.ndarray]:
    eng = ClusterEngine(ClusterConfig(backend=backend, linkage=linkage))
    labels = jax.block_until_ready(eng.labels(r, N_BLOCKS))   # compile
    t0 = time.perf_counter()
    for _ in range(n_iter):
        labels = jax.block_until_ready(eng.labels(r, N_BLOCKS))
    return (time.perf_counter() - t0) / n_iter, np.asarray(labels)


def bench_point(n: int, linkage: str, run_pallas: bool
                ) -> tuple[list[str], dict]:
    r, _ = block_similarity(n)
    s_np, lab_np = _time_numpy(r, linkage)
    s_jnp, lab_jnp = _time_engine(r, "jnp", linkage)
    parity_jnp = float(clu.adjusted_rand_index(lab_jnp, lab_np))
    assert parity_jnp == 1.0, (
        f"jnp/numpy HAC label parity broken at N={n} ({linkage}): "
        f"ARI={parity_jnp}")
    rec = {
        "N": n, "linkage": linkage,
        "numpy_s": round(s_np, 4),
        "jnp_s": round(s_jnp, 4),
        "speedup_jnp_vs_numpy": round(s_np / s_jnp, 2),
        "parity_jnp": True,
    }
    if run_pallas:
        s_pl, lab_pl = _time_engine(r, "pallas", linkage, n_iter=1)
        parity_pl = float(clu.adjusted_rand_index(lab_pl, lab_np))
        assert parity_pl == 1.0, (
            f"pallas/numpy HAC label parity broken at N={n} ({linkage})")
        rec["pallas_s"] = round(s_pl, 4)
        rec["parity_pallas"] = True
        rec["pallas_interpret"] = jax.default_backend() != "tpu"
    rows = [common.row(
        f"hac_N{n}_{linkage}", s_jnp * 1e6,
        numpy_us=round(s_np * 1e6, 1),
        speedup_vs_numpy=rec["speedup_jnp_vs_numpy"],
        parity=True)]
    return rows, rec


def run(quick: bool = False, pallas_all: bool = False,
        json_path: str | None = None) -> list[str]:
    grid = [256] if quick else [256, 1024, 4096]
    on_tpu = jax.default_backend() == "tpu"
    rows, records = [], []
    for n in grid:
        # All three linkages at the smallest point (parity coverage); the
        # scaling points time the paper's default average linkage.
        linkages = LINKAGES if n == grid[0] else ("average",)
        for lk in linkages:
            run_pallas = (lk == "average") and (n == 256 or pallas_all
                                                or on_tpu)
            r, rec = bench_point(n, lk, run_pallas)
            rows.extend(r)
            records.append(rec)
        jax.clear_caches()
    payload = {"quick": quick, "n_blocks": N_BLOCKS,
               "backend": jax.default_backend(), "grid": records}
    if json_path:
        common.record_result(json_path, payload)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: N=256 only, same code paths")
    ap.add_argument("--pallas-all", action="store_true",
                    help="run the pallas backend at every N (slow off-TPU: "
                         "interpret mode)")
    ap.add_argument("--json",
                    default="benchmarks/results/bench_clustering.json",
                    help="where to record the speedup grid")
    args = ap.parse_args()
    for r in run(quick=args.quick, pallas_all=args.pallas_all,
                 json_path=args.json):
        print(r, flush=True)
