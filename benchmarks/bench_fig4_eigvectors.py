"""Paper Fig. 4: how many shared eigenvectors are needed?

Sweeps top_k and reports (a) the relevance gap between same-task and
different-task user pairs and (b) clustering accuracy, on the FMNIST
three-task layout.  Paper: 5 eigenvectors suffice (vs exchanging the full
784x784 matrix)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import clustering as clu
from repro.core import oneshot
from repro.core.similarity import SimilarityConfig
from repro.data import partition as dpart


def run(ks=(1, 2, 5, 10, 20, 50)) -> list[str]:
    users = dpart.paper_fmnist_three_task(seed=0, scale=0.25)
    feats = [u.x for u in users]
    true = [u.task_id for u in users]
    tid = np.asarray(true)
    rows = []
    for k in ks:
        res = oneshot.one_shot_clustering(feats, n_clusters=3,
                                          cfg=SimilarityConfig(top_k=k))
        r = res.similarity
        same = (tid[:, None] == tid[None, :]) & ~np.eye(len(tid), dtype=bool)
        gap = float(r[same].mean() - r[~(tid[:, None] == tid[None, :])].mean())
        acc = clu.clustering_accuracy(res.labels, true)
        d = feats[0].shape[1]
        rows.append(common.row(
            f"fig4_top{k}_eigvectors", 0.0,
            relevance_gap=round(gap, 4), clustering_accuracy=acc,
            bytes_shared_per_user=4 * k * d,
            bytes_full_matrix=4 * d * d))
    return rows
